import os
import sys

# Tests are run from the ``python/`` directory (see Makefile), but make the
# package importable regardless of the invocation cwd.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
