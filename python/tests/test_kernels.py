"""Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, N:M patterns and value distributions; every
property here is an invariant the Rust packing/runtime layers rely on.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    masked_matmul,
    nm_mask,
    outlier_mask,
    pack_outliers,
    ria_score,
    split_salient,
    unpack_outliers,
    variance_correct,
)
from compile.kernels import ref

PATTERNS = [(2, 4), (4, 8), (8, 16), (16, 32)]
OUTLIER_PATTERNS = [(4, 256), (8, 256), (16, 256)]


def _rand(rng, *shape):
    return jnp.array(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# nm_mask
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", PATTERNS + OUTLIER_PATTERNS)
def test_nm_mask_matches_ref(n, m):
    rng = np.random.default_rng(n * 1000 + m)
    s = _rand(rng, 32, 512)
    got = np.asarray(nm_mask(s, n, m))
    want = np.asarray(ref.nm_mask_ref(s, n, m))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,m", PATTERNS)
def test_nm_mask_exact_n_per_block(n, m):
    rng = np.random.default_rng(7)
    s = _rand(rng, 16, 256)
    mask = np.asarray(nm_mask(s, n, m)).reshape(16, -1, m)
    assert (mask.sum(-1) == n).all()


def test_nm_mask_ties_stable():
    # All-equal scores: the first N positions of each block must win.
    s = jnp.ones((4, 64), jnp.float32)
    mask = np.asarray(nm_mask(s, 8, 16)).reshape(4, 4, 16)
    want = np.zeros((4, 4, 16), np.float32)
    want[..., :8] = 1.0
    assert np.array_equal(mask, want)


def test_nm_mask_keeps_largest():
    rng = np.random.default_rng(3)
    s = np.abs(rng.standard_normal((8, 128))).astype(np.float32)
    mask = np.asarray(nm_mask(jnp.array(s), 2, 4)).reshape(8, 32, 4)
    sb = s.reshape(8, 32, 4)
    kept_min = np.where(mask > 0, sb, np.inf).min(-1)
    dropped_max = np.where(mask == 0, sb, -np.inf).max(-1)
    assert (kept_min >= dropped_max).all()


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 8, 32, 96]),
    blocks=st.integers(1, 8),
    pattern=st.sampled_from(PATTERNS),
    seed=st.integers(0, 2**31 - 1),
)
def test_nm_mask_property(rows, blocks, pattern, seed):
    n, m = pattern
    rng = np.random.default_rng(seed)
    s = _rand(rng, rows, blocks * m)
    got = np.asarray(nm_mask(s, n, m))
    want = np.asarray(ref.nm_mask_ref(s, n, m))
    assert np.array_equal(got, want)
    assert (got.reshape(rows, blocks, m).sum(-1) == n).all()


# ---------------------------------------------------------------------------
# ria_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq", [False, True])
def test_ria_matches_ref(sq):
    rng = np.random.default_rng(11)
    w = _rand(rng, 64, 512)
    colmax = jnp.abs(_rand(rng, 512))
    al2 = jnp.abs(_rand(rng, 512))
    got = np.asarray(ria_score(w, colmax, al2, sq=sq))
    wm = ref.equalize_ref(w, colmax) if sq else w
    want = np.asarray(ref.ria_score_ref(wm, al2))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_ria_zero_column_guard():
    rng = np.random.default_rng(13)
    w = np.asarray(_rand(rng, 16, 256)).copy()
    w[:, 3] = 0.0  # dead input channel
    al2 = jnp.abs(_rand(rng, 256))
    colmax = jnp.abs(_rand(rng, 256))
    s = np.asarray(ria_score(jnp.array(w), colmax, al2, sq=True))
    assert np.isfinite(s).all()
    assert (s[:, 3] == 0).all()


def test_ria_sq_changes_ordering_only_via_metric():
    # SQ equalization must not change W itself — it only reweights the score.
    rng = np.random.default_rng(17)
    w = _rand(rng, 32, 256)
    colmax = jnp.abs(_rand(rng, 256)) * 10.0
    al2 = jnp.abs(_rand(rng, 256))
    s_plain = np.asarray(ria_score(w, colmax, al2, sq=False))
    s_sq = np.asarray(ria_score(w, colmax, al2, sq=True))
    assert not np.allclose(s_plain, s_sq)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([4, 16, 64]),
    cols=st.sampled_from([256, 512]),
    alpha=st.sampled_from([0.0, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ria_property(rows, cols, alpha, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, rows, cols)
    colmax = jnp.abs(_rand(rng, cols))
    al2 = jnp.abs(_rand(rng, cols))
    got = np.asarray(ria_score(w, colmax, al2, alpha=alpha, sq=False))
    want = np.asarray(ref.ria_score_ref(w, al2, alpha=alpha))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# variance correction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["global", "row"])
def test_vc_matches_ref(mode):
    rng = np.random.default_rng(19)
    w = _rand(rng, 64, 512)
    wp = w * ref.nm_mask_ref(jnp.abs(w), 8, 16)
    got = np.asarray(variance_correct(wp, w, mode=mode))
    want = np.asarray(ref.variance_correct_ref(wp, w, mode=mode))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_vc_restores_variance():
    rng = np.random.default_rng(23)
    w = _rand(rng, 128, 512)
    wp = w * ref.nm_mask_ref(jnp.abs(w), 2, 4)
    out = np.asarray(variance_correct(wp, w))
    assert abs(out.var() - np.asarray(w).var()) / np.asarray(w).var() < 0.05


def test_vc_preserves_mask():
    rng = np.random.default_rng(29)
    w = _rand(rng, 32, 256)
    mask = np.asarray(ref.nm_mask_ref(jnp.abs(w), 8, 16))
    wp = w * mask
    out = np.asarray(variance_correct(wp, w))
    assert (out[mask == 0] == 0).all()


def test_vc_noop_on_dense():
    rng = np.random.default_rng(31)
    w = _rand(rng, 32, 256)
    out = np.asarray(variance_correct(w, w))
    np.testing.assert_allclose(out, np.asarray(w), rtol=1e-4)


# ---------------------------------------------------------------------------
# masked matmul
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 8, 32]),
    cout=st.sampled_from([32, 64, 256]),
    cin=st.sampled_from([256, 512]),
    pattern=st.sampled_from(PATTERNS),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_property(b, cout, cin, pattern, seed):
    n, m = pattern
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, cin)
    w = _rand(rng, cout, cin)
    mask = ref.nm_mask_ref(jnp.abs(w), n, m)
    got = np.asarray(masked_matmul(x, w, mask))
    want = np.asarray(ref.masked_matmul_ref(x, w, mask))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spmm_tiling_boundaries():
    rng = np.random.default_rng(37)
    x = _rand(rng, 8, 1536)  # cin not a power of two (3 * 512)
    w = _rand(rng, 96, 1536)
    mask = ref.nm_mask_ref(jnp.abs(w), 8, 16)
    got = np.asarray(masked_matmul(x, w, mask))
    want = np.asarray(ref.masked_matmul_ref(x, w, mask))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# outlier extraction / packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,m", OUTLIER_PATTERNS)
def test_outlier_roundtrip(k, m):
    rng = np.random.default_rng(41)
    w = _rand(rng, 32, 512)
    score = ref.ria_score_ref(w, jnp.abs(_rand(rng, 512)))
    omask = outlier_mask(score, k, m)
    vals, idx = pack_outliers(w, omask, k, m)
    assert vals.shape == (32, 512 // m, k)
    dense = np.asarray(unpack_outliers(vals, idx, 32, 512, m))
    np.testing.assert_allclose(dense, np.asarray(w * omask), rtol=1e-6)


def test_outlier_indices_sorted_unique():
    rng = np.random.default_rng(43)
    w = _rand(rng, 16, 512)
    omask = outlier_mask(jnp.abs(w), 16, 256)
    _, idx = pack_outliers(w, omask, 16, 256)
    idx = np.asarray(idx)
    assert (np.diff(idx, axis=-1) > 0).all(), "indices strictly ascending"
    assert idx.min() >= 0 and idx.max() < 256


def test_split_salient_partitions():
    rng = np.random.default_rng(47)
    w = _rand(rng, 32, 512)
    omask = outlier_mask(jnp.abs(w), 8, 256)
    sal, res = split_salient(w, omask)
    np.testing.assert_allclose(np.asarray(sal + res), np.asarray(w), rtol=1e-6)
    assert (np.asarray(sal)[np.asarray(omask) == 0] == 0).all()
    assert (np.asarray(res)[np.asarray(omask) == 1] == 0).all()


# ---------------------------------------------------------------------------
# full prune_layer oracle self-consistency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("k", [0, 4, 16])
def test_prune_layer_ref_budget(n, m, k):
    rng = np.random.default_rng(53)
    w = _rand(rng, 64, 512)
    colmax = jnp.abs(_rand(rng, 512))
    al2 = jnp.abs(_rand(rng, 512))
    w_ns, keep, omask = ref.prune_layer_ref(
        w, colmax, al2, n, m, k_outlier=k, use_sq=True, use_vc=True
    )
    keep, omask = np.asarray(keep), np.asarray(omask)
    # salient and kept sets are disjoint
    assert (keep * omask == 0).all()
    # N:M budget exactly filled in blocks without salient positions
    blocks_keep = keep.reshape(64, -1, m).sum(-1)
    blocks_sal = omask.reshape(64, -1, m).sum(-1)
    assert (blocks_keep + np.minimum(blocks_sal, 99) >= n).all() or k == 0
    if k == 0:
        assert (blocks_keep == n).all()
    # non-salient output vanishes outside the keep mask
    assert (np.asarray(w_ns)[keep == 0] == 0).all()


# ---------------------------------------------------------------------------
# quant_dequant
# ---------------------------------------------------------------------------

from compile.kernels import quant_dequant


@pytest.mark.parametrize("bits,group", [(3, 64), (4, 128), (8, 128)])
def test_quant_matches_ref(bits, group):
    rng = np.random.default_rng(bits * 100 + group)
    w = _rand(rng, 16, 512)
    got = np.asarray(quant_dequant(w, bits=bits, group=group))
    want = np.asarray(ref.quant_dequant_ref(w, bits=bits, group=group))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_quant_error_bounded_by_half_step():
    rng = np.random.default_rng(7)
    w = _rand(rng, 8, 256)
    bits, group = 4, 64
    qmax = 2 ** (bits - 1) - 1
    d = np.asarray(quant_dequant(w, bits=bits, group=group))
    wg = np.asarray(w).reshape(8, 256 // group, group)
    dg = d.reshape(8, 256 // group, group)
    step = np.abs(wg).max(axis=2, keepdims=True) / qmax
    assert (np.abs(dg - wg) <= 0.5 * step + 1e-7).all()


def test_quant_zero_group_stays_zero():
    w = jnp.zeros((2, 128), jnp.float32).at[1, 64].set(3.0)
    d = np.asarray(quant_dequant(w, bits=4, group=64))
    assert (d[0] == 0).all()
    assert (d[1, :64] == 0).all()
    assert abs(d[1, 64] - 3.0) < 1e-6


def test_quant_more_bits_less_error():
    rng = np.random.default_rng(9)
    w = _rand(rng, 16, 512)
    errs = []
    for bits in (2, 3, 4, 8):
        d = np.asarray(quant_dequant(w, bits=bits, group=128))
        errs.append(np.abs(d - np.asarray(w)).mean())
    assert errs == sorted(errs, reverse=True)


@settings(deadline=None, max_examples=15)
@given(
    rows=st.integers(1, 16),
    groups=st.integers(1, 4),
    bits=st.sampled_from([3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_property_idempotent(rows, groups, bits, seed):
    rng = np.random.default_rng(seed)
    group = 64
    w = _rand(rng, rows, groups * group)
    d1 = np.asarray(quant_dequant(w, bits=bits, group=group))
    d2 = np.asarray(quant_dequant(jnp.array(d1), bits=bits, group=group))
    np.testing.assert_allclose(d2, d1, rtol=1e-5, atol=1e-7)
