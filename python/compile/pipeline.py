"""Layer-2 pruning pipeline graphs (compose the L1 Pallas kernels).

Each function here becomes one HLO artifact per distinct linear-layer shape
of a model config.  The Rust coordinator chains them per layer:

    score  ->  (outlier mask)  ->  (nm mask)  ->  finalize(+VC)

Keeping the stages granular (rather than one fused prune_layer artifact)
lets the coordinator mix methods per experiment cell — e.g. magnitude
scores with structured outlier recovery (Table 5), or RIA without SQ
(Table 4) — without a combinatorial artifact explosion.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import nm_mask, ria_score, variance_correct
from .kernels.ref import DEFAULT_ALPHA


def score_graph(w, colmax_x, act_l2, *, sq: bool, alpha: float = DEFAULT_ALPHA):
    """RIA importance scores (Pallas), optionally SmoothQuant-equalized."""
    return ria_score(w, colmax_x, act_l2, alpha=alpha, sq=sq)


def magnitude_graph(w):
    """|W| baseline scores (kept in L2 so the artifact set is uniform)."""
    return jnp.abs(w)


def wanda_graph(w, act_l2):
    """Wanda baseline scores |W| * ||x||_2."""
    return jnp.abs(w) * act_l2[None, :]


def mask_graph(score, *, n: int, m: int):
    """Exact top-N per (1, M) block keep mask (Pallas)."""
    return nm_mask(score, n, m)


def mask_excluding_graph(score, excl, *, n: int, m: int):
    """N:M mask over ``score`` with already-salient positions excluded.

    Salient weights live in their own structured matrix, so they must not
    consume N:M slots: their score is forced to -inf first (§4 stage 2).
    """
    neg = jnp.asarray(-jnp.inf, score.dtype)
    return nm_mask(jnp.where(excl > 0, neg, score), n, m) * (1.0 - excl)


def finalize_graph(w, keep, omask, *, vc: bool):
    """Apply the keep mask and (optionally) variance-correct (Pallas).

    Returns the corrected non-salient weight matrix; the effective
    compressed weight is ``w_ns + w * omask``.
    """
    w_ns = w * keep
    if vc:
        dense_ref = w * (1.0 - omask)
        w_ns = variance_correct(w_ns, dense_ref, mode="global")
    return w_ns
