"""AOT lowering: every L1/L2 graph -> HLO *text* artifacts + JSON manifests.

This is the only place Python runs in the whole system, and it runs once
(``make artifacts``).  The Rust runtime loads the text with
``HloModuleProto::from_text_file`` and executes via PJRT.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Layout:

    artifacts/
      kernels/<r>x<c>/<name>.hlo.txt   # shape-keyed, shared across configs
      kernels/<r>x<c>/manifest.json
      <config>/<name>.hlo.txt          # model-level graphs
      <config>/manifest.json

Usage: ``python -m compile.aot --out-root ../artifacts [--configs tiny,small]``
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, pipeline
from .configs import (
    BLOCK_LINEAR,
    BLOCK_PARAMS,
    CONFIGS,
    OUTLIER_PATTERNS,
    SPARSITY_PATTERNS,
    ModelConfig,
)

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args):
    out = []
    for a in args:
        out.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    return out


class Exporter:
    """Lowers functions and records their signatures into a manifest."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = {}
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn, in_specs, static_out=None):
        # keep_unused: an input that a variant ignores (e.g. finalize_vc0's
        # omask) must stay an HLO parameter so every variant shares one
        # calling convention on the Rust side.
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        flat_out, _ = jax.tree.flatten(out_avals)
        self.entries[name] = {
            "file": fname,
            "inputs": _sig(in_specs),
            "outputs": _sig(flat_out),
        }
        print(f"  [{name}] {len(text) / 1024:.0f} KiB "
              f"({len(in_specs)} in / {len(flat_out)} out)")

    def write_manifest(self, extra=None):
        manifest = {"artifacts": self.entries}
        if extra:
            manifest.update(extra)
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)


# ---------------------------------------------------------------------------
# kernel artifacts (shape-keyed, shared across model configs)
# ---------------------------------------------------------------------------

def export_kernels_for_shape(root: str, r: int, c: int, spmm_batch: int):
    ex = Exporter(os.path.join(root, "kernels", f"{r}x{c}"))
    w = spec((r, c))
    vec = spec((c,))

    for sq in (False, True):
        ex.export(
            f"score_sq{int(sq)}",
            functools.partial(pipeline.score_graph, sq=sq),
            [w, vec, vec],
        )
    ex.export("magnitude", pipeline.magnitude_graph, [w])
    ex.export("wanda", pipeline.wanda_graph, [w, vec])

    for (n, m) in SPARSITY_PATTERNS + OUTLIER_PATTERNS:
        if c % m != 0:
            continue
        ex.export(
            f"mask_{n}_{m}",
            functools.partial(pipeline.mask_excluding_graph, n=n, m=m),
            [w, w],
        )

    for vc in (False, True):
        ex.export(
            f"finalize_vc{int(vc)}",
            functools.partial(pipeline.finalize_graph, vc=vc),
            [w, w, w],
        )

    from .kernels import masked_matmul, quant_dequant
    ex.export(
        "spmm",
        lambda x, wt, mk: masked_matmul(x, wt, mk),
        [spec((spmm_batch, c)), w, w],
    )
    # SPQR-composition twin: fake group quantization of the base weights
    for bits, group in ((4, 128), (8, 128)):
        if c % group == 0:
            ex.export(
                f"quant_{bits}_{group}",
                functools.partial(quant_dequant, bits=bits, group=group),
                [w],
            )
    ex.write_manifest({"shape": [r, c], "spmm_batch": spmm_batch})


# ---------------------------------------------------------------------------
# model artifacts
# ---------------------------------------------------------------------------

def export_model(root: str, cfg: ModelConfig):
    ex = Exporter(os.path.join(root, cfg.name))
    b, s, d, v = cfg.batch, cfg.seq, cfg.dim, cfg.vocab
    names = cfg.param_names()
    pspecs = [spec(cfg.param_shape(n)) for n in names]
    nb = len(BLOCK_PARAMS)
    bspecs = pspecs[1:1 + nb]  # block 0 params (all blocks share shapes)

    ex.export("embed_fwd", model.embed_fwd, [spec((v, d)), spec((b, s), I32)])

    def bf(*args):
        return model.block_fwd(cfg, args[:nb], args[nb], with_stats=True)

    ex.export("block_fwd", bf, bspecs + [spec((b, s, d))])

    ex.export(
        "head_nll",
        model.head_nll,
        [spec((d,)), spec((v, d)), spec((b, s, d)), spec((b, s), I32)],
    )

    def nll(*args):
        return model.lm_nll(cfg, args[:-1], args[-1])

    ex.export("lm_nll", nll, pspecs + [spec((b, s + 1), I32)])

    np = len(pspecs)

    def ts(*args):
        params = args[:np]
        m_st = args[np:2 * np]
        v_st = args[2 * np:3 * np]
        step, lr, tokens = args[3 * np], args[3 * np + 1], args[3 * np + 2]
        return model.train_step(cfg, params, m_st, v_st, step, lr, tokens)

    ex.export(
        "train_step",
        ts,
        pspecs * 3 + [spec(()), spec(()), spec((b, s + 1), I32)],
    )

    nl = len(BLOCK_LINEAR)
    lin_specs = [spec(cfg.param_shape(f"blk0.{n}")) for n in BLOCK_LINEAR]

    def es(*args):
        i = 0
        params = args[i:i + nb]; i += nb
        masks = args[i:i + nl]; i += nl
        salient = args[i:i + nl]; i += nl
        x, y = args[i], args[i + 1]; i += 2
        m_st = args[i:i + nb]; i += nb
        v_st = args[i:i + nb]; i += nb
        step, lr = args[i], args[i + 1]
        return model.ebft_step(cfg, params, masks, salient, x, y, m_st, v_st,
                               step, lr)

    ex.export(
        "ebft_step",
        es,
        list(bspecs) + lin_specs + lin_specs
        + [spec((b, s, d)), spec((b, s, d))]
        + list(bspecs) * 2 + [spec(()), spec(())],
    )

    ex.write_manifest({
        "config": {
            "name": cfg.name, "dim": cfg.dim, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
            "hidden": cfg.hidden, "vocab": cfg.vocab, "seq": cfg.seq,
            "batch": cfg.batch, "rope_theta": cfg.rope_theta,
            "adam_b1": cfg.adam_b1, "adam_b2": cfg.adam_b2,
            "adam_eps": cfg.adam_eps, "weight_decay": cfg.weight_decay,
            "head_dim": cfg.head_dim, "kv_dim": cfg.kv_dim,
            "n_params": cfg.n_params(),
        },
        "params": [{"name": n, "shape": list(cfg.param_shape(n))}
                   for n in names],
        "block_params": BLOCK_PARAMS,
        "block_linear": BLOCK_LINEAR,
        "linear_shapes": [[k, list(sh)] for k, sh in cfg.linear_shapes()],
    })


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-root", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,gqa,wide,e2e")
    args = ap.parse_args()

    cfgs = [CONFIGS[c] for c in args.configs.split(",") if c]
    shapes = {}
    for cfg in cfgs:
        for _, (r, c) in cfg.linear_shapes():
            shapes[(r, c)] = cfg.batch * cfg.seq

    for (r, c), sb in sorted(shapes.items()):
        print(f"kernels {r}x{c}:")
        export_kernels_for_shape(args.out_root, r, c, sb)

    for cfg in cfgs:
        print(f"model {cfg.name}:")
        export_model(args.out_root, cfg)

    with open(os.path.join(args.out_root, "index.json"), "w") as f:
        json.dump({
            "configs": [c.name for c in cfgs],
            "kernel_shapes": [[r, c] for (r, c) in sorted(shapes)],
        }, f, indent=1)
    print("done.")


if __name__ == "__main__":
    main()
