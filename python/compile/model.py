"""Layer-2 JAX model: a LLaMA-style transformer LM.

Architecture (matching the paper's subjects): RMSNorm pre-norm blocks,
rotary position embeddings, SwiGLU MLP, optional grouped-query attention,
tied input/output embedding.  Everything is written over *flat positional
parameter lists* so each function lowers to an HLO artifact whose inputs
the Rust runtime feeds as PJRT literals in manifest order (no pytrees on
the wire).

The functions exported by ``aot.py``:

* ``embed_fwd``    — token embedding lookup
* ``block_fwd``    — one transformer block + per-linear input activation
                     statistics (channel max-abs and L2) for calibration
* ``head_nll``     — final norm + tied head + per-token negative
                     log-likelihood
* ``lm_nll``       — whole-model fwd (cross-checks the layered chain)
* ``train_step``   — fwd + bwd + AdamW, donated state (pre-training driver)
* ``ebft_step``    — EBFT (Guo et al., 2024): one blockwise reconstruction
                     fine-tuning step under fixed sparsity masks
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .configs import BLOCK_LINEAR, BLOCK_PARAMS, ModelConfig

RMS_EPS = 1e-5


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + RMS_EPS) * w


def rope_tables(seq: int, head_dim: int, theta: float):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    freq = theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    ang = pos * freq[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, Dh); rotate pairs (even, odd)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def _stats(x2d: jnp.ndarray):
    """(colmax, l2) per input channel of a linear layer input."""
    colmax = jnp.max(jnp.abs(x2d), axis=0)
    l2 = jnp.sqrt(jnp.sum(jnp.square(x2d), axis=0))
    return colmax, l2


# ---------------------------------------------------------------------------
# transformer block
# ---------------------------------------------------------------------------

def block_fwd(cfg: ModelConfig, params: Sequence[jnp.ndarray], h: jnp.ndarray,
              with_stats: bool = True):
    """One pre-norm block.  ``params`` in BLOCK_PARAMS order.

    Returns ``h_out`` and, when ``with_stats``, the calibration statistics
    of the four distinct linear inputs: (attn_in, o_in, mlp_in, down_in)
    as interleaved (colmax, l2) vectors.
    """
    ln1, wq, wk, wv, wo, ln2, wg, wu, wd = params
    b, s, d = h.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    x = rmsnorm(h, ln1)
    x2 = x.reshape(b * s, d)
    q = (x2 @ wq.T).reshape(b, s, nh, hd)
    k = (x2 @ wk.T).reshape(b, s, nkv, hd)
    v = (x2 @ wv.T).reshape(b, s, nkv, hd)

    cos, sin = rope_tables(s, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if nkv != nh:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(causal[None, None, :, :], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * s, d)
    attn_out = (o @ wo.T).reshape(b, s, d)
    h1 = h + attn_out

    y = rmsnorm(h1, ln2)
    y2 = y.reshape(b * s, d)
    g = y2 @ wg.T
    u = y2 @ wu.T
    z = jax.nn.silu(g) * u
    mlp_out = (z @ wd.T).reshape(b, s, d)
    h2 = h1 + mlp_out

    if not with_stats:
        return h2
    stats = []
    for t in (x2, o, y2, z):
        cm, l2 = _stats(t)
        stats.extend([cm, l2])
    return (h2, *stats)


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------

def split_params(cfg: ModelConfig, params: Sequence[jnp.ndarray]):
    """flat list -> (tok_emb, [block params], ln_f)."""
    nb = len(BLOCK_PARAMS)
    tok_emb = params[0]
    blocks = [params[1 + i * nb: 1 + (i + 1) * nb] for i in range(cfg.n_layers)]
    ln_f = params[1 + cfg.n_layers * nb]
    return tok_emb, blocks, ln_f


def embed_fwd(tok_emb: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return tok_emb[tokens]


def head_nll(ln_f: jnp.ndarray, tok_emb: jnp.ndarray, h: jnp.ndarray,
             targets: jnp.ndarray) -> jnp.ndarray:
    """Per-token negative log-likelihood (B, S). Head is tied to tok_emb."""
    x = rmsnorm(h, ln_f)
    logits = x @ tok_emb.T  # (B, S, V)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse - tgt


def lm_nll(cfg: ModelConfig, params: Sequence[jnp.ndarray],
           tokens: jnp.ndarray) -> jnp.ndarray:
    """Whole-model per-token nll over ``tokens`` (B, S+1)."""
    tok_emb, blocks, ln_f = split_params(cfg, params)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    h = embed_fwd(tok_emb, inp)
    for bp in blocks:
        h = block_fwd(cfg, bp, h, with_stats=False)
    return head_nll(ln_f, tok_emb, h, tgt)


def lm_loss(cfg: ModelConfig, params: Sequence[jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(lm_nll(cfg, params, tokens))


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def adamw_update(cfg: ModelConfig, p, g, m, v, step, lr, mask=None,
                 weight_decay=None):
    """One AdamW step for a single tensor; ``mask`` freezes zeroed entries."""
    wd = cfg.weight_decay if weight_decay is None else weight_decay
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    if mask is not None:
        g = g * mask
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * jnp.square(g)
    mhat = m / (1.0 - jnp.power(b1, step))
    vhat = v / (1.0 - jnp.power(b2, step))
    upd = mhat / (jnp.sqrt(vhat) + eps) + wd * p
    if mask is not None:
        upd = upd * mask
    return p - lr * upd, m, v


def train_step(cfg: ModelConfig, params, m_state, v_state, step, lr, tokens):
    """Full-model AdamW pre-training step. Returns (params', m', v', loss)."""
    loss, grads = jax.value_and_grad(
        lambda ps: lm_loss(cfg, ps, tokens)
    )(list(params))
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, m_state, v_state):
        p2, m2, v2 = adamw_update(cfg, p, g, m, v, step, lr)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return new_p, new_m, new_v, loss


# ---------------------------------------------------------------------------
# EBFT — blockwise reconstruction fine-tuning (Guo et al., 2024)
# ---------------------------------------------------------------------------

def ebft_loss(cfg: ModelConfig, params, masks, salient, x, y):
    """MSE between the sparse block's output and the dense block's output.

    ``params`` are the *trainable* block tensors (BLOCK_PARAMS order) where
    linear weights hold only non-salient values; ``masks`` fix the N:M keep
    pattern of each linear; ``salient`` are the frozen structured-outlier
    matrices added back to form the effective weight.
    """
    eff = []
    li = 0
    for name, p in zip(BLOCK_PARAMS, params):
        if name in BLOCK_LINEAR:
            eff.append(p * masks[li] + salient[li])
            li += 1
        else:
            eff.append(p)
    out = block_fwd(cfg, eff, x, with_stats=False)
    return jnp.mean(jnp.square(out - y))


def ebft_step(cfg: ModelConfig, params, masks, salient, x, y,
              m_state, v_state, step, lr):
    """One masked AdamW step on the block-reconstruction objective.

    Only non-salient linear weights (through their masks) and the RMSNorm
    gains are updated, exactly as §4 stage 4 prescribes.  Returns
    ``(params', m', v', loss)``.
    """
    loss, grads = jax.value_and_grad(
        lambda ps: ebft_loss(cfg, ps, masks, salient, x, y)
    )(list(params))
    new_p, new_m, new_v = [], [], []
    li = 0
    for name, p, g, m, v in zip(BLOCK_PARAMS, params, grads, m_state, v_state):
        mask = None
        wd = None
        if name in BLOCK_LINEAR:
            mask = masks[li]
            li += 1
        else:
            wd = 0.0  # no weight decay on norm gains
        p2, m2, v2 = adamw_update(cfg, p, g, m, v, step, lr, mask=mask,
                                  weight_decay=wd)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return new_p, new_m, new_v, loss


# ---------------------------------------------------------------------------
# initialization (used by tests; the Rust side has its own initializer
# mirroring these scales)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> list:
    params = []
    for name in cfg.param_names():
        shape = cfg.param_shape(name)
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[1]
            std = fan_in ** -0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params
