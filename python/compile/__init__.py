"""sparselm build-time Python package: L1 Pallas kernels + L2 JAX graphs.

Never imported at runtime — ``compile.aot`` lowers everything to HLO text
once and the Rust binary is self-contained afterwards.
"""
