"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each Pallas kernel in
``nm_prune.py`` / ``ria_score.py`` / ``nm_spmm.py`` / ``outlier_extract.py`` /
``variance_correct.py`` must match its oracle here to float tolerance
(``python/tests/`` sweeps shapes and dtypes with hypothesis).

The math follows the paper:

* **RIA** (Zhang et al., 2024, as used in §4):
  ``score_ij = (|W_ij| / sum_i' |W_i'j| + |W_ij| / sum_j' |W_ij'|) * a_j^alpha``
  where ``a_j`` is the L2 norm of input channel ``j`` over the calibration
  set and ``alpha`` defaults to 0.5.
* **SmoothQuant-style equalization** (§4.1, Eq. 1): channel scale
  ``s_j = max|x_j| / max|W_:,j|``; ``W_ec = W @ S^{-1}``.  Only the
  *importance metric* is computed on ``W_ec`` — actual weights never change.
* **N:M mask selection**: within every contiguous ``(1, M)`` block along the
  input-channel axis keep the ``N`` highest-scoring entries (exactly ``N``,
  ties broken by position — first occurrence wins, matching a stable
  descending argsort).
* **Variance correction** (§4.2, Eq. 2):
  ``W_ns_corrected = W_ns * sqrt(Var(W_dense) / (Var(W_ns) + eps))``
  with variances taken over the full matrix (``global`` mode) or per output
  row (``row`` mode).
"""

from __future__ import annotations

import jax.numpy as jnp

DEFAULT_ALPHA = 0.5
VC_EPS = 1e-8


# ---------------------------------------------------------------------------
# mask selection
# ---------------------------------------------------------------------------

def nm_mask_ref(scores: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Exact top-``n`` per ``(1, m)`` block mask. Returns float mask (0/1).

    ``scores`` has shape ``(rows, cols)`` with ``cols % m == 0``. Ties are
    broken by position: stable argsort of ``-scores`` means the earlier
    element of a tied pair is kept first.
    """
    rows, cols = scores.shape
    assert cols % m == 0, f"cols={cols} not divisible by m={m}"
    blocks = scores.reshape(rows, cols // m, m)
    # rank[i] = position of element i in the descending order of its block
    order = jnp.argsort(-blocks, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = (ranks < n).astype(scores.dtype)
    return mask.reshape(rows, cols)


def outlier_mask_ref(scores: jnp.ndarray, k: int, m: int = 256) -> jnp.ndarray:
    """Structured salient-weight mask: top-``k`` per ``(1, m)`` block."""
    return nm_mask_ref(scores, k, m)


# ---------------------------------------------------------------------------
# importance scoring
# ---------------------------------------------------------------------------

def sq_scales_ref(w: jnp.ndarray, colmax_x: jnp.ndarray) -> jnp.ndarray:
    """SmoothQuant channel scales ``s_j = max|x_j| / max|W_:,j|`` (Eq. 1).

    Guarded so dead channels (all-zero weight column or activation) give
    ``s_j = 1`` instead of inf/0.
    """
    wmax = jnp.max(jnp.abs(w), axis=0)
    s = jnp.abs(colmax_x) / jnp.where(wmax > 0, wmax, 1.0)
    return jnp.where((wmax > 0) & (jnp.abs(colmax_x) > 0), s, 1.0)


def equalize_ref(w: jnp.ndarray, colmax_x: jnp.ndarray) -> jnp.ndarray:
    """``W_ec = W @ S^{-1}`` — the metric-only equalized weights."""
    s = sq_scales_ref(w, colmax_x)
    return w / s[None, :]


def ria_score_ref(
    w: jnp.ndarray, act_l2: jnp.ndarray, alpha: float = DEFAULT_ALPHA
) -> jnp.ndarray:
    """RIA importance score (relative row + column importance × activation)."""
    aw = jnp.abs(w)
    rowsum = jnp.sum(aw, axis=1, keepdims=True)
    colsum = jnp.sum(aw, axis=0, keepdims=True)
    rel = aw / jnp.where(rowsum > 0, rowsum, 1.0) + aw / jnp.where(
        colsum > 0, colsum, 1.0
    )
    return rel * jnp.power(jnp.maximum(act_l2, 0.0), alpha)[None, :]


def magnitude_score_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Magnitude pruning baseline score: ``|W|``."""
    return jnp.abs(w)


def wanda_score_ref(w: jnp.ndarray, act_l2: jnp.ndarray) -> jnp.ndarray:
    """Wanda (Sun et al., 2023) baseline score: ``|W| * ||x_j||_2``."""
    return jnp.abs(w) * act_l2[None, :]


# ---------------------------------------------------------------------------
# variance correction
# ---------------------------------------------------------------------------

def variance_correct_ref(
    w_pruned: jnp.ndarray,
    w_dense: jnp.ndarray,
    mode: str = "global",
    eps: float = VC_EPS,
) -> jnp.ndarray:
    """Rescale the pruned (non-salient) weights to restore dense variance.

    ``mode='global'`` uses one scale for the matrix (the paper's Eq. 2);
    ``mode='row'`` computes the correction per output row.
    """
    if mode == "global":
        var_d = jnp.var(w_dense)
        var_p = jnp.var(w_pruned)
        scale = jnp.sqrt(var_d / (var_p + eps))
        return w_pruned * scale
    if mode == "row":
        var_d = jnp.var(w_dense, axis=1, keepdims=True)
        var_p = jnp.var(w_pruned, axis=1, keepdims=True)
        scale = jnp.sqrt(var_d / (var_p + eps))
        return w_pruned * scale
    raise ValueError(f"unknown vc mode {mode!r}")


# ---------------------------------------------------------------------------
# fake quantization (SPQR-composition oracle)
# ---------------------------------------------------------------------------

def quant_dequant_ref(w: jnp.ndarray, bits: int = 4, group: int = 128) -> jnp.ndarray:
    """Symmetric per-group integer round-trip: one absmax scale per
    ``group`` contiguous row elements, values on ``[-qmax, qmax]``."""
    rows, cols = w.shape
    qmax = float(2 ** (bits - 1) - 1)
    g = w.reshape(rows, cols // group, group)
    absmax = jnp.max(jnp.abs(g), axis=2, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / qmax, 0.0)
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(g * inv), -qmax, qmax)
    return (q * scale).reshape(rows, cols)


# ---------------------------------------------------------------------------
# sparse matmul
# ---------------------------------------------------------------------------

def masked_matmul_ref(
    x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """``y = x @ (W * mask)^T`` — x: (B, Cin), W/mask: (Cout, Cin)."""
    return x @ (w * mask).T


# ---------------------------------------------------------------------------
# end-to-end prune reference (used by pipeline tests)
# ---------------------------------------------------------------------------

def prune_layer_ref(
    w: jnp.ndarray,
    colmax_x: jnp.ndarray,
    act_l2: jnp.ndarray,
    n: int,
    m: int,
    k_outlier: int = 0,
    m_outlier: int = 256,
    use_sq: bool = True,
    use_vc: bool = True,
    alpha: float = DEFAULT_ALPHA,
    method: str = "ria",
):
    """Full per-layer pipeline oracle.

    Returns ``(w_nonsalient, keep_mask, outlier_mask)`` where the effective
    compressed weight is ``w_nonsalient + w * outlier_mask``.
    Salient positions are excluded from the N:M budget by forcing their
    score to -inf before block top-N selection.
    """
    w_metric = equalize_ref(w, colmax_x) if use_sq else w
    if method == "ria":
        score = ria_score_ref(w_metric, act_l2, alpha)
    elif method == "magnitude":
        score = magnitude_score_ref(w_metric)
    elif method == "wanda":
        score = wanda_score_ref(w_metric, act_l2)
    else:
        raise ValueError(f"unknown method {method!r}")

    if k_outlier > 0:
        omask = outlier_mask_ref(score, k_outlier, m_outlier)
        score = jnp.where(omask > 0, -jnp.inf, score)
    else:
        omask = jnp.zeros_like(w)

    keep = nm_mask_ref(score, n, m) * (1.0 - omask)
    w_ns = w * keep
    if use_vc:
        w_ns = variance_correct_ref(w_ns, w * (1.0 - omask))
    return w_ns, keep, omask
