"""Pallas kernel: exact top-N per (1, M) block mask selection.

This is the structural heart of the paper — the N:M pattern selector that
turns an importance-score matrix into a semi-structured keep mask.  It is
used both for weight sparsity (2:4, 4:8, 8:16, 16:32) and, with M=256, for
the structured salient-weight patterns (4:256, 8:256, 16:256).

TPU adaptation (DESIGN.md §Hardware-Adaptation): selection is a
bandwidth-bound streaming pass.  The kernel tiles over rows with the full
channel dimension resident in VMEM; inside the tile the scores are reshaped
to (TILE_R, C//M, M) and ranked with a double-argsort along the length-M
axis — for M <= 32 this lowers to a small sorting network, and for M = 256
it is still a single-lane sort well inside the VPU budget.  Ranks, not a
threshold, give *exactly* N survivors per block even with tied scores
(stable order: earlier index wins), which the packed storage format on the
Rust side relies on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _nm_mask_kernel(s_ref, o_ref, *, n: int, m: int):
    s = s_ref[...]
    tr, c = s.shape
    blocks = s.reshape(tr, c // m, m)
    order = jnp.argsort(-blocks, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = (ranks < n).astype(s.dtype)
    o_ref[...] = mask.reshape(tr, c)


@functools.partial(jax.jit, static_argnames=("n", "m"))
def nm_mask(scores: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Top-``n`` per ``(1, m)`` block keep mask, Pallas-tiled over rows."""
    rows, cols = scores.shape
    common.check_divisible(cols, m)
    tr = common.row_tile(rows)
    grid = (rows // tr,)
    return pl.pallas_call(
        functools.partial(_nm_mask_kernel, n=n, m=m),
        grid=grid,
        in_specs=[pl.BlockSpec((tr, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(scores.shape, scores.dtype),
        interpret=common.INTERPRET,
    )(scores)
