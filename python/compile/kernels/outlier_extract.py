"""Pallas kernel: structured salient-weight (outlier) extraction.

The paper stores the top-k most important weights of every (1, 256) block
in a separate structured matrix (patterns 4:256 / 8:256 / 16:256, §1, §4
stage 2).  Selection is the same exact-top-k-per-block primitive as
``nm_prune`` with M = 256; this module adds the *extraction* step used by
the packing path: splitting W into the salient part (kept at full value)
and the residual passed on to N:M pruning, plus the compact per-block
(values, byte-index) representation mirrored by ``sparse::outliers`` on the
Rust side.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .nm_prune import nm_mask

OUTLIER_M = 256


def outlier_mask(scores: jnp.ndarray, k: int, m: int = OUTLIER_M) -> jnp.ndarray:
    """Top-``k`` per ``(1, m)`` block salient mask (Pallas)."""
    return nm_mask(scores, k, m)


def _split_kernel(w_ref, mask_ref, sal_ref, res_ref):
    w = w_ref[...]
    mask = mask_ref[...]
    sal_ref[...] = w * mask
    res_ref[...] = w * (1.0 - mask)


@jax.jit
def split_salient(w: jnp.ndarray, mask: jnp.ndarray):
    """Split ``w`` into (salient, residual) along a precomputed mask."""
    rows, cols = w.shape
    tr = common.row_tile(rows)
    grid = (rows // tr,)
    spec = pl.BlockSpec((tr, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _split_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[
            pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            pl.BlockSpec((tr, cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        ],
        interpret=common.INTERPRET,
    )(w, mask)


@functools.partial(jax.jit, static_argnames=("k", "m"))
def pack_outliers(w: jnp.ndarray, mask: jnp.ndarray, k: int, m: int = OUTLIER_M):
    """Compact (values, indices) form of a k:m structured salient matrix.

    Returns ``values`` (rows, cols//m, k) f32 and ``indices`` (rows,
    cols//m, k) int32 — the in-block byte offsets.  This is the memory
    layout whose footprint ``hwsim`` accounts (k * (2 + 1) bytes per block
    at bf16).  Requires the mask to hold exactly k entries per block, which
    the selection kernel guarantees.
    """
    rows, cols = w.shape
    common.check_divisible(cols, m)
    nb = cols // m
    mb = mask.reshape(rows, nb, m)
    wb = w.reshape(rows, nb, m)
    # stable: kept positions in ascending index order
    order = jnp.argsort(-mb, axis=-1, stable=True)[..., :k]
    idx = jnp.sort(order, axis=-1)
    vals = jnp.take_along_axis(wb, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def unpack_outliers(vals, idx, rows: int, cols: int, m: int = OUTLIER_M):
    """Inverse of :func:`pack_outliers` — scatter back to dense."""
    nb = cols // m
    dense = jnp.zeros((rows, nb, m), vals.dtype)
    dense = jnp.put_along_axis(dense, idx.astype(jnp.int32), vals, axis=-1,
                               inplace=False)
    return dense.reshape(rows, cols)
