"""Pallas kernel: RIA importance scores (+ SmoothQuant equalization fold).

RIA (Zhang et al., 2024) evaluates each weight's importance *relative to its
row and column*:

    score_ij = (|W_ij| / rowsum_i + |W_ij| / colsum_j) * act_l2_j ** alpha

The kernel is tiled over output rows with the full input-channel dimension
resident, so row sums are computed in-tile; column sums span all rows and
are passed in as a precomputed vector (one cheap ``jnp.sum`` in the L2
wrapper — on TPU this is a single-pass reduction fused by XLA).

When ``sq=True`` the SmoothQuant-style equalization (paper Eq. 1) is folded
into the same pass: the metric is computed on ``W_ec = W / s_j`` with
``s_j = max|x_j| / max|W_:,j|``.  Column max-abs is likewise passed in
precomputed.  Only the *metric* sees the equalized weights; W is unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .ref import DEFAULT_ALPHA


def _ria_kernel(w_ref, colsum_ref, colmax_w_ref, colmax_x_ref, actl2_ref,
                o_ref, *, alpha: float, sq: bool):
    w = w_ref[...]
    if sq:
        wmax = colmax_w_ref[...]
        xmax = jnp.abs(colmax_x_ref[...])
        s = jnp.where((wmax > 0) & (xmax > 0), xmax / jnp.where(wmax > 0, wmax, 1.0), 1.0)
        w = w / s[None, :]
    aw = jnp.abs(w)
    rowsum = jnp.sum(aw, axis=1, keepdims=True)
    colsum = colsum_ref[...][None, :]
    rel = aw / jnp.where(rowsum > 0, rowsum, 1.0) + aw / jnp.where(
        colsum > 0, colsum, 1.0
    )
    act = jnp.power(jnp.maximum(actl2_ref[...], 0.0), alpha)
    o_ref[...] = rel * act[None, :]


@functools.partial(jax.jit, static_argnames=("alpha", "sq"))
def ria_score(
    w: jnp.ndarray,
    colmax_x: jnp.ndarray,
    act_l2: jnp.ndarray,
    alpha: float = DEFAULT_ALPHA,
    sq: bool = True,
) -> jnp.ndarray:
    """RIA score matrix for ``w`` (Cout, Cin); stats are per input channel."""
    rows, cols = w.shape
    tr = common.row_tile(rows)
    grid = (rows // tr,)

    # Column statistics must be consistent with the (possibly equalized)
    # metric weights, so compute the equalization scale first, then the
    # column sums of |W_ec|.
    colmax_w = jnp.max(jnp.abs(w), axis=0)
    if sq:
        xmax = jnp.abs(colmax_x)
        s = jnp.where((colmax_w > 0) & (xmax > 0),
                      xmax / jnp.where(colmax_w > 0, colmax_w, 1.0), 1.0)
        colsum = jnp.sum(jnp.abs(w / s[None, :]), axis=0)
    else:
        colsum = jnp.sum(jnp.abs(w), axis=0)

    vec = lambda: pl.BlockSpec((cols,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_ria_kernel, alpha=alpha, sq=sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            vec(), vec(), vec(), vec(),
        ],
        out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=common.INTERPRET,
    )(w, colsum, colmax_w, colmax_x, act_l2)
