"""Layer-1 Pallas kernels for the sparselm compression pipeline.

Every kernel has a pure-jnp oracle in :mod:`ref` and is swept against it by
``python/tests/test_kernels.py`` (hypothesis over shapes/patterns/dtypes).
All kernels lower with ``interpret=True`` so the emitted HLO runs on the
CPU PJRT plugin the Rust runtime uses.
"""

from .nm_prune import nm_mask
from .ria_score import ria_score
from .nm_spmm import masked_matmul
from .outlier_extract import outlier_mask, split_salient, pack_outliers, unpack_outliers
from .variance_correct import variance_correct
from .quant import quant_dequant

__all__ = [
    "nm_mask",
    "ria_score",
    "masked_matmul",
    "outlier_mask",
    "split_salient",
    "pack_outliers",
    "unpack_outliers",
    "variance_correct",
]
