"""Pallas kernel: tiled masked GEMM — the sparse-weight matmul hot path.

``y = x @ (W * mask)^T`` with x: (B, Cin), W/mask: (Cout, Cin).

TPU adaptation of the paper's bandwidth argument (DESIGN.md
§Hardware-Adaptation): on sparse tensor-core hardware the 2:4/8:16 weight
stays compressed in DRAM and is expanded inside the MAC array.  The TPU
analogue keeps the packed weight in HBM and expands tile-by-tile into VMEM
before a dense MXU matmul — HBM traffic halves, MXU work unchanged.  This
kernel expresses that schedule: the mask-multiply happens on the VMEM tile
right before the ``jnp.dot`` (which maps onto the MXU with
``preferred_element_type=f32``), and the K-loop is the innermost grid axis
so each (i, j) output tile accumulates in a VMEM scratch accumulator across
K steps (classic double-buffered Pallas matmul shape).

Under interpret mode the expansion is simulated with a dense mask-multiply;
``hwsim`` on the Rust side models the actual bytes moved by the packed
format.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import common


def _spmm_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...] * m_ref[...]          # expand sparse tile in VMEM
    acc_ref[...] += jnp.dot(
        x_ref[...], w.T, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick(t: int, size: int) -> int:
    t = min(t, size)
    while size % t != 0:
        t //= 2
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("tb", "tn", "tk"))
def masked_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    mask: jnp.ndarray,
    tb: int = 128,
    tn: int = 256,
    tk: int = 512,
) -> jnp.ndarray:
    """Tiled ``x @ (w * mask)^T`` with K-innermost accumulation."""
    b, cin = x.shape
    cout, cin2 = w.shape
    assert cin == cin2, f"x Cin={cin} vs w Cin={cin2}"
    tb = _pick(tb, b)
    tn = _pick(tn, cout)
    tk = _pick(tk, cin)
    nk = cin // tk
    grid = (b // tb, cout // tn, nk)
    return pl.pallas_call(
        functools.partial(_spmm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, tk), lambda i, j, k: (j, k)),
            pl.BlockSpec((tn, tk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((tb, tn), jnp.float32)],
        interpret=common.INTERPRET,
    )(x, w, mask)
