"""Pallas kernel: post-pruning Variance Correction (paper §4.2, Eq. 2).

    W_ns_corrected = W_ns * sqrt(Var(W_dense) / (Var(W_ns) + eps))

``global`` mode (the paper's formulation) applies one scalar per matrix —
the two variances are computed by a cheap fused reduction in the wrapper
and the kernel is a streaming scale.  ``row`` mode computes both variances
per output row inside the row tile (a strictly more local variant we
ablate in bench t4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .ref import VC_EPS


def _vc_global_kernel(w_ref, scale_ref, o_ref):
    o_ref[...] = w_ref[...] * scale_ref[0]


def _vc_row_kernel(w_ref, wd_ref, o_ref, *, eps: float):
    w = w_ref[...]
    wd = wd_ref[...]
    var_p = jnp.var(w, axis=1, keepdims=True)
    var_d = jnp.var(wd, axis=1, keepdims=True)
    o_ref[...] = w * jnp.sqrt(var_d / (var_p + eps))


@functools.partial(jax.jit, static_argnames=("mode", "eps"))
def variance_correct(
    w_pruned: jnp.ndarray,
    w_dense: jnp.ndarray,
    mode: str = "global",
    eps: float = VC_EPS,
) -> jnp.ndarray:
    """Variance-preserving rescale of the pruned non-salient weights."""
    rows, cols = w_pruned.shape
    tr = common.row_tile(rows)
    grid = (rows // tr,)
    if mode == "global":
        scale = jnp.sqrt(jnp.var(w_dense) / (jnp.var(w_pruned) + eps))
        return pl.pallas_call(
            _vc_global_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tr, cols), lambda i: (i, 0)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(w_pruned.shape, w_pruned.dtype),
            interpret=common.INTERPRET,
        )(w_pruned, scale.reshape(1))
    if mode == "row":
        return pl.pallas_call(
            functools.partial(_vc_row_kernel, eps=eps),
            grid=grid,
            in_specs=[
                pl.BlockSpec((tr, cols), lambda i: (i, 0)),
                pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(w_pruned.shape, w_pruned.dtype),
            interpret=common.INTERPRET,
        )(w_pruned, w_dense)
    raise ValueError(f"unknown vc mode {mode!r}")
