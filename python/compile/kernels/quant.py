"""Pallas kernel: symmetric per-group fake-quantization (quantize →
dequantize in one pass).

The Rust coordinator owns real packed int storage
(``rust/src/quant/groupq.rs``); this kernel is its on-accelerator twin —
the compute path a fused sparse+quant deployment would run before the
matmul, and the oracle the Rust packer is cross-validated against in
``rust/tests/runtime_kernels.rs``.

Grid: one program per row tile; each tile holds ``(TILE_R, cols)`` so a
row's groups are reduced entirely in VMEM (groups are contiguous spans of
the row — the same layout the packed format streams).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _quant_kernel(w_ref, o_ref, *, group: int, qmax: float):
    w = w_ref[...]
    tr, cols = w.shape
    g = w.reshape(tr, cols // group, group)
    absmax = jnp.max(jnp.abs(g), axis=2, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / qmax, 0.0)
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(g * inv), -qmax, qmax)
    o_ref[...] = (q * scale).reshape(tr, cols)


@functools.partial(jax.jit, static_argnames=("bits", "group"))
def quant_dequant(w: jnp.ndarray, bits: int = 4, group: int = 128) -> jnp.ndarray:
    """Round-trip ``w`` through the symmetric ``bits``-wide integer grid
    with one absmax scale per ``group`` contiguous elements per row."""
    rows, cols = w.shape
    assert cols % group == 0, f"cols {cols} % group {group}"
    qmax = float(2 ** (bits - 1) - 1)
    tr = common.row_tile(rows)
    return pl.pallas_call(
        functools.partial(_quant_kernel, group=group, qmax=qmax),
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=common.INTERPRET,
    )(w)
