"""Shared tiling helpers for the Pallas kernels.

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel into plain HLO so
the Rust runtime can execute it.  Interpret mode evaluates one grid cell at
a time in Python, so the tiling below deliberately keeps grids SMALL
(large row tiles) — on a real TPU the same BlockSpecs would be shrunk to
VMEM-sized tiles (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

INTERPRET = True  # flipped to False only for TPU compile-only builds

# Row tile used by the streaming (row-parallel) kernels. Grid size for a
# (4096, 4096) layer is 16 cells — cheap even under interpret mode, and on
# TPU a (256, C) f32 tile of a transformer linear (C <= 2048) is < 2 MiB,
# comfortably inside the ~16 MiB VMEM budget together with its outputs.
ROW_TILE = 256


def row_tile(rows: int) -> int:
    """Largest power-of-two row tile that divides ``rows`` (cap ROW_TILE)."""
    t = min(ROW_TILE, rows)
    while rows % t != 0:
        t //= 2
        if t == 1:
            return 1
    return t


def check_divisible(cols: int, m: int) -> None:
    if cols % m != 0:
        raise ValueError(f"cols={cols} must be divisible by block size m={m}")
