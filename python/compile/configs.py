"""Model configurations shared between the JAX build path and Rust runtime.

The paper evaluates LLaMA-2-7B/13B, LLaMA-3-8B and Mistral-7B.  Those
checkpoints are not available here (repro band 0/5), so each is substituted
by a from-scratch-trainable stand-in that keeps the *architectural contrast*
the corresponding table needs (see DESIGN.md §Substitutions):

* ``tiny``  ↔ LLaMA-2-7B   (baseline MHA model)
* ``small`` ↔ LLaMA-2-13B  (~2.3× params of ``tiny`` — the Performance
  Threshold comparison "sparse 13B ≥ dense 7B" becomes
  "sparse small ≥ dense tiny")
* ``gqa``   ↔ LLaMA-3-8B   (grouped-query attention, larger vocab)
* ``wide``  ↔ Mistral-7B   (wider MLP, fewer heads)
* ``e2e``   ↔ the end-to-end validation model (largest; examples only)

Every linear input dimension is a multiple of 256 so the structured
outlier patterns (k:256) tile exactly.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    dim: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    hidden: int
    vocab: int
    seq: int
    batch: int  # batch size baked into the AOT artifacts
    rope_theta: float = 10000.0
    # EBFT / train hyperparameters baked into the optimizer artifacts
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.01

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def linear_shapes(self):
        """Distinct (rows, cols) of the prunable linear layers."""
        shapes = {
            ("attn_qo", (self.dim, self.dim)),
            ("attn_kv", (self.kv_dim, self.dim)),
            ("mlp_in", (self.hidden, self.dim)),
            ("mlp_out", (self.dim, self.hidden)),
        }
        return sorted(shapes)

    def param_names(self):
        """Flat parameter ordering shared with the Rust side."""
        names = ["tok_emb"]
        for i in range(self.n_layers):
            for p in BLOCK_PARAMS:
                names.append(f"blk{i}.{p}")
        names.append("ln_f")
        return names

    def param_shape(self, name: str):
        d, h, kv, v = self.dim, self.hidden, self.kv_dim, self.vocab
        if name == "tok_emb":
            return (v, d)
        if name == "ln_f":
            return (d,)
        base = name.split(".")[-1]
        return {
            "ln1": (d,),
            "wq": (d, d),
            "wk": (kv, d),
            "wv": (kv, d),
            "wo": (d, d),
            "ln2": (d,),
            "wg": (h, d),
            "wu": (h, d),
            "wd": (d, h),
        }[base]

    def n_params(self) -> int:
        total = 0
        for n in self.param_names():
            s = self.param_shape(n)
            p = 1
            for x in s:
                p *= x
            total += p
        return total


# per-block parameter order (shared contract with rust/src/model/)
BLOCK_PARAMS = ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"]
# linear (maskable) weights within a block, in BLOCK_PARAMS order
BLOCK_LINEAR = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]

CONFIGS = {
    "tiny": ModelConfig("tiny", dim=256, n_layers=4, n_heads=4, n_kv_heads=4,
                        hidden=512, vocab=2048, seq=128, batch=4),
    "small": ModelConfig("small", dim=256, n_layers=8, n_heads=8, n_kv_heads=8,
                         hidden=768, vocab=2048, seq=128, batch=4),
    "gqa": ModelConfig("gqa", dim=256, n_layers=6, n_heads=8, n_kv_heads=2,
                       hidden=768, vocab=4096, seq=128, batch=4),
    "wide": ModelConfig("wide", dim=256, n_layers=6, n_heads=4, n_kv_heads=4,
                        hidden=1024, vocab=2048, seq=128, batch=4),
    "e2e": ModelConfig("e2e", dim=512, n_layers=8, n_heads=8, n_kv_heads=8,
                       hidden=1536, vocab=4096, seq=128, batch=4),
}

# sparsity patterns the artifacts are built for
SPARSITY_PATTERNS = [(2, 4), (4, 8), (8, 16), (16, 32)]
OUTLIER_PATTERNS = [(4, 256), (8, 256), (16, 256)]
