//! Evaluation harnesses: perplexity and zero-shot multiple-choice
//! accuracy — the two metrics every table of the paper reports.
//!
//! Both harnesses are generic over [`NllModel`], the one-method contract
//! "score a `(B, S+1)` token window": the PJRT artifact path
//! ([`PjrtModel`]) and the offline decode-free packed path
//! ([`crate::model::SparseLm`]) plug in interchangeably, so eval results
//! can be produced with packed weights staying packed end-to-end.
//!
//! Generation rides on the same contract: [`sample`] provides the token
//! pickers ([`Sampler`] — greedy / temperature softmax) the decode
//! engine uses, and [`continuation_nll`] scores generated continuations
//! back through an [`NllModel`] window.

mod ppl;
pub mod sample;
mod zeroshot;

pub use ppl::{perplexity, perplexity_model, PplReport};
pub use sample::{argmax, continuation_nll, softmax_sample, Sampler};
pub use zeroshot::{
    eval_task, eval_task_model, zero_shot_accuracy, zero_shot_accuracy_model, TaskReport,
    ZeroShotReport,
};

use crate::coordinator::{ModelExec, ParamLiterals};
use crate::model::SparseLm;
use crate::tensor::Tensor;

/// A language model that can score token windows — the only capability
/// the eval harnesses (and the serve scorer) need.
pub trait NllModel {
    /// Batch rows per scoring call (the window's B).
    fn batch(&self) -> usize;
    /// Scored positions per row (the window's S; windows are S+1 ids).
    fn seq(&self) -> usize;
    /// Per-token negative log-likelihood of a flat `(B, S+1)` window,
    /// returned as a `(B, S)` tensor.
    fn lm_nll(&self, tokens: &[i32]) -> crate::Result<Tensor>;
}

/// The artifact-backed scorer: `lm_nll` HLO over device-resident params.
pub struct PjrtModel<'a> {
    pub exec: &'a ModelExec,
    pub params: &'a ParamLiterals,
}

impl NllModel for PjrtModel<'_> {
    fn batch(&self) -> usize {
        self.exec.config.batch
    }

    fn seq(&self) -> usize {
        self.exec.config.seq
    }

    fn lm_nll(&self, tokens: &[i32]) -> crate::Result<Tensor> {
        self.exec.lm_nll(self.params, tokens)
    }
}

impl NllModel for SparseLm {
    fn batch(&self) -> usize {
        self.config.batch
    }

    fn seq(&self) -> usize {
        self.config.seq
    }

    fn lm_nll(&self, tokens: &[i32]) -> crate::Result<Tensor> {
        // inherent method — the host forward over kernel-backed linears
        SparseLm::lm_nll(self, tokens)
    }
}
