//! Evaluation harnesses: perplexity and zero-shot multiple-choice
//! accuracy — the two metrics every table of the paper reports.

mod ppl;
mod zeroshot;

pub use ppl::{perplexity, PplReport};
pub use zeroshot::{zero_shot_accuracy, TaskReport, ZeroShotReport};
