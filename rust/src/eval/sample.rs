//! Token sampling for autoregressive decoding, plus a scoring hook that
//! closes the loop with the eval harnesses.
//!
//! The generation engine ([`crate::model::SparseLm::decode_step`], the
//! `serve` continuous-batching scheduler) is sampling-agnostic: it hands
//! a logits row to a picker. This module provides the pickers —
//! deterministic greedy argmax and temperature softmax over a seeded
//! [`Rng`] — and [`continuation_nll`], which scores a generated
//! continuation through any [`super::NllModel`] window (the same
//! `pack_windows` convention the scorer and zero-shot harness use), so
//! generated text can be ranked by the very model that produced it.

use crate::data::batch::pack_windows;
use crate::util::Rng;

/// Greedy argmax with the lowest-index tie rule (deterministic across
/// backends — ties break the same way however the logits were computed).
pub fn argmax(logits: &[f32]) -> usize {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Sample from `softmax(logits / temperature)` — numerically stable
/// (max-shifted), exact inverse-CDF walk over the seeded [`Rng`].
pub fn softmax_sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    debug_assert!(temperature > 0.0);
    let inv_t = 1.0 / temperature as f64;
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x)) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&l| ((l as f64 - mx) * inv_t).exp())
        .collect();
    rng.categorical(&weights)
}

/// A reusable picker: greedy at `temperature == 0`, seeded softmax
/// sampling otherwise. One `Sampler` per sequence keeps generation
/// reproducible from `(seed, prompt)` regardless of what else shares
/// the decode batch.
#[derive(Clone, Debug)]
pub struct Sampler {
    pub temperature: f32,
    rng: Rng,
}

impl Sampler {
    pub fn new(temperature: f32, seed: u64) -> Sampler {
        assert!(temperature >= 0.0, "temperature must be >= 0");
        Sampler {
            temperature,
            rng: Rng::new(seed),
        }
    }

    /// Deterministic argmax picker.
    pub fn greedy() -> Sampler {
        Sampler::new(0.0, 0)
    }

    /// Pick the next token id from a logits row.
    pub fn next(&mut self, logits: &[f32]) -> usize {
        if self.temperature == 0.0 {
            argmax(logits)
        } else {
            softmax_sample(logits, self.temperature, &mut self.rng)
        }
    }
}

/// Mean NLL the served model assigns to `continuation` given `prompt` —
/// generated text scored back through the standard `(B, S+1)` eval
/// window of any [`super::NllModel`] (PJRT or packed host forward).
/// Returns `(mean_nll, scored_tokens)`.
pub fn continuation_nll(
    model: &impl super::NllModel,
    prompt: &[i32],
    continuation: &[i32],
) -> crate::Result<(f64, usize)> {
    anyhow::ensure!(!continuation.is_empty(), "empty continuation");
    let mut ids = Vec::with_capacity(prompt.len() + continuation.len());
    ids.extend_from_slice(prompt);
    ids.extend_from_slice(continuation);
    let (b, s) = (model.batch(), model.seq());
    let items = vec![(ids, prompt.len())];
    let (window, mask) = pack_windows(&items, b, s);
    let nll = model.lm_nll(&window)?;
    let row = &nll.data()[..s];
    let mrow = &mask[..s];
    let sum: f64 = row
        .iter()
        .zip(mrow)
        .map(|(&n, &m)| n as f64 * m as f64)
        .sum();
    let count = mrow.iter().filter(|&&m| m != 0.0).count();
    anyhow::ensure!(count > 0, "continuation fell outside the scoring window");
    Ok((sum / count as f64, count))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ParamSet, SparseLm};

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn greedy_sampler_is_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.next(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(s.next(&[2.0, 0.9, 0.5]), 0);
    }

    #[test]
    fn softmax_sampling_tracks_distribution_and_seed() {
        // strongly peaked logits: the mode dominates at T=1
        let logits = [0.0f32, 6.0, 0.0, 0.0];
        let mut a = Sampler::new(1.0, 7);
        let mut b = Sampler::new(1.0, 7);
        let mut mode = 0;
        for _ in 0..200 {
            let x = a.next(&logits);
            assert_eq!(x, b.next(&logits), "same seed, same stream");
            if x == 1 {
                mode += 1;
            }
        }
        assert!(mode > 150, "mode sampled {mode}/200");
        // high temperature flattens: all ids appear
        let mut hot = Sampler::new(50.0, 11);
        let seen: std::collections::HashSet<usize> =
            (0..400).map(|_| hot.next(&logits)).collect();
        assert_eq!(seen.len(), logits.len());
    }

    #[test]
    fn continuation_nll_scores_only_the_continuation() {
        let mut cfg = ModelConfig::preset("tiny").unwrap();
        cfg.seq = 16;
        cfg.batch = 2;
        cfg.vocab = 256;
        let mut rng = crate::util::Rng::new(3);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let prompt = vec![5, 6, 7];
        let cont = vec![8, 9];
        let (mean, count) = continuation_nll(&lm, &prompt, &cont).unwrap();
        assert_eq!(count, cont.len());
        assert!(mean.is_finite() && mean > 0.0);
        assert!(continuation_nll(&lm, &prompt, &[]).is_err());
    }
}
