//! Zero-shot multiple-choice accuracy with LM log-likelihood scoring —
//! the lm-eval-harness protocol used for the paper's ARC-e/ARC-c/PIQA/
//! Winogrande/HellaSwag numbers (Tables 2/3/8).
//!
//! Each candidate completion is appended to the context; the candidate
//! with the lowest *length-normalized* NLL over its completion tokens
//! wins.  Items are packed into fixed-shape (B, S+1) batches (the aot
//! graphs have static shapes), several choices per batch row. Scoring is
//! generic over [`NllModel`], so the same harness runs against PJRT
//! artifacts or the decode-free packed host forward.

use super::{NllModel, PjrtModel};
use crate::coordinator::{ModelExec, ParamLiterals};
use crate::data::batch::pack_windows;
use crate::data::tasks::{McItem, TaskKind, ALL_TASKS};
use crate::data::{Tokenizer, World};

#[derive(Clone, Debug)]
pub struct TaskReport {
    pub task: &'static str,
    pub accuracy: f64,
    pub n_items: usize,
    pub chance: f64,
}

#[derive(Clone, Debug)]
pub struct ZeroShotReport {
    pub tasks: Vec<TaskReport>,
}

impl ZeroShotReport {
    /// Mean accuracy across tasks — the headline number of Tables 2/3/8.
    pub fn mean_accuracy(&self) -> f64 {
        if self.tasks.is_empty() {
            return 0.0;
        }
        self.tasks.iter().map(|t| t.accuracy).sum::<f64>() / self.tasks.len() as f64
    }
}

/// Score one item: per-choice length-normalized NLL.
fn score_item(model: &dyn NllModel, tok: &Tokenizer, item: &McItem) -> crate::Result<usize> {
    let (b, s) = (model.batch(), model.seq());
    // encode every choice as (ids, scored_from)
    let mut encoded: Vec<(Vec<i32>, usize)> = Vec::with_capacity(item.choices.len());
    for choice in &item.choices {
        let ctx = tok.encode(&item.context);
        let full = format!("{} {}", item.context, choice);
        let mut ids = vec![crate::data::tokenizer::BOS];
        ids.extend(tok.encode(&full));
        let scored_from = 1 + ctx.len();
        encoded.push((ids, scored_from));
    }
    // pack into as few (B, S+1) executions as needed
    let mut nlls = Vec::with_capacity(encoded.len());
    for chunk in encoded.chunks(b) {
        let (ids, mask) = pack_windows(chunk, b, s);
        let nll = model.lm_nll(&ids)?;
        for (r, _) in chunk.iter().enumerate() {
            let row = &nll.data()[r * s..(r + 1) * s];
            let mrow = &mask[r * s..(r + 1) * s];
            let total: f64 = row
                .iter()
                .zip(mrow)
                .map(|(&n, &m)| n as f64 * m as f64)
                .sum();
            let count: f64 = mrow.iter().map(|&m| m as f64).sum();
            nlls.push(if count > 0.0 { total / count } else { f64::INFINITY });
        }
    }
    let best = nlls
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    Ok(best)
}

/// Run one task suite against any scorer.
pub fn eval_task_model(
    model: &dyn NllModel,
    tok: &Tokenizer,
    world: &World,
    task: TaskKind,
    n_items: usize,
    seed: u64,
) -> crate::Result<TaskReport> {
    let items = task.generate(world, n_items, seed);
    let mut correct = 0usize;
    for item in &items {
        if score_item(model, tok, item)? == item.answer {
            correct += 1;
        }
    }
    Ok(TaskReport {
        task: task.label(),
        accuracy: correct as f64 / n_items.max(1) as f64,
        n_items,
        chance: 1.0 / task.n_choices() as f64,
    })
}

/// Run one task suite through the PJRT artifact path.
pub fn eval_task(
    exec: &ModelExec,
    params: &ParamLiterals,
    tok: &Tokenizer,
    world: &World,
    task: TaskKind,
    n_items: usize,
    seed: u64,
) -> crate::Result<TaskReport> {
    eval_task_model(&PjrtModel { exec, params }, tok, world, task, n_items, seed)
}

/// All five suites against any scorer; `n_items` each.
pub fn zero_shot_accuracy_model(
    model: &dyn NllModel,
    tok: &Tokenizer,
    world: &World,
    n_items: usize,
    seed: u64,
) -> crate::Result<ZeroShotReport> {
    let mut tasks = Vec::new();
    for task in ALL_TASKS {
        tasks.push(eval_task_model(model, tok, world, task, n_items, seed)?);
    }
    Ok(ZeroShotReport { tasks })
}

/// All five suites through the PJRT artifact path; `n_items` each.
pub fn zero_shot_accuracy(
    exec: &ModelExec,
    params: &ParamLiterals,
    tok: &Tokenizer,
    world: &World,
    n_items: usize,
    seed: u64,
) -> crate::Result<ZeroShotReport> {
    zero_shot_accuracy_model(&PjrtModel { exec, params }, tok, world, n_items, seed)
}
