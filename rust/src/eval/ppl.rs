//! Perplexity: exp(mean per-token NLL) over deterministic
//! non-overlapping windows of a held-out stream — the WikiText-2/C4
//! protocol of the paper's Tables 1/4/5/6.
//!
//! Backend-agnostic: [`perplexity_model`] drives any [`NllModel`]
//! (PJRT artifacts or the decode-free packed host forward);
//! [`perplexity`] is the artifact-path convenience wrapper.

use super::{NllModel, PjrtModel};
use crate::coordinator::{ModelExec, ParamLiterals};
use crate::data::TokenStream;

#[derive(Clone, Debug)]
pub struct PplReport {
    pub ppl: f64,
    pub mean_nll: f64,
    pub tokens: usize,
    pub batches: usize,
}

/// Evaluate perplexity of any scorer on up to `max_batches` windows.
pub fn perplexity_model(
    model: &dyn NllModel,
    stream: &TokenStream,
    max_batches: usize,
) -> crate::Result<PplReport> {
    let (b, s) = (model.batch(), model.seq());
    let batches = stream.eval_batches(b, s, max_batches);
    anyhow::ensure!(!batches.is_empty(), "stream too short for evaluation");
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for batch in &batches {
        let nll = model.lm_nll(batch)?;
        total_nll += nll.sum();
        total_tokens += nll.len();
    }
    let mean = total_nll / total_tokens as f64;
    Ok(PplReport {
        ppl: mean.exp(),
        mean_nll: mean,
        tokens: total_tokens,
        batches: batches.len(),
    })
}

/// Evaluate perplexity of `params` through the PJRT artifact path.
pub fn perplexity(
    exec: &ModelExec,
    params: &ParamLiterals,
    stream: &TokenStream,
    max_batches: usize,
) -> crate::Result<PplReport> {
    perplexity_model(&PjrtModel { exec, params }, stream, max_batches)
}
