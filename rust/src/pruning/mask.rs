//! Exact top-N per (1, M) block mask selection — host mirror of the
//! `nm_prune` Pallas kernel, including its tie semantics (stable
//! descending order: the earlier index wins ties).

use crate::tensor::Tensor;

/// Keep the `n` highest-scoring entries of every `(1, m)` block.
pub fn mask_topn_per_block(score: &Tensor, n: usize, m: usize) -> Tensor {
    let (rows, cols) = score.dims2();
    assert!(cols % m == 0, "cols {cols} not divisible by m {m}");
    assert!(n <= m);
    let mut out = vec![0.0f32; rows * cols];
    // (perf) selection instead of a full stable sort: keep the running
    // top-n in a tiny insertion buffer — blocks are small (m ≤ 256, and
    // n ≤ m), and the stable-descending tie rule ("earlier index wins",
    // matching jnp.argsort(-s, stable=True)) falls out of strict `>`
    // comparisons during insertion. ~3× faster than sort_by on the
    // per-layer prune hot path (EXPERIMENTS.md §Perf).
    let mut top: Vec<usize> = Vec::with_capacity(n);
    for r in 0..rows {
        let srow = score.row(r);
        for b in 0..cols / m {
            let blk = &srow[b * m..(b + 1) * m];
            top.clear();
            for j in 0..m {
                let s = blk[j];
                if top.len() == n {
                    // full: compare against the current minimum (last)
                    if !(s > blk[top[n - 1]]) {
                        continue;
                    }
                    top.pop();
                }
                // insert j before the first strictly-smaller entry,
                // after any equal entry (stable: earlier index first)
                let pos = top.partition_point(|&k| blk[k] >= s);
                top.insert(pos, j);
            }
            for &i in &top {
                out[r * cols + b * m + i] = 1.0;
            }
        }
    }
    Tensor::new(vec![rows, cols], out)
}

/// N:M selection with already-salient positions excluded from the budget:
/// their score is treated as -inf, and they are never kept (mirrors
/// `mask_excluding_graph`).
pub fn mask_excluding(score: &Tensor, excl: &Tensor, n: usize, m: usize) -> Tensor {
    assert_eq!(score.shape(), excl.shape());
    let masked = score.zip(excl, |s, e| if e > 0.0 { f32::NEG_INFINITY } else { s });
    let keep = mask_topn_per_block(&masked, n, m);
    keep.zip(excl, |k, e| k * (1.0 - e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};
    use crate::util::Rng;

    #[test]
    fn exact_budget() {
        let mut rng = Rng::new(1);
        let s = Tensor::randn(vec![16, 128], 1.0, &mut rng);
        for (n, m) in [(2usize, 4usize), (4, 8), (8, 16), (16, 32)] {
            let mask = mask_topn_per_block(&s, n, m);
            for r in 0..16 {
                for b in 0..128 / m {
                    let cnt = mask.row(r)[b * m..(b + 1) * m]
                        .iter()
                        .filter(|&&x| x != 0.0)
                        .count();
                    assert_eq!(cnt, n);
                }
            }
        }
    }

    #[test]
    fn keeps_largest() {
        let s = Tensor::new(vec![1, 4], vec![0.1, 0.9, 0.5, 0.2]);
        let mask = mask_topn_per_block(&s, 2, 4);
        assert_eq!(mask.data(), &[0., 1., 1., 0.]);
    }

    #[test]
    fn tie_break_prefers_earlier_index() {
        let s = Tensor::ones(vec![1, 16]);
        let mask = mask_topn_per_block(&s, 8, 16);
        let want: Vec<f32> = (0..16).map(|i| if i < 8 { 1.0 } else { 0.0 }).collect();
        assert_eq!(mask.data(), &want[..]);
    }

    #[test]
    fn excluding_never_keeps_salient() {
        let mut rng = Rng::new(3);
        let s = Tensor::randn(vec![8, 256], 1.0, &mut rng).map(f32::abs);
        let excl = mask_topn_per_block(&s, 16, 256);
        let keep = mask_excluding(&s, &excl, 8, 16);
        for (k, e) in keep.data().iter().zip(excl.data()) {
            assert!(!(*k != 0.0 && *e != 0.0));
        }
    }

    #[test]
    fn excluding_budget_adapts() {
        // if a 16-block is fully salient, nothing else is kept there
        let s = Tensor::ones(vec![1, 32]);
        let mut e = vec![0.0f32; 32];
        for j in 0..16 {
            e[j] = 1.0;
        }
        let excl = Tensor::new(vec![1, 32], e);
        let keep = mask_excluding(&s, &excl, 8, 16);
        let first: f32 = keep.data()[..16].iter().sum();
        let second: f32 = keep.data()[16..].iter().sum();
        assert_eq!(first, 0.0);
        assert_eq!(second, 8.0);
    }

    #[test]
    fn property_mask_matches_sort_definition() {
        check("mask keeps exactly the top-n", 30, |g: &mut Gen| {
            let (n, m) = *g.choose(&[(2usize, 4usize), (4, 8), (8, 16)]);
            let rows = g.int(1, 8);
            let blocks = g.int(1, 6);
            let cols = blocks * m;
            let s = Tensor::new(vec![rows, cols], g.vec_normal(rows * cols));
            let mask = mask_topn_per_block(&s, n, m);
            for r in 0..rows {
                for b in 0..blocks {
                    let blk = &s.row(r)[b * m..(b + 1) * m];
                    let mblk = &mask.row(r)[b * m..(b + 1) * m];
                    let kept_min = blk
                        .iter()
                        .zip(mblk)
                        .filter(|(_, &k)| k != 0.0)
                        .fold(f32::INFINITY, |a, (&x, _)| a.min(x));
                    let drop_max = blk
                        .iter()
                        .zip(mblk)
                        .filter(|(_, &k)| k == 0.0)
                        .fold(f32::NEG_INFINITY, |a, (&x, _)| a.max(x));
                    if kept_min < drop_max {
                        return Err(format!(
                            "block ({r},{b}): kept {kept_min} < dropped {drop_max}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
