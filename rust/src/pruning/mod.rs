//! Rust mirrors of the pruning math (L1 kernels have the same semantics).
//!
//! The coordinator normally runs scoring/masking through the Pallas HLO
//! artifacts; these host implementations serve three purposes: (1) they
//! cross-validate the artifacts in integration tests (same numbers from
//! two independent implementations), (2) they let unit tests and benches
//! run without PJRT, and (3) they prune matrices whose shapes have no
//! exported artifact.
//!
//! Semantics are locked to `python/compile/kernels/ref.py` — including tie
//! handling (stable descending order, earlier index wins).

mod mask;
pub mod owl;
mod score;
pub mod sparsegpt;
mod sq;
mod vc;

pub use mask::{mask_excluding, mask_topn_per_block};
pub use owl::{layer_outlier_distribution, owl_allocate, LayerOutlierStats, OwlAllocation};
pub use score::{magnitude_score, ria_score, wanda_score, PruneMethod};
pub use sparsegpt::{sparsegpt_prune, Hessian, SparseGptConfig, SparseGptResult};
pub use sq::{equalize, sq_scales};
pub use vc::{variance_correct, VcMode, VC_EPS};

use crate::tensor::Tensor;

pub const DEFAULT_ALPHA: f32 = 0.5;

/// Everything the scoring path needs to know about a layer's input
/// activations, accumulated over the calibration set.
#[derive(Clone, Debug)]
pub struct ActStats {
    /// per-channel max |x| (SmoothQuant statistic)
    pub colmax: Vec<f32>,
    /// per-channel L2 norm (RIA/Wanda statistic)
    pub l2: Vec<f32>,
}

impl ActStats {
    pub fn new(cols: usize) -> Self {
        ActStats {
            colmax: vec![0.0; cols],
            l2: vec![0.0; cols],
        }
    }

    /// Fold another batch's statistics in (max for colmax, RMS-combine
    /// for l2: norms over concatenated batches compose as sqrt(a²+b²)).
    pub fn merge(&mut self, colmax: &[f32], l2: &[f32]) {
        assert_eq!(self.colmax.len(), colmax.len());
        for (a, &b) in self.colmax.iter_mut().zip(colmax) {
            *a = a.max(b);
        }
        for (a, &b) in self.l2.iter_mut().zip(l2) {
            *a = (*a * *a + b * b).sqrt();
        }
    }

    /// Uniform statistics (used when calibration is disabled).
    pub fn uniform(cols: usize) -> Self {
        ActStats {
            colmax: vec![1.0; cols],
            l2: vec![1.0; cols],
        }
    }
}

/// Configuration of one prune pass over one weight matrix (§4 pipeline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PruneSpec {
    pub method: PruneMethod,
    /// N:M pattern for non-salient weights
    pub n: usize,
    pub m: usize,
    /// structured outlier pattern (k per 256); 0 disables outlier recovery
    pub k_outlier: usize,
    pub m_outlier: usize,
    pub use_sq: bool,
    pub use_vc: bool,
    pub alpha: f32,
}

impl PruneSpec {
    pub fn new(n: usize, m: usize) -> Self {
        PruneSpec {
            method: PruneMethod::Ria,
            n,
            m,
            k_outlier: 0,
            m_outlier: 256,
            use_sq: true,
            use_vc: true,
            alpha: DEFAULT_ALPHA,
        }
    }

    pub fn method(mut self, method: PruneMethod) -> Self {
        self.method = method;
        self
    }

    pub fn outliers(mut self, k: usize) -> Self {
        self.k_outlier = k;
        self
    }

    pub fn sq(mut self, on: bool) -> Self {
        self.use_sq = on;
        self
    }

    pub fn vc(mut self, on: bool) -> Self {
        self.use_vc = on;
        self
    }

    pub fn label(&self) -> String {
        let mut s = format!("{:?}", self.method).to_lowercase();
        if self.use_sq {
            s.push_str("+sq");
        }
        if self.use_vc {
            s.push_str("+vc");
        }
        s.push_str(&format!(" {}:{}", self.n, self.m));
        if self.k_outlier > 0 {
            s.push_str(&format!(" o{}:{}", self.k_outlier, self.m_outlier));
        }
        s
    }
}

/// Output of a per-layer prune: the corrected non-salient weights, the
/// keep mask, and the salient mask (`w_eff = w_ns + w * omask`).
pub struct PruneResult {
    pub w_ns: Tensor,
    pub keep: Tensor,
    pub omask: Tensor,
}

/// Host-side reference implementation of the full §4 per-layer pipeline —
/// mirrors `prune_layer_ref` in the Python oracle exactly.
pub fn prune_layer(w: &Tensor, stats: &ActStats, spec: &PruneSpec) -> PruneResult {
    let w_metric = if spec.use_sq {
        equalize(w, &stats.colmax)
    } else {
        w.clone()
    };
    let score = match spec.method {
        PruneMethod::Ria => ria_score(&w_metric, &stats.l2, spec.alpha),
        PruneMethod::Magnitude => magnitude_score(&w_metric),
        PruneMethod::Wanda => wanda_score(&w_metric, &stats.l2),
    };

    let (rows, cols) = w.dims2();
    let omask = if spec.k_outlier > 0 {
        mask_topn_per_block(&score, spec.k_outlier, spec.m_outlier)
    } else {
        Tensor::zeros(vec![rows, cols])
    };

    let keep = mask_excluding(&score, &omask, spec.n, spec.m);
    let mut w_ns = w.mul(&keep);
    if spec.use_vc {
        let dense_ref = w.zip(&omask, |x, o| x * (1.0 - o));
        w_ns = variance_correct(&w_ns, &dense_ref, VcMode::Global);
    }
    PruneResult { w_ns, keep, omask }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup(rows: usize, cols: usize) -> (Tensor, ActStats) {
        let mut rng = Rng::new(77);
        let w = Tensor::randn_outliers(vec![rows, cols], 0.05, 0.01, 8.0, &mut rng);
        let mut stats = ActStats::new(cols);
        let colmax: Vec<f32> = (0..cols).map(|_| rng.f32() * 3.0 + 0.1).collect();
        let l2: Vec<f32> = (0..cols).map(|_| rng.f32() * 5.0 + 0.1).collect();
        stats.merge(&colmax, &l2);
        (w, stats)
    }

    #[test]
    fn budget_no_outliers() {
        let (w, stats) = setup(32, 512);
        let spec = PruneSpec::new(8, 16);
        let r = prune_layer(&w, &stats, &spec);
        let kept = r.keep.data().iter().filter(|&&x| x != 0.0).count();
        assert_eq!(kept, 32 * 512 / 2);
        assert_eq!(r.omask.count_nonzero(), 0);
    }

    #[test]
    fn budget_with_outliers_disjoint() {
        let (w, stats) = setup(32, 512);
        let spec = PruneSpec::new(2, 4).outliers(8);
        let r = prune_layer(&w, &stats, &spec);
        // salient and kept never overlap
        let overlap = r
            .keep
            .data()
            .iter()
            .zip(r.omask.data())
            .filter(|(&k, &o)| k != 0.0 && o != 0.0)
            .count();
        assert_eq!(overlap, 0);
        assert_eq!(r.omask.count_nonzero(), 32 * 2 * 8);
    }

    #[test]
    fn vc_restores_variance_scale() {
        let (w, stats) = setup(64, 512);
        let with = prune_layer(&w, &stats, &PruneSpec::new(2, 4).vc(true));
        let without = prune_layer(&w, &stats, &PruneSpec::new(2, 4).vc(false));
        let var_d = w.var();
        let dv_with = (with.w_ns.var() - var_d).abs();
        let dv_without = (without.w_ns.var() - var_d).abs();
        assert!(dv_with < dv_without, "{dv_with} !< {dv_without}");
    }

    #[test]
    fn methods_give_different_masks() {
        let (w, stats) = setup(32, 512);
        let a = prune_layer(&w, &stats, &PruneSpec::new(8, 16).method(PruneMethod::Ria));
        let b = prune_layer(
            &w,
            &stats,
            &PruneSpec::new(8, 16).method(PruneMethod::Magnitude).sq(false),
        );
        assert_ne!(a.keep, b.keep);
    }

    #[test]
    fn act_stats_merge_semantics() {
        let mut s = ActStats::new(2);
        s.merge(&[1.0, 5.0], &[3.0, 4.0]);
        s.merge(&[2.0, 1.0], &[4.0, 3.0]);
        assert_eq!(s.colmax, vec![2.0, 5.0]);
        assert!((s.l2[0] - 5.0).abs() < 1e-6);
        assert!((s.l2[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn spec_labels() {
        assert_eq!(PruneSpec::new(8, 16).label(), "ria+sq+vc 8:16");
        assert_eq!(
            PruneSpec::new(2, 4).sq(false).vc(false).outliers(4).label(),
            "ria 2:4 o4:256"
        );
    }
}
