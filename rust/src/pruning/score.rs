//! Importance scoring: magnitude, Wanda, and RIA — semantics locked to
//! `python/compile/kernels/ref.py`.

use crate::tensor::{col_abssum, col_l2 as _col_l2, row_abssum, Tensor};

// re-export guard so the unused import lint stays quiet if col_l2 usage moves
#[allow(unused_imports)]
use _col_l2 as col_l2_stat;

/// Pruning importance metric (paper baselines + RIA).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PruneMethod {
    Magnitude,
    Wanda,
    Ria,
}

impl PruneMethod {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "magnitude" | "mag" => Some(PruneMethod::Magnitude),
            "wanda" => Some(PruneMethod::Wanda),
            "ria" => Some(PruneMethod::Ria),
            _ => None,
        }
    }
}

/// `|W|` — magnitude baseline (Table 4/5).
pub fn magnitude_score(w: &Tensor) -> Tensor {
    w.map(f32::abs)
}

/// `|W| * ||x_j||_2` — Wanda (Sun et al., 2023).
pub fn wanda_score(w: &Tensor, act_l2: &[f32]) -> Tensor {
    let (rows, cols) = w.dims2();
    assert_eq!(cols, act_l2.len());
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = w.row(r);
        for c in 0..cols {
            out[r * cols + c] = row[c].abs() * act_l2[c];
        }
    }
    Tensor::new(vec![rows, cols], out)
}

/// RIA (Zhang et al., 2024):
/// `(|W_ij|/rowsum_i + |W_ij|/colsum_j) * act_l2_j^alpha`.
pub fn ria_score(w: &Tensor, act_l2: &[f32], alpha: f32) -> Tensor {
    let (rows, cols) = w.dims2();
    assert_eq!(cols, act_l2.len());
    let rowsum = row_abssum(w);
    let colsum = col_abssum(w);
    let act: Vec<f32> = act_l2.iter().map(|&a| a.max(0.0).powf(alpha)).collect();
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = w.row(r);
        let rs = if rowsum[r] > 0.0 { rowsum[r] } else { 1.0 };
        for c in 0..cols {
            let cs = if colsum[c] > 0.0 { colsum[c] } else { 1.0 };
            let aw = row[c].abs();
            out[r * cols + c] = (aw / rs + aw / cs) * act[c];
        }
    }
    Tensor::new(vec![rows, cols], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn magnitude_is_abs() {
        let w = Tensor::new(vec![1, 4], vec![-3., 1., -2., 0.]);
        assert_eq!(magnitude_score(&w).data(), &[3., 1., 2., 0.]);
    }

    #[test]
    fn wanda_scales_by_activation() {
        let w = Tensor::new(vec![1, 2], vec![2., 2.]);
        let s = wanda_score(&w, &[1.0, 3.0]);
        assert_eq!(s.data(), &[2., 6.]);
    }

    #[test]
    fn ria_relative_importance() {
        // row [3, 1]: rowsum 4; cols sums 3 and 1 => both elems score
        // 3/4 + 3/3 = 1.75 and 1/4 + 1/1 = 1.25 with unit activations
        let w = Tensor::new(vec![1, 2], vec![3., 1.]);
        let s = ria_score(&w, &[1.0, 1.0], 0.5);
        assert!((s.data()[0] - 1.75).abs() < 1e-6);
        assert!((s.data()[1] - 1.25).abs() < 1e-6);
    }

    #[test]
    fn ria_zero_row_guard() {
        let w = Tensor::new(vec![2, 2], vec![0., 0., 1., 1.]);
        let s = ria_score(&w, &[1.0, 1.0], 0.5);
        assert!(s.data().iter().all(|x| x.is_finite()));
        assert_eq!(s.data()[0], 0.0);
    }

    #[test]
    fn ria_alpha_zero_ignores_activations() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(vec![8, 16], 1.0, &mut rng);
        let a = ria_score(&w, &[1.0; 16], 0.0);
        let big: Vec<f32> = (0..16).map(|i| (i + 1) as f32).collect();
        let b = ria_score(&w, &big, 0.0);
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn method_parse() {
        assert_eq!(PruneMethod::parse("RIA"), Some(PruneMethod::Ria));
        assert_eq!(PruneMethod::parse("mag"), Some(PruneMethod::Magnitude));
        assert_eq!(PruneMethod::parse("wanda"), Some(PruneMethod::Wanda));
        assert_eq!(PruneMethod::parse("x"), None);
    }
}
