//! OWL — Outlier-Weighed Layerwise sparsity (Yin et al. 2023).
//!
//! The paper's related work observes that uniform per-layer sparsity is
//! suboptimal: layers whose weight distributions carry more outliers are
//! more damaged by pruning. OWL measures a per-layer **Layer Outlier
//! Distribution** (the fraction of entries whose magnitude exceeds
//! `theta ×` the layer mean |W|) and assigns *lower* sparsity to
//! outlier-heavy layers while holding the global budget fixed.
//!
//! In the N:M world of this paper the allocation is over patterns with a
//! shared `M`: each layer gets `n_l : M` where `Σ n_l·size_l / (M·Σ size_l)`
//! equals the target keep fraction. [`owl_allocate`] performs the
//! water-filling; the ablation bench `a1_owl` contrasts it with uniform
//! N:M.

use crate::tensor::Tensor;

/// Per-layer outlier statistics driving the allocation.
#[derive(Clone, Debug)]
pub struct LayerOutlierStats {
    /// layer label (diagnostics only)
    pub name: String,
    /// number of weight entries
    pub size: usize,
    /// fraction of entries with |w| > theta * mean|w|
    pub lod: f64,
}

/// Compute the Layer Outlier Distribution of one weight matrix:
/// `mean(|w| > theta * mean(|w|))` — OWL's D_i statistic.
pub fn layer_outlier_distribution(w: &Tensor, theta: f32) -> f64 {
    assert!(theta > 0.0, "theta must be positive");
    let mean_abs = w.data().iter().map(|x| x.abs() as f64).sum::<f64>()
        / w.len().max(1) as f64;
    let thr = theta as f64 * mean_abs;
    if w.is_empty() {
        return 0.0;
    }
    w.data().iter().filter(|x| (x.abs() as f64) > thr).count() as f64 / w.len() as f64
}

/// One layer's allocation result.
#[derive(Clone, Debug, PartialEq)]
pub struct OwlAllocation {
    pub name: String,
    /// kept values per M-block for this layer
    pub n: usize,
    pub m: usize,
}

/// Allocate per-layer `n_l : m` patterns from outlier statistics.
///
/// Layers are granted keep-slots proportional to
/// `target_keep + lambda * (lod_l - mean_lod)` (OWL's shifted allocation),
/// clamped to `[n_min, m]`, then greedily adjusted ±1 slot at a time —
/// moving the layer with the largest rounding slack — until the exact
/// global weight budget `round(target_keep * Σ size)` is met.
pub fn owl_allocate(
    stats: &[LayerOutlierStats],
    m: usize,
    target_keep: f64,
    lambda: f64,
    n_min: usize,
) -> Vec<OwlAllocation> {
    assert!(m > 0 && n_min <= m);
    assert!(
        (0.0..=1.0).contains(&target_keep),
        "target_keep {target_keep} out of range"
    );
    if stats.is_empty() {
        return Vec::new();
    }
    let mean_lod = stats.iter().map(|s| s.lod).sum::<f64>() / stats.len() as f64;
    let total: usize = stats.iter().map(|s| s.size).sum();
    let budget_slots = (target_keep * total as f64).round() as i64;

    // ideal fractional keep per layer, clamped
    let ideal: Vec<f64> = stats
        .iter()
        .map(|s| {
            let k = target_keep + lambda * (s.lod - mean_lod);
            k.clamp(n_min as f64 / m as f64, 1.0)
        })
        .collect();
    // integer n per layer by rounding
    let mut ns: Vec<i64> = ideal
        .iter()
        .map(|&k| ((k * m as f64).round() as i64).clamp(n_min as i64, m as i64))
        .collect();

    let slots = |ns: &[i64]| -> i64 {
        ns.iter()
            .zip(stats)
            .map(|(&n, s)| n * (s.size / m) as i64)
            .sum()
    };

    // greedy repair toward the exact global budget: each step applies the
    // single ±1 move that most reduces the absolute slot residual (ties
    // broken toward the layer whose fractional ideal most wants the
    // move). Residual strictly decreases, so this terminates.
    loop {
        let res = slots(&ns) - budget_slots;
        if res == 0 {
            break;
        }
        let mut best: Option<(i64, f64, usize, i64)> = None; // (|new res|, want, layer, dir)
        for (i, &n) in ns.iter().enumerate() {
            let blocks = (stats[i].size / m) as i64;
            for dir in [-1i64, 1] {
                let nn = n + dir;
                if nn < n_min as i64 || nn > m as i64 {
                    continue;
                }
                let new_res = (res + dir * blocks).abs();
                if new_res >= res.abs() {
                    continue; // only strictly-improving moves
                }
                let want = (ideal[i] * m as f64 - n as f64) * dir as f64;
                let better = match best {
                    None => true,
                    Some((br, bw, _, _)) => new_res < br || (new_res == br && want > bw),
                };
                if better {
                    best = Some((new_res, want, i, dir));
                }
            }
        }
        match best {
            Some((_, _, i, dir)) => ns[i] += dir,
            None => break, // no improving move: budget unreachable exactly
        }
    }

    stats
        .iter()
        .zip(ns)
        .map(|(s, n)| OwlAllocation {
            name: s.name.clone(),
            n: n as usize,
            m,
        })
        .collect()
}

/// Realized global keep fraction of an allocation.
pub fn realized_keep(allocs: &[OwlAllocation], stats: &[LayerOutlierStats]) -> f64 {
    let total: usize = stats.iter().map(|s| s.size).sum();
    if total == 0 {
        return 0.0;
    }
    let kept: f64 = allocs
        .iter()
        .zip(stats)
        .map(|(a, s)| (a.n as f64 / a.m as f64) * s.size as f64)
        .sum();
    kept / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk_stats(lods: &[f64]) -> Vec<LayerOutlierStats> {
        lods.iter()
            .enumerate()
            .map(|(i, &lod)| LayerOutlierStats {
                name: format!("layer{i}"),
                size: 16 * 256,
                lod,
            })
            .collect()
    }

    #[test]
    fn lod_of_gaussian_matches_tail_mass() {
        let mut rng = Rng::new(31);
        let w = Tensor::randn(vec![200, 500], 1.0, &mut rng);
        // mean|N(0,1)| = sqrt(2/pi) ≈ 0.7979; P(|x| > 3*0.798) ≈ 0.0167
        let lod = layer_outlier_distribution(&w, 3.0);
        assert!((lod - 0.0167).abs() < 0.005, "{lod}");
    }

    #[test]
    fn lod_heavier_tail_is_larger() {
        let mut rng = Rng::new(32);
        let plain = Tensor::randn(vec![100, 256], 0.05, &mut rng);
        let heavy = Tensor::randn_outliers(vec![100, 256], 0.05, 0.02, 10.0, &mut rng);
        assert!(
            layer_outlier_distribution(&heavy, 5.0)
                > layer_outlier_distribution(&plain, 5.0)
        );
    }

    #[test]
    fn uniform_lod_gives_uniform_pattern() {
        let stats = mk_stats(&[0.02, 0.02, 0.02, 0.02]);
        let a = owl_allocate(&stats, 16, 0.5, 5.0, 1);
        assert!(a.iter().all(|x| x.n == 8), "{a:?}");
    }

    #[test]
    fn outlier_heavy_layers_keep_more() {
        let stats = mk_stats(&[0.08, 0.02, 0.02, 0.08]);
        let a = owl_allocate(&stats, 16, 0.5, 5.0, 1);
        assert!(a[0].n > a[1].n, "{a:?}");
        assert!(a[3].n > a[2].n, "{a:?}");
        // budget preserved exactly
        let keep = realized_keep(&a, &stats);
        assert!((keep - 0.5).abs() < 1e-9, "{keep}");
    }

    #[test]
    fn budget_met_with_uneven_layer_sizes() {
        let mut stats = mk_stats(&[0.10, 0.01, 0.05]);
        stats[0].size = 4 * 256; // small outlier-heavy layer
        stats[1].size = 64 * 256;
        let a = owl_allocate(&stats, 16, 0.5, 8.0, 2);
        let keep = realized_keep(&a, &stats);
        assert!((keep - 0.5).abs() < 0.02, "{keep}");
        assert!(a.iter().all(|x| x.n >= 2 && x.n <= 16));
    }

    #[test]
    fn lambda_zero_is_uniform() {
        let stats = mk_stats(&[0.2, 0.0, 0.1, 0.05]);
        let a = owl_allocate(&stats, 16, 0.5, 0.0, 1);
        assert!(a.iter().all(|x| x.n == 8), "{a:?}");
    }

    #[test]
    fn empty_input_ok() {
        assert!(owl_allocate(&[], 16, 0.5, 5.0, 1).is_empty());
    }

    #[test]
    fn clamps_respected_under_extreme_lambda() {
        let stats = mk_stats(&[0.5, 0.0]);
        let a = owl_allocate(&stats, 4, 0.5, 100.0, 1);
        assert!(a.iter().all(|x| (1..=4).contains(&x.n)), "{a:?}");
        // budget still met (4:4 + 0:4 clamped to 1:4 → repair balances)
        let keep = realized_keep(&a, &stats);
        assert!((keep - 0.5).abs() < 0.26, "{keep}");
    }
}
