//! Variance Correction (paper §4.2, Eq. 2) — host mirror of the
//! `variance_correct` Pallas kernel.
//!
//! `W_ns_corrected = W_ns * sqrt(Var(W_dense) / (Var(W_ns) + eps))`
//! restores the dense weight variance after pruning, stabilizing the layer
//! output scale without learnable bias terms.

use crate::tensor::Tensor;

pub const VC_EPS: f64 = 1e-8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VcMode {
    /// one scalar per matrix (the paper's Eq. 2)
    Global,
    /// per output row (local ablation variant)
    Row,
}

pub fn variance_correct(w_pruned: &Tensor, w_dense: &Tensor, mode: VcMode) -> Tensor {
    assert_eq!(w_pruned.shape(), w_dense.shape());
    match mode {
        VcMode::Global => {
            let scale = (w_dense.var() / (w_pruned.var() + VC_EPS)).sqrt() as f32;
            w_pruned.scale(scale)
        }
        VcMode::Row => {
            let (rows, cols) = w_pruned.dims2();
            let mut out = Vec::with_capacity(rows * cols);
            for r in 0..rows {
                let pr = w_pruned.row(r);
                let dr = w_dense.row(r);
                let scale = (row_var(dr) / (row_var(pr) + VC_EPS)).sqrt() as f32;
                out.extend(pr.iter().map(|&x| x * scale));
            }
            Tensor::new(vec![rows, cols], out)
        }
    }
}

fn row_var(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mu = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
    xs.iter()
        .map(|&x| {
            let d = x as f64 - mu;
            d * d
        })
        .sum::<f64>()
        / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask_topn_per_block;
    use crate::util::Rng;

    #[test]
    fn global_restores_variance() {
        let mut rng = Rng::new(21);
        let w = Tensor::randn(vec![64, 256], 0.1, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 2, 4);
        let pruned = w.mul(&mask);
        let fixed = variance_correct(&pruned, &w, VcMode::Global);
        let rel = (fixed.var() - w.var()).abs() / w.var();
        assert!(rel < 0.01, "rel var error {rel}");
    }

    #[test]
    fn row_mode_fixes_each_row() {
        let mut rng = Rng::new(23);
        let w = Tensor::randn(vec![8, 512], 0.1, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let fixed = variance_correct(&w.mul(&mask), &w, VcMode::Row);
        for r in 0..8 {
            let rel = (row_var(fixed.row(r)) - row_var(w.row(r))).abs() / row_var(w.row(r));
            assert!(rel < 0.05, "row {r} rel {rel}");
        }
    }

    #[test]
    fn zeros_stay_zero() {
        let mut rng = Rng::new(25);
        let w = Tensor::randn(vec![8, 64], 1.0, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 2, 4);
        let fixed = variance_correct(&w.mul(&mask), &w, VcMode::Global);
        for (f, m) in fixed.data().iter().zip(mask.data()) {
            if *m == 0.0 {
                assert_eq!(*f, 0.0);
            }
        }
    }

    #[test]
    fn identity_on_unpruned() {
        let mut rng = Rng::new(27);
        let w = Tensor::randn(vec![4, 64], 1.0, &mut rng);
        let fixed = variance_correct(&w, &w, VcMode::Global);
        for (a, b) in fixed.data().iter().zip(w.data()) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
