//! SmoothQuant-style weight equalization (paper §4.1, Eq. 1) — host
//! mirror of the `sq` fold inside the `ria_score` Pallas kernel.
//!
//! `s_j = max|x_j| / max|W_:,j|`; the importance metric is computed on
//! `W_ec = W / s_j`. Only the metric sees equalized weights; the model's
//! actual weights and activations never change (§4.1 Implementation Note).

use crate::tensor::{col_absmax, Tensor};

/// Channel scales with dead-channel guards (zero column or activation → 1).
pub fn sq_scales(w: &Tensor, colmax_x: &[f32]) -> Vec<f32> {
    let wmax = col_absmax(w);
    assert_eq!(wmax.len(), colmax_x.len());
    wmax.iter()
        .zip(colmax_x)
        .map(|(&wm, &xm)| {
            if wm > 0.0 && xm.abs() > 0.0 {
                xm.abs() / wm
            } else {
                1.0
            }
        })
        .collect()
}

/// `W_ec = W / s_j` column-wise.
pub fn equalize(w: &Tensor, colmax_x: &[f32]) -> Tensor {
    let s = sq_scales(w, colmax_x);
    let (rows, cols) = w.dims2();
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        let row = w.row(r);
        for c in 0..cols {
            out.push(row[c] / s[c]);
        }
    }
    Tensor::new(vec![rows, cols], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn scales_formula() {
        let w = Tensor::new(vec![2, 2], vec![1., -4., 2., 2.]);
        // col maxes: 2, 4
        let s = sq_scales(&w, &[6.0, 2.0]);
        assert_eq!(s, vec![3.0, 0.5]);
    }

    #[test]
    fn dead_channel_guard() {
        let w = Tensor::new(vec![2, 2], vec![0., 1., 0., 2.]);
        let s = sq_scales(&w, &[5.0, 0.0]);
        assert_eq!(s, vec![1.0, 1.0]);
    }

    #[test]
    fn equalize_balances_columns() {
        // after equalization every column's max equals its activation max /
        // scale consistency: max|W_ec[:,j]| == max|W[:,j]| / s_j
        let mut rng = Rng::new(9);
        let w = Tensor::randn(vec![16, 8], 1.0, &mut rng);
        let colmax_x: Vec<f32> = (0..8).map(|i| (i + 1) as f32).collect();
        let ec = equalize(&w, &colmax_x);
        let s = sq_scales(&w, &colmax_x);
        let wmax = col_absmax(&w);
        let ecmax = col_absmax(&ec);
        for j in 0..8 {
            assert!((ecmax[j] - wmax[j] / s[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn equalize_identity_when_balanced() {
        // if max|x_j| == max|W_:,j| the scale is 1 and W_ec == W
        let w = Tensor::new(vec![1, 3], vec![2., -3., 4.]);
        let ec = equalize(&w, &[2., 3., 4.]);
        assert_eq!(ec, w);
    }
}
