//! SparseGPT-style OBS pruner (Frantar & Alistarh 2023) — the strongest
//! one-shot baseline the paper's related-work section positions RIA
//! against.
//!
//! Unlike the scoring-only methods ([`super::magnitude_score`],
//! [`super::wanda_score`], [`super::ria_score`]) which pick a mask and
//! zero weights, OBS *updates the surviving weights* to compensate for
//! each removal, using the inverse Hessian of the layer's least-squares
//! reconstruction problem `H = Σ xᵀx + λI`.
//!
//! The implementation follows the blocked algorithm of the paper:
//! columns are processed left to right; at the start of every `m`-column
//! group the N:M mask for the group is chosen from the OBS saliency
//! `w² / diag(H⁻¹)²`; pruning a weight adds the rank-1 correction
//! `w_ij / [H⁻¹]_jj · [H⁻¹]_{j,j+1:}` to the unprocessed tail of the row.
//! The inverse Hessian is consumed through its upper Cholesky factor, so
//! the correction only ever touches columns to the right.

use crate::tensor::{cholesky_upper, spd_inverse, Tensor};

/// Tuning knobs for the OBS pruner.
#[derive(Clone, Copy, Debug)]
pub struct SparseGptConfig {
    /// N:M pattern applied to non-salient weights.
    pub n: usize,
    pub m: usize,
    /// Hessian dampening as a fraction of mean diagonal (paper uses 1%).
    pub percdamp: f64,
    /// Lazy-update block width (columns processed before the global
    /// trailing update). Must be a multiple of `m`.
    pub block: usize,
}

impl SparseGptConfig {
    pub fn new(n: usize, m: usize) -> Self {
        SparseGptConfig {
            n,
            m,
            percdamp: 0.01,
            block: 128,
        }
    }
}

/// Accumulated Hessian for one linear layer (`cin × cin`).
///
/// Feed calibration activation batches with [`Self::update`]; the
/// coordinator keeps one per layer during the calibration pass, exactly
/// like it keeps [`super::ActStats`] for the scoring methods.
#[derive(Clone, Debug)]
pub struct Hessian {
    h: Tensor,
    pub samples: usize,
}

impl Hessian {
    pub fn new(cin: usize) -> Self {
        Hessian {
            h: Tensor::zeros(vec![cin, cin]),
            samples: 0,
        }
    }

    /// Fold a `(batch, cin)` activation matrix into `H += 2 xᵀx`.
    pub fn update(&mut self, x: &Tensor) {
        let (b, cin) = x.dims2();
        let (hc, _) = self.h.dims2();
        assert_eq!(cin, hc, "activation width {cin} vs Hessian {hc}");
        let g = crate::tensor::gram(x);
        self.h = self.h.zip(&g, |a, b| a + 2.0 * b);
        self.samples += b;
    }

    /// Uniform Hessian (identity): degrades OBS to magnitude-with-update;
    /// used when calibration is disabled and by tests.
    pub fn identity(cin: usize) -> Self {
        let mut h = Tensor::zeros(vec![cin, cin]);
        for i in 0..cin {
            h.set2(i, i, 1.0);
        }
        Hessian { h, samples: 1 }
    }

    pub fn dims(&self) -> usize {
        self.h.dims2().0
    }
}

/// Output of an OBS prune: compensated weights and the keep mask.
pub struct SparseGptResult {
    /// pruned **and compensated** weight matrix (`w * mask` plus OBS
    /// corrections folded into surviving entries)
    pub w: Tensor,
    pub mask: Tensor,
    /// Σ (w_ij/[H⁻¹]_jj)² — the OBS estimate of the layer reconstruction
    /// error introduced by pruning
    pub obs_error: f64,
}

/// Prune `w (cout, cin)` to the config's N:M pattern with OBS weight
/// updates. `excl` marks entries excluded from pruning (structured salient
/// weights, `1.0` = salient); they are never pruned and never updated,
/// mirroring how [`super::prune_layer`] treats the outlier matrix.
pub fn sparsegpt_prune(
    w: &Tensor,
    hess: &Hessian,
    excl: Option<&Tensor>,
    cfg: &SparseGptConfig,
) -> crate::Result<SparseGptResult> {
    let (rows, cols) = w.dims2();
    assert_eq!(hess.dims(), cols, "Hessian dim {} vs cin {cols}", hess.dims());
    assert_eq!(cols % cfg.m, 0, "cin {cols} not divisible by m {}", cfg.m);
    assert!(cfg.n <= cfg.m && cfg.n > 0);
    let block = cfg.block.max(cfg.m) / cfg.m * cfg.m;
    if let Some(e) = excl {
        assert_eq!(e.shape(), w.shape(), "exclusion mask shape");
    }

    // ---- dampen H, drop dead columns, invert, upper-Cholesky ----------
    let mut h = hess.h.clone();
    let mut mean_diag = 0.0f64;
    for i in 0..cols {
        mean_diag += h.at2(i, i) as f64;
    }
    mean_diag /= cols as f64;
    let damp = (cfg.percdamp * mean_diag).max(1e-8) as f32;
    let mut dead = vec![false; cols];
    for i in 0..cols {
        if h.at2(i, i) == 0.0 {
            dead[i] = true;
            h.set2(i, i, 1.0);
        } else {
            let v = h.at2(i, i) + damp;
            h.set2(i, i, v);
        }
    }
    let hinv = spd_inverse(&h).map_err(|e| anyhow::anyhow!("sparsegpt: {e}"))?;
    // upper Cholesky factor U of H^{-1}: U[j, k>=j] drives the updates
    let u = cholesky_upper(&hinv).map_err(|e| anyhow::anyhow!("sparsegpt: {e}"))?;

    let mut wk = w.clone(); // working copy; corrections land here
    let mut mask = Tensor::zeros(vec![rows, cols]);
    let mut obs_error = 0.0f64;

    // dead columns carry no signal: prune them outright (they cost 0)
    for r in 0..rows {
        for (j, &d) in dead.iter().enumerate() {
            if d {
                wk.set2(r, j, 0.0);
            }
        }
    }

    let ud = u.data();
    for b0 in (0..cols).step_by(block) {
        let b1 = (b0 + block).min(cols);
        // per-row error accumulator for the lazy trailing update:
        // err[r][j-b0] = w_rj / U_jj for pruned (r,j) in this block
        let mut err = vec![0.0f32; rows * (b1 - b0)];
        for r in 0..rows {
            let wrow = wk.row_mut(r);
            let erow = &mut err[r * (b1 - b0)..(r + 1) * (b1 - b0)];
            for g0 in (b0..b1).step_by(cfg.m) {
                // ---- choose the group's keep set by OBS saliency ----
                // saliency of pruning j: (w_rj / U_jj)^2
                let mut sal: Vec<(f32, usize)> = (g0..g0 + cfg.m)
                    .map(|j| {
                        let ujj = ud[j * cols + j];
                        let s = wrow[j] / ujj;
                        (s * s, j)
                    })
                    .collect();
                // salient (excluded) entries never consume keep slots —
                // they move to the outlier matrix (mirrors mask_excluding)
                if let Some(e) = excl {
                    for (s, j) in sal.iter_mut() {
                        if e.at2(r, *j) != 0.0 {
                            *s = f32::NEG_INFINITY;
                        }
                    }
                }
                // keep the n highest-cost-to-prune, stable ties
                sal.sort_by(|a, b| {
                    b.0.partial_cmp(&a.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.1.cmp(&b.1))
                });
                let keep: Vec<usize> = sal[..cfg.n]
                    .iter()
                    .filter(|&&(s, _)| s > f32::NEG_INFINITY)
                    .map(|&(_, j)| j)
                    .collect();
                // ---- prune + sequential in-block compensation ----
                for j in g0..g0 + cfg.m {
                    if excl.map_or(false, |e| e.at2(r, j) != 0.0) {
                        // salient: value preserved exactly in the outlier
                        // matrix — removal is lossless, no compensation
                        wrow[j] = 0.0;
                        continue;
                    }
                    if keep.contains(&j) {
                        mask.set2(r, j, 1.0);
                        continue;
                    }
                    let ujj = ud[j * cols + j];
                    let e = wrow[j] / ujj;
                    obs_error += (e * e) as f64;
                    erow[j - b0] = e;
                    // correct the rest of this block's row (k in (j, b1))
                    let urow = &ud[j * cols..(j + 1) * cols];
                    for k in j + 1..b1 {
                        wrow[k] -= e * urow[k];
                    }
                    wrow[j] = 0.0;
                }
            }
        }
        // ---- lazy trailing update: w[:, b1:] -= err @ U[b0:b1, b1:] ----
        if b1 < cols {
            for r in 0..rows {
                let erow = &err[r * (b1 - b0)..(r + 1) * (b1 - b0)];
                let wrow = wk.row_mut(r);
                for (dj, &e) in erow.iter().enumerate() {
                    if e == 0.0 {
                        continue;
                    }
                    let j = b0 + dj;
                    let urow = &ud[j * cols..(j + 1) * cols];
                    for k in b1..cols {
                        wrow[k] -= e * urow[k];
                    }
                }
            }
        }
    }

    // salient entries keep their original (uncompensated) values; they
    // live in the outlier matrix, not in the N:M tensor
    if let Some(e) = excl {
        for r in 0..rows {
            for j in 0..cols {
                if e.at2(r, j) != 0.0 {
                    wk.set2(r, j, 0.0);
                }
            }
        }
    }

    Ok(SparseGptResult {
        w: wk,
        mask,
        obs_error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_wt, rel_error};
    use crate::util::Rng;

    fn calib(rows: usize, cin: usize, seed: u64) -> (Tensor, Tensor, Hessian) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn_outliers(vec![rows, cin], 0.05, 0.01, 8.0, &mut rng);
        let x = Tensor::randn(vec![4 * cin, cin], 1.0, &mut rng);
        let mut h = Hessian::new(cin);
        h.update(&x);
        (w, x, h)
    }

    #[test]
    fn mask_budget_exact() {
        let (w, _x, h) = calib(16, 64, 1);
        let r = sparsegpt_prune(&w, &h, None, &SparseGptConfig::new(8, 16)).unwrap();
        assert_eq!(r.mask.count_nonzero(), 16 * 64 / 2);
        // every pruned entry is exactly zero, every kept entry nonzero-ish
        for i in 0..w.len() {
            if r.mask.data()[i] == 0.0 {
                assert_eq!(r.w.data()[i], 0.0);
            }
        }
    }

    #[test]
    fn per_group_cardinality() {
        let (w, _x, h) = calib(8, 64, 2);
        let cfg = SparseGptConfig::new(2, 4);
        let r = sparsegpt_prune(&w, &h, None, &cfg).unwrap();
        for row in 0..8 {
            for g in 0..64 / 4 {
                let kept = (0..4)
                    .filter(|&j| r.mask.at2(row, g * 4 + j) != 0.0)
                    .count();
                assert_eq!(kept, 2, "row {row} group {g}");
            }
        }
    }

    #[test]
    fn compensation_beats_plain_masking() {
        // OBS's whole point: ||x(w - w')ᵀ|| is lower with weight updates
        // than with the same-scoring mask alone.
        let (w, x, h) = calib(24, 128, 3);
        let cfg = SparseGptConfig::new(2, 4);
        let obs = sparsegpt_prune(&w, &h, None, &cfg).unwrap();
        let plain = w.mul(&obs.mask); // same mask, no compensation
        let y = matmul_wt(&x, &w);
        let e_obs = rel_error(&matmul_wt(&x, &obs.w), &y);
        let e_plain = rel_error(&matmul_wt(&x, &plain), &y);
        assert!(
            e_obs < e_plain,
            "obs {e_obs:.4} should beat plain {e_plain:.4}"
        );
    }

    #[test]
    fn excluded_outliers_untouched_and_unpruned() {
        let (w, _x, h) = calib(8, 64, 4);
        let mut excl = Tensor::zeros(vec![8, 64]);
        excl.set2(0, 3, 1.0);
        excl.set2(5, 60, 1.0);
        let r = sparsegpt_prune(&w, &h, Some(&excl), &SparseGptConfig::new(2, 4)).unwrap();
        // salient entries are carved out of the N:M tensor entirely
        assert_eq!(r.w.at2(0, 3), 0.0);
        assert_eq!(r.w.at2(5, 60), 0.0);
        assert_eq!(r.mask.at2(0, 3), 0.0);
        // effective weight = w_ns + w*excl reconstructs the original there
        let eff = r.w.add(&w.mul(&excl));
        assert_eq!(eff.at2(0, 3), w.at2(0, 3));
        assert_eq!(eff.at2(5, 60), w.at2(5, 60));
    }

    #[test]
    fn identity_hessian_matches_magnitude_selection() {
        // With H = I there is no cross-correlation: OBS saliency reduces
        // to w² and no compensation should change kept weights.
        let mut rng = Rng::new(5);
        let w = Tensor::randn(vec![4, 32], 1.0, &mut rng);
        let h = Hessian::identity(32);
        let r = sparsegpt_prune(&w, &h, None, &SparseGptConfig::new(2, 4)).unwrap();
        let want_mask = crate::pruning::mask_topn_per_block(&w.map(|x| x * x), 2, 4);
        assert_eq!(r.mask, want_mask);
        for i in 0..w.len() {
            if r.mask.data()[i] != 0.0 {
                assert!((r.w.data()[i] - w.data()[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn dead_columns_pruned_for_free() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(vec![4, 16], 1.0, &mut rng);
        let mut x = Tensor::randn(vec![64, 16], 1.0, &mut rng);
        for r in 0..64 {
            x.set2(r, 7, 0.0); // channel 7 never fires
        }
        let mut h = Hessian::new(16);
        h.update(&x);
        let r = sparsegpt_prune(&w, &h, None, &SparseGptConfig::new(8, 16)).unwrap();
        for row in 0..4 {
            assert_eq!(r.w.at2(row, 7), 0.0, "dead channel should be pruned");
        }
    }

    #[test]
    fn blocked_equals_unblocked() {
        let (w, _x, h) = calib(8, 128, 7);
        let mut small = SparseGptConfig::new(4, 8);
        small.block = 8;
        let mut big = SparseGptConfig::new(4, 8);
        big.block = 128;
        let a = sparsegpt_prune(&w, &h, None, &small).unwrap();
        let b = sparsegpt_prune(&w, &h, None, &big).unwrap();
        assert_eq!(a.mask, b.mask);
        assert!(rel_error(&a.w, &b.w) < 1e-3, "{}", rel_error(&a.w, &b.w));
    }

    #[test]
    fn obs_error_reported() {
        let (w, _x, h) = calib(8, 64, 8);
        let r24 = sparsegpt_prune(&w, &h, None, &SparseGptConfig::new(2, 4)).unwrap();
        let r816 = sparsegpt_prune(&w, &h, None, &SparseGptConfig::new(8, 16)).unwrap();
        assert!(r24.obs_error > 0.0);
        // 8:16 is a strict superset of feasible 2:4 masks → lower OBS error
        assert!(
            r816.obs_error < r24.obs_error,
            "8:16 {:.4} !< 2:4 {:.4}",
            r816.obs_error,
            r24.obs_error
        );
    }
}
