//! `store` — the packed-model artifact container (`.spak`) and its
//! mmap zero-copy reader.
//!
//! The compression pipeline ends with calibrated, variance-corrected,
//! optionally fine-tuned and quantized packed weights — but until this
//! module existed they were flattened back to a dense checkpoint, and a
//! server cold-started by **re-packing with magnitude-only selection**,
//! silently discarding everything the pipeline computed. The `.spak`
//! container makes the paper's storage claim a literal on-disk byte
//! count (2.9375 bits/param at 8:16 / int4 / g128, cross-checked
//! byte-exactly by [`crate::hwsim::artifact`]) and turns cold start into
//! "mmap and go":
//!
//! * [`PackedModel`] — the fully compressed model in memory: config,
//!   dense non-linear params (embeddings/norms), and one
//!   [`PackedLayer`] per prunable linear ([`PackedNm`] bf16 /
//!   [`PackedQnm`] int-quantized / [`PackedTnm`] ternary /
//!   [`PackedVnm`] base, plus the structured-outlier side stream). Produced by the pipeline's
//!   pack-artifact stage ([`crate::coordinator::CompressionPipeline::run_packed`])
//!   or, magnitude-only, by [`PackedModel::compress`] (the `sparselm
//!   pack` subcommand).
//! * [`write_artifact`] / [`read_artifact`] — the `SPAK` binary
//!   container (versioned, FNV-1a-checksummed payload, 64-byte-aligned
//!   sections, JSON per-tensor index; layout spec in `docs/FORMAT.md`).
//!   The reader memory-maps the file and hands every weight stream to
//!   its format as a [`crate::sparse::Storage::Mapped`] window, so
//!   [`PackedModel::into_sparse_lm`] builds a serving model whose spmm
//!   kernels stream weights **directly from the page cache** — zero
//!   per-linear heap copies, byte-identical `operand_bytes` accounting,
//!   bitwise-identical outputs to the in-memory packed model, and one
//!   physical copy shared by every server process on the host.
//!
//! `serve --model x.spak` / `generate --model x.spak` boot through this
//! path; `docs/ARCHITECTURE.md` contrasts it with the legacy
//! dense-checkpoint + `--repack` cold start.

pub mod container;

pub use container::{
    inspect_artifact, read_artifact, write_artifact, ArtifactInfo, TensorInfo, ALIGN, MAGIC,
    VERSION,
};

use std::collections::BTreeMap;

use crate::model::{BlockWeights, ModelConfig, ParamSet, SparseLm};
use crate::quant::QuantSpec;
use crate::sparse::{
    Kernel, PackedLinear, PackedNm, PackedQnm, PackedQuantLinear, PackedTernaryLinear, PackedTnm,
    PackedVnm, StructuredOutliers,
};
use crate::tensor::Tensor;

/// The packed base weights of one linear layer — every N:M family the
/// container can hold.
#[derive(Clone, Debug)]
pub enum PackedWeights {
    /// per-row N:M, bf16 kept values
    Nm(PackedNm),
    /// V-row-tiled N:M, bf16 kept values
    Vnm(PackedVnm),
    /// per-row N:M, int-quantized kept values (dequantized in-kernel)
    Qnm(PackedQnm),
    /// per-row N:M, ternary kept values (5 trits/byte, dequantized
    /// in-kernel)
    Tnm(PackedTnm),
}

impl PackedWeights {
    /// `(out_features, in_features)` of the dense matrix this packs.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            PackedWeights::Nm(p) => (p.rows, p.cols),
            PackedWeights::Vnm(p) => (p.rows, p.cols),
            PackedWeights::Qnm(p) => (p.rows, p.cols),
            PackedWeights::Tnm(p) => (p.rows, p.cols),
        }
    }

    /// Exact serialized stream bytes (values/codes/scales + full meta
    /// words) — what the container stores, and what
    /// [`crate::hwsim::artifact`] models.
    pub fn stream_bytes(&self) -> usize {
        match self {
            PackedWeights::Nm(p) => p.values_raw().len() * 2 + p.meta_words().len() * 8,
            PackedWeights::Vnm(p) => p.values_raw().len() * 2 + p.meta_words().len() * 8,
            PackedWeights::Qnm(p) => {
                p.codes_raw().len() * 4 + p.scales_raw().len() * 2 + p.meta_words().len() * 8
            }
            PackedWeights::Tnm(p) => {
                p.trits_raw().len() + p.scales_raw().len() * 2 + p.meta_words().len() * 8
            }
        }
    }

    /// Short format tag used in the artifact index
    /// (`nm`/`vnm`/`qnm`/`tnm`).
    pub fn kind(&self) -> &'static str {
        match self {
            PackedWeights::Nm(_) => "nm",
            PackedWeights::Vnm(_) => "vnm",
            PackedWeights::Qnm(_) => "qnm",
            PackedWeights::Tnm(_) => "tnm",
        }
    }
}

/// One prunable linear in its serving format: packed base + optional
/// structured-outlier side stream.
#[derive(Clone, Debug)]
pub struct PackedLayer {
    pub name: String,
    pub weights: PackedWeights,
    pub outliers: Option<StructuredOutliers>,
}

impl PackedLayer {
    /// Exact serialized bytes of the outlier side stream (0 when none).
    pub fn outlier_stream_bytes(&self) -> usize {
        self.outliers
            .as_ref()
            .map_or(0, |o| o.values_raw().len() * 2 + o.indices_raw().len())
    }

    /// Turn this layer into the fused kernel the forward pass applies.
    /// V:N:M has no outlier composite (it exists for the a3 ablation,
    /// not the §4 serving format), so it is servable only without a
    /// side stream.
    pub fn into_kernel(self) -> crate::Result<Box<dyn Kernel>> {
        if let Some(o) = &self.outliers {
            let (r, c) = self.weights.dims();
            anyhow::ensure!(
                (o.rows, o.cols) == (r, c),
                "layer {}: outlier shape ({}, {}) vs base ({r}, {c})",
                self.name,
                o.rows,
                o.cols
            );
        }
        Ok(match self.weights {
            PackedWeights::Nm(p) => Box::new(PackedLinear::new(p, self.outliers)),
            PackedWeights::Qnm(p) => Box::new(PackedQuantLinear::new(p, self.outliers)),
            PackedWeights::Tnm(p) => Box::new(PackedTernaryLinear::new(p, self.outliers)),
            PackedWeights::Vnm(p) => {
                anyhow::ensure!(
                    self.outliers.is_none(),
                    "layer {}: V:N:M base cannot carry an outlier side stream",
                    self.name
                );
                Box::new(p)
            }
        })
    }
}

/// The fully compressed model — exactly what the `.spak` container
/// persists. Field order follows the parameter contract
/// ([`ModelConfig::param_names`]): `dense` holds every non-linear
/// tensor (tok_emb, per-block norms, ln_f), `layers` every prunable
/// linear, block-major in [`crate::model::BLOCK_LINEAR`] order.
pub struct PackedModel {
    pub config: ModelConfig,
    /// pipeline provenance label (e.g. `RIA+SQ+VC+INT4`), `Magnitude`
    /// for checkpoint-repacks
    pub label: String,
    pub dense: Vec<(String, Tensor)>,
    pub layers: Vec<PackedLayer>,
}

impl PackedModel {
    /// Magnitude-selection pack of a dense parameter set — the same
    /// selection as [`SparseLm::compress`] / [`SparseLm::compress_quant`]
    /// (one shared `select_outliers_and_keep` body underneath), so a
    /// written-then-mmapped artifact is bitwise interchangeable with the
    /// in-memory packed model. This is the `sparselm pack` path; the
    /// calibrated path is the pipeline's pack-artifact stage.
    pub fn compress(
        params: &ParamSet,
        n: usize,
        m: usize,
        k_out: usize,
        quant: Option<QuantSpec>,
    ) -> PackedModel {
        let linear: std::collections::BTreeSet<String> =
            params.linear_indices().into_iter().map(|(name, _)| name).collect();
        let mut dense = Vec::new();
        let mut layers = Vec::new();
        for (name, t) in params.names.iter().zip(&params.tensors) {
            if !linear.contains(name) {
                dense.push((name.clone(), t.clone()));
                continue;
            }
            let score = t.map(f32::abs);
            let (weights, outliers) = match quant {
                Some(spec) => {
                    let l = PackedQuantLinear::compress(t, &score, n, m, k_out, spec);
                    (PackedWeights::Qnm(l.weights), l.outliers)
                }
                None => {
                    let l = PackedLinear::compress(t, &score, n, m, k_out);
                    (PackedWeights::Nm(l.weights), l.outliers)
                }
            };
            layers.push(PackedLayer {
                name: name.clone(),
                weights,
                outliers,
            });
        }
        let label = match quant {
            Some(spec) => format!("Magnitude+INT{}", spec.bits),
            None => "Magnitude".to_string(),
        };
        PackedModel {
            config: params.config.clone(),
            label,
            dense,
            layers,
        }
    }

    /// Magnitude-selection **ternary** pack — the sub-2-bits/param
    /// counterpart of [`Self::compress`] with the same shared selection
    /// body, kept values quantized to {-1, 0, +1} per
    /// [`crate::sparse::PackedTnm`] (`group` gcd-fitted per layer
    /// width). This is the `sparselm pack --quant ternary` path.
    pub fn compress_ternary(
        params: &ParamSet,
        n: usize,
        m: usize,
        k_out: usize,
        group: usize,
    ) -> PackedModel {
        let linear: std::collections::BTreeSet<String> =
            params.linear_indices().into_iter().map(|(name, _)| name).collect();
        let mut dense = Vec::new();
        let mut layers = Vec::new();
        for (name, t) in params.names.iter().zip(&params.tensors) {
            if !linear.contains(name) {
                dense.push((name.clone(), t.clone()));
                continue;
            }
            let score = t.map(f32::abs);
            let l = PackedTernaryLinear::compress(t, &score, n, m, k_out, group);
            layers.push(PackedLayer {
                name: name.clone(),
                weights: PackedWeights::Tnm(l.weights),
                outliers: l.outliers,
            });
        }
        PackedModel {
            config: params.config.clone(),
            label: "Magnitude+T158".to_string(),
            dense,
            layers,
        }
    }

    /// The uniform pack settings across every linear, when consistent:
    /// `(n, m, quant spec of the base)`. `None` when layers mix
    /// patterns, formats, or quant specs — including quant groups that
    /// were gcd-fitted differently per layer shape, where no single
    /// spec reproduces the stored streams (per-layer N:M allocation à
    /// la OWL would land here too). Callers printing an analytic
    /// cross-check skip it in that case rather than report a false
    /// mismatch.
    pub fn pack_summary(&self) -> Option<(usize, usize, Option<QuantSpec>)> {
        let mut summary: Option<(usize, usize, Option<QuantSpec>)> = None;
        for l in &self.layers {
            let this = match &l.weights {
                PackedWeights::Nm(p) => (p.pattern.n, p.pattern.m, None),
                PackedWeights::Qnm(p) => (p.pattern.n, p.pattern.m, Some(p.spec())),
                // V:N:M and ternary have no QuantSpec representation;
                // analytic cross-checks use the per-kind breakdown
                // instead (`inspect`, hwsim::artifact)
                PackedWeights::Vnm(_) | PackedWeights::Tnm(_) => return None,
            };
            match summary {
                None => summary = Some(this),
                Some(prev) if prev != this => return None,
                Some(_) => {}
            }
        }
        summary
    }

    /// Total dense elements across the packed linears (the bits/param
    /// denominator).
    pub fn linear_elems(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                let (r, c) = l.weights.dims();
                r * c
            })
            .sum()
    }

    /// Exact serialized bytes of the packed base streams.
    pub fn linear_stream_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weights.stream_bytes()).sum()
    }

    /// Exact serialized bytes of the outlier side streams.
    pub fn outlier_stream_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.outlier_stream_bytes()).sum()
    }

    /// Build the serving model, consuming `self` — when the layers came
    /// out of [`read_artifact`] their streams are [`crate::sparse::Storage::Mapped`]
    /// windows, so the resulting [`SparseLm`]'s kernels read weights
    /// straight from the page cache (no per-linear heap copies; dense
    /// non-linear params are copied into f32 tensors, which is outside
    /// the zero-copy contract). Validates every tensor against the
    /// parameter contract of `config`.
    pub fn into_sparse_lm(self) -> crate::Result<SparseLm> {
        let cfg = self.config;
        let mut dense: BTreeMap<String, Tensor> = self.dense.into_iter().collect();
        let mut layers: BTreeMap<String, PackedLayer> = self
            .layers
            .into_iter()
            .map(|l| (l.name.clone(), l))
            .collect();

        let mut take_dense = |name: &str, want: &[usize]| -> crate::Result<Tensor> {
            let t = dense
                .remove(name)
                .ok_or_else(|| anyhow::anyhow!("artifact missing dense param {name:?}"))?;
            anyhow::ensure!(
                t.shape() == want,
                "dense param {name}: artifact shape {:?} vs config {:?}",
                t.shape(),
                want
            );
            Ok(t)
        };

        let tok_emb = take_dense("tok_emb", &cfg.param_shape("tok_emb")?)?;
        let ln_f = take_dense("ln_f", &cfg.param_shape("ln_f")?)?.into_data();

        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            let mut lin = |p: &str| -> crate::Result<Box<dyn Kernel>> {
                let name = format!("blk{b}.{p}");
                let layer = layers
                    .remove(&name)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing packed linear {name:?}"))?;
                let want = cfg.param_shape(&name)?;
                let (r, c) = layer.weights.dims();
                anyhow::ensure!(
                    vec![r, c] == want,
                    "linear {name}: artifact shape [{r}, {c}] vs config {want:?}"
                );
                layer.into_kernel()
            };
            let wq = lin("wq")?;
            let wk = lin("wk")?;
            let wv = lin("wv")?;
            let wo = lin("wo")?;
            let wg = lin("wg")?;
            let wu = lin("wu")?;
            let wd = lin("wd")?;
            let ln1 = take_dense(&format!("blk{b}.ln1"), &[cfg.dim])?.into_data();
            let ln2 = take_dense(&format!("blk{b}.ln2"), &[cfg.dim])?.into_data();
            blocks.push(BlockWeights {
                ln1,
                wq,
                wk,
                wv,
                wo,
                ln2,
                wg,
                wu,
                wd,
            });
        }
        Ok(SparseLm {
            config: cfg,
            tok_emb,
            blocks,
            ln_f,
            threads: 1,
        })
    }

    /// `true` when every packed weight stream is a live mmap window —
    /// the zero-copy property [`read_artifact`] establishes (reported
    /// through [`ArtifactInfo::mapped`] too; exposed here for tests).
    pub fn all_streams_mapped(&self) -> bool {
        self.layers.iter().all(|l| {
            let base = match &l.weights {
                PackedWeights::Nm(p) => p.is_mapped(),
                PackedWeights::Vnm(p) => p.is_mapped(),
                PackedWeights::Qnm(p) => p.is_mapped(),
                PackedWeights::Tnm(p) => p.is_mapped(),
            };
            base && l.outliers.iter().all(|o| o.is_mapped())
        })
    }
}
