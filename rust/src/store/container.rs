//! The `SPAK` binary container: writer, mmap reader, and inspection.
//!
//! Layout (little-endian; full spec with a worked example in
//! `docs/FORMAT.md`):
//!
//! ```text
//! [0..4)    magic  b"SPAK"
//! [4..8)    version u32
//! [8..12)   index_len u32
//! [12..12+index_len)  index JSON (config + label + per-tensor entries)
//! zero pad to the next 64-byte boundary            -> data_start
//! sections: one byte stream per tensor component, each aligned to a
//!           64-byte boundary relative to data_start (offsets in the
//!           index are relative to data_start)
//! [file_len-8..file_len)  u64 FNV-1a over [12, file_len-8)
//! ```
//!
//! The checksum trailer covers everything after the fixed header —
//! index JSON, alignment padding **and** sections — so a bit flip in a
//! stream offset is caught just like one in the stream bytes (an index
//! that lies about offsets would otherwise remap windows silently).
//!
//! Every index entry names a tensor, its kind (`dense`/`nm`/`vnm`/
//! `qnm`/`tnm`), its dense shape, the kind's parameters (`n`, `m`, `v`,
//! `qbits`, `qgroup`, `tgroup`) and its streams (`{off, bytes}` each); packed
//! linears may carry a nested `outliers` object. The reader validates
//! magic/version/checksum with the shared typed errors
//! ([`crate::Error::BadMagic`] / [`crate::Error::BadVersion`] /
//! [`crate::Error::ChecksumMismatch`] / [`crate::Error::Truncated`] —
//! the same conditions `model/checkpoint.rs` raises), then rebuilds
//! every packed format over [`Storage::mapped`] windows: loads are
//! zero-copy, and stream lengths are validated against the packers'
//! exact layout rules so the reconstructed operands are byte-identical
//! to the originals.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::model::{config_from_json, config_json};
use crate::quant::QuantSpec;
use crate::sparse::storage::{Pod, Storage};
use crate::sparse::{PackedNm, PackedQnm, PackedTnm, PackedVnm, StructuredOutliers};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::mmap::MappedFile;
use crate::util::{fnv1a, FNV_OFFSET};

use super::{PackedLayer, PackedModel, PackedWeights};

/// Container magic bytes.
pub const MAGIC: &[u8; 4] = b"SPAK";
/// Container format version this build writes and reads.
pub const VERSION: u32 = 1;
/// Section alignment: every stream starts on a 64-byte boundary (cache-
/// line aligned, and a multiple of every stream dtype's alignment).
pub const ALIGN: u64 = 64;

const FIXED_HEADER: u64 = 12;

fn align_up(x: u64, a: u64) -> u64 {
    (x + a - 1) / a * a
}

// ------------------------------------------------------------- streams

/// A typed view of one serialized stream (borrowed from the in-memory
/// packed model at write time).
enum StreamData<'a> {
    U8(&'a [u8]),
    U16(&'a [u16]),
    U32(&'a [u32]),
    U64(&'a [u64]),
    F32(&'a [f32]),
}

impl StreamData<'_> {
    fn byte_len(&self) -> usize {
        match self {
            StreamData::U8(s) => s.len(),
            StreamData::U16(s) => s.len() * 2,
            StreamData::U32(s) => s.len() * 4,
            StreamData::U64(s) => s.len() * 8,
            StreamData::F32(s) => s.len() * 4,
        }
    }

    /// Raw little-endian bytes (this crate targets little-endian hosts;
    /// the checkpoint writer makes the same assumption).
    fn as_bytes(&self) -> &[u8] {
        // SAFETY: all stream dtypes are plain-old-data; the slice
        // lengths are recomputed in bytes.
        unsafe {
            match self {
                StreamData::U8(s) => s,
                StreamData::U16(s) => {
                    std::slice::from_raw_parts(s.as_ptr() as *const u8, s.len() * 2)
                }
                StreamData::U32(s) => {
                    std::slice::from_raw_parts(s.as_ptr() as *const u8, s.len() * 4)
                }
                StreamData::U64(s) => {
                    std::slice::from_raw_parts(s.as_ptr() as *const u8, s.len() * 8)
                }
                StreamData::F32(s) => {
                    std::slice::from_raw_parts(s.as_ptr() as *const u8, s.len() * 4)
                }
            }
        }
    }
}

/// One stream scheduled for writing: key (index name), data, and its
/// offset relative to `data_start` (assigned by the allocator).
struct StreamRec<'a> {
    key: &'static str,
    data: StreamData<'a>,
    off: u64,
}

struct EntryPlan<'a> {
    name: &'a str,
    kind: &'static str,
    shape: Vec<usize>,
    /// kind parameters serialized into the index entry
    attrs: Vec<(&'static str, Json)>,
    streams: Vec<StreamRec<'a>>,
    /// nested outlier side stream: (k, m, streams)
    outlier: Option<(usize, usize, Vec<StreamRec<'a>>)>,
}

fn plan_entries(model: &PackedModel) -> Vec<EntryPlan<'_>> {
    let mut entries = Vec::new();
    for (name, t) in &model.dense {
        entries.push(EntryPlan {
            name: name.as_str(),
            kind: "dense",
            shape: t.shape().to_vec(),
            attrs: Vec::new(),
            streams: vec![StreamRec {
                key: "f32",
                data: StreamData::F32(t.data()),
                off: 0,
            }],
            outlier: None,
        });
    }
    for layer in &model.layers {
        let (rows, cols) = layer.weights.dims();
        let (attrs, streams) = match &layer.weights {
            PackedWeights::Nm(p) => (
                vec![
                    ("n", Json::num(p.pattern.n as f64)),
                    ("m", Json::num(p.pattern.m as f64)),
                ],
                vec![
                    StreamRec { key: "values", data: StreamData::U16(p.values_raw()), off: 0 },
                    StreamRec { key: "meta", data: StreamData::U64(p.meta_words()), off: 0 },
                ],
            ),
            PackedWeights::Vnm(p) => (
                vec![
                    ("v", Json::num(p.v as f64)),
                    ("n", Json::num(p.pattern.n as f64)),
                    ("m", Json::num(p.pattern.m as f64)),
                ],
                vec![
                    StreamRec { key: "values", data: StreamData::U16(p.values_raw()), off: 0 },
                    StreamRec { key: "meta", data: StreamData::U64(p.meta_words()), off: 0 },
                ],
            ),
            PackedWeights::Qnm(p) => (
                vec![
                    ("n", Json::num(p.pattern.n as f64)),
                    ("m", Json::num(p.pattern.m as f64)),
                    ("qbits", Json::num(p.spec().bits as f64)),
                    ("qgroup", Json::num(p.spec().group as f64)),
                ],
                vec![
                    StreamRec { key: "codes", data: StreamData::U32(p.codes_raw()), off: 0 },
                    StreamRec { key: "scales", data: StreamData::U16(p.scales_raw()), off: 0 },
                    StreamRec { key: "meta", data: StreamData::U64(p.meta_words()), off: 0 },
                ],
            ),
            PackedWeights::Tnm(p) => (
                vec![
                    ("n", Json::num(p.pattern.n as f64)),
                    ("m", Json::num(p.pattern.m as f64)),
                    ("tgroup", Json::num(p.group as f64)),
                ],
                vec![
                    StreamRec { key: "trits", data: StreamData::U8(p.trits_raw()), off: 0 },
                    StreamRec { key: "scales", data: StreamData::U16(p.scales_raw()), off: 0 },
                    StreamRec { key: "meta", data: StreamData::U64(p.meta_words()), off: 0 },
                ],
            ),
        };
        let outlier = layer.outliers.as_ref().map(|o| {
            (
                o.k,
                o.m,
                vec![
                    StreamRec { key: "values", data: StreamData::U16(o.values_raw()), off: 0 },
                    StreamRec { key: "indices", data: StreamData::U8(o.indices_raw()), off: 0 },
                ],
            )
        });
        entries.push(EntryPlan {
            name: layer.name.as_str(),
            kind: layer.weights.kind(),
            shape: vec![rows, cols],
            attrs,
            streams,
            outlier,
        });
    }
    entries
}

// -------------------------------------------------------- ArtifactInfo

/// One tensor's footprint inside an artifact (base + outlier streams).
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    pub kind: String,
    pub shape: Vec<usize>,
    pub stream_bytes: usize,
    /// Per-stream byte breakdown: `(stream key, bytes)` in index order,
    /// outlier-side streams prefixed `outlier.`. Sums to
    /// [`TensorInfo::stream_bytes`] on both the write and mmap-read
    /// paths — the `inspect` CLI folds these into its per-kind table
    /// and re-derives `total_bits_per_param` from them byte-exactly.
    pub streams: Vec<(String, usize)>,
}

/// Byte-exact accounting for a written or opened `.spak` artifact — the
/// measured side of the [`crate::hwsim::artifact`] cross-check.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub path: PathBuf,
    /// total on-disk size
    pub file_bytes: u64,
    /// index JSON bytes (excluding the 12 fixed header bytes)
    pub index_bytes: usize,
    /// sum of all stream bytes (no padding)
    pub payload_bytes: usize,
    /// alignment padding between streams
    pub padding_bytes: usize,
    /// dense (non-linear) stream bytes — f32 embeddings and norms
    pub dense_stream_bytes: usize,
    /// packed base streams of the linears (values/codes/scales/meta)
    pub linear_stream_bytes: usize,
    /// structured-outlier side streams
    pub outlier_stream_bytes: usize,
    /// dense element count across the packed linears
    pub linear_elems: usize,
    pub label: String,
    /// read path: `true` when the bytes are served by a live mmap
    pub mapped: bool,
    pub tensors: Vec<TensorInfo>,
}

impl ArtifactInfo {
    /// Bytes the fixed header + index + its alignment pad occupy.
    pub fn header_bytes(&self) -> u64 {
        align_up(FIXED_HEADER + self.index_bytes as u64, ALIGN)
    }

    /// The container's structural identity: header + padded payload
    /// span + 8-byte checksum trailer account for every file byte.
    pub fn expected_file_bytes(&self) -> u64 {
        self.header_bytes() + (self.payload_bytes + self.padding_bytes) as u64 + 8
    }

    /// Stored bits per dense linear parameter of the packed **base**
    /// streams — the artifact-measured side of the Table-1 /
    /// `nm_quant_bits_per_param` accounting.
    pub fn base_bits_per_param(&self) -> f64 {
        8.0 * self.linear_stream_bytes as f64 / self.linear_elems.max(1) as f64
    }

    /// Base + outlier side streams, bits per dense linear parameter.
    pub fn total_bits_per_param(&self) -> f64 {
        8.0 * (self.linear_stream_bytes + self.outlier_stream_bytes) as f64
            / self.linear_elems.max(1) as f64
    }
}

// --------------------------------------------------------------- write

/// Serialize `model` to `path` as a `SPAK` container. Returns the
/// byte-exact accounting (whose `expected_file_bytes` is asserted
/// against the actual file).
pub fn write_artifact(path: &Path, model: &PackedModel) -> crate::Result<ArtifactInfo> {
    let mut entries = plan_entries(model);

    // pass 1: assign aligned offsets relative to data_start
    let mut off = 0u64;
    let mut padding = 0u64;
    let mut payload = 0u64;
    {
        let mut place = |s: &mut StreamRec<'_>| {
            let aligned = align_up(off, ALIGN);
            padding += aligned - off;
            s.off = aligned;
            off = aligned + s.data.byte_len() as u64;
            payload += s.data.byte_len() as u64;
        };
        for e in &mut entries {
            for s in &mut e.streams {
                place(s);
            }
            if let Some((_, _, streams)) = &mut e.outlier {
                for s in streams {
                    place(s);
                }
            }
        }
    }
    let span = off;

    // pass 2: index JSON (offsets now known) + accounting
    let stream_obj = |streams: &[StreamRec<'_>]| -> Json {
        Json::obj(
            streams
                .iter()
                .map(|s| {
                    (
                        s.key,
                        Json::obj(vec![
                            ("off", Json::num(s.off as f64)),
                            ("bytes", Json::num(s.data.byte_len() as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    };
    let mut tensor_infos = Vec::new();
    let mut tensors_json = Vec::new();
    let (mut dense_b, mut linear_b, mut outlier_b) = (0usize, 0usize, 0usize);
    let mut linear_elems = 0usize;
    for e in &entries {
        let base_bytes: usize = e.streams.iter().map(|s| s.data.byte_len()).sum();
        let mut fields = vec![
            ("name", Json::str(e.name)),
            ("kind", Json::str(e.kind)),
            (
                "shape",
                Json::Arr(e.shape.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
        ];
        for &(k, ref v) in &e.attrs {
            fields.push((k, v.clone()));
        }
        fields.push(("streams", stream_obj(&e.streams)));
        let mut stream_list: Vec<(String, usize)> = e
            .streams
            .iter()
            .map(|s| (s.key.to_string(), s.data.byte_len()))
            .collect();
        let mut total = base_bytes;
        if e.kind == "dense" {
            dense_b += base_bytes;
        } else {
            linear_b += base_bytes;
            linear_elems += e.shape.iter().product::<usize>();
        }
        if let Some((k, m, streams)) = &e.outlier {
            let ob: usize = streams.iter().map(|s| s.data.byte_len()).sum();
            outlier_b += ob;
            total += ob;
            stream_list.extend(
                streams
                    .iter()
                    .map(|s| (format!("outlier.{}", s.key), s.data.byte_len())),
            );
            fields.push((
                "outliers",
                Json::obj(vec![
                    ("k", Json::num(*k as f64)),
                    ("m", Json::num(*m as f64)),
                    ("streams", stream_obj(streams)),
                ]),
            ));
        }
        tensor_infos.push(TensorInfo {
            name: e.name.to_string(),
            kind: e.kind.to_string(),
            shape: e.shape.clone(),
            stream_bytes: total,
            streams: stream_list,
        });
        tensors_json.push(Json::obj(fields));
    }
    let index = Json::obj(vec![
        ("format", Json::str("spak")),
        ("label", Json::str(model.label.clone())),
        ("config", config_json(&model.config)),
        ("tensors", Json::Arr(tensors_json)),
    ])
    .to_string();
    anyhow::ensure!(
        index.len() < u32::MAX as usize,
        "artifact index of {} bytes exceeds the u32 header field",
        index.len()
    );

    // pass 3: write
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating artifact {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(index.len() as u32).to_le_bytes())?;
    w.write_all(index.as_bytes())?;
    let zeros = [0u8; ALIGN as usize];
    let header_end = FIXED_HEADER + index.len() as u64;
    let mut pad = (align_up(header_end, ALIGN) - header_end) as usize;
    w.write_all(&zeros[..pad])?;

    // the trailer covers [12, len-8): index + header pad + sections
    let mut checksum = fnv1a(index.as_bytes(), FNV_OFFSET);
    checksum = fnv1a(&zeros[..pad], checksum);
    let mut pos = 0u64;
    for e in &entries {
        let all = e
            .streams
            .iter()
            .chain(e.outlier.iter().flat_map(|(_, _, s)| s.iter()));
        for s in all {
            pad = (s.off - pos) as usize;
            w.write_all(&zeros[..pad])?;
            checksum = fnv1a(&zeros[..pad], checksum);
            let bytes = s.data.as_bytes();
            w.write_all(bytes)?;
            checksum = fnv1a(bytes, checksum);
            pos = s.off + bytes.len() as u64;
        }
    }
    debug_assert_eq!(pos, span);
    w.write_all(&checksum.to_le_bytes())?;
    w.flush()?;

    let info = ArtifactInfo {
        path: path.to_path_buf(),
        file_bytes: align_up(header_end, ALIGN) + span + 8,
        index_bytes: index.len(),
        payload_bytes: payload as usize,
        padding_bytes: padding as usize,
        dense_stream_bytes: dense_b,
        linear_stream_bytes: linear_b,
        outlier_stream_bytes: outlier_b,
        linear_elems,
        label: model.label.clone(),
        mapped: false,
        tensors: tensor_infos,
    };
    debug_assert_eq!(info.expected_file_bytes(), info.file_bytes);
    Ok(info)
}

// ---------------------------------------------------------------- read

/// Typed-error helpers over the untrusted index document.
fn want_obj<'a>(j: &'a Json, key: &str, what: &str) -> crate::Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| anyhow::anyhow!("artifact index: {what} missing {key:?}"))
}

/// Strict non-negative integer read — `Json::as_usize` is a saturating
/// f64 cast, which would silently coerce a corrupt `-64` offset to 0 or
/// a fractional `qbits` to its floor; untrusted indices get neither.
fn want_usize(j: &Json, key: &str, what: &str) -> crate::Result<usize> {
    let x = want_obj(j, key, what)?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("artifact index: {what}.{key} is not a number"))?;
    anyhow::ensure!(
        x >= 0.0 && x.fract() == 0.0 && x <= 9_007_199_254_740_992.0, // 2^53
        "artifact index: {what}.{key} = {x} is not a non-negative integer"
    );
    Ok(x as usize)
}

/// Collect the `(key, bytes)` pairs a `streams` index object declares,
/// in key order, for [`TensorInfo::streams`]. The byte counts come from
/// the index itself, so the `inspect` breakdown reports exactly what
/// the container promises — any drift from the mapped windows would
/// already have failed `mapped_stream`'s bounds checks.
fn stream_breakdown(
    streams: &Json,
    prefix: &str,
    what: &str,
) -> crate::Result<Vec<(String, usize)>> {
    let m = streams
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("artifact index: {what}.streams is not an object"))?;
    let mut out = Vec::with_capacity(m.len());
    for (k, s) in m {
        out.push((
            format!("{prefix}{k}"),
            want_usize(s, "bytes", &format!("{what}.streams.{k}"))?,
        ));
    }
    Ok(out)
}

/// Resolve one `{off, bytes}` stream of `streams` into a typed mapped
/// window. `data_start`/`data_end` bound the payload span (the trailer
/// and header are never addressable).
fn mapped_stream<T: Pod>(
    map: &std::sync::Arc<MappedFile>,
    streams: &Json,
    key: &str,
    what: &str,
    data_start: u64,
    data_end: u64,
) -> crate::Result<Storage<T>> {
    let s = want_obj(streams, key, what)?;
    let off = want_usize(s, "off", what)? as u64;
    let bytes = want_usize(s, "bytes", what)? as u64;
    let elem = std::mem::size_of::<T>() as u64;
    anyhow::ensure!(
        bytes % elem == 0,
        "artifact index: {what}.{key} of {bytes} bytes is not a whole number of \
         {elem}-byte elements"
    );
    let abs = data_start
        .checked_add(off)
        .ok_or_else(|| anyhow::anyhow!("artifact index: {what}.{key} offset overflows"))?;
    anyhow::ensure!(
        abs.checked_add(bytes).is_some_and(|end| end <= data_end),
        "artifact index: {what}.{key} [{off}, {off}+{bytes}) leaves the payload span"
    );
    Storage::mapped(std::sync::Arc::clone(map), abs as usize, (bytes / elem) as usize)
}

/// Open a `.spak` artifact: mmap, validate magic/version/checksum
/// (typed errors), parse the index, and rebuild every tensor with
/// zero-copy mapped streams. The returned [`PackedModel`] serves
/// through [`PackedModel::into_sparse_lm`]; the [`ArtifactInfo`] is the
/// byte-exact accounting of what was mapped.
pub fn read_artifact(path: &Path) -> crate::Result<(PackedModel, ArtifactInfo)> {
    let map = MappedFile::open(path)
        .with_context(|| format!("opening artifact {}", path.display()))?;
    let bytes = map.bytes();
    let p = || path.display().to_string();
    if (bytes.len() as u64) < FIXED_HEADER {
        return Err(crate::Error::Truncated {
            path: p(),
            need: FIXED_HEADER,
            have: bytes.len() as u64,
        }
        .into());
    }
    let magic: [u8; 4] = bytes[0..4].try_into().unwrap();
    if &magic != MAGIC {
        return Err(crate::Error::BadMagic { path: p(), want: *MAGIC, got: magic }.into());
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(
            crate::Error::BadVersion { path: p(), want: VERSION, got: version }.into(),
        );
    }
    let index_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as u64;
    let data_start = align_up(FIXED_HEADER + index_len, ALIGN);
    if (bytes.len() as u64) < data_start + 8 {
        return Err(crate::Error::Truncated {
            path: p(),
            need: data_start + 8,
            have: bytes.len() as u64,
        }
        .into());
    }
    let data_end = bytes.len() as u64 - 8;
    let stored = u64::from_le_bytes(bytes[data_end as usize..].try_into().unwrap());
    let computed = fnv1a(&bytes[FIXED_HEADER as usize..data_end as usize], FNV_OFFSET);
    if stored != computed {
        return Err(crate::Error::ChecksumMismatch {
            path: p(),
            want: stored,
            got: computed,
        }
        .into());
    }

    let index_str = std::str::from_utf8(&bytes[12..(FIXED_HEADER + index_len) as usize])
        .with_context(|| format!("artifact index of {} is not utf-8", p()))?;
    let index = Json::parse(index_str)
        .map_err(|e| anyhow::anyhow!("artifact index of {}: {e}", p()))?;
    let config = config_from_json(want_obj(&index, "config", "index")?)?;
    let label = index
        .get("label")
        .and_then(|l| l.as_str())
        .unwrap_or("")
        .to_string();

    let mut dense = Vec::new();
    let mut layers = Vec::new();
    let mut tensor_infos = Vec::new();
    let (mut dense_b, mut linear_b, mut outlier_b) = (0usize, 0usize, 0usize);
    let mut linear_elems = 0usize;
    let mut payload = 0usize;
    let entries = want_obj(&index, "tensors", "index")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("artifact index: tensors is not an array"))?;
    for e in entries {
        let name = want_obj(e, "name", "tensor")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("artifact index: tensor name is not a string"))?
            .to_string();
        let what = format!("tensor {name}");
        let kind = want_obj(e, "kind", &what)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("artifact index: {what}.kind is not a string"))?
            .to_string();
        let shape = want_obj(e, "shape", &what)?
            .usize_arr()
            .ok_or_else(|| anyhow::anyhow!("artifact index: {what}.shape malformed"))?;
        let streams = want_obj(e, "streams", &what)?;
        let mut stream_list = stream_breakdown(streams, "", &what)?;
        let elems: usize = shape.iter().product();
        let entry_bytes = if kind == "dense" {
            let data: Storage<f32> =
                mapped_stream(&map, streams, "f32", &what, data_start, data_end)?;
            anyhow::ensure!(
                data.len() == elems,
                "{what}: f32 stream holds {} values, shape {shape:?} wants {elems}",
                data.len()
            );
            dense_b += elems * 4;
            // dense params are copied (they are outside the packed
            // zero-copy contract: the forward mutates nothing but needs
            // an owned Tensor)
            dense.push((name.clone(), Tensor::new(shape.clone(), data.to_vec())));
            elems * 4
        } else {
            anyhow::ensure!(
                shape.len() == 2,
                "{what}: packed kind {kind:?} wants a rank-2 shape, got {shape:?}"
            );
            let (rows, cols) = (shape[0], shape[1]);
            let n = want_usize(e, "n", &what)?;
            let m = want_usize(e, "m", &what)?;
            let weights = match kind.as_str() {
                "nm" => PackedWeights::Nm(PackedNm::from_raw_parts(
                    n,
                    m,
                    rows,
                    cols,
                    mapped_stream(&map, streams, "values", &what, data_start, data_end)?,
                    mapped_stream(&map, streams, "meta", &what, data_start, data_end)?,
                )?),
                "vnm" => PackedWeights::Vnm(PackedVnm::from_raw_parts(
                    want_usize(e, "v", &what)?,
                    n,
                    m,
                    rows,
                    cols,
                    mapped_stream(&map, streams, "values", &what, data_start, data_end)?,
                    mapped_stream(&map, streams, "meta", &what, data_start, data_end)?,
                )?),
                "qnm" => {
                    let qbits = want_usize(e, "qbits", &what)?;
                    let qgroup = want_usize(e, "qgroup", &what)?;
                    anyhow::ensure!(
                        (2..=8).contains(&qbits) && qgroup > 0,
                        "{what}: bad quant spec int{qbits} g{qgroup}"
                    );
                    PackedWeights::Qnm(PackedQnm::from_raw_parts(
                        n,
                        m,
                        rows,
                        cols,
                        QuantSpec::new(qbits as u32, qgroup),
                        mapped_stream(&map, streams, "codes", &what, data_start, data_end)?,
                        mapped_stream(&map, streams, "scales", &what, data_start, data_end)?,
                        mapped_stream(&map, streams, "meta", &what, data_start, data_end)?,
                    )?)
                }
                "tnm" => {
                    let tgroup = want_usize(e, "tgroup", &what)?;
                    PackedWeights::Tnm(PackedTnm::from_raw_parts(
                        n,
                        m,
                        rows,
                        cols,
                        tgroup,
                        mapped_stream(&map, streams, "trits", &what, data_start, data_end)?,
                        mapped_stream(&map, streams, "scales", &what, data_start, data_end)?,
                        mapped_stream(&map, streams, "meta", &what, data_start, data_end)?,
                    )?)
                }
                other => anyhow::bail!("{what}: unknown tensor kind {other:?}"),
            };
            let mut eb = weights.stream_bytes();
            linear_b += eb;
            linear_elems += elems;
            let outliers = match e.get("outliers") {
                None => None,
                Some(o) => {
                    let ow = format!("{what}.outliers");
                    let k = want_usize(o, "k", &ow)?;
                    let om = want_usize(o, "m", &ow)?;
                    let ostreams = want_obj(o, "streams", &ow)?;
                    let so = StructuredOutliers::from_raw_parts(
                        k,
                        om,
                        rows,
                        cols,
                        mapped_stream(&map, ostreams, "values", &ow, data_start, data_end)?,
                        mapped_stream(&map, ostreams, "indices", &ow, data_start, data_end)?,
                    )?;
                    let ob = so.values_raw().len() * 2 + so.indices_raw().len();
                    outlier_b += ob;
                    eb += ob;
                    stream_list.extend(stream_breakdown(ostreams, "outlier.", &ow)?);
                    Some(so)
                }
            };
            layers.push(PackedLayer { name: name.clone(), weights, outliers });
            eb
        };
        payload += entry_bytes;
        anyhow::ensure!(
            stream_list.iter().map(|(_, b)| b).sum::<usize>() == entry_bytes,
            "tensor {name}: index stream bytes disagree with the mapped windows"
        );
        tensor_infos.push(TensorInfo {
            name,
            kind,
            shape,
            stream_bytes: entry_bytes,
            streams: stream_list,
        });
    }

    let info = ArtifactInfo {
        path: path.to_path_buf(),
        file_bytes: bytes.len() as u64,
        index_bytes: index_len as usize,
        payload_bytes: payload,
        padding_bytes: ((data_end - data_start) as usize).saturating_sub(payload),
        dense_stream_bytes: dense_b,
        linear_stream_bytes: linear_b,
        outlier_stream_bytes: outlier_b,
        linear_elems,
        label,
        mapped: map.is_mapped(),
        tensors: tensor_infos,
    };
    let model = PackedModel {
        config,
        label: info.label.clone(),
        dense,
        layers,
    };
    Ok((model, info))
}

/// Validate and account a `.spak` file without keeping the model — the
/// `sparselm inspect` backend (full magic/version/checksum/layout
/// validation runs, since accounting is only as trustworthy as the
/// index it came from).
pub fn inspect_artifact(path: &Path) -> crate::Result<ArtifactInfo> {
    read_artifact(path).map(|(_, info)| info)
}
