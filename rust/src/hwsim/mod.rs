//! Hardware simulator: an analytic memory-traffic + compute-occupancy
//! model for sparse GEMM, reproducing the paper's §2 hardware discussion
//! (Table 1 bits/element, the 2× bandwidth argument, and the projected
//! "~1.5–2× acceleration scaling with matrix size" for 2:4 — extended to
//! 8:16).
//!
//! No silicon implements 8:16 (paper Limitations §8), so — per the
//! substitution rule — *latencies* are modeled, not measured: a roofline
//! over bytes moved (weights + pattern metadata + activations) and MACs,
//! with a fixed per-kernel launch overhead. The model reproduces the
//! qualitative shape the paper cites: bandwidth-bound large GEMMs
//! approach 2×, small GEMMs are overhead-bound, and 8:16's extra metadata
//! (0.875 vs 0.75 bits/elt) costs only ~1% of the dense traffic.
//!
//! The *bytes* side, however, is now measured: the decode-free spmm
//! kernels report the operand bytes they actually stream
//! ([`crate::sparse::Kernel::operand_bytes`]), and [`ModelCheck`] ties
//! that measurement back to this model's prediction — `cargo bench
//! --bench f2_spmm` walks the paper's layer shapes and asserts
//! measured ≈ modeled and packed ≤ 0.60× dense at 8:16. Every such
//! bench also records its measured-vs-modeled numbers (plus
//! [`HwModel::to_json`], the device parameters that produced them) in
//! a `BENCH_*.json` trajectory file that CI's `bench-gate` job
//! compares against `bench/baseline.json` — see `docs/BENCHMARKS.md`.

//! The artifact side goes further: [`artifact`] reproduces the packers'
//! layout arithmetic **byte-exactly**, so a `.spak` file's measured
//! stream bytes are gated against the model with equality, not
//! tolerance (`cargo bench --bench f4_coldstart`).

mod speedup;
mod traffic;

pub mod artifact;

pub use speedup::{speedup_curve, SpeedupPoint};
pub use traffic::{GemmShape, HwModel, ModelCheck, TrafficReport};
