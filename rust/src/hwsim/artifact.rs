//! Byte-**exact** `.spak` artifact size accounting — the bridge between
//! the Table-1 / [`crate::quant::nm_quant_bits_per_param`] analysis and
//! an actual `ls -l` of a packed-model file.
//!
//! The roofline checks in [`super::HwModel`] compare *operand traffic*
//! within ±1% (the pattern stream's trailing-word padding is tolerated).
//! Artifact files are different: their size is a deterministic function
//! of the model config and pack settings, so the cross-check here is
//! **equality**, not tolerance — each function reproduces the packers'
//! own layout arithmetic (kept counts, the `u32` code-word rule of
//! [`crate::quant::GroupQuant`], the `u64` pattern-word growth rule
//! shared through `sparse::bits::packed_words`) and must match the
//! written streams to the byte. `cargo bench --bench f4_coldstart`
//! gates the identity in CI; `tests/store_roundtrip.rs` property-checks
//! it across shapes.

use crate::model::ModelConfig;
use crate::quant::QuantSpec;
use crate::sparse::bits::packed_words;
use crate::sparse::{PackedQnm, PackedTnm, PatternInfo};

/// Exact serialized bytes of one [`crate::sparse::PackedNm`] base:
/// bf16 kept values + full `u64` pattern words.
pub fn nm_stream_bytes(rows: usize, cols: usize, n: usize, m: usize) -> usize {
    let blocks = rows * cols / m;
    let bits = PatternInfo::new(n, m).codebook_bits();
    blocks * n * 2 + packed_words(blocks, bits) * 8
}

/// Exact serialized bytes of one [`crate::sparse::PackedQnm`] base:
/// packed int codes + bf16 group scales + full `u64` pattern words.
/// `spec` is fitted to the row's kept count exactly as pack time does
/// ([`PackedQnm::fit_spec`]).
pub fn qnm_stream_bytes(
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    spec: QuantSpec,
) -> usize {
    let fitted = PackedQnm::fit_spec(spec, n, m, cols);
    let kpr = PackedQnm::kept_per_row(n, m, cols);
    let codes = (rows * kpr * fitted.bits as usize + 31) / 32 * 4;
    let scales = rows * (kpr / fitted.group) * 2;
    let blocks = rows * cols / m;
    let bits = PatternInfo::new(n, m).codebook_bits();
    codes + scales + packed_words(blocks, bits) * 8
}

/// Exact serialized bytes of one [`crate::sparse::PackedTnm`] base:
/// row-aligned base-3 trit bytes + bf16 group scales + full `u64`
/// pattern words. `group` is gcd-fitted to the row's kept count exactly
/// as pack time does ([`PackedTnm::fit_group`]).
pub fn tnm_stream_bytes(
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    group: usize,
) -> usize {
    let fitted = PackedTnm::fit_group(group, n, m, cols);
    let kpr = cols / m * n;
    let trits = rows * PackedTnm::trit_row_bytes(kpr);
    let scales = rows * (kpr / fitted) * 2;
    let blocks = rows * cols / m;
    let bits = PatternInfo::new(n, m).codebook_bits();
    trits + scales + packed_words(blocks, bits) * 8
}

/// Exact serialized bytes of one `k`:256 structured-outlier side stream
/// (bf16 value + one-byte index per salient entry).
pub fn outlier_stream_bytes(rows: usize, cols: usize, k_out: usize) -> usize {
    rows * cols / crate::sparse::outliers::OUTLIER_M * k_out * 3
}

/// Exact packed **base**-stream bytes of every prunable linear of
/// `cfg`, under pattern `n:m` (bf16 values when `quant` is `None`, int
/// codes + scales otherwise). This is the number an artifact's
/// [`crate::store::ArtifactInfo::linear_stream_bytes`] must equal.
pub fn model_linear_stream_bytes(
    cfg: &ModelConfig,
    n: usize,
    m: usize,
    quant: Option<QuantSpec>,
) -> usize {
    cfg.decode_linear_shapes()
        .iter()
        .map(|&(rows, cols)| match quant {
            None => nm_stream_bytes(rows, cols, n, m),
            Some(spec) => qnm_stream_bytes(rows, cols, n, m, spec),
        })
        .sum()
}

/// Exact packed-**ternary** base-stream bytes of every prunable linear
/// of `cfg` under pattern `n:m` — the ternary counterpart of
/// [`model_linear_stream_bytes`], gated against the written artifact by
/// `cargo bench --bench f4_coldstart`.
pub fn model_linear_stream_bytes_ternary(
    cfg: &ModelConfig,
    n: usize,
    m: usize,
    group: usize,
) -> usize {
    cfg.decode_linear_shapes()
        .iter()
        .map(|&(rows, cols)| tnm_stream_bytes(rows, cols, n, m, group))
        .sum()
}

/// Exact outlier side-stream bytes across the same linears.
pub fn model_outlier_stream_bytes(cfg: &ModelConfig, k_out: usize) -> usize {
    cfg.decode_linear_shapes()
        .iter()
        .map(|&(rows, cols)| outlier_stream_bytes(rows, cols, k_out))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask_topn_per_block;
    use crate::sparse::{PackedNm, StructuredOutliers};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn nm_model_is_byte_exact_against_the_packer() {
        let mut rng = Rng::new(71);
        for (rows, cols, n, m) in
            [(16usize, 256usize, 8usize, 16usize), (48, 512, 2, 4), (7, 64, 4, 8)]
        {
            let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let p = PackedNm::from_dense_mask(&w, &mask, n, m);
            let measured = p.values_raw().len() * 2 + p.meta_words().len() * 8;
            assert_eq!(measured, nm_stream_bytes(rows, cols, n, m), "{rows}x{cols} {n}:{m}");
        }
    }

    #[test]
    fn qnm_model_is_byte_exact_against_the_packer() {
        let mut rng = Rng::new(72);
        let spec = QuantSpec::int4_g128();
        for (rows, cols, n, m) in [(16usize, 256usize, 8usize, 16usize), (8, 512, 4, 8)] {
            let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let fitted = PackedQnm::fit_spec(spec, n, m, cols);
            let p = PackedQnm::from_dense_mask(&w, &mask, n, m, fitted);
            let measured =
                p.codes_raw().len() * 4 + p.scales_raw().len() * 2 + p.meta_words().len() * 8;
            assert_eq!(measured, qnm_stream_bytes(rows, cols, n, m, spec), "{n}:{m}");
        }
    }

    #[test]
    fn tnm_model_is_byte_exact_against_the_packer() {
        let mut rng = Rng::new(74);
        for (rows, cols, n, m) in
            [(16usize, 256usize, 8usize, 16usize), (8, 512, 4, 8), (7, 64, 2, 4)]
        {
            let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let fitted = PackedTnm::fit_group(128, n, m, cols);
            let p = PackedTnm::from_dense_mask(&w, &mask, n, m, fitted);
            let measured =
                p.trits_raw().len() + p.scales_raw().len() * 2 + p.meta_words().len() * 8;
            assert_eq!(measured, tnm_stream_bytes(rows, cols, n, m, 128), "{n}:{m}");
        }
    }

    #[test]
    fn outlier_model_is_byte_exact_against_the_packer() {
        let mut rng = Rng::new(73);
        let w = Tensor::randn(vec![16, 512], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 16, 256);
        let so = StructuredOutliers::from_dense_mask(&w, &mask, 16, 256);
        let measured = so.values_raw().len() * 2 + so.indices_raw().len();
        assert_eq!(measured, outlier_stream_bytes(16, 512, 16));
    }

    #[test]
    fn stream_bytes_track_table1_bits_per_param() {
        // the exact byte model is the analytic bits/param plus only the
        // trailing-word padding sliver (< 0.5% on paper-scale layers)
        let (rows, cols) = (1024usize, 1024usize);
        let exact = nm_stream_bytes(rows, cols, 8, 16);
        let analytic = crate::quant::nm_bits_per_param(8, 16) * (rows * cols) as f64 / 8.0;
        let ratio = exact as f64 / analytic;
        assert!(ratio >= 1.0 && ratio < 1.005, "{ratio}");
        let exact_q = qnm_stream_bytes(rows, cols, 8, 16, QuantSpec::int4_g128());
        let analytic_q =
            crate::quant::nm_quant_bits_per_param(8, 16, 4, 128) * (rows * cols) as f64 / 8.0;
        let ratio_q = exact_q as f64 / analytic_q;
        assert!(ratio_q >= 1.0 && ratio_q < 1.005, "{ratio_q}");
        let exact_t = tnm_stream_bytes(rows, cols, 8, 16, 128);
        let analytic_t =
            crate::quant::nm_ternary_bits_per_param(8, 16, 128) * (rows * cols) as f64 / 8.0;
        let ratio_t = exact_t as f64 / analytic_t;
        assert!(ratio_t >= 1.0 && ratio_t < 1.005, "{ratio_t}");
    }

    #[test]
    fn model_sums_cover_every_decode_linear() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let total = model_linear_stream_bytes(&cfg, 8, 16, None);
        let by_hand: usize = cfg
            .decode_linear_shapes()
            .iter()
            .map(|&(r, c)| nm_stream_bytes(r, c, 8, 16))
            .sum();
        assert_eq!(total, by_hand);
        assert!(model_outlier_stream_bytes(&cfg, 16) > 0);
        assert_eq!(model_outlier_stream_bytes(&cfg, 0), 0);
    }
}
