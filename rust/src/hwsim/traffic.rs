//! Byte-exact traffic accounting and a roofline latency model for dense
//! vs N:M-sparse GEMM.

use crate::quant::QuantSpec;
use crate::sparse::PatternInfo;

/// `y (b, n) = x (b, k) @ W^T (n, k)` — the linear-layer GEMM.
#[derive(Clone, Copy, Debug)]
pub struct GemmShape {
    pub b: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn new(b: usize, n: usize, k: usize) -> Self {
        GemmShape { b, n, k }
    }

    pub fn macs(&self) -> u64 {
        (self.b * self.n * self.k) as u64
    }
}

/// Device parameters (defaults approximate an A100-class accelerator; the
/// *ratios* the paper argues about are device-independent).
#[derive(Clone, Copy, Debug)]
pub struct HwModel {
    /// HBM bandwidth, bytes/s
    pub bandwidth: f64,
    /// dense MAC throughput, MAC/s (bf16)
    pub compute: f64,
    /// per-kernel launch overhead, s
    pub overhead: f64,
    /// can the MAC array skip zeros (sparse tensor cores)?
    pub sparse_compute: bool,
    /// weight element size in bytes (bf16)
    pub elem_bytes: f64,
}

impl Default for HwModel {
    fn default() -> Self {
        HwModel {
            bandwidth: 2.0e12,
            compute: 156e12,
            overhead: 5e-6,
            sparse_compute: true,
            elem_bytes: 2.0,
        }
    }
}

/// Traffic + latency for one GEMM under one storage format.
#[derive(Clone, Debug)]
pub struct TrafficReport {
    pub weight_bytes: f64,
    pub meta_bytes: f64,
    pub act_bytes: f64,
    pub macs: f64,
    pub mem_time: f64,
    pub compute_time: f64,
    pub latency: f64,
}

impl HwModel {
    /// Dense GEMM.
    pub fn dense(&self, g: GemmShape) -> TrafficReport {
        let weight_bytes = (g.n * g.k) as f64 * self.elem_bytes;
        let act_bytes = ((g.b * g.k) + (g.b * g.n)) as f64 * self.elem_bytes;
        let macs = g.macs() as f64;
        self.finish(weight_bytes, 0.0, act_bytes, macs)
    }

    /// N:M sparse GEMM with codebook metadata (the paper's format).
    pub fn sparse_nm(&self, g: GemmShape, n: usize, m: usize) -> TrafficReport {
        let p = PatternInfo::new(n, m);
        let kept = (g.n * g.k) as f64 * p.density();
        let weight_bytes = kept * self.elem_bytes;
        let meta_bytes = (g.n * g.k) as f64 * p.bits_per_element_codebook() / 8.0;
        let act_bytes = ((g.b * g.k) + (g.b * g.n)) as f64 * self.elem_bytes;
        let macs = if self.sparse_compute {
            g.macs() as f64 * p.density()
        } else {
            g.macs() as f64
        };
        self.finish(weight_bytes, meta_bytes, act_bytes, macs)
    }

    /// N:M sparse GEMM with **int-quantized kept values** (the
    /// [`crate::sparse::PackedQnm`] format): weight bytes are the
    /// `spec.bits`-wide codes plus one bf16 scale per `spec.group` kept
    /// values; metadata is the same codebook mask stream as
    /// [`Self::sparse_nm`]. At 8:16 / int4 / g128 the operand streams
    /// 2.9375 bits/param — 0.18× the dense bf16 bytes.
    pub fn sparse_nm_quant(
        &self,
        g: GemmShape,
        n: usize,
        m: usize,
        spec: QuantSpec,
    ) -> TrafficReport {
        let p = PatternInfo::new(n, m);
        let kept = (g.n * g.k) as f64 * p.density();
        let weight_bytes = kept * spec.bits as f64 / 8.0 + kept / spec.group as f64 * 2.0;
        let meta_bytes = (g.n * g.k) as f64 * p.bits_per_element_codebook() / 8.0;
        let act_bytes = ((g.b * g.k) + (g.b * g.n)) as f64 * self.elem_bytes;
        let macs = if self.sparse_compute {
            g.macs() as f64 * p.density()
        } else {
            g.macs() as f64
        };
        self.finish(weight_bytes, meta_bytes, act_bytes, macs)
    }

    /// N:M sparse GEMM with **ternary kept values** (the
    /// [`crate::sparse::PackedTnm`] format): weight bytes are the base-3
    /// trit stream (5 trits per byte, row-aligned — priced *exactly*,
    /// `ceil(kept_per_row / 5)` bytes per output row, not the asymptotic
    /// 1.6 bits/value, so the ±1% measured-vs-modeled gate holds at
    /// small widths) plus one bf16 scale per `group` kept values, with
    /// the group gcd-fitted per shape exactly as
    /// [`crate::sparse::PackedTnm::fit_group`] does at pack time.
    /// Metadata is the same codebook mask stream as [`Self::sparse_nm`].
    /// At 8:16 / g128 the operand streams ≈ 1.75 bits/param — 0.11× the
    /// dense bf16 bytes.
    pub fn sparse_nm_ternary(
        &self,
        g: GemmShape,
        n: usize,
        m: usize,
        group: usize,
    ) -> TrafficReport {
        use crate::sparse::PackedTnm;
        let p = PatternInfo::new(n, m);
        let kept_per_row = g.k / m * n;
        let fitted = PackedTnm::fit_group(group, n, m, g.k);
        let weight_bytes = (g.n * PackedTnm::trit_row_bytes(kept_per_row)) as f64
            + (g.n * (kept_per_row / fitted) * 2) as f64;
        let meta_bytes = (g.n * g.k) as f64 * p.bits_per_element_codebook() / 8.0;
        let act_bytes = ((g.b * g.k) + (g.b * g.n)) as f64 * self.elem_bytes;
        let macs = if self.sparse_compute {
            g.macs() as f64 * p.density()
        } else {
            g.macs() as f64
        };
        self.finish(weight_bytes, meta_bytes, act_bytes, macs)
    }

    /// Structured k:256 outlier side-stream (added to a sparse GEMM when
    /// salient weights are recovered).
    pub fn outlier_overhead(&self, g: GemmShape, k: usize) -> f64 {
        // k values (bf16) + k byte indices per 256 elements
        (g.n * g.k) as f64 * (k as f64 / 256.0) * (self.elem_bytes + 1.0)
    }

    /// CSR unstructured side-stream at the same salient budget.
    pub fn csr_overhead(&self, g: GemmShape, k: usize) -> f64 {
        // value (bf16) + u32 column index per nonzero + row pointers,
        // plus irregular-access inefficiency (each nonzero pulls a
        // partial cache line; model 2× amplification, Schulte et al. '23)
        let nnz = (g.n * g.k) as f64 * (k as f64 / 256.0);
        let raw = nnz * (self.elem_bytes + 4.0) + (g.n as f64 + 1.0) * 4.0;
        raw * 2.0
    }

    fn finish(
        &self,
        weight_bytes: f64,
        meta_bytes: f64,
        act_bytes: f64,
        macs: f64,
    ) -> TrafficReport {
        let bytes = weight_bytes + meta_bytes + act_bytes;
        let mem_time = bytes / self.bandwidth;
        let compute_time = macs / self.compute;
        TrafficReport {
            weight_bytes,
            meta_bytes,
            act_bytes,
            macs,
            mem_time,
            compute_time,
            latency: self.overhead + mem_time.max(compute_time),
        }
    }

    /// End-to-end speedup of N:M sparse over dense for one GEMM.
    pub fn speedup(&self, g: GemmShape, n: usize, m: usize) -> f64 {
        self.dense(g).latency / self.sparse_nm(g, n, m).latency
    }

    /// Device-parameter description embedded in `BENCH_*.json`
    /// trajectory files, so every recorded modeled number names the
    /// roofline that produced it (see `docs/BENCHMARKS.md`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("bandwidth_bytes_s", Json::num(self.bandwidth)),
            ("compute_macs_s", Json::num(self.compute)),
            ("overhead_s", Json::num(self.overhead)),
            ("sparse_compute", Json::Bool(self.sparse_compute)),
            ("elem_bytes", Json::num(self.elem_bytes)),
        ])
    }

    /// Modeled weight-operand traffic (values + pattern metadata bytes)
    /// of one packed N:M GEMM — the prediction side of the
    /// measured-vs-modeled comparison.
    pub fn nm_operand_bytes(&self, g: GemmShape, n: usize, m: usize) -> f64 {
        let r = self.sparse_nm(g, n, m);
        r.weight_bytes + r.meta_bytes
    }

    /// Compare the bytes a real kernel streams
    /// ([`crate::sparse::Kernel::operand_bytes`]) against this model's
    /// prediction for the same GEMM. Driven by `cargo bench --bench
    /// f2_spmm`, which walks the paper's layer shapes.
    pub fn check_nm_operand(
        &self,
        g: GemmShape,
        n: usize,
        m: usize,
        measured_bytes: usize,
    ) -> ModelCheck {
        ModelCheck {
            measured_bytes: measured_bytes as f64,
            modeled_bytes: self.nm_operand_bytes(g, n, m),
        }
    }

    /// Modeled weight-operand traffic of one packed-quant N:M GEMM
    /// (codes + scales + pattern metadata) — the prediction side of the
    /// measured-vs-modeled comparison for [`crate::sparse::PackedQnm`].
    pub fn nm_quant_operand_bytes(
        &self,
        g: GemmShape,
        n: usize,
        m: usize,
        spec: QuantSpec,
    ) -> f64 {
        let r = self.sparse_nm_quant(g, n, m, spec);
        r.weight_bytes + r.meta_bytes
    }

    /// Measured-vs-modeled for a packed-quant operand
    /// ([`crate::sparse::PackedQnm::bytes`] against
    /// [`Self::nm_quant_operand_bytes`]); `cargo bench --bench f2_spmm`
    /// asserts agreement within ±1%.
    pub fn check_nm_quant_operand(
        &self,
        g: GemmShape,
        n: usize,
        m: usize,
        spec: QuantSpec,
        measured_bytes: usize,
    ) -> ModelCheck {
        ModelCheck {
            measured_bytes: measured_bytes as f64,
            modeled_bytes: self.nm_quant_operand_bytes(g, n, m, spec),
        }
    }

    /// Modeled weight-operand traffic of one packed-ternary N:M GEMM
    /// (trits + scales + pattern metadata) — the prediction side of the
    /// measured-vs-modeled comparison for [`crate::sparse::PackedTnm`].
    pub fn nm_ternary_operand_bytes(
        &self,
        g: GemmShape,
        n: usize,
        m: usize,
        group: usize,
    ) -> f64 {
        let r = self.sparse_nm_ternary(g, n, m, group);
        r.weight_bytes + r.meta_bytes
    }

    /// Measured-vs-modeled for a packed-ternary operand
    /// ([`crate::sparse::PackedTnm::bytes`] against
    /// [`Self::nm_ternary_operand_bytes`]); `cargo bench --bench
    /// f2_spmm` asserts agreement within ±1%.
    pub fn check_nm_ternary_operand(
        &self,
        g: GemmShape,
        n: usize,
        m: usize,
        group: usize,
        measured_bytes: usize,
    ) -> ModelCheck {
        ModelCheck {
            measured_bytes: measured_bytes as f64,
            modeled_bytes: self.nm_ternary_operand_bytes(g, n, m, group),
        }
    }

    // ---------------------------------------------- decode-phase model
    //
    // One autoregressive decode step is a batch-1 GEMV per linear: the
    // activation row is tiny, so latency is weight-operand streaming —
    // the regime §8 says packed N:M wins most. `shapes` is the model's
    // per-step weight operand list with multiplicity
    // (`ModelConfig::decode_linear_shapes`); the measured counterpart is
    // `SparseLm::linear_operand_bytes`, which a decode step streams
    // exactly once.

    /// Modeled packed weight-operand bytes (values + pattern metadata,
    /// plus `k_out`:256 structured-outlier side streams when
    /// `k_out > 0`) one decode step streams across `shapes`.
    pub fn decode_operand_bytes(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
    ) -> f64 {
        shapes
            .iter()
            .map(|&(rows, cols)| {
                let g = GemmShape::new(1, rows, cols);
                let mut b = self.nm_operand_bytes(g, n, m);
                if k_out > 0 {
                    b += self.outlier_overhead(g, k_out);
                }
                b
            })
            .sum()
    }

    /// The dense bf16 weight bytes the same decode step would stream.
    pub fn decode_dense_bytes(&self, shapes: &[(usize, usize)]) -> f64 {
        shapes
            .iter()
            .map(|&(rows, cols)| (rows * cols) as f64 * self.elem_bytes)
            .sum()
    }

    /// Modeled end-to-end speedup of one packed decode step over dense:
    /// per-linear roofline latencies summed across `shapes` (each linear
    /// is its own kernel launch, like the spmm path runs them). When
    /// `k_out > 0` the `k_out`:256 outlier side stream's extra bytes are
    /// priced into the packed side, so the full paper format is not
    /// flattered with the base format's traffic.
    pub fn decode_speedup(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
    ) -> f64 {
        let dense: f64 = shapes
            .iter()
            .map(|&(rows, cols)| self.dense(GemmShape::new(1, rows, cols)).latency)
            .sum();
        let sparse: f64 = shapes
            .iter()
            .map(|&(rows, cols)| {
                let g = GemmShape::new(1, rows, cols);
                let r = self.sparse_nm(g, n, m);
                let extra = if k_out > 0 {
                    self.outlier_overhead(g, k_out) / self.bandwidth
                } else {
                    0.0
                };
                self.overhead + (r.mem_time + extra).max(r.compute_time)
            })
            .sum();
        dense / sparse
    }

    /// Measured-vs-modeled for the decode phase: the bytes a packed
    /// model's kernels report streaming per decode step
    /// (`SparseLm::linear_operand_bytes`) against
    /// [`Self::decode_operand_bytes`]. Driven by `cargo bench --bench
    /// f3_decode`.
    pub fn check_decode_operand(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
        measured_bytes: usize,
    ) -> ModelCheck {
        ModelCheck {
            measured_bytes: measured_bytes as f64,
            modeled_bytes: self.decode_operand_bytes(shapes, n, m, k_out),
        }
    }

    /// Modeled packed-quant weight-operand bytes one decode step streams
    /// across `shapes` (codes + scales + mask metadata, plus the
    /// `k_out`:256 bf16 outlier side stream when `k_out > 0`). The
    /// group is fitted per shape exactly as
    /// [`crate::sparse::PackedQnm::fit_spec`] does at pack time, so the
    /// model prices the bytes the kernel actually stores.
    pub fn decode_quant_operand_bytes(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
        spec: QuantSpec,
    ) -> f64 {
        shapes
            .iter()
            .map(|&(rows, cols)| {
                let g = GemmShape::new(1, rows, cols);
                let fitted = crate::sparse::PackedQnm::fit_spec(spec, n, m, cols);
                let mut b = self.nm_quant_operand_bytes(g, n, m, fitted);
                if k_out > 0 {
                    b += self.outlier_overhead(g, k_out);
                }
                b
            })
            .sum()
    }

    /// Modeled end-to-end speedup of one packed-quant decode step over
    /// dense — [`Self::decode_speedup`] with the quantized operand's
    /// (smaller) memory time on the packed side.
    pub fn decode_quant_speedup(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
        spec: QuantSpec,
    ) -> f64 {
        let dense: f64 = shapes
            .iter()
            .map(|&(rows, cols)| self.dense(GemmShape::new(1, rows, cols)).latency)
            .sum();
        let sparse: f64 = shapes
            .iter()
            .map(|&(rows, cols)| {
                let g = GemmShape::new(1, rows, cols);
                let fitted = crate::sparse::PackedQnm::fit_spec(spec, n, m, cols);
                let r = self.sparse_nm_quant(g, n, m, fitted);
                let extra = if k_out > 0 {
                    self.outlier_overhead(g, k_out) / self.bandwidth
                } else {
                    0.0
                };
                self.overhead + (r.mem_time + extra).max(r.compute_time)
            })
            .sum();
        dense / sparse
    }

    /// Measured-vs-modeled for the quantized decode phase
    /// (`SparseLm::linear_operand_bytes` of a `compress_quant` model
    /// against [`Self::decode_quant_operand_bytes`]). Driven by `cargo
    /// bench --bench f3_decode`.
    pub fn check_decode_quant_operand(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
        spec: QuantSpec,
        measured_bytes: usize,
    ) -> ModelCheck {
        ModelCheck {
            measured_bytes: measured_bytes as f64,
            modeled_bytes: self.decode_quant_operand_bytes(shapes, n, m, k_out, spec),
        }
    }

    /// Modeled packed-ternary weight-operand bytes one decode step
    /// streams across `shapes` (trits + scales + mask metadata, plus the
    /// `k_out`:256 bf16 outlier side stream when `k_out > 0`). The
    /// group is gcd-fitted per shape exactly as
    /// [`crate::sparse::PackedTnm::fit_group`] does at pack time.
    pub fn decode_ternary_operand_bytes(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
        group: usize,
    ) -> f64 {
        shapes
            .iter()
            .map(|&(rows, cols)| {
                let g = GemmShape::new(1, rows, cols);
                let mut b = self.nm_ternary_operand_bytes(g, n, m, group);
                if k_out > 0 {
                    b += self.outlier_overhead(g, k_out);
                }
                b
            })
            .sum()
    }

    /// Modeled end-to-end speedup of one packed-ternary decode step over
    /// dense — [`Self::decode_speedup`] with the trit operand's
    /// (smallest) memory time on the packed side.
    pub fn decode_ternary_speedup(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
        group: usize,
    ) -> f64 {
        let dense: f64 = shapes
            .iter()
            .map(|&(rows, cols)| self.dense(GemmShape::new(1, rows, cols)).latency)
            .sum();
        let sparse: f64 = shapes
            .iter()
            .map(|&(rows, cols)| {
                let g = GemmShape::new(1, rows, cols);
                let r = self.sparse_nm_ternary(g, n, m, group);
                let extra = if k_out > 0 {
                    self.outlier_overhead(g, k_out) / self.bandwidth
                } else {
                    0.0
                };
                self.overhead + (r.mem_time + extra).max(r.compute_time)
            })
            .sum();
        dense / sparse
    }

    /// Measured-vs-modeled for the ternary decode phase
    /// (`SparseLm::linear_operand_bytes` of a `compress_ternary` model
    /// against [`Self::decode_ternary_operand_bytes`]). Driven by `cargo
    /// bench --bench f3_decode`.
    pub fn check_decode_ternary_operand(
        &self,
        shapes: &[(usize, usize)],
        n: usize,
        m: usize,
        k_out: usize,
        group: usize,
        measured_bytes: usize,
    ) -> ModelCheck {
        ModelCheck {
            measured_bytes: measured_bytes as f64,
            modeled_bytes: self.decode_ternary_operand_bytes(shapes, n, m, k_out, group),
        }
    }
}

/// Measured-vs-modeled weight traffic for one packed operand.
#[derive(Clone, Copy, Debug)]
pub struct ModelCheck {
    pub measured_bytes: f64,
    pub modeled_bytes: f64,
}

impl ModelCheck {
    /// measured / modeled — 1.0 when the implementation streams exactly
    /// the bytes the roofline assumes (u64 word padding of the pattern
    /// stream adds a sliver above 1 on small matrices).
    pub fn ratio(&self) -> f64 {
        self.measured_bytes / self.modeled_bytes
    }

    /// |ratio - 1| ≤ tol.
    pub fn within(&self, tol: f64) -> bool {
        (self.ratio() - 1.0).abs() <= tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_gemm_speedup_approaches_2x() {
        let hw = HwModel::default();
        // decode-style batch=16: weight-bandwidth-bound
        let g = GemmShape::new(16, 8192, 8192);
        let s24 = hw.speedup(g, 2, 4);
        let s816 = hw.speedup(g, 8, 16);
        assert!(s24 > 1.7 && s24 < 2.0, "2:4 speedup {s24}");
        assert!(s816 > 1.7 && s816 < 2.0, "8:16 speedup {s816}");
        // 8:16 pays slightly more metadata than 2:4
        assert!(s816 <= s24);
    }

    #[test]
    fn small_gemm_overhead_bound() {
        let hw = HwModel::default();
        let g = GemmShape::new(1, 256, 256);
        let s = hw.speedup(g, 2, 4);
        assert!(s < 1.2, "small GEMM should see little speedup, got {s}");
    }

    #[test]
    fn speedup_scales_with_size() {
        // the paper's "~1.5-2x scaling with matrix size" claim
        let hw = HwModel::default();
        let sizes = [512usize, 1024, 2048, 4096, 8192];
        let mut prev = 0.0;
        for &d in &sizes {
            let s = hw.speedup(GemmShape::new(8, d, d), 8, 16);
            assert!(s >= prev - 1e-9, "monotone in size: {s} < {prev}");
            prev = s;
        }
        assert!(prev > 1.5);
    }

    #[test]
    fn metadata_bytes_match_table1() {
        let hw = HwModel::default();
        let g = GemmShape::new(1, 1024, 1024);
        let r24 = hw.sparse_nm(g, 2, 4);
        let r816 = hw.sparse_nm(g, 8, 16);
        let bits24 = r24.meta_bytes * 8.0 / (1024.0 * 1024.0);
        let bits816 = r816.meta_bytes * 8.0 / (1024.0 * 1024.0);
        assert!((bits24 - 0.75).abs() < 1e-9);
        assert!((bits816 - 0.875).abs() < 1e-9);
    }

    #[test]
    fn structured_outliers_cheaper_than_csr() {
        let hw = HwModel::default();
        let g = GemmShape::new(8, 4096, 4096);
        for k in [4usize, 8, 16] {
            assert!(hw.outlier_overhead(g, k) < hw.csr_overhead(g, k));
        }
    }

    #[test]
    fn measured_packed_bytes_match_model() {
        use crate::pruning::mask_topn_per_block;
        use crate::sparse::{Kernel, PackedNm};
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let hw = HwModel::default();
        let mut rng = Rng::new(9);
        for (n, m) in [(2usize, 4usize), (8, 16)] {
            let (rows, cols) = (256usize, 512usize);
            let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let packed = PackedNm::from_dense_mask(&w, &mask, n, m);
            let g = GemmShape::new(8, rows, cols);
            let chk = hw.check_nm_operand(g, n, m, packed.operand_bytes());
            assert!(chk.within(0.01), "{n}:{m}: ratio {}", chk.ratio());
        }
    }

    #[test]
    fn packed_operand_leq_060_dense_at_8_16() {
        // the bench acceptance bar, verified at model level too
        let hw = HwModel::default();
        let g = GemmShape::new(8, 4096, 4096);
        let dense = hw.dense(g).weight_bytes;
        assert!(hw.nm_operand_bytes(g, 8, 16) <= 0.60 * dense);
    }

    #[test]
    fn decode_step_is_bandwidth_bound_and_packed_wins() {
        let hw = HwModel::default();
        // paper-scale decoder: 7 block linears per layer, 32 layers
        let mut cfg = crate::model::ModelConfig::preset("e2e").unwrap();
        cfg.dim = 4096;
        cfg.hidden = 14336;
        cfg.n_layers = 32;
        cfg.n_heads = 32;
        cfg.n_kv_heads = 8;
        let shapes = cfg.decode_linear_shapes();
        let s816 = hw.decode_speedup(&shapes, 8, 16, 0);
        // batch-1 GEMVs: memory-bound, so speedup tracks the traffic
        // ratio (≈1/0.555 = 1.8) minus launch overhead
        assert!(s816 > 1.4 && s816 < 2.0, "decode speedup {s816}");
        // the outlier side stream costs real bandwidth: pricing it in
        // must strictly lower the modeled speedup
        let s_out = hw.decode_speedup(&shapes, 8, 16, 16);
        assert!(s_out < s816, "outliers priced in: {s_out} !< {s816}");
        assert!(s_out > 1.2, "still a win with outliers: {s_out}");
        // packed decode-step traffic ≤ 0.60× dense (the bench bar)
        let packed = hw.decode_operand_bytes(&shapes, 8, 16, 0);
        let dense = hw.decode_dense_bytes(&shapes);
        assert!(packed <= 0.60 * dense, "{packed} vs {dense}");
    }

    #[test]
    fn measured_decode_bytes_match_decode_model() {
        use crate::model::{ModelConfig, ParamSet, SparseLm};
        use crate::util::Rng;
        let hw = HwModel::default();
        let mut cfg = ModelConfig::preset("tiny").unwrap();
        cfg.n_layers = 2;
        cfg.vocab = 512;
        let mut rng = Rng::new(21);
        let params = ParamSet::init(&cfg, &mut rng);
        let shapes = cfg.decode_linear_shapes();
        for k_out in [0usize, 16] {
            let lm = SparseLm::compress(&params, 8, 16, k_out);
            let chk =
                hw.check_decode_operand(&shapes, 8, 16, k_out, lm.linear_operand_bytes());
            assert!(
                chk.within(0.01),
                "k_out={k_out}: measured/modeled ratio {}",
                chk.ratio()
            );
        }
    }

    #[test]
    fn device_description_json_has_all_params() {
        let j = HwModel::default().to_json();
        for key in [
            "bandwidth_bytes_s",
            "compute_macs_s",
            "overhead_s",
            "sparse_compute",
            "elem_bytes",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn quant_operand_is_2_9375_bits_per_param() {
        let hw = HwModel::default();
        let g = GemmShape::new(1, 1024, 1024);
        let spec = QuantSpec::int4_g128();
        let bytes = hw.nm_quant_operand_bytes(g, 8, 16, spec);
        let bits_per_param = bytes * 8.0 / (1024.0 * 1024.0);
        assert!((bits_per_param - 2.9375).abs() < 1e-9, "{bits_per_param}");
        // ≤ 0.20× dense bf16 — the f2/f3 acceptance bar, at model level
        let dense = hw.dense(g).weight_bytes;
        assert!(bytes <= 0.20 * dense, "{bytes} vs {dense}");
        // and it matches the shared accounting helper exactly
        let want = crate::quant::nm_quant_bits_per_param(8, 16, 4, 128);
        assert!((bits_per_param - want).abs() < 1e-9);
    }

    #[test]
    fn measured_packed_quant_bytes_match_model() {
        use crate::pruning::mask_topn_per_block;
        use crate::sparse::{Kernel, PackedQnm};
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let hw = HwModel::default();
        let mut rng = Rng::new(19);
        let (rows, cols) = (256usize, 512usize);
        let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let spec = QuantSpec::int4_g128();
        let packed = PackedQnm::from_dense_mask(&w, &mask, 8, 16, spec);
        let g = GemmShape::new(8, rows, cols);
        let chk = hw.check_nm_quant_operand(g, 8, 16, spec, packed.operand_bytes());
        assert!(chk.within(0.01), "ratio {}", chk.ratio());
    }

    #[test]
    fn measured_quant_decode_bytes_match_decode_model() {
        use crate::model::{ModelConfig, ParamSet, SparseLm};
        use crate::util::Rng;
        let hw = HwModel::default();
        let mut cfg = ModelConfig::preset("tiny").unwrap();
        cfg.n_layers = 2;
        cfg.vocab = 512;
        let mut rng = Rng::new(22);
        let params = ParamSet::init(&cfg, &mut rng);
        let shapes = cfg.decode_linear_shapes();
        let spec = QuantSpec::int4_g128();
        for k_out in [0usize, 16] {
            let lm = SparseLm::compress_quant(&params, 8, 16, k_out, spec);
            let chk = hw.check_decode_quant_operand(
                &shapes,
                8,
                16,
                k_out,
                spec,
                lm.linear_operand_bytes(),
            );
            assert!(
                chk.within(0.01),
                "k_out={k_out}: measured/modeled ratio {}",
                chk.ratio()
            );
            // quantized decode streams ≤ 0.20× the dense bf16 bytes
            if k_out == 0 {
                let dense = hw.decode_dense_bytes(&shapes);
                assert!(lm.linear_operand_bytes() as f64 <= 0.20 * dense);
            }
        }
        // pricing the quantized values in strictly raises the modeled
        // speedup over the bf16 packed format (fewer bytes, same macs)
        let s_bf16 = hw.decode_speedup(&shapes, 8, 16, 0);
        let s_q4 = hw.decode_quant_speedup(&shapes, 8, 16, 0, spec);
        assert!(s_q4 > s_bf16, "{s_q4} !> {s_bf16}");
    }

    #[test]
    fn ternary_operand_is_sub_2_bits_per_param() {
        let hw = HwModel::default();
        let g = GemmShape::new(1, 1024, 1024);
        let bytes = hw.nm_ternary_operand_bytes(g, 8, 16, 128);
        let bits_per_param = bytes * 8.0 / (1024.0 * 1024.0);
        // kept/row = 512 -> 103 trit bytes/row (exact) + 4 scales/row:
        // 0.875 mask + (103*8 + 64)/1024 = 1.7422 bits/param
        assert!((bits_per_param - (0.875 + (103.0 * 8.0 + 64.0) / 1024.0)).abs() < 1e-9);
        assert!(bits_per_param < 2.0, "{bits_per_param}");
        // ≤ 0.12× dense bf16 — the t158 f2/f3 acceptance bar, at model
        // level, and strictly under the int4 operand
        let dense = hw.dense(g).weight_bytes;
        assert!(bytes <= 0.12 * dense, "{bytes} vs {dense}");
        assert!(bytes < hw.nm_quant_operand_bytes(g, 8, 16, QuantSpec::int4_g128()));
    }

    #[test]
    fn measured_packed_ternary_bytes_match_model() {
        use crate::pruning::mask_topn_per_block;
        use crate::sparse::{Kernel, PackedTnm};
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let hw = HwModel::default();
        let mut rng = Rng::new(29);
        let (rows, cols) = (256usize, 512usize);
        let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let group = PackedTnm::fit_group(128, 8, 16, cols);
        let packed = PackedTnm::from_dense_mask(&w, &mask, 8, 16, group);
        let g = GemmShape::new(8, rows, cols);
        let chk = hw.check_nm_ternary_operand(g, 8, 16, 128, packed.operand_bytes());
        assert!(chk.within(0.01), "ratio {}", chk.ratio());
    }

    #[test]
    fn measured_ternary_decode_bytes_match_decode_model() {
        use crate::model::{ModelConfig, ParamSet, SparseLm};
        use crate::util::Rng;
        let hw = HwModel::default();
        let mut cfg = ModelConfig::preset("tiny").unwrap();
        cfg.n_layers = 2;
        cfg.vocab = 512;
        let mut rng = Rng::new(23);
        let params = ParamSet::init(&cfg, &mut rng);
        let shapes = cfg.decode_linear_shapes();
        for k_out in [0usize, 16] {
            let lm = SparseLm::compress_ternary(&params, 8, 16, k_out, 128);
            let chk = hw.check_decode_ternary_operand(
                &shapes,
                8,
                16,
                k_out,
                128,
                lm.linear_operand_bytes(),
            );
            assert!(
                chk.within(0.01),
                "k_out={k_out}: measured/modeled ratio {}",
                chk.ratio()
            );
            // ternary decode streams ≤ 0.12× the dense bf16 bytes
            if k_out == 0 {
                let dense = hw.decode_dense_bytes(&shapes);
                assert!(lm.linear_operand_bytes() as f64 <= 0.12 * dense);
            }
        }
        // fewer bytes than int4, same macs: modeled speedup must rise
        let s_q4 = hw.decode_quant_speedup(&shapes, 8, 16, 0, QuantSpec::int4_g128());
        let s_t = hw.decode_ternary_speedup(&shapes, 8, 16, 0, 128);
        assert!(s_t > s_q4, "{s_t} !> {s_q4}");
    }

    #[test]
    fn flops_halved_with_sparse_compute() {
        let hw = HwModel::default();
        let g = GemmShape::new(64, 1024, 1024);
        let d = hw.dense(g);
        let s = hw.sparse_nm(g, 8, 16);
        assert!((s.macs - d.macs * 0.5).abs() < 1.0);
    }
}
