//! The PJRT execution engine: one CPU client, a compiled-executable cache,
//! and typed execute helpers.
//!
//! `Engine` is `Sync`-shared across coordinator workers behind `Arc`; the
//! compile cache is a mutexed map keyed by artifact path (compilation
//! happens once per artifact per process, execution is lock-free after a
//! handle is cloned out... the `xla` crate's `PjRtLoadedExecutable` is a
//! ref-counted wrapper, cheap to clone).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::Context;

use super::manifest::Manifest;
use crate::util::timer::Stopwatch;

/// Shared PJRT engine with artifact compile caching.
pub struct Engine {
    client: xla::PjRtClient,
    root: PathBuf,
    cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
    /// cumulative (compiles, executes) counters for metrics
    stats: Mutex<EngineStats>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compiles: usize,
    pub executions: usize,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

impl Engine {
    /// Create a CPU engine rooted at the artifacts directory.
    pub fn new(artifacts_root: impl AsRef<Path>) -> crate::Result<Engine> {
        let root = artifacts_root.as_ref().to_path_buf();
        anyhow::ensure!(
            root.exists(),
            "artifacts root {} missing — run `make artifacts` first",
            root.display()
        );
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            client,
            root,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(EngineStats::default()),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Load the manifest of a model config directory (e.g. "tiny").
    pub fn model_manifest(&self, config: &str) -> crate::Result<Manifest> {
        Manifest::load(&self.root.join(config))
    }

    /// Load the manifest of a kernel shape directory (e.g. 512x256).
    pub fn kernel_manifest(&self, rows: usize, cols: usize) -> crate::Result<Manifest> {
        Manifest::load(&self.root.join("kernels").join(format!("{rows}x{cols}")))
    }

    /// Compile (or fetch from cache) an artifact by absolute file path.
    pub fn compile(&self, file: &Path) -> crate::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(Arc::clone(exe));
        }
        let sw = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(file)
            .with_context(|| format!("loading HLO text {}", file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", file.display()))?,
        );
        {
            let mut st = self.stats.lock().unwrap();
            st.compiles += 1;
            st.compile_secs += sw.secs();
        }
        log::debug!("compiled {} in {:.2}s", file.display(), sw.secs());
        self.cache
            .lock()
            .unwrap()
            .insert(file.to_path_buf(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Upload a host literal to a device-resident buffer, keeping the
    /// literal alive alongside it.
    ///
    /// `BufferFromHostLiteral` copies **asynchronously** on the TFRT CPU
    /// client and the C shim exposes no readiness hook, so the source
    /// literal must outlive the transfer; [`DeviceBuffer`] ties the two
    /// lifetimes together. Callers that execute the same inputs
    /// repeatedly (model parameters under eval/serve) should upload once
    /// and pass the buffers to [`Self::run_buffers`] — host→device
    /// copies then leave the hot path.
    pub fn upload(&self, lit: xla::Literal) -> crate::Result<DeviceBuffer> {
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok(DeviceBuffer { buf, _host: lit })
    }

    /// Execute an artifact: literals in, decomposed tuple of literals out.
    ///
    /// All aot.py graphs lower with `return_tuple=True`, so the single
    /// output buffer is a tuple literal that we decompose into the
    /// manifest-ordered outputs.
    ///
    /// NOTE: inputs are uploaded to device buffers here and freed after
    /// the call. The vendored `xla` crate's `execute::<Literal>` path is
    /// **not** used — its C shim leaks every input buffer
    /// (`BufferFromHostLiteral(..).release()` with no matching free),
    /// which OOM-killed long pipeline runs before this wrapper existed.
    /// Upload without retaining the literal — ONLY safe when the literal
    /// outlives the synchronous execute that consumes the buffer (the
    /// transfer is async; execution awaits it, so a literal that lives
    /// until the run's outputs materialize is sufficient).
    pub(crate) fn upload_borrowed(
        &self,
        lit: &xla::Literal,
    ) -> crate::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    pub fn run(
        &self,
        file: &Path,
        inputs: &[xla::Literal],
    ) -> crate::Result<Vec<xla::Literal>> {
        // borrowed uploads are safe here: the input literals outlive the
        // synchronous run_buffers call, which awaits the output chain
        let bufs = inputs
            .iter()
            .map(|l| self.upload_borrowed(l))
            .collect::<crate::Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_buffers(file, &refs)
    }

    /// Execute an artifact over device-resident input buffers (borrowed —
    /// the caller keeps ownership and can reuse them across calls).
    pub fn run_buffers(
        &self,
        file: &Path,
        inputs: &[&xla::PjRtBuffer],
    ) -> crate::Result<Vec<xla::Literal>> {
        let exe = self.compile(file)?;
        let sw = Stopwatch::start();
        let result = exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_secs += sw.secs();
        }
        Ok(outs)
    }

    /// Execute by (manifest, artifact-name) with input arity checking.
    pub fn run_artifact(
        &self,
        manifest: &Manifest,
        name: &str,
        inputs: &[xla::Literal],
    ) -> crate::Result<Vec<xla::Literal>> {
        let sig = manifest.artifact(name)?;
        anyhow::ensure!(
            sig.inputs.len() == inputs.len(),
            "artifact {name}: expected {} inputs, got {}",
            sig.inputs.len(),
            inputs.len()
        );
        let outs = self
            .run(&sig.file, inputs)
            .with_context(|| format!("executing artifact {name}"))?;
        anyhow::ensure!(
            outs.len() == sig.outputs.len(),
            "artifact {name}: expected {} outputs, got {}",
            sig.outputs.len(),
            outs.len()
        );
        Ok(outs)
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.lock().unwrap()
    }

    /// Number of distinct artifacts compiled so far.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// A device-resident input buffer paired with the host literal it was
/// uploaded from (the async `BufferFromHostLiteral` transfer reads the
/// literal after `upload` returns — see [`Engine::upload`]).
pub struct DeviceBuffer {
    buf: xla::PjRtBuffer,
    _host: xla::Literal,
}

impl std::ops::Deref for DeviceBuffer {
    type Target = xla::PjRtBuffer;
    fn deref(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}

/// Pre-resolved kernel manifest handles for one linear-layer shape — the
/// per-layer prune path asks for these once and then stays allocation-free
/// on the artifact-lookup side.
pub struct KernelSet {
    pub manifest: Manifest,
    pub rows: usize,
    pub cols: usize,
}

impl KernelSet {
    pub fn load(engine: &Engine, rows: usize, cols: usize) -> crate::Result<KernelSet> {
        Ok(KernelSet {
            manifest: engine.kernel_manifest(rows, cols)?,
            rows,
            cols,
        })
    }

    /// `score_sq0` / `score_sq1` artifact name for an SQ setting.
    pub fn score_name(sq: bool) -> &'static str {
        if sq {
            "score_sq1"
        } else {
            "score_sq0"
        }
    }

    /// `mask_{n}_{m}` artifact name.
    pub fn mask_name(n: usize, m: usize) -> String {
        format!("mask_{n}_{m}")
    }

    /// `finalize_vc{0,1}` artifact name.
    pub fn finalize_name(vc: bool) -> &'static str {
        if vc {
            "finalize_vc1"
        } else {
            "finalize_vc0"
        }
    }
}
