//! Artifact manifest parsing (the JSON contract written by `aot.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Option<TensorSig> {
        Some(TensorSig {
            shape: j.at("shape").usize_arr()?,
            dtype: j.at("dtype").as_str()?.to_string(),
        })
    }
}

/// One HLO artifact: file plus its I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// A parsed `manifest.json` (model dir or kernel-shape dir).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSig>,
    pub raw: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let raw = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let mut artifacts = BTreeMap::new();
        let arts = raw
            .at("artifacts")
            .as_obj()
            .context("manifest: artifacts must be an object")?;
        for (name, a) in arts {
            let parse_sigs = |key: &str| -> crate::Result<Vec<TensorSig>> {
                a.at(key)
                    .as_arr()
                    .context("sigs must be an array")?
                    .iter()
                    .map(|j| TensorSig::parse(j).context("bad tensor sig"))
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    name: name.clone(),
                    file: dir.join(a.at("file").as_str().context("file")?),
                    inputs: parse_sigs("inputs")?,
                    outputs: parse_sigs("outputs")?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            raw,
        })
    }

    pub fn artifact(&self, name: &str) -> crate::Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in {}", self.dir.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_manifest() {
        // written by `make artifacts`; skip silently when absent so unit
        // tests can run before the artifacts exist
        let dir = Path::new("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        let bf = m.artifact("block_fwd").unwrap();
        assert_eq!(bf.inputs.len(), 10);
        assert_eq!(bf.outputs.len(), 9);
        assert!(bf.file.exists());
        let cfg = m.raw.at("config");
        assert_eq!(cfg.at("name").as_str(), Some("tiny"));
    }

    #[test]
    fn missing_artifact_is_error() {
        let dir = Path::new("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.artifact("nope").is_err());
    }
}
