//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only boundary between the Rust coordinator and the XLA
//! world. Python never runs here — artifacts are self-contained HLO
//! modules compiled once per process and cached ([`Engine`]).
//!
//! The `xla` dependency is feature-gated: the default (offline) build
//! links the CPU stub in `vendor/xla`, under which every literal/upload
//! path here works but artifact compilation/execution returns a typed
//! error ([`pjrt_available`] reports which backend is linked). The
//! decode-free packed hot path ([`crate::sparse::spmm()`] +
//! [`crate::model::SparseLm`]) needs none of this and serves fully
//! offline.

mod engine;
mod manifest;

pub use engine::{DeviceBuffer, Engine, KernelSet};
pub use manifest::{ArtifactSig, Manifest, TensorSig};

use crate::tensor::Tensor;

/// True when the crate was built with the real PJRT backend
/// (`--features xla`); false under the offline `vendor/xla` CPU stub.
pub fn pjrt_available() -> bool {
    cfg!(feature = "xla")
}

/// Convert a host tensor to an f32 PJRT literal.
pub fn literal_f32(t: &Tensor) -> crate::Result<xla::Literal> {
    literal_f32_slice(t.data(), t.shape())
}

/// f32 literal directly from a slice + shape.
pub fn literal_f32_slice(data: &[f32], shape: &[usize]) -> crate::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {shape:?} vs len {}", data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// i32 literal from a slice + shape (token ids).
pub fn literal_i32(data: &[i32], shape: &[usize]) -> crate::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {shape:?} vs len {}", data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )?)
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Read an f32 literal back into a host tensor.
pub fn tensor_from_literal(lit: &xla::Literal) -> crate::Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

/// Read an f32 literal as a flat vec.
pub fn vec_from_literal(lit: &xla::Literal) -> crate::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
