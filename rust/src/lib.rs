//! # sparselm
//!
//! Reproduction of *"From 2:4 to 8:16 sparsity patterns in LLMs for Outliers
//! and Weights with Variance Correction"* as a three-layer Rust + JAX +
//! Pallas compression framework.
//!
//! * **Layer 1** (build-time Python): Pallas kernels for N:M mask selection,
//!   RIA scoring, masked GEMM, outlier extraction and variance correction.
//! * **Layer 2** (build-time Python): a LLaMA-style LM, its training step,
//!   the per-layer pruning graphs and the EBFT block fine-tuning step — all
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 3** (this crate): the production coordinator. It owns the
//!   event loop, the sparse storage formats **and the decode-free packed
//!   GEMM that serves them** ([`sparse::Kernel`] / [`sparse::spmm()`]),
//!   the host forward ([`model::SparseLm`]), calibration, the per-layer
//!   pruning scheduler, EBFT orchestration, evaluation harnesses, the
//!   hardware memory-traffic simulator, the scoring server and the CLI.
//!   Python never runs on the request path.
//!
//! Two execution backends share the eval/serve surfaces: the offline
//! default applies packed N:M weights straight from their bit-packed
//! storage (tokens → batcher → packed spmm → logits; weights never
//! expand to dense), and the artifact path executes the AOT HLO graphs
//! through PJRT ([`runtime::Engine`], `--features xla`). The request
//! path is walked through in `docs/ARCHITECTURE.md`; the packed on-disk
//! layout is specified in `docs/FORMAT.md`.
//!
//! Start with [`coordinator::CompressionPipeline`] for the paper's §4
//! pipeline, [`sparse`] for the storage formats and spmm kernels,
//! [`store`] for the `.spak` packed-model artifact container (mmap
//! zero-copy cold start), [`model::SparseLm::prefill`] /
//! [`model::SparseLm::decode_step`] for KV-cached generation, and
//! `examples/` for runnable entry points (`packed_serve` scores,
//! `packed_generate` decodes — both offline end-to-end demos).

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod hwsim;
pub mod model;
pub mod pruning;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod store;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Typed error conditions a serving process must survive without
/// aborting: malformed checkpoints/configs and bad CLI flags used to
/// `panic!` deep inside the coordinator, which would take the whole
/// server down. They now surface as `Error` variants carried through
/// [`anyhow`], so `crate::Result` call sites compose unchanged while
/// callers that care can still `downcast_ref::<Error>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Error {
    /// A parameter name outside the `ModelConfig::param_names` contract
    /// (e.g. a corrupted or foreign checkpoint).
    UnknownParam(String),
    /// A block parameter that is not one of the prunable linears.
    NotALinear(String),
    /// A `--key value` CLI flag that failed to parse as its declared type.
    BadFlag {
        key: String,
        value: String,
        want: &'static str,
    },
    /// A binary container whose magic bytes name a different format —
    /// shared by the checkpoint loader (`SPLM`) and the `.spak` artifact
    /// reader (`SPAK`), so "you passed the wrong file" is one
    /// downcastable condition everywhere.
    BadMagic {
        path: String,
        want: [u8; 4],
        got: [u8; 4],
    },
    /// A container written by an incompatible format version.
    BadVersion { path: String, want: u32, got: u32 },
    /// The container's payload checksum does not match its trailer —
    /// truncated tail, bit rot, or a partially written file.
    ChecksumMismatch { path: String, want: u64, got: u64 },
    /// The file ends before a section its header promises.
    Truncated { path: String, need: u64, have: u64 },
    /// A KV cache (or similar ring buffer) was requested with zero
    /// slots — a config with `seq == 0` or a bad capacity override.
    ZeroCapacity { what: &'static str },
    /// A KV rollback would expose positions the ring has already
    /// overwritten: once `len > capacity` the window has slid, and
    /// truncating below `len` cannot restore the discarded state.
    LossyRollback {
        len: usize,
        capacity: usize,
        new_len: usize,
    },
}

impl Error {
    fn fmt_magic(m: &[u8; 4]) -> String {
        m.iter()
            .map(|&b| {
                if b.is_ascii_graphic() {
                    (b as char).to_string()
                } else {
                    format!("\\x{b:02x}")
                }
            })
            .collect()
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::UnknownParam(name) => {
                write!(f, "unknown param {name:?} (not in the model's parameter contract)")
            }
            Error::NotALinear(name) => {
                write!(f, "not a prunable linear: {name:?}")
            }
            Error::BadFlag { key, value, want } => {
                write!(f, "--{key} expects {want}, got {value:?} (usage: --{key} <{want}>)")
            }
            Error::BadMagic { path, want, got } => {
                write!(
                    f,
                    "{path}: bad magic {:?} (want {:?} — not a {} file?)",
                    Error::fmt_magic(got),
                    Error::fmt_magic(want),
                    if want == b"SPAK" { "packed-model artifact" } else { "checkpoint" }
                )
            }
            Error::BadVersion { path, want, got } => {
                write!(f, "{path}: unsupported container version {got} (this build reads {want})")
            }
            Error::ChecksumMismatch { path, want, got } => {
                write!(
                    f,
                    "{path}: payload checksum mismatch (stored {want:#018x}, computed \
                     {got:#018x}) — corrupt or partially written file"
                )
            }
            Error::Truncated { path, need, have } => {
                write!(f, "{path}: truncated — header promises {need} bytes, file has {have}")
            }
            Error::ZeroCapacity { what } => {
                write!(f, "{what} needs at least one slot (capacity 0 requested)")
            }
            Error::LossyRollback {
                len,
                capacity,
                new_len,
            } => {
                write!(
                    f,
                    "cannot roll back to {new_len} positions: the ring slid past its \
                     capacity ({len} appended > {capacity} slots), so the discarded \
                     state is already overwritten"
                )
            }
        }
    }
}

impl std::error::Error for Error {}
