//! Parameter registry: the flat, named, artifact-ordered set of model
//! tensors the coordinator owns and feeds to PJRT.

use super::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Per-block parameter order — the contract with `aot.py` / `configs.py`.
pub const BLOCK_PARAMS: [&str; 9] =
    ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"];

/// The maskable (prunable) linears within a block, in BLOCK_PARAMS order.
pub const BLOCK_LINEAR: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];

/// A model's parameters in flat artifact order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub config: ModelConfig,
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    /// Initialize like `model.init_params`: N(0, 1/fan_in) linears,
    /// unit norm gains.
    pub fn init(config: &ModelConfig, rng: &mut Rng) -> ParamSet {
        let names = config.param_names();
        let tensors = names
            .iter()
            .map(|n| {
                let shape = config
                    .param_shape(n)
                    .expect("param_names() yields only known params");
                if shape.len() == 1 {
                    Tensor::ones(shape)
                } else {
                    let std = (shape[1] as f32).powf(-0.5);
                    Tensor::randn(shape, std, rng)
                }
            })
            .collect();
        ParamSet {
            config: config.clone(),
            names,
            tensors,
        }
    }

    /// Heavy-tailed init used by pruning benches when no trained
    /// checkpoint is required: realistic outlier structure without a
    /// training run.
    pub fn init_outliers(config: &ModelConfig, rng: &mut Rng) -> ParamSet {
        let mut ps = ParamSet::init(config, rng);
        for (name, t) in ps.names.clone().iter().zip(ps.tensors.iter_mut()) {
            let shape = config
                .param_shape(name)
                .expect("param_names() yields only known params");
            if shape.len() == 2 && name != "tok_emb" {
                let std = (shape[1] as f32).powf(-0.5);
                *t = Tensor::randn_outliers(shape, std, 0.005, 8.0, rng);
            }
        }
        ps
    }

    pub fn index_of(&self, name: &str) -> usize {
        self.names
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("unknown param {name}"))
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[self.index_of(name)]
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let i = self.index_of(name);
        &mut self.tensors[i]
    }

    /// Parameter indices of block `b` in BLOCK_PARAMS order.
    pub fn block_indices(&self, b: usize) -> Vec<usize> {
        let base = 1 + b * BLOCK_PARAMS.len();
        (base..base + BLOCK_PARAMS.len()).collect()
    }

    /// (name, index) of every prunable linear weight, block-major.
    pub fn linear_indices(&self) -> Vec<(String, usize)> {
        let mut out = Vec::new();
        for b in 0..self.config.n_layers {
            for p in BLOCK_LINEAR {
                let name = format!("blk{b}.{p}");
                let idx = self.index_of(&name);
                out.push((name, idx));
            }
        }
        out
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Zero-filled clone (optimizer state).
    pub fn zeros_like(&self) -> ParamSet {
        ParamSet {
            config: self.config.clone(),
            names: self.names.clone(),
            tensors: self
                .tensors
                .iter()
                .map(|t| Tensor::zeros(t.shape().to_vec()))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            dim: 256,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 4,
            hidden: 512,
            vocab: 512,
            seq: 32,
            batch: 2,
            rope_theta: 1e4,
            adam_b1: 0.9,
            adam_b2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.01,
        }
    }

    #[test]
    fn init_shapes_and_scales() {
        let mut rng = Rng::new(1);
        let ps = ParamSet::init(&cfg(), &mut rng);
        assert_eq!(ps.names.len(), ps.tensors.len());
        assert_eq!(ps.get("ln_f").data(), &vec![1.0f32; 256][..]);
        let wq = ps.get("blk0.wq");
        assert_eq!(wq.shape(), &[256, 256]);
        // std ≈ 1/16
        assert!((wq.var().sqrt() - 1.0 / 16.0).abs() < 0.005);
        assert_eq!(ps.n_params(), cfg().n_params());
    }

    #[test]
    fn block_indices_align_with_names() {
        let mut rng = Rng::new(2);
        let ps = ParamSet::init(&cfg(), &mut rng);
        let idx = ps.block_indices(1);
        assert_eq!(ps.names[idx[0]], "blk1.ln1");
        assert_eq!(ps.names[idx[8]], "blk1.wd");
    }

    #[test]
    fn linear_indices_cover_all_blocks() {
        let mut rng = Rng::new(3);
        let ps = ParamSet::init(&cfg(), &mut rng);
        let lins = ps.linear_indices();
        assert_eq!(lins.len(), 2 * 7);
        assert!(lins.iter().all(|(n, i)| &ps.names[*i] == n));
    }

    #[test]
    fn outlier_init_has_heavier_tails() {
        let mut rng = Rng::new(4);
        let a = ParamSet::init(&cfg(), &mut rng);
        let b = ParamSet::init_outliers(&cfg(), &mut rng);
        let am = a.get("blk0.wq").abs_max();
        let bm = b.get("blk0.wq").abs_max();
        assert!(bm > am * 2.0, "{bm} !> {am}*2");
    }
}
