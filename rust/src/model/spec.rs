//! Self-speculative decoding: int4 draft + bf16 batched verify.
//!
//! The repo holds two bitwise-characterized views of the same model —
//! [`SparseLm::compress_quant`] (PackedQnm, 2.9375 bits/param) and
//! [`SparseLm::compress`] (PackedNm, bf16 values) — built from one
//! weight set with a shared mask stream. [`SpecDecoder`] turns that
//! pair into single-stream decode speedup: draft `k` greedy tokens on
//! the cheap quantized GEMV path, then verify the whole window in one
//! k-row [`SparseLm::decode_window`] pass through the bf16 target,
//! whose batched `TiledGemm` dispatch streams the weights once instead
//! of k times.
//!
//! Acceptance is **exact-match**: a drafted token survives iff it
//! equals the target's own greedy argmax at that position, so the
//! emitted stream is token-for-token identical to plain bf16 greedy
//! decoding (no sampling approximation — `tests/spec_decode.rs` holds
//! the live server to bitwise parity). On the first divergence both KV
//! caches roll back via [`KvCache::truncate`] and decoding continues
//! from the target's token.
//!
//! Under non-greedy sampling the committed token may differ from the
//! speculated one: [`SpecDecoder::advance`] keeps a queue of
//! speculated `(token, logits)` pairs and transparently re-drafts from
//! the committed prefix on a mismatch, so the decoder is correct under
//! *any* sampler — speculation then only pays off as far as the
//! sampler happens to follow the greedy chain.
//!
//! The draft window adapts per sequence: full acceptance grows `k`,
//! under-50% acceptance shrinks it, clamped to `[K_MIN, K_MAX]`.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::eval::argmax;
use crate::util::{perf, trace};

use super::{KvCache, ModelConfig, SparseLm};

/// Smallest adaptive draft window (speculation effectively off).
pub const K_MIN: usize = 1;
/// Largest adaptive draft window — the batch-8 tiled-kernel sweet spot
/// measured by `perf_hotpath`.
pub const K_MAX: usize = 8;
/// Fresh sequences start mid-range and adapt from there.
const K_INIT: usize = 4;

/// A draft/target model pair for lossless greedy speculative decoding.
///
/// Both models must share a config (and, for the acceptance rate to be
/// non-trivial, a weight provenance — the intended pairing is
/// [`SparseLm::compress_quant`] draft + [`SparseLm::compress`] target
/// over the same parameters, which share one mask stream by
/// construction).
pub struct SpecDecoder {
    draft: Arc<SparseLm>,
    target: Arc<SparseLm>,
}

/// Per-sequence speculative state: the two KV caches (kept in lockstep
/// by every round), the committed-position counter, and the queue of
/// speculated tokens awaiting commitment.
pub struct SpecState {
    draft_cache: KvCache,
    target_cache: KvCache,
    /// cache positions confirmed by committed tokens — the rollback
    /// target whenever speculation ran ahead of the sampler
    committed: usize,
    /// speculated tokens already fed to both caches, front-first:
    /// `(expected token, target logits after feeding it)`
    pending: VecDeque<(i32, Vec<f32>)>,
    /// adaptive draft-window size, clamped to `[K_MIN, K_MAX]`
    k: usize,
}

impl SpecState {
    /// Reset for a fresh sequence, keeping storage **and** the adapted
    /// window size (acceptance propensity is a property of the model
    /// pair, not of one sequence).
    pub fn clear(&mut self) {
        self.draft_cache.clear();
        self.target_cache.clear();
        self.committed = 0;
        self.pending.clear();
    }

    /// Current adaptive draft-window size.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Positions committed so far (prompt + accepted tokens).
    pub fn committed(&self) -> usize {
        self.committed
    }
}

impl SpecDecoder {
    /// Pair a quantized draft with a bf16 target. The configs must
    /// match exactly — the two views describe the *same* model.
    pub fn new(draft: Arc<SparseLm>, target: Arc<SparseLm>) -> crate::Result<SpecDecoder> {
        anyhow::ensure!(
            draft.config == target.config,
            "speculative pair mismatch: draft is {:?} ({} params), target is {:?} ({} params) \
             — both views must come from the same model",
            draft.config.name,
            draft.config.n_params(),
            target.config.name,
            target.config.n_params(),
        );
        Ok(SpecDecoder { draft, target })
    }

    /// Build the canonical pair from one dense parameter set: int4
    /// draft and bf16 target share the mask stream by construction
    /// (both go through the same magnitude selection).
    pub fn from_dense(
        params: &super::ParamSet,
        n: usize,
        m: usize,
        k_out: usize,
        qspec: crate::quant::QuantSpec,
        threads: usize,
    ) -> crate::Result<SpecDecoder> {
        let draft =
            Arc::new(SparseLm::compress_quant(params, n, m, k_out, qspec).with_threads(threads));
        let target = Arc::new(SparseLm::compress(params, n, m, k_out).with_threads(threads));
        Self::new(draft, target)
    }

    /// [`Self::from_dense`] with a **ternary** draft
    /// ([`SparseLm::compress_ternary`], ≈ 1.75 bits/param at 8:16/g128)
    /// instead of int4. The acceptance contract is unchanged — exact
    /// match against the bf16 target keeps the emitted stream lossless —
    /// so a coarser draft only moves the accept *rate*, trading draft
    /// bandwidth (0.6× the int4 bytes) against shorter accepted runs.
    pub fn from_dense_ternary(
        params: &super::ParamSet,
        n: usize,
        m: usize,
        k_out: usize,
        group: usize,
        threads: usize,
    ) -> crate::Result<SpecDecoder> {
        let draft =
            Arc::new(SparseLm::compress_ternary(params, n, m, k_out, group).with_threads(threads));
        let target = Arc::new(SparseLm::compress(params, n, m, k_out).with_threads(threads));
        Self::new(draft, target)
    }

    /// The shared model config (draft and target agree by construction).
    pub fn config(&self) -> &ModelConfig {
        &self.target.config
    }

    /// The bf16 verify model — the distribution the output follows.
    pub fn target(&self) -> &Arc<SparseLm> {
        &self.target
    }

    /// The quantized draft model.
    pub fn draft(&self) -> &Arc<SparseLm> {
        &self.draft
    }

    /// Allocate per-sequence state sized to the model context window.
    pub fn new_state(&self) -> crate::Result<SpecState> {
        Ok(SpecState {
            draft_cache: KvCache::new(&self.draft.config)?,
            target_cache: KvCache::new(&self.target.config)?,
            committed: 0,
            pending: VecDeque::new(),
            k: K_INIT,
        })
    }

    /// Prefill `prompt` into both caches and return the target's
    /// last-position logits — bitwise identical to a plain
    /// [`SparseLm::prefill_last`] on the target, so admission through
    /// the speculative engine is indistinguishable from the plain one.
    pub fn start(&self, state: &mut SpecState, prompt: &[i32]) -> crate::Result<Vec<f32>> {
        state.clear();
        // the draft only needs its cache filled; its logits are unused
        let _ = self.draft.prefill_last(prompt, &mut state.draft_cache)?;
        let logits = self.target.prefill_last(prompt, &mut state.target_cache)?;
        state.committed = state.target_cache.len();
        Ok(logits)
    }

    /// Commit `tok` and return the target's next-token logits — the
    /// speculative equivalent of one [`SparseLm::decode_step`], bitwise
    /// identical to it row for row.
    ///
    /// If `tok` was speculated, the logits are served from the queue
    /// with no model call at all; otherwise the caches roll back to the
    /// committed prefix and a fresh draft/verify round runs.
    pub fn advance(&self, state: &mut SpecState, tok: i32) -> crate::Result<Vec<f32>> {
        if let Some(&(expected, _)) = state.pending.front() {
            if expected == tok {
                let (_, logits) = state.pending.pop_front().expect("front exists");
                state.committed += 1;
                return Ok(logits);
            }
            // the sampler left the speculated chain (impossible under
            // greedy): everything queued is stale
            perf::record_spec_mispredict();
            state.pending.clear();
        }
        self.round(state, tok)
    }

    /// One draft/verify round from the committed prefix: feed `tok`
    /// plus `w-1` drafted continuations to both models, accept the
    /// longest prefix of drafts matching the target's greedy choices,
    /// queue them for commitment, and return the logits after `tok`.
    fn round(&self, state: &mut SpecState, tok: i32) -> crate::Result<Vec<f32>> {
        let mut rsp = trace::span("spec.round");
        let _in_round = trace::scope(trace::Ctx {
            trace: rsp.trace(),
            span: rsp.id(),
        });
        // discard speculative positions past the committed prefix
        // (no-op when the previous window was fully committed); both
        // caches were fed the same window, so they stay in lockstep
        {
            let mut sp = trace::span("spec.rollback");
            sp.arg("to", state.committed);
            state.draft_cache.truncate(state.committed)?;
            state.target_cache.truncate(state.committed)?;
        }
        let cap = state.target_cache.capacity();
        anyhow::ensure!(
            state.committed < cap,
            "speculative round: {} committed positions already fill the context ({cap})",
            state.committed
        );
        // bound the window so the ring never slides — the rollback
        // above must stay exact (see KvCache::truncate)
        let w = state.k.min(cap - state.committed);
        rsp.arg("k", w);

        // ---- draft: w greedy steps on the quantized GEMV path --------
        let mut window = Vec::with_capacity(w);
        window.push(tok);
        let mut drafted = Vec::with_capacity(w);
        {
            let _d = perf::phase(perf::Phase::Draft);
            let mut sp = trace::span("spec.draft");
            sp.arg("tokens", w);
            let _in_draft = trace::scope(trace::Ctx {
                trace: sp.trace(),
                span: sp.id(),
            });
            let mut cur = tok;
            for _ in 0..w {
                let lg = self.draft.decode_step(&[cur], &mut [&mut state.draft_cache])?;
                cur = argmax(lg.row(0)) as i32;
                drafted.push(cur);
                if window.len() < w {
                    window.push(cur);
                }
            }
        }

        // ---- verify: one w-row batched forward on the bf16 target ----
        let logits = {
            let _v = perf::phase(perf::Phase::Verify);
            let mut sp = trace::span("spec.verify");
            sp.arg("rows", w);
            let _in_verify = trace::scope(trace::Ctx {
                trace: sp.trace(),
                span: sp.id(),
            });
            self.target.decode_window(&window, &mut state.target_cache)?
        };

        // longest prefix of drafts matching the target's own argmax
        let mut accepted = 0usize;
        while accepted < w && drafted[accepted] == argmax(logits.row(accepted)) as i32 {
            accepted += 1;
        }
        perf::record_spec_round(w, accepted);
        rsp.arg("accepted", accepted);

        // window[i] = drafted[i-1] for i >= 1: those positions are fed
        // and verified — queue them so the sampler can commit them
        // without another model call
        for i in 1..=accepted.min(w - 1) {
            state.pending.push_back((drafted[i - 1], logits.row(i).to_vec()));
        }
        state.committed += 1; // `tok` itself is committed by this call

        // adaptive window: grow on full acceptance, shrink under 50%
        if accepted == w {
            state.k = (state.k + 1).min(K_MAX);
        } else if accepted * 2 < w {
            state.k = state.k.saturating_sub(1).max(K_MIN);
        }
        Ok(logits.row(0).to_vec())
    }

    /// Autoregressive generation mirroring [`SparseLm::generate`]
    /// (same budget capping, same stop semantics) but speculative —
    /// under a greedy `pick` the output is token-for-token identical to
    /// `self.target().generate(..)`.
    pub fn generate(
        &self,
        prompt: &[i32],
        max_tokens: usize,
        stop: Option<i32>,
        mut pick: impl FnMut(&[f32]) -> usize,
    ) -> crate::Result<Vec<i32>> {
        anyhow::ensure!(!prompt.is_empty(), "generate: empty prompt");
        let mut state = self.new_state()?;
        let cap = state.target_cache.capacity();
        anyhow::ensure!(
            prompt.len() <= cap,
            "generate: prompt of {} tokens exceeds context capacity {cap}",
            prompt.len()
        );
        let budget = max_tokens.min(cap - prompt.len());
        let mut out = Vec::with_capacity(budget);
        if budget == 0 {
            return Ok(out);
        }
        let mut logits = self.start(&mut state, prompt)?;
        loop {
            let tok = pick(&logits) as i32;
            if Some(tok) == stop {
                break;
            }
            out.push(tok);
            if out.len() >= budget {
                break;
            }
            logits = self.advance(&mut state, tok)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Sampler;
    use crate::model::ParamSet;
    use crate::quant::QuantSpec;
    use crate::util::Rng;

    fn spec_cfg(seq: usize) -> ModelConfig {
        let mut cfg = ModelConfig::preset("gqa").unwrap();
        cfg.n_layers = 2;
        cfg.seq = seq;
        cfg.batch = 1;
        cfg.vocab = 256;
        cfg
    }

    fn pair(cfg: &ModelConfig, seed: u64) -> SpecDecoder {
        let mut rng = Rng::new(seed);
        let params = ParamSet::init_outliers(cfg, &mut rng);
        SpecDecoder::from_dense(&params, 8, 16, 16, QuantSpec::new(4, 128), 1).unwrap()
    }

    #[test]
    fn config_mismatch_is_rejected() {
        let cfg = spec_cfg(32);
        let mut other = cfg.clone();
        other.vocab = 512;
        let mut rng = Rng::new(7);
        let a = Arc::new(SparseLm::from_params(&ParamSet::init(&cfg, &mut rng)));
        let b = Arc::new(SparseLm::from_params(&ParamSet::init(&other, &mut rng)));
        assert!(SpecDecoder::new(a, b).is_err());
    }

    #[test]
    fn greedy_spec_generate_is_bitwise_plain_bf16_over_64_tokens() {
        // the tentpole acceptance bar, in-process: >= 64 greedy tokens,
        // token-for-token equal to the plain bf16 target decode
        let cfg = spec_cfg(80);
        let spec = pair(&cfg, 51);
        let mut rng = Rng::new(52);
        let prompt: Vec<i32> = (0..8).map(|_| rng.below(cfg.vocab) as i32).collect();
        let want = spec.target().generate(&prompt, 70, None, argmax).unwrap();
        let got = spec.generate(&prompt, 70, None, argmax).unwrap();
        assert_eq!(want.len(), 70);
        assert_eq!(got, want, "speculative output diverged from plain greedy");
    }

    #[test]
    fn ternary_draft_stream_is_still_bitwise_plain_bf16() {
        // a coarser draft may accept less, never emit differently: the
        // exact-match rule makes losslessness draft-independent
        let cfg = spec_cfg(64);
        let mut rng = Rng::new(57);
        let params = ParamSet::init_outliers(&cfg, &mut rng);
        let spec = SpecDecoder::from_dense_ternary(&params, 8, 16, 16, 128, 1).unwrap();
        let prompt: Vec<i32> = (0..6).map(|_| rng.below(cfg.vocab) as i32).collect();
        let want = spec.target().generate(&prompt, 50, None, argmax).unwrap();
        let got = spec.generate(&prompt, 50, None, argmax).unwrap();
        assert_eq!(got, want, "ternary-draft output diverged from plain greedy");
    }

    #[test]
    fn stop_token_semantics_match_plain_generate() {
        let cfg = spec_cfg(48);
        let spec = pair(&cfg, 53);
        let prompt = [3, 5, 7];
        let free = spec.generate(&prompt, 24, None, argmax).unwrap();
        assert_eq!(free.len(), 24);
        let stop = free[5];
        let first = free.iter().position(|&t| t == stop).unwrap();
        let stopped = spec.generate(&prompt, 24, Some(stop), argmax).unwrap();
        assert_eq!(stopped, free[..first].to_vec());
        let plain = spec.target().generate(&prompt, 24, Some(stop), argmax).unwrap();
        assert_eq!(stopped, plain);
    }

    #[test]
    fn budget_caps_at_context_window_without_ring_slide() {
        // drive the speculative windows right up against the cache
        // boundary: prompt 5 + 27 generated fills seq 32 exactly, and
        // every round's window is clamped so truncate stays exact
        let cfg = spec_cfg(32);
        let spec = pair(&cfg, 54);
        let prompt = [1, 2, 3, 4, 5];
        let got = spec.generate(&prompt, 100, None, argmax).unwrap();
        let want = spec.target().generate(&prompt, 100, None, argmax).unwrap();
        assert_eq!(got.len(), cfg.seq - prompt.len());
        assert_eq!(got, want);
    }

    #[test]
    fn sampled_decoding_survives_mispredicts_and_matches_plain_path() {
        // temperature > 0: the sampler leaves the greedy chain, forcing
        // rollbacks — the advance() stream must still be bitwise equal
        // to plain decode_step logits, so same seed -> same tokens
        let cfg = spec_cfg(48);
        let spec = pair(&cfg, 55);
        let prompt = [9, 11, 13];
        let run = |spec_path: bool| -> Vec<i32> {
            let mut sampler = Sampler::new(0.9, 424242);
            if spec_path {
                spec.generate(&prompt, 30, None, |l| sampler.next(l)).unwrap()
            } else {
                spec.target().generate(&prompt, 30, None, |l| sampler.next(l)).unwrap()
            }
        };
        let plain = run(false);
        let speculative = run(true);
        assert_eq!(speculative, plain, "sampled stream diverged");
        let d = perf::snapshot();
        assert!(d.spec_rounds > 0, "no speculative rounds ran");
    }

    #[test]
    fn adaptive_k_stays_clamped() {
        let cfg = spec_cfg(64);
        let spec = pair(&cfg, 56);
        let mut state = spec.new_state().unwrap();
        let mut logits = spec.start(&mut state, &[2, 4, 6]).unwrap();
        for _ in 0..40 {
            assert!((K_MIN..=K_MAX).contains(&state.k()), "k = {}", state.k());
            if state.committed() + 1 >= cfg.seq {
                break;
            }
            let tok = argmax(&logits) as i32;
            logits = spec.advance(&mut state, tok).unwrap();
        }
        // state reuse across sequences keeps the adapted k
        let k_after = state.k();
        spec.start(&mut state, &[1]).unwrap();
        assert_eq!(state.k(), k_after);
        assert_eq!(state.committed(), 1);
    }
}
