//! Binary checkpoint format (no serde offline): a small self-describing
//! container for a [`ParamSet`].
//!
//! Layout (little-endian):
//!
//! ```text
//! magic  b"SPLM"  | version u32 | json_len u32 | json bytes (config+names)
//! per tensor: rank u32, dims u64×rank, f32 data
//! trailer: crc32-like checksum u64 over all tensor bytes
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::Context;

use super::config::ModelConfig;
use super::params::ParamSet;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::{fnv1a, FNV_OFFSET};

const MAGIC: &[u8; 4] = b"SPLM";
const VERSION: u32 = 1;

/// Serialize a [`ModelConfig`] as the flat JSON object both binary
/// containers (checkpoint and `.spak` artifact) embed in their headers.
pub(crate) fn config_json(cfg: &ModelConfig) -> Json {
    Json::obj(vec![
        ("name", Json::str(cfg.name.clone())),
        ("dim", Json::num(cfg.dim as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("n_heads", Json::num(cfg.n_heads as f64)),
        ("n_kv_heads", Json::num(cfg.n_kv_heads as f64)),
        ("hidden", Json::num(cfg.hidden as f64)),
        ("vocab", Json::num(cfg.vocab as f64)),
        ("seq", Json::num(cfg.seq as f64)),
        ("batch", Json::num(cfg.batch as f64)),
        ("rope_theta", Json::num(cfg.rope_theta)),
        ("adam_b1", Json::num(cfg.adam_b1)),
        ("adam_b2", Json::num(cfg.adam_b2)),
        ("adam_eps", Json::num(cfg.adam_eps)),
        ("weight_decay", Json::num(cfg.weight_decay)),
    ])
}

/// Inverse of [`config_json`] (shared with the `.spak` reader, whose
/// input is untrusted — hence the typed error instead of the
/// trusted-manifest panic of [`ModelConfig::from_manifest`]).
pub(crate) fn config_from_json(j: &Json) -> crate::Result<ModelConfig> {
    let wrapped = Json::obj(vec![("config", j.clone())]);
    ModelConfig::try_from_manifest(&wrapped)
}

pub fn save_checkpoint(path: &Path, params: &ParamSet) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    let header = config_json(&params.config).to_string();
    w.write_all(&(header.len() as u32).to_le_bytes())?;
    w.write_all(header.as_bytes())?;

    let mut checksum = FNV_OFFSET;
    for t in &params.tensors {
        w.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
        };
        checksum = fnv1a(bytes, checksum);
        w.write_all(bytes)?;
    }
    w.write_all(&checksum.to_le_bytes())?;
    w.flush()?;
    Ok(())
}

pub fn load_checkpoint(path: &Path) -> crate::Result<ParamSet> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(crate::Error::BadMagic {
            path: path.display().to_string(),
            want: *MAGIC,
            got: magic,
        }
        .into());
    }
    let mut u32b = [0u8; 4];
    r.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        return Err(crate::Error::BadVersion {
            path: path.display().to_string(),
            want: VERSION,
            got: version,
        }
        .into());
    }
    r.read_exact(&mut u32b)?;
    let hlen = u32::from_le_bytes(u32b) as usize;
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
    let config = config_from_json(&header)?;

    let names = config.param_names();
    let mut tensors = Vec::with_capacity(names.len());
    let mut checksum = FNV_OFFSET;
    let mut u64b = [0u8; 8];
    for name in &names {
        r.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            r.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let want_shape = config.param_shape(name)?;
        anyhow::ensure!(
            dims == want_shape,
            "param {name}: checkpoint shape {dims:?} vs config {want_shape:?}"
        );
        let n: usize = dims.iter().product();
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        checksum = fnv1a(&bytes, checksum);
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push(Tensor::new(dims, data));
    }
    r.read_exact(&mut u64b)?;
    let want = u64::from_le_bytes(u64b);
    if want != checksum {
        return Err(crate::Error::ChecksumMismatch {
            path: path.display().to_string(),
            want,
            got: checksum,
        }
        .into());
    }
    Ok(ParamSet {
        config,
        names,
        tensors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "ckpt-test".into(),
            dim: 64,
            n_layers: 1,
            n_heads: 2,
            n_kv_heads: 2,
            hidden: 128,
            vocab: 128,
            seq: 16,
            batch: 2,
            rope_theta: 1e4,
            adam_b1: 0.9,
            adam_b2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.01,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(7);
        let ps = ParamSet::init(&cfg(), &mut rng);
        let dir = std::env::temp_dir().join("sparselm-test-ckpt");
        let path = dir.join("roundtrip.bin");
        save_checkpoint(&path, &ps).unwrap();
        let back = load_checkpoint(&path).unwrap();
        assert_eq!(back.config, ps.config);
        assert_eq!(back.names, ps.names);
        for (a, b) in back.tensors.iter().zip(&ps.tensors) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Rng::new(9);
        let ps = ParamSet::init(&cfg(), &mut rng);
        let dir = std::env::temp_dir().join("sparselm-test-ckpt");
        let path = dir.join("corrupt.bin");
        save_checkpoint(&path, &ps).unwrap();
        // flip one payload byte
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load_checkpoint(Path::new("/nonexistent/x.bin")).is_err());
    }

    #[test]
    fn magic_version_checksum_errors_are_typed() {
        let mut rng = Rng::new(11);
        let ps = ParamSet::init(&cfg(), &mut rng);
        let dir = std::env::temp_dir().join("sparselm-test-ckpt");
        let path = dir.join("typed.bin");
        save_checkpoint(&path, &ps).unwrap();
        let good = std::fs::read(&path).unwrap();

        // wrong magic
        let mut bytes = good.clone();
        bytes[..4].copy_from_slice(b"SPAK");
        std::fs::write(&path, &bytes).unwrap();
        let err = load_checkpoint(&path).unwrap_err();
        match err.downcast_ref::<crate::Error>() {
            Some(crate::Error::BadMagic { want, got, .. }) => {
                assert_eq!(want, b"SPLM");
                assert_eq!(got, b"SPAK");
            }
            other => panic!("want BadMagic, got {other:?}"),
        }

        // future version
        let mut bytes = good.clone();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_checkpoint(&path).unwrap_err();
        match err.downcast_ref::<crate::Error>() {
            Some(crate::Error::BadVersion { want, got, .. }) => {
                assert_eq!((*want, *got), (VERSION, 99));
            }
            other => panic!("want BadVersion, got {other:?}"),
        }

        // flipped payload byte
        let mut bytes = good.clone();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_checkpoint(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::Error>(),
                Some(crate::Error::ChecksumMismatch { .. })
            ),
            "want ChecksumMismatch, got {err:?}"
        );
        std::fs::remove_file(&path).ok();
    }
}
