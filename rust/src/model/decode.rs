//! KV-cached incremental forward: prefill + single-token decode steps.
//!
//! The monolithic scorer ([`SparseLm::lm_nll`]) recomputes every
//! position of a window per call; generation would make that O(L²) per
//! token. This module is the O(L)-per-token path the paper's decode
//! roofline (§8 / [`crate::hwsim`]) actually describes:
//!
//! * [`SparseLm::prefill`] runs a prompt once, filling a
//!   [`KvCache`] and returning per-position logits;
//! * [`SparseLm::decode_step`] advances a **batch of independent
//!   sequences** by one token each — the activations of all sequences
//!   share each packed-weight GEMM, so pattern unranking and bf16
//!   widening amortize across the decode batch exactly as they do
//!   across prefill rows (the continuous-batching scheduler in
//!   [`crate::serve`] lives on this property);
//! * a single-sequence step routes every linear through
//!   [`crate::sparse::spmm_vec`], the one-activation-row GEMV fast
//!   path.
//!
//! Per-sequence results are **independent of decode-batch
//! composition**: every kernel accumulates each activation row
//! separately and attention reads only the sequence's own cache, so a
//! sequence decoded alone is bitwise identical to the same sequence
//! decoded while sharing the batch with others (asserted in the tests
//! below). Incremental logits match the full-sequence forward
//! ([`SparseLm::full_logits`]) step-for-step within f32 tolerance —
//! `tests/generate_parity.rs` holds both backends to that.

use crate::sparse::{spmm_vec, Kernel};
use crate::tensor::{dot, Tensor};
use crate::util::perf;

use super::forward::{apply_rope, rmsnorm, rope_tables_range, rotate_heads, silu};
use super::kv::KvCache;
use super::SparseLm;

impl SparseLm {
    /// Apply a linear to `rows` activations, taking the
    /// [`spmm_vec`] GEMV fast path when there is exactly one row — the
    /// bandwidth-bound decode shape the packed formats exist for.
    fn lin_rows(&self, w: &dyn Kernel, x: &Tensor) -> Tensor {
        if x.dims2().0 == 1 {
            let out = spmm_vec(x.row(0), w);
            Tensor::new(vec![1, out.len()], out)
        } else {
            self.lin(w, x)
        }
    }

    /// Run `tokens` (one sequence) through the model, appending their
    /// K/V rows to `cache`, and return the `(len, vocab)` logits of
    /// every prompt position. The cache may already hold context
    /// (chunked prefill); `cache.len() + tokens.len()` must fit the
    /// cache capacity so the attended window never slides mid-prompt.
    ///
    /// Generation only needs the *last* position's logits — use
    /// [`Self::prefill_last`] there: the tied-head GEMM is the model's
    /// largest matmul, and running it over every prompt row just to
    /// discard all but one is `len×` wasted head compute.
    pub fn prefill(&self, tokens: &[i32], cache: &mut KvCache) -> crate::Result<Tensor> {
        let h = self.prefill_hidden(tokens, cache)?;
        let xf = rmsnorm(&h, &self.ln_f);
        Ok(self.lin_rows(&self.tok_emb, &xf))
    }

    /// [`Self::prefill`] computing the head only for the final prompt
    /// position — the admission path of the generation engine. The
    /// returned row is bitwise identical to the last row of
    /// [`Self::prefill`] (per-row independent norm + GEMV).
    pub fn prefill_last(&self, tokens: &[i32], cache: &mut KvCache) -> crate::Result<Vec<f32>> {
        let h = self.prefill_hidden(tokens, cache)?;
        let (rows, d) = h.dims2();
        let last = Tensor::new(vec![1, d], h.row(rows - 1).to_vec());
        let xf = rmsnorm(&last, &self.ln_f);
        Ok(self.lin_rows(&self.tok_emb, &xf).into_data())
    }

    /// Shared prefill body: block stack + cache writes, stopping before
    /// the final norm/head.
    fn prefill_hidden(&self, tokens: &[i32], cache: &mut KvCache) -> crate::Result<Tensor> {
        let _perf = perf::phase(perf::Phase::Prefill);
        self.extend_hidden(tokens, cache)
    }

    /// Append `tokens` (one sequence) to `cache` and return the hidden
    /// states of every appended position — the phase-agnostic body
    /// shared by prompt prefill and the speculative-decode verify
    /// window. Callers wrap it in the [`perf::Phase`] that matches
    /// their role.
    fn extend_hidden(&self, tokens: &[i32], cache: &mut KvCache) -> crate::Result<Tensor> {
        let cfg = &self.config;
        let s = tokens.len();
        anyhow::ensure!(s > 0, "extend: empty token sequence");
        anyhow::ensure!(
            cache.len() + s <= cache.capacity(),
            "extend: {} cached + {s} new tokens exceed cache capacity {}",
            cache.len(),
            cache.capacity()
        );
        let (nh, nkv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let kvd = cfg.kv_dim();
        let d = cfg.dim;
        let start = cache.len();

        let mut h = self.embed(tokens); // (s, d)
        let rope = rope_tables_range(start, s, hd, cfg.rope_theta);
        for (bi, blk) in self.blocks.iter().enumerate() {
            let x = rmsnorm(&h, &blk.ln1);
            let mut q = self.lin_rows(&*blk.wq, &x);
            let mut k = self.lin_rows(&*blk.wk, &x);
            let v = self.lin_rows(&*blk.wv, &x);
            apply_rope(&mut q, 1, s, nh, hd, &rope.0, &rope.1);
            apply_rope(&mut k, 1, s, nkv, hd, &rope.0, &rope.1);
            for p in 0..s {
                cache.put(bi, start + p, &k.row(p)[..kvd], &v.row(p)[..kvd]);
            }
            let mut o = vec![0.0f32; s * d];
            for p in 0..s {
                let orow = &mut o[p * d..(p + 1) * d];
                attend_cached(q.row(p), cache, bi, start + p, nh, nkv, hd, orow);
            }
            let attn_out = self.lin_rows(&*blk.wo, &Tensor::new(vec![s, d], o));
            let h1 = h.add(&attn_out);
            let y = rmsnorm(&h1, &blk.ln2);
            let g = self.lin_rows(&*blk.wg, &y);
            let u = self.lin_rows(&*blk.wu, &y);
            let z = g.zip(&u, |gv, uv| silu(gv) * uv);
            let mlp = self.lin_rows(&*blk.wd, &z);
            h = h1.add(&mlp);
        }
        cache.advance(s);
        Ok(h)
    }

    /// Append a multi-token window of **one** sequence to its cache and
    /// return the `(window, vocab)` logits of every appended position —
    /// the speculative-decode verify path: row `i` is bitwise identical
    /// to the row [`Self::decode_step`] would have produced for
    /// `tokens[i]` at the same cache state, but all rows share each
    /// packed-weight GEMM (the batched `TiledGemm` dispatch), so a
    /// k-token window streams the weights once instead of k times.
    ///
    /// The bitwise identity holds because every per-position computation
    /// is shared with the single-step path: RoPE tables are computed
    /// per absolute position in f64, norm is per-row, attention reads
    /// only the sequence's own cache, and the batched kernels accumulate
    /// each activation row independently (`tests/spmm_tiling.rs` pins
    /// GEMV ≡ tiled per row). Time is metered as [`perf::Phase::Decode`]
    /// — the caller may additionally meter it as a verify region.
    pub fn decode_window(&self, tokens: &[i32], cache: &mut KvCache) -> crate::Result<Tensor> {
        let _perf = perf::phase(perf::Phase::Decode);
        let h = self.extend_hidden(tokens, cache)?;
        let xf = rmsnorm(&h, &self.ln_f);
        Ok(self.lin_rows(&self.tok_emb, &xf))
    }

    /// Advance a batch of independent sequences by one token each:
    /// `toks[i]` is appended to the sequence whose state is `caches[i]`,
    /// and row `i` of the returned `(len, vocab)` tensor holds that
    /// sequence's next-token logits.
    ///
    /// All sequences share each weight GEMM (the decode batch is the
    /// activation matrix), but attention, RoPE position and cache are
    /// strictly per-sequence — results do not depend on which other
    /// sequences happen to share the step.
    pub fn decode_step(
        &self,
        toks: &[i32],
        caches: &mut [&mut KvCache],
    ) -> crate::Result<Tensor> {
        let _perf = perf::phase(perf::Phase::Decode);
        let b = toks.len();
        anyhow::ensure!(b > 0, "decode_step: empty batch");
        anyhow::ensure!(
            caches.len() == b,
            "decode_step: {b} tokens but {} caches",
            caches.len()
        );
        let cfg = &self.config;
        let (nh, nkv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let kvd = cfg.kv_dim();
        let d = cfg.dim;
        // each sequence decodes at its own absolute position
        let pos: Vec<usize> = caches.iter().map(|c| c.len()).collect();
        let rope_rows: Vec<(Vec<f32>, Vec<f32>)> = pos
            .iter()
            .map(|&p| rope_tables_range(p, 1, hd, cfg.rope_theta))
            .collect();

        let mut h = self.embed(toks); // (b, d)
        for (bi, blk) in self.blocks.iter().enumerate() {
            let x = rmsnorm(&h, &blk.ln1);
            let mut q = self.lin_rows(&*blk.wq, &x);
            let mut k = self.lin_rows(&*blk.wk, &x);
            let v = self.lin_rows(&*blk.wv, &x);
            for i in 0..b {
                let (cos, sin) = &rope_rows[i];
                rotate_heads(&mut q.row_mut(i)[..d], nh, hd, cos, sin);
                rotate_heads(&mut k.row_mut(i)[..kvd], nkv, hd, cos, sin);
                caches[i].put(bi, pos[i], &k.row(i)[..kvd], &v.row(i)[..kvd]);
            }
            let mut o = vec![0.0f32; b * d];
            for i in 0..b {
                attend_cached(
                    q.row(i),
                    &*caches[i],
                    bi,
                    pos[i],
                    nh,
                    nkv,
                    hd,
                    &mut o[i * d..(i + 1) * d],
                );
            }
            let attn_out = self.lin_rows(&*blk.wo, &Tensor::new(vec![b, d], o));
            let h1 = h.add(&attn_out);
            let y = rmsnorm(&h1, &blk.ln2);
            let g = self.lin_rows(&*blk.wg, &y);
            let u = self.lin_rows(&*blk.wu, &y);
            let z = g.zip(&u, |gv, uv| silu(gv) * uv);
            let mlp = self.lin_rows(&*blk.wd, &z);
            h = h1.add(&mlp);
        }
        for c in caches.iter_mut() {
            c.advance(1);
        }
        let xf = rmsnorm(&h, &self.ln_f);
        Ok(self.lin_rows(&self.tok_emb, &xf))
    }

    /// Autoregressive generation for one sequence: prefill the prompt,
    /// then decode until `max_tokens` tokens are emitted or `pick`
    /// selects the `stop` token (which is not emitted). `pick` maps a
    /// logits row to the chosen token id (greedy argmax, temperature
    /// sampling, …; see [`crate::eval::Sampler`]).
    ///
    /// The budget is capped so `prompt + generated` fits the model's
    /// context window — generation never silently degrades to
    /// sliding-window attention, keeping the output identical to a
    /// full-sequence greedy decode (the `tests/generate_parity.rs`
    /// guarantee). This is the same loop the serve-layer scheduler and
    /// the `generate` CLI subcommand run.
    pub fn generate(
        &self,
        prompt: &[i32],
        max_tokens: usize,
        stop: Option<i32>,
        mut pick: impl FnMut(&[f32]) -> usize,
    ) -> crate::Result<Vec<i32>> {
        anyhow::ensure!(!prompt.is_empty(), "generate: empty prompt");
        let mut cache = KvCache::new(&self.config)?;
        anyhow::ensure!(
            prompt.len() <= cache.capacity(),
            "generate: prompt of {} tokens exceeds context capacity {}",
            prompt.len(),
            cache.capacity()
        );
        let budget = max_tokens.min(cache.capacity() - prompt.len());
        let mut out = Vec::with_capacity(budget);
        if budget == 0 {
            return Ok(out);
        }
        let logits = self.prefill_last(prompt, &mut cache)?;
        let mut tok = pick(&logits) as i32;
        while Some(tok) != stop {
            out.push(tok);
            if out.len() >= budget {
                break;
            }
            let lg = self.decode_step(&[tok], &mut [&mut cache])?;
            tok = pick(lg.row(0)) as i32;
        }
        Ok(out)
    }
}

/// Causal softmax attention of one query row against a sequence's
/// cache: query at absolute position `pos` attends every cached
/// position in the ring's window up to and including itself, with GQA
/// head grouping (`q` head `h` reads kv head `h / (nh/nkv)`).
/// Accumulates the context vector into `out` (`nh * hd` floats,
/// pre-zeroed).
#[allow(clippy::too_many_arguments)]
fn attend_cached(
    q_row: &[f32],
    cache: &KvCache,
    blk: usize,
    pos: usize,
    nh: usize,
    nkv: usize,
    hd: usize,
    out: &mut [f32],
) {
    let rep = nh / nkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let lo = (pos + 1).saturating_sub(cache.capacity());
    let span = pos + 1 - lo;
    let mut att = vec![0.0f32; span];
    for hh in 0..nh {
        let kvh = hh / rep;
        let qvec = &q_row[hh * hd..(hh + 1) * hd];
        let mut mx = f32::NEG_INFINITY;
        for (ai, kp) in (lo..=pos).enumerate() {
            let kvec = &cache.k_row(blk, kp)[kvh * hd..][..hd];
            let sc = dot(qvec, kvec) * scale;
            att[ai] = sc;
            if sc > mx {
                mx = sc;
            }
        }
        let mut denom = 0.0f32;
        for a in att.iter_mut() {
            *a = (*a - mx).exp();
            denom += *a;
        }
        let inv = 1.0 / denom;
        let orow = &mut out[hh * hd..(hh + 1) * hd];
        for (ai, kp) in (lo..=pos).enumerate() {
            let w = att[ai] * inv;
            let vvec = &cache.v_row(blk, kp)[kvh * hd..][..hd];
            for (o, &vv) in orow.iter_mut().zip(vvec) {
                *o += w * vv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ParamSet};
    use crate::tensor::rel_error;
    use crate::util::propcheck::assert_allclose;
    use crate::util::Rng;

    fn small_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::preset("gqa").unwrap();
        cfg.n_layers = 2;
        cfg.seq = 24;
        cfg.batch = 1;
        cfg.vocab = 512;
        cfg
    }

    fn toks(n: usize, cfg: &ModelConfig, rng: &mut Rng) -> Vec<i32> {
        (0..n).map(|_| rng.below(cfg.vocab) as i32).collect()
    }

    #[test]
    fn prefill_matches_full_logits() {
        let cfg = small_cfg();
        let mut rng = Rng::new(41);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let prompt = toks(9, &cfg, &mut rng);
        let want = lm.full_logits(&prompt).unwrap();
        let mut cache = KvCache::new(&cfg).unwrap();
        let got = lm.prefill(&prompt, &mut cache).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert_eq!(cache.len(), prompt.len());
        assert!(
            rel_error(&got, &want) < 1e-5,
            "prefill vs full: {}",
            rel_error(&got, &want)
        );
        // the admission-path variant is the last row, bitwise
        let mut cache2 = KvCache::new(&cfg).unwrap();
        let last = lm.prefill_last(&prompt, &mut cache2).unwrap();
        assert_eq!(last.as_slice(), got.row(prompt.len() - 1));
        assert_eq!(cache2.len(), prompt.len());
    }

    #[test]
    fn decode_steps_match_full_logits_at_every_position() {
        let cfg = small_cfg();
        let mut rng = Rng::new(42);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let seq = toks(14, &cfg, &mut rng);
        let mut cache = KvCache::new(&cfg).unwrap();
        lm.prefill(&seq[..4], &mut cache).unwrap();
        for t in 4..seq.len() {
            let lg = lm.decode_step(&[seq[t]], &mut [&mut cache]).unwrap();
            let full = lm.full_logits(&seq[..=t]).unwrap();
            let last = full.row(t);
            assert_allclose(lg.row(0), last, 1e-4, 1e-5)
                .unwrap_or_else(|e| panic!("step {t}: {e}"));
        }
    }

    #[test]
    fn batched_decode_is_independent_of_batch_composition() {
        let cfg = small_cfg();
        let mut rng = Rng::new(43);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let a = toks(6, &cfg, &mut rng);
        let b = toks(3, &cfg, &mut rng);

        // joint: both sequences share each decode step's GEMMs
        let mut ca = KvCache::new(&cfg).unwrap();
        let mut cb = KvCache::new(&cfg).unwrap();
        lm.prefill(&a, &mut ca).unwrap();
        lm.prefill(&b, &mut cb).unwrap();
        let joint = lm
            .decode_step(&[7, 9], &mut [&mut ca, &mut cb])
            .unwrap();

        // solo: each sequence decoded alone (spmm_vec fast path)
        let mut ca2 = KvCache::new(&cfg).unwrap();
        let mut cb2 = KvCache::new(&cfg).unwrap();
        lm.prefill(&a, &mut ca2).unwrap();
        lm.prefill(&b, &mut cb2).unwrap();
        let solo_a = lm.decode_step(&[7], &mut [&mut ca2]).unwrap();
        let solo_b = lm.decode_step(&[9], &mut [&mut cb2]).unwrap();

        assert_eq!(joint.row(0), solo_a.row(0), "seq a depends on batch-mate");
        assert_eq!(joint.row(1), solo_b.row(0), "seq b depends on batch-mate");
    }

    #[test]
    fn generate_is_deterministic_greedy() {
        let cfg = small_cfg();
        let mut rng = Rng::new(44);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let prompt = toks(5, &cfg, &mut rng);
        let pick = |l: &[f32]| {
            l.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        let g1 = lm.generate(&prompt, 8, None, pick).unwrap();
        let g2 = lm.generate(&prompt, 8, None, pick).unwrap();
        assert_eq!(g1.len(), 8);
        assert_eq!(g1, g2);
        assert!(g1.iter().all(|&t| (t as usize) < cfg.vocab));
        // a stop token ends generation at its *first* occurrence,
        // without being emitted (greedy chains may repeat tokens)
        let stop = g1[2];
        let first = g1.iter().position(|&t| t == stop).unwrap();
        let stopped = lm.generate(&prompt, 8, Some(stop), pick).unwrap();
        assert_eq!(stopped, g1[..first].to_vec());
    }

    #[test]
    fn generate_budget_capped_at_context_window() {
        // prompt + generated never exceeds the cache capacity: the
        // window must not silently slide mid-generation
        let cfg = small_cfg(); // seq = 24
        let mut rng = Rng::new(46);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let prompt = toks(20, &cfg, &mut rng);
        let out = lm.generate(&prompt, 100, None, |l| {
            l.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        }).unwrap();
        assert_eq!(out.len(), cfg.seq - prompt.len());
    }

    #[test]
    fn decode_window_rows_bitwise_match_decode_steps() {
        // the speculative-verify contract: a k-row window through the
        // batched kernels produces, row for row, the exact bits the
        // one-token GEMV path would have produced
        let cfg = small_cfg();
        let mut rng = Rng::new(47);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let prompt = toks(6, &cfg, &mut rng);
        let window = toks(5, &cfg, &mut rng);

        let mut step_cache = KvCache::new(&cfg).unwrap();
        lm.prefill(&prompt, &mut step_cache).unwrap();
        let step_rows: Vec<Vec<f32>> = window
            .iter()
            .map(|&t| {
                lm.decode_step(&[t], &mut [&mut step_cache])
                    .unwrap()
                    .row(0)
                    .to_vec()
            })
            .collect();

        let mut win_cache = KvCache::new(&cfg).unwrap();
        lm.prefill(&prompt, &mut win_cache).unwrap();
        let win = lm.decode_window(&window, &mut win_cache).unwrap();
        assert_eq!(win.dims2(), (window.len(), cfg.vocab));
        assert_eq!(win_cache.len(), step_cache.len());
        for (i, want) in step_rows.iter().enumerate() {
            assert_eq!(win.row(i), &want[..], "window row {i} diverged");
        }
        // the caches themselves agree bitwise (the rollback guarantee
        // rests on this: truncating a window-fed cache must leave the
        // same state as stepping one token at a time)
        for blk in 0..win_cache.n_blocks() {
            for pos in 0..win_cache.len() {
                assert_eq!(win_cache.k_row(blk, pos), step_cache.k_row(blk, pos));
                assert_eq!(win_cache.v_row(blk, pos), step_cache.v_row(blk, pos));
            }
        }
    }

    #[test]
    fn truncate_then_decode_bitwise_matches_fresh_prefill() {
        // rollback parity at the ring boundary: fill the cache to its
        // exact capacity (the last position where rollback is still
        // exact), truncate away the speculative tail, and re-decode —
        // the logits must be bit-identical to a never-speculated run
        let mut cfg = small_cfg();
        cfg.seq = 12;
        let mut rng = Rng::new(48);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let prompt = toks(7, &cfg, &mut rng);
        let spec_tail = toks(5, &cfg, &mut rng); // fills to len == capacity
        let replay = toks(3, &cfg, &mut rng);

        let mut cache = KvCache::new(&cfg).unwrap();
        lm.prefill(&prompt, &mut cache).unwrap();
        lm.decode_window(&spec_tail, &mut cache).unwrap();
        assert_eq!(cache.len(), cache.capacity());
        cache.truncate(prompt.len()).unwrap();

        let mut fresh = KvCache::new(&cfg).unwrap();
        lm.prefill(&prompt, &mut fresh).unwrap();
        for &t in &replay {
            let a = lm.decode_step(&[t], &mut [&mut cache]).unwrap();
            let b = lm.decode_step(&[t], &mut [&mut fresh]).unwrap();
            assert_eq!(a.row(0), b.row(0), "post-rollback decode diverged");
        }

        // past the boundary the ring slides and rollback must refuse:
        // decode_step happily runs into sliding-window attention, after
        // which the discarded state is unrecoverable
        let mut slid = KvCache::with_capacity(&cfg, 4).unwrap();
        lm.prefill(&toks(4, &cfg, &mut rng), &mut slid).unwrap();
        lm.decode_step(&[1], &mut [&mut slid]).unwrap(); // len 5 > cap 4
        let err = slid.truncate(4).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::Error>(),
                Some(crate::Error::LossyRollback { .. })
            ),
            "want LossyRollback, got {err:#}"
        );
    }

    #[test]
    fn prefill_rejects_overflow_and_empty() {
        let cfg = small_cfg();
        let mut rng = Rng::new(45);
        let lm = SparseLm::from_params(&ParamSet::init(&cfg, &mut rng));
        let mut cache = KvCache::with_capacity(&cfg, 4).unwrap();
        assert!(lm.prefill(&[], &mut cache).is_err());
        let long = toks(5, &cfg, &mut rng);
        assert!(lm.prefill(&long, &mut cache).is_err());
        assert!(cache.is_empty(), "failed prefill must not commit positions");
    }
}
