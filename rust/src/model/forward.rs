//! Host forward pass over pluggable linear kernels — the offline serving
//! path: tokens → embed → blocks (packed spmm linears) → tied head →
//! per-token NLL, with **packed weights staying packed end-to-end**.
//!
//! Mirrors `python/compile/model.py` exactly (RMSNorm `eps = 1e-5`,
//! even/odd-pair RoPE, grouped-query attention via consecutive repeat,
//! SwiGLU, tied input/output embedding), so [`SparseLm::lm_nll`] is the
//! same function the `lm_nll` HLO artifact computes — but every linear is
//! a [`Kernel`], so a [`PackedLinear`] layer is applied straight from its
//! bit-packed N:M + structured-outlier storage via
//! [`crate::sparse::spmm()`] / [`crate::sparse::spmm_parallel()`].
//!
//! This is what `serve::spmm_scorer` and the offline eval harnesses run;
//! the PJRT path ([`crate::coordinator::ModelExec`]) remains the
//! artifact-backed alternative. `docs/ARCHITECTURE.md` walks the full
//! request path.
//!
//! The forward is factored into reusable stages — `embed`, `block_fwd`,
//! `head_logits` — shared by three consumers: the batch scorer
//! [`SparseLm::lm_nll`], the full-sequence reference
//! [`SparseLm::full_logits`], and the KV-cached incremental path
//! ([`SparseLm::prefill`] / [`SparseLm::decode_step`] in
//! `model/decode.rs`).

use crate::quant::QuantSpec;
use crate::sparse::{
    spmm, spmm_parallel, Kernel, PackedLinear, PackedQuantLinear, PackedTernaryLinear,
};
use crate::tensor::{dot, Tensor};
use crate::util::perf;

use super::config::ModelConfig;
use super::params::ParamSet;

/// RMSNorm epsilon — must match `model.py::RMS_EPS`.
pub const RMS_EPS: f32 = 1e-5;

/// One transformer block's weights; every linear is kernel-backed.
pub struct BlockWeights {
    pub ln1: Vec<f32>,
    pub wq: Box<dyn Kernel>,
    pub wk: Box<dyn Kernel>,
    pub wv: Box<dyn Kernel>,
    pub wo: Box<dyn Kernel>,
    pub ln2: Vec<f32>,
    pub wg: Box<dyn Kernel>,
    pub wu: Box<dyn Kernel>,
    pub wd: Box<dyn Kernel>,
}

/// A host-resident LM whose linear layers apply themselves through the
/// [`Kernel`] trait — dense tensors, [`PackedLinear`] (N:M + outliers),
/// or any mix.
pub struct SparseLm {
    pub config: ModelConfig,
    /// tied input/output embedding, dense `(vocab, dim)`
    pub tok_emb: Tensor,
    pub blocks: Vec<BlockWeights>,
    pub ln_f: Vec<f32>,
    /// worker threads for the row-blocked spmm (1 = serial)
    pub threads: usize,
}

impl SparseLm {
    /// Wrap a parameter set with dense reference kernels.
    pub fn from_params(params: &ParamSet) -> SparseLm {
        Self::build(params, |w| Box::new(w.clone()))
    }

    /// Compress every prunable linear to the paper's format — N:M packed
    /// base (magnitude selection) plus `k_out`:256 structured outliers
    /// when `k_out > 0` — and keep it packed for inference.
    pub fn compress(params: &ParamSet, n: usize, m: usize, k_out: usize) -> SparseLm {
        Self::build(params, |w| {
            Box::new(PackedLinear::compress(w, &w.map(f32::abs), n, m, k_out))
        })
    }

    /// [`Self::compress`] with the kept base values **group-quantized**
    /// under `spec` ([`PackedQuantLinear`]): mask metadata + int codes +
    /// bf16 scales stream through the spmm kernels, dequantized
    /// in-kernel; outliers stay bf16. This is the `--backend spmm-q4`
    /// deployment — at 8:16 / int4 / g128 a decode step streams
    /// 2.9375 bits/param, ≤ 0.20× the dense bf16 weight traffic
    /// (asserted by `cargo bench --bench f3_decode`).
    pub fn compress_quant(
        params: &ParamSet,
        n: usize,
        m: usize,
        k_out: usize,
        spec: QuantSpec,
    ) -> SparseLm {
        Self::build(params, |w| {
            Box::new(PackedQuantLinear::compress(w, &w.map(f32::abs), n, m, k_out, spec))
        })
    }

    /// [`Self::compress`] with the kept base values quantized to
    /// **ternary** {-1, 0, +1} against per-group bf16 scales
    /// ([`PackedTernaryLinear`], `group` gcd-fitted per layer width);
    /// outliers stay bf16. This is the `--backend spmm-t` deployment —
    /// at 8:16 / g128 a decode step streams ≈ 1.75 bits/param, ≤ 0.12×
    /// the dense bf16 weight traffic (asserted by `cargo bench --bench
    /// f3_decode`).
    pub fn compress_ternary(
        params: &ParamSet,
        n: usize,
        m: usize,
        k_out: usize,
        group: usize,
    ) -> SparseLm {
        Self::build(params, |w| {
            Box::new(PackedTernaryLinear::compress(w, &w.map(f32::abs), n, m, k_out, group))
        })
    }

    fn build(params: &ParamSet, mut lin: impl FnMut(&Tensor) -> Box<dyn Kernel>) -> SparseLm {
        let cfg = params.config.clone();
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for bi in 0..cfg.n_layers {
            let g = |p: &str| params.get(&format!("blk{bi}.{p}"));
            blocks.push(BlockWeights {
                ln1: g("ln1").data().to_vec(),
                wq: lin(g("wq")),
                wk: lin(g("wk")),
                wv: lin(g("wv")),
                wo: lin(g("wo")),
                ln2: g("ln2").data().to_vec(),
                wg: lin(g("wg")),
                wu: lin(g("wu")),
                wd: lin(g("wd")),
            });
        }
        SparseLm {
            config: cfg,
            tok_emb: params.get("tok_emb").clone(),
            blocks,
            ln_f: params.get("ln_f").data().to_vec(),
            threads: 1,
        }
    }

    /// Set the spmm worker count (see [`crate::util::pool::default_parallelism`]).
    pub fn with_threads(mut self, threads: usize) -> SparseLm {
        self.threads = threads.max(1);
        self
    }

    #[inline]
    pub(super) fn lin(&self, w: &dyn Kernel, x: &Tensor) -> Tensor {
        if self.threads > 1 {
            spmm_parallel(x, w, self.threads)
        } else {
            spmm(x, w)
        }
    }

    /// Embedding gather: token ids → `(len, dim)` hidden states.
    /// Out-of-vocab ids clamp to the last embedding row (the artifact
    /// path clips identically inside its gather).
    pub(super) fn embed(&self, inp: &[i32]) -> Tensor {
        let (d, vocab) = (self.config.dim, self.config.vocab);
        let mut hbuf = vec![0.0f32; inp.len() * d];
        for (i, &t) in inp.iter().enumerate() {
            let id = (t.max(0) as usize).min(vocab - 1);
            hbuf[i * d..(i + 1) * d].copy_from_slice(self.tok_emb.row(id));
        }
        Tensor::new(vec![inp.len(), d], hbuf)
    }

    /// Final RMSNorm + tied-head GEMM: `(rows, dim)` hidden states →
    /// `(rows, vocab)` logits.
    pub(super) fn head_logits(&self, h: &Tensor) -> Tensor {
        let xf = rmsnorm(h, &self.ln_f);
        self.lin(&self.tok_emb, &xf)
    }

    /// Bytes a decoder streams for all block linears — the measured
    /// weight traffic of one full forward (embedding excluded: it is a
    /// gather, not a GEMM operand).
    pub fn linear_operand_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.wg, &b.wu, &b.wd]
                    .map(|k| k.operand_bytes())
            })
            .sum()
    }

    /// The bf16 footprint the same linears would stream dense.
    pub fn dense_linear_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.wg, &b.wu, &b.wd].map(|k| {
                    let (r, c) = k.dims();
                    r * c * 2
                })
            })
            .sum()
    }

    /// Per-token negative log-likelihood over a flat `(B, S+1)` token
    /// window — the same contract as the `lm_nll` artifact /
    /// [`crate::coordinator::ModelExec::lm_nll`]. Out-of-vocab ids clamp
    /// to the last row of the embedding (the artifact path clips
    /// identically inside the gather).
    pub fn lm_nll(&self, tokens: &[i32]) -> crate::Result<Tensor> {
        let _perf = perf::phase(perf::Phase::Score);
        let cfg = &self.config;
        let (b, s) = (cfg.batch, cfg.seq);
        anyhow::ensure!(
            tokens.len() == b * (s + 1),
            "lm_nll batch shape: got {} tokens, want {}x{}",
            tokens.len(),
            b,
            s + 1
        );
        let mut inp = Vec::with_capacity(b * s);
        let mut tgt = Vec::with_capacity(b * s);
        for r in 0..b {
            let row = &tokens[r * (s + 1)..(r + 1) * (s + 1)];
            inp.extend_from_slice(&row[..s]);
            tgt.extend_from_slice(&row[1..]);
        }
        let mut h = self.embed(&inp); // (B*S, D)

        // RoPE tables depend only on (seq, head_dim, theta): build once
        // per call, shared by every block
        let rope = rope_tables(s, cfg.head_dim(), cfg.rope_theta);
        for blk in &self.blocks {
            h = self.block_fwd(blk, &h, &rope, b, s);
        }

        let logits = self.head_logits(&h); // (B*S, V)
        let (_, v) = logits.dims2();
        let mut nll = vec![0.0f32; b * s];
        for (i, out) in nll.iter_mut().enumerate() {
            let row = logits.row(i);
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse = mx + row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln();
            let t = (tgt[i].max(0) as usize).min(v - 1);
            *out = lse - row[t];
        }
        Ok(Tensor::new(vec![b, s], nll))
    }

    /// Full-sequence logits for **one** sequence: `(L,)` token ids →
    /// `(L, vocab)`. This is the monolithic forward (the same code path
    /// as [`Self::lm_nll`], batch 1) and serves as the reference the
    /// KV-cached incremental path is checked against — it never touches
    /// [`super::KvCache`].
    pub fn full_logits(&self, tokens: &[i32]) -> crate::Result<Tensor> {
        let _perf = perf::phase(perf::Phase::Score);
        anyhow::ensure!(!tokens.is_empty(), "full_logits: empty sequence");
        let cfg = &self.config;
        let s = tokens.len();
        let mut h = self.embed(tokens);
        let rope = rope_tables(s, cfg.head_dim(), cfg.rope_theta);
        for blk in &self.blocks {
            h = self.block_fwd(blk, &h, &rope, 1, s);
        }
        Ok(self.head_logits(&h))
    }

    /// One pre-norm block over `(b*s, D)` hidden states — `b` sequences
    /// of `s` positions each, causally masked within each sequence.
    pub(super) fn block_fwd(
        &self,
        blk: &BlockWeights,
        h: &Tensor,
        rope: &(Vec<f32>, Vec<f32>),
        b: usize,
        s: usize,
    ) -> Tensor {
        let cfg = &self.config;
        debug_assert_eq!(h.dims2().0, b * s);
        let (nh, nkv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());

        let x = rmsnorm(h, &blk.ln1);
        let mut q = self.lin(&*blk.wq, &x);
        let mut k = self.lin(&*blk.wk, &x);
        let v = self.lin(&*blk.wv, &x);
        let (cos, sin) = (&rope.0, &rope.1);
        apply_rope(&mut q, b, s, nh, hd, cos, sin);
        apply_rope(&mut k, b, s, nkv, hd, cos, sin);
        let o = attention(&q, &k, &v, b, s, nh, nkv, hd);
        let attn_out = self.lin(&*blk.wo, &o);
        let h1 = h.add(&attn_out);

        let y = rmsnorm(&h1, &blk.ln2);
        let g = self.lin(&*blk.wg, &y);
        let u = self.lin(&*blk.wu, &y);
        let z = g.zip(&u, |gv, uv| silu(gv) * uv);
        let mlp = self.lin(&*blk.wd, &z);
        h1.add(&mlp)
    }
}

#[inline]
pub(super) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RMSNorm over the rows of a `(rows, d)` matrix.
pub(super) fn rmsnorm(x: &Tensor, gain: &[f32]) -> Tensor {
    let (rows, d) = x.dims2();
    debug_assert_eq!(gain.len(), d);
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        let orow = &mut out[r * d..(r + 1) * d];
        for j in 0..d {
            orow[j] = row[j] * inv * gain[j];
        }
    }
    Tensor::new(vec![rows, d], out)
}

/// `(cos, sin)` tables, `(s, hd/2)` row-major — `model.py::rope_tables`.
pub(super) fn rope_tables(s: usize, hd: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    rope_tables_range(0, s, hd, theta)
}

/// RoPE tables for absolute positions `start .. start + count` — row `i`
/// holds position `start + i`, with values identical to the same row of
/// a from-zero table (the incremental decode path depends on that).
pub(super) fn rope_tables_range(
    start: usize,
    count: usize,
    hd: usize,
    theta: f64,
) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; count * half];
    let mut sin = vec![0.0f32; count * half];
    for t in 0..half {
        let freq = theta.powf(-((2 * t) as f64) / hd as f64);
        for i in 0..count {
            let ang = (start + i) as f64 * freq;
            cos[i * half + t] = ang.cos() as f32;
            sin[i * half + t] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// Rotate (even, odd) pairs of every head of one activation row in
/// place, given the single position's `(hd/2,)` cos/sin rows — the one
/// copy of the rotation convention; [`apply_rope`] (full-sequence) and
/// the incremental decode path (`model/decode.rs`) both call it.
pub(super) fn rotate_heads(row: &mut [f32], nh: usize, hd: usize, cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    for hh in 0..nh {
        let head = &mut row[hh * hd..(hh + 1) * hd];
        for j in 0..half {
            let (x1, x2) = (head[2 * j], head[2 * j + 1]);
            let (c, sn) = (cos[j], sin[j]);
            head[2 * j] = x1 * c - x2 * sn;
            head[2 * j + 1] = x1 * sn + x2 * c;
        }
    }
}

/// Rotate (even, odd) pairs of every head in place — `model.py::apply_rope`.
pub(super) fn apply_rope(
    t: &mut Tensor,
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
    cos: &[f32],
    sin: &[f32],
) {
    let d = nh * hd;
    let half = hd / 2;
    let data = t.data_mut();
    for bi in 0..b {
        for p in 0..s {
            let row = &mut data[(bi * s + p) * d..(bi * s + p + 1) * d];
            let (c, sn) = (&cos[p * half..(p + 1) * half], &sin[p * half..(p + 1) * half]);
            rotate_heads(row, nh, hd, c, sn);
        }
    }
}

/// Causal softmax attention with grouped-query heads (`q` head `h` reads
/// kv head `h / (nh/nkv)`, matching `jnp.repeat(..., axis=2)`).
fn attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    b: usize,
    s: usize,
    nh: usize,
    nkv: usize,
    hd: usize,
) -> Tensor {
    let d = nh * hd;
    let kvd = nkv * hd;
    let rep = nh / nkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let mut out = vec![0.0f32; b * s * d];
    let mut att = vec![0.0f32; s];
    for bi in 0..b {
        for hh in 0..nh {
            let kvh = hh / rep;
            for qp in 0..s {
                let qvec = &qd[(bi * s + qp) * d + hh * hd..][..hd];
                let mut mx = f32::NEG_INFINITY;
                for (kp, a) in att.iter_mut().enumerate().take(qp + 1) {
                    let kvec = &kd[(bi * s + kp) * kvd + kvh * hd..][..hd];
                    let sc = dot(qvec, kvec) * scale;
                    *a = sc;
                    if sc > mx {
                        mx = sc;
                    }
                }
                let mut denom = 0.0f32;
                for a in att.iter_mut().take(qp + 1) {
                    *a = (*a - mx).exp();
                    denom += *a;
                }
                let inv = 1.0 / denom;
                let orow = &mut out[(bi * s + qp) * d + hh * hd..][..hd];
                for (kp, &a) in att.iter().enumerate().take(qp + 1) {
                    let w = a * inv;
                    let vvec = &vd[(bi * s + kp) * kvd + kvh * hd..][..hd];
                    for (o, &vv) in orow.iter_mut().zip(vvec) {
                        *o += w * vv;
                    }
                }
            }
        }
    }
    Tensor::new(vec![b * s, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_error;
    use crate::util::Rng;

    fn tiny_test_config() -> ModelConfig {
        let mut cfg = ModelConfig::preset("tiny").unwrap();
        // shrink the static shapes so tests stay fast; the math is
        // shape-generic
        cfg.seq = 16;
        cfg.batch = 2;
        cfg.vocab = 512;
        cfg
    }

    fn window(cfg: &ModelConfig, rng: &mut Rng) -> Vec<i32> {
        (0..cfg.batch * (cfg.seq + 1))
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect()
    }

    #[test]
    fn untrained_nll_near_uniform() {
        let cfg = tiny_test_config();
        let mut rng = Rng::new(11);
        let params = ParamSet::init(&cfg, &mut rng);
        let lm = SparseLm::from_params(&params);
        let nll = lm.lm_nll(&window(&cfg, &mut rng)).unwrap();
        assert_eq!(nll.shape(), &[cfg.batch, cfg.seq]);
        let uniform = (cfg.vocab as f64).ln();
        assert!(
            (nll.mean() - uniform).abs() < 1.5,
            "untrained mean nll {} should be near ln(V) = {uniform}",
            nll.mean()
        );
    }

    #[test]
    fn packed_forward_tracks_dense_forward() {
        // 8:16 + 16:256 packed linears must stay close to the dense
        // forward of the *masked* weights — identical up to bf16 storage
        let cfg = tiny_test_config();
        let mut rng = Rng::new(12);
        let params = ParamSet::init_outliers(&cfg, &mut rng);
        let w = window(&cfg, &mut rng);

        let packed = SparseLm::compress(&params, 8, 16, 16);
        let got = packed.lm_nll(&w).unwrap();

        // dense reference: rebuild each layer's effective weight through
        // the same deterministic selection, expanded to dense
        let mut masked = params.clone();
        for (_, idx) in params.linear_indices() {
            let wt = &params.tensors[idx];
            let layer =
                crate::sparse::PackedLinear::compress(wt, &wt.map(f32::abs), 8, 16, 16);
            masked.tensors[idx] = layer.to_dense();
        }
        let reference = SparseLm::from_params(&masked);
        let want = reference.lm_nll(&w).unwrap();
        assert!(
            rel_error(&got, &want) < 1e-4,
            "packed vs dense-of-packed: {}",
            rel_error(&got, &want)
        );
    }

    #[test]
    fn quantized_forward_tracks_dequantized_dense_forward() {
        // the quantized packed forward must equal (up to fp reassociation)
        // the dense forward over the *dequantized* weights — quantization
        // error is baked into the stored values, the kernel adds none
        let cfg = tiny_test_config();
        let mut rng = Rng::new(17);
        let params = ParamSet::init_outliers(&cfg, &mut rng);
        let w = window(&cfg, &mut rng);
        let spec = QuantSpec::int4_g128();

        let packed = SparseLm::compress_quant(&params, 8, 16, 16, spec);
        let got = packed.lm_nll(&w).unwrap();

        let mut dequant = params.clone();
        for (_, idx) in params.linear_indices() {
            let wt = &params.tensors[idx];
            let layer = crate::sparse::PackedQuantLinear::compress(
                wt,
                &wt.map(f32::abs),
                8,
                16,
                16,
                spec,
            );
            dequant.tensors[idx] = layer.to_dense();
        }
        let reference = SparseLm::from_params(&dequant);
        let want = reference.lm_nll(&w).unwrap();
        assert!(
            rel_error(&got, &want) < 1e-4,
            "quant packed vs dense-of-dequant: {}",
            rel_error(&got, &want)
        );
    }

    #[test]
    fn ternary_forward_tracks_dequantized_dense_forward() {
        // same contract as the int4 path: the ternary kernel adds no
        // error beyond what the stored {-s, 0, +s} values already carry
        let cfg = tiny_test_config();
        let mut rng = Rng::new(19);
        let params = ParamSet::init_outliers(&cfg, &mut rng);
        let w = window(&cfg, &mut rng);

        let packed = SparseLm::compress_ternary(&params, 8, 16, 16, 128);
        let got = packed.lm_nll(&w).unwrap();

        let mut dequant = params.clone();
        for (_, idx) in params.linear_indices() {
            let wt = &params.tensors[idx];
            let layer = crate::sparse::PackedTernaryLinear::compress(
                wt,
                &wt.map(f32::abs),
                8,
                16,
                16,
                128,
            );
            dequant.tensors[idx] = layer.to_dense();
        }
        let reference = SparseLm::from_params(&dequant);
        let want = reference.lm_nll(&w).unwrap();
        assert!(
            rel_error(&got, &want) < 1e-4,
            "ternary packed vs dense-of-dequant: {}",
            rel_error(&got, &want)
        );
    }

    #[test]
    fn parallel_forward_matches_serial() {
        let cfg = tiny_test_config();
        let mut rng = Rng::new(13);
        let params = ParamSet::init(&cfg, &mut rng);
        let w = window(&cfg, &mut rng);
        let serial = SparseLm::compress(&params, 8, 16, 0);
        let nll1 = serial.lm_nll(&w).unwrap();
        let par = SparseLm::compress(&params, 8, 16, 0).with_threads(4);
        let nll2 = par.lm_nll(&w).unwrap();
        assert_eq!(nll1, nll2, "threading must not change results");
    }

    #[test]
    fn compression_shrinks_linear_traffic() {
        let cfg = tiny_test_config();
        let mut rng = Rng::new(14);
        let params = ParamSet::init(&cfg, &mut rng);
        let packed = SparseLm::compress(&params, 8, 16, 0);
        let dense = packed.dense_linear_bytes();
        let got = packed.linear_operand_bytes();
        assert!(
            (got as f64) < 0.60 * dense as f64,
            "packed {got} vs dense {dense}"
        );
    }

    #[test]
    fn rope_is_norm_preserving_rotation() {
        let mut rng = Rng::new(15);
        let (b, s, nh, hd) = (1usize, 8usize, 2usize, 8usize);
        let mut t = Tensor::randn(vec![b * s, nh * hd], 1.0, &mut rng);
        let before: Vec<f32> = t
            .data()
            .chunks(hd)
            .map(|c| c.iter().map(|x| x * x).sum::<f32>())
            .collect();
        let (cos, sin) = rope_tables(s, hd, 10000.0);
        apply_rope(&mut t, b, s, nh, hd, &cos, &sin);
        let after: Vec<f32> = t
            .data()
            .chunks(hd)
            .map(|c| c.iter().map(|x| x * x).sum::<f32>())
            .collect();
        for (x, y) in before.iter().zip(&after) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        // position 0 is the identity rotation
        let mut t0 = Tensor::ones(vec![1, hd]);
        let (c1, s1) = rope_tables(1, hd, 10000.0);
        apply_rope(&mut t0, 1, 1, 1, hd, &c1, &s1);
        for &x in t0.data() {
            assert!((x - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn attention_is_causal() {
        // changing a *future* token must not change past NLL positions
        let cfg = tiny_test_config();
        let mut rng = Rng::new(16);
        let params = ParamSet::init(&cfg, &mut rng);
        let lm = SparseLm::from_params(&params);
        let mut w = window(&cfg, &mut rng);
        let a = lm.lm_nll(&w).unwrap();
        let last = cfg.seq; // final token of row 0's (S+1) window
        w[last] = (w[last] + 1) % cfg.vocab as i32;
        let b2 = lm.lm_nll(&w).unwrap();
        // the edited token is only ever a *target* (of position S-1), so
        // every other NLL position is bitwise untouched
        for j in 0..cfg.seq - 1 {
            assert_eq!(a.at2(0, j), b2.at2(0, j), "pos {j}");
        }
        assert_ne!(a.at2(0, cfg.seq - 1), b2.at2(0, cfg.seq - 1));
        // other batch rows are fully independent
        for j in 0..cfg.seq {
            assert_eq!(a.at2(1, j), b2.at2(1, j));
        }
    }
}
