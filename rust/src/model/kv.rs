//! KV cache for incremental decoding — per-block K/V rings.
//!
//! Autoregressive generation recomputes nothing: each step projects one
//! token's q/k/v, appends the new K/V rows here, and attends over the
//! cached positions. The cache is **GQA-aware**: it stores
//! `n_kv_heads * head_dim` floats per position (the grouped K/V heads),
//! not the full query width — query head `h` reads cached head
//! `h / (n_heads / n_kv_heads)`, exactly like the full-sequence forward.
//!
//! Storage is a ring per transformer block: position `p` lives in slot
//! `p % capacity`, so a sequence can in principle run past `capacity`
//! with sliding-window attention (the oldest positions fall out of the
//! attended window). The serving path never relies on that — the
//! generation scheduler caps `prompt + max_tokens` at the capacity so
//! incremental logits stay step-for-step consistent with the
//! full-sequence forward (asserted by `tests/generate_parity.rs`).

use super::config::ModelConfig;

/// K/V rings for one sequence across all transformer blocks.
#[derive(Clone, Debug)]
pub struct KvCache {
    /// positions the ring can hold before the window starts sliding
    capacity: usize,
    /// floats per cached position: `n_kv_heads * head_dim`
    kv_dim: usize,
    /// absolute positions appended so far (RoPE phase of the next token)
    len: usize,
    /// per block: `capacity * kv_dim` keys, ring-indexed by position
    k: Vec<Vec<f32>>,
    /// per block: `capacity * kv_dim` values, same layout
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// Cache sized to the model's trained context window (`cfg.seq`).
    /// Errors (typed [`crate::Error::ZeroCapacity`]) on a config with
    /// `seq == 0` — configs are untrusted once they come out of
    /// artifact manifests, and a serving process must survive them.
    pub fn new(cfg: &ModelConfig) -> crate::Result<KvCache> {
        Self::with_capacity(cfg, cfg.seq)
    }

    /// Cache with an explicit position capacity. Zero capacity is a
    /// typed error, not a panic (the PR 2 panic-to-Result policy).
    pub fn with_capacity(cfg: &ModelConfig, capacity: usize) -> crate::Result<KvCache> {
        if capacity == 0 {
            return Err(crate::Error::ZeroCapacity { what: "KvCache" }.into());
        }
        let kv_dim = cfg.kv_dim();
        Ok(KvCache {
            capacity,
            kv_dim,
            len: 0,
            k: (0..cfg.n_layers).map(|_| vec![0.0; capacity * kv_dim]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; capacity * kv_dim]).collect(),
        })
    }

    /// Absolute positions appended so far — also the RoPE position of
    /// the *next* token.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Floats per cached position (`n_kv_heads * head_dim`).
    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    pub fn n_blocks(&self) -> usize {
        self.k.len()
    }

    /// First absolute position still inside the attended window.
    pub fn window_start(&self) -> usize {
        self.len.saturating_sub(self.capacity)
    }

    /// Reset to empty without releasing storage (slot reuse in the
    /// continuous-batching scheduler).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Write the K/V rows of absolute position `pos` for block `blk`.
    /// Rows are written for every block at the same `pos` before
    /// [`Self::advance`] commits the position.
    pub fn put(&mut self, blk: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.kv_dim);
        debug_assert_eq!(v_row.len(), self.kv_dim);
        let slot = (pos % self.capacity) * self.kv_dim;
        self.k[blk][slot..slot + self.kv_dim].copy_from_slice(k_row);
        self.v[blk][slot..slot + self.kv_dim].copy_from_slice(v_row);
    }

    /// Cached K row of absolute position `pos` for block `blk`.
    #[inline]
    pub fn k_row(&self, blk: usize, pos: usize) -> &[f32] {
        let slot = (pos % self.capacity) * self.kv_dim;
        &self.k[blk][slot..slot + self.kv_dim]
    }

    /// Cached V row of absolute position `pos` for block `blk`.
    #[inline]
    pub fn v_row(&self, blk: usize, pos: usize) -> &[f32] {
        let slot = (pos % self.capacity) * self.kv_dim;
        &self.v[blk][slot..slot + self.kv_dim]
    }

    /// Commit `n` freshly written positions (call once per forward step,
    /// after every block has [`Self::put`] its rows).
    pub fn advance(&mut self, n: usize) {
        self.len += n;
    }

    /// Roll back to `new_len` committed positions, discarding the rest —
    /// the speculative-decode rejection path: draft positions past the
    /// accepted prefix are dropped and the next step re-fills their
    /// slots.
    ///
    /// `new_len >= len` clamps to a no-op (nothing to discard), so
    /// callers may pass a conservative bound without pre-checking.
    ///
    /// **Ring-slide interaction**: rollback is exact only while every
    /// appended position still owns its slot, i.e. `len <= capacity`.
    /// Once the window has slid (`len > capacity`), position `len-1`
    /// overwrote the slot of position `len-1-capacity`, which lies
    /// *inside* any shorter window — the discarded state is gone, so
    /// truncation is a typed [`crate::Error::LossyRollback`] instead of
    /// silently resurrecting stale rows. The serving path never trips
    /// this: the generation scheduler caps `prompt + max_tokens` at the
    /// capacity, and the speculative decoder bounds each draft window by
    /// `capacity - committed`.
    pub fn truncate(&mut self, new_len: usize) -> crate::Result<()> {
        if new_len >= self.len {
            return Ok(());
        }
        if self.len > self.capacity {
            return Err(crate::Error::LossyRollback {
                len: self.len,
                capacity: self.capacity,
                new_len,
            }
            .into());
        }
        self.len = new_len;
        Ok(())
    }

    /// Bytes of K/V state this sequence holds resident (f32 host cache).
    pub fn resident_bytes(&self) -> usize {
        2 * self.n_blocks() * self.capacity * self.kv_dim * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        let mut c = ModelConfig::preset("gqa").unwrap();
        c.seq = 8;
        c
    }

    #[test]
    fn put_get_roundtrip_and_advance() {
        let c = cfg();
        let mut kv = KvCache::new(&c).unwrap();
        assert_eq!(kv.capacity(), 8);
        assert_eq!(kv.kv_dim(), c.kv_dim());
        assert!(kv.is_empty());
        let krow: Vec<f32> = (0..kv.kv_dim()).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..kv.kv_dim()).map(|i| -(i as f32)).collect();
        for blk in 0..kv.n_blocks() {
            kv.put(blk, 0, &krow, &vrow);
        }
        kv.advance(1);
        assert_eq!(kv.len(), 1);
        assert_eq!(kv.k_row(1, 0), &krow[..]);
        assert_eq!(kv.v_row(1, 0), &vrow[..]);
    }

    #[test]
    fn ring_wraps_and_window_slides() {
        let c = cfg();
        let mut kv = KvCache::with_capacity(&c, 4).unwrap();
        let dim = kv.kv_dim();
        for pos in 0..6 {
            let row = vec![pos as f32; dim];
            kv.put(0, pos, &row, &row);
            kv.advance(1);
        }
        assert_eq!(kv.len(), 6);
        // window covers positions 2..6; slot of pos 5 is 5 % 4 = 1
        assert_eq!(kv.window_start(), 2);
        assert_eq!(kv.k_row(0, 5)[0], 5.0);
        assert_eq!(kv.k_row(0, 4)[0], 4.0);
        // pos 0/1 were overwritten by 4/5 (same slots)
        assert_eq!(kv.k_row(0, 0)[0], 4.0);
    }

    #[test]
    fn clear_resets_without_realloc() {
        let c = cfg();
        let mut kv = KvCache::new(&c).unwrap();
        let row = vec![1.0; kv.kv_dim()];
        kv.put(0, 0, &row, &row);
        kv.advance(1);
        kv.clear();
        assert!(kv.is_empty());
        assert_eq!(kv.window_start(), 0);
        assert!(kv.resident_bytes() > 0);
    }

    #[test]
    fn zero_capacity_is_a_typed_error_not_a_panic() {
        let mut c = cfg();
        c.seq = 0;
        for r in [KvCache::new(&c), KvCache::with_capacity(&c, 0)] {
            let err = r.unwrap_err();
            match err.downcast_ref::<crate::Error>() {
                Some(crate::Error::ZeroCapacity { what }) => assert_eq!(*what, "KvCache"),
                other => panic!("want ZeroCapacity, got {other:?}"),
            }
        }
    }

    #[test]
    fn truncate_discards_positions_and_clamps_past_len() {
        let c = cfg();
        let mut kv = KvCache::new(&c).unwrap();
        let dim = kv.kv_dim();
        for pos in 0..5 {
            let row = vec![pos as f32; dim];
            kv.put(0, pos, &row, &row);
            kv.advance(1);
        }
        kv.truncate(3).unwrap();
        assert_eq!(kv.len(), 3);
        // surviving rows are untouched — the ring never slid
        assert_eq!(kv.k_row(0, 2)[0], 2.0);
        // clamp: rolling "back" to a longer length is a no-op
        kv.truncate(10).unwrap();
        assert_eq!(kv.len(), 3);
        // discarded slots are re-fillable: append fresh position 3
        let row = vec![30.0; dim];
        kv.put(0, 3, &row, &row);
        kv.advance(1);
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.k_row(0, 3)[0], 30.0);
    }

    #[test]
    fn truncate_after_ring_slide_is_a_typed_error() {
        let c = cfg();
        let mut kv = KvCache::with_capacity(&c, 4).unwrap();
        let dim = kv.kv_dim();
        for pos in 0..6 {
            let row = vec![pos as f32; dim];
            kv.put(0, pos, &row, &row);
            kv.advance(1);
        }
        // len 6 > capacity 4: positions 0/1 are overwritten, so any
        // shorter window would contain resurrected stale rows
        let err = kv.truncate(5).unwrap_err();
        match err.downcast_ref::<crate::Error>() {
            Some(crate::Error::LossyRollback {
                len,
                capacity,
                new_len,
            }) => assert_eq!((*len, *capacity, *new_len), (6, 4, 5)),
            other => panic!("want LossyRollback, got {other:?}"),
        }
        assert_eq!(kv.len(), 6, "failed truncate must not move len");
        // clamping still works even after the slide
        kv.truncate(6).unwrap();
        kv.truncate(9).unwrap();
        assert_eq!(kv.len(), 6);
    }
}
