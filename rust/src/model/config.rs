//! Model configuration — mirrors `python/compile/configs.py` and is
//! normally *read from the artifact manifest* so the two sides can never
//! drift.

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub hidden: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub rope_theta: f64,
    pub adam_b1: f64,
    pub adam_b2: f64,
    pub adam_eps: f64,
    pub weight_decay: f64,
}

impl ModelConfig {
    /// Parse the `config` object of a model manifest. Panics on a
    /// malformed document — **trusted manifests only** (the artifact
    /// tree this binary was built against). Untrusted containers
    /// (checkpoints, `.spak` artifacts) go through
    /// [`Self::try_from_manifest`].
    pub fn from_manifest(raw: &Json) -> ModelConfig {
        Self::try_from_manifest(raw).unwrap_or_else(|e| panic!("model manifest: {e}"))
    }

    /// [`Self::from_manifest`] with typed errors instead of panics, for
    /// config JSON read out of files a serving process must survive.
    pub fn try_from_manifest(raw: &Json) -> crate::Result<ModelConfig> {
        let c = raw
            .get("config")
            .ok_or_else(|| anyhow::anyhow!("missing \"config\" object"))?;
        let u = |k: &str| {
            c.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("config.{k} missing or not a number"))
        };
        let f = |k: &str| {
            c.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("config.{k} missing or not a number"))
        };
        Ok(ModelConfig {
            name: c
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("config.name missing or not a string"))?
                .to_string(),
            dim: u("dim")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            hidden: u("hidden")?,
            vocab: u("vocab")?,
            seq: u("seq")?,
            batch: u("batch")?,
            rope_theta: f("rope_theta")?,
            adam_b1: f("adam_b1")?,
            adam_b2: f("adam_b2")?,
            adam_eps: f("adam_eps")?,
            weight_decay: f("weight_decay")?,
        })
    }

    /// Built-in config family, mirroring `python/compile/configs.py`
    /// (`CONFIGS`). Artifact-backed runs still read the manifest — this
    /// exists for the offline host-forward path, which has no manifest.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let mk = |name: &str, dim, n_layers, n_heads, n_kv_heads, hidden, vocab| ModelConfig {
            name: name.to_string(),
            dim,
            n_layers,
            n_heads,
            n_kv_heads,
            hidden,
            vocab,
            seq: 128,
            batch: 4,
            rope_theta: 10000.0,
            adam_b1: 0.9,
            adam_b2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.01,
        };
        match name {
            "tiny" => Some(mk("tiny", 256, 4, 4, 4, 512, 2048)),
            "small" => Some(mk("small", 256, 8, 8, 8, 768, 2048)),
            "gqa" => Some(mk("gqa", 256, 6, 8, 2, 768, 4096)),
            "wide" => Some(mk("wide", 256, 6, 4, 4, 1024, 2048)),
            "e2e" => Some(mk("e2e", 512, 8, 8, 8, 1536, 4096)),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Flat parameter names in artifact order (contract with aot.py).
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["tok_emb".to_string()];
        for i in 0..self.n_layers {
            for p in super::params::BLOCK_PARAMS {
                names.push(format!("blk{i}.{p}"));
            }
        }
        names.push("ln_f".to_string());
        names
    }

    /// Shape of a named parameter. A name outside the
    /// [`Self::param_names`] contract returns a typed
    /// [`crate::Error::UnknownParam`] instead of panicking — a malformed
    /// checkpoint must not abort a serving process.
    pub fn param_shape(&self, name: &str) -> crate::Result<Vec<usize>> {
        let (d, h, kv, v) = (self.dim, self.hidden, self.kv_dim(), self.vocab);
        if name == "tok_emb" {
            return Ok(vec![v, d]);
        }
        if name == "ln_f" {
            return Ok(vec![d]);
        }
        let base = name.rsplit('.').next().unwrap();
        Ok(match base {
            "ln1" | "ln2" => vec![d],
            "wq" | "wo" => vec![d, d],
            "wk" | "wv" => vec![kv, d],
            "wg" | "wu" => vec![h, d],
            "wd" => vec![d, h],
            _ => return Err(crate::Error::UnknownParam(name.to_string()).into()),
        })
    }

    pub fn n_params(&self) -> usize {
        self.param_names()
            .iter()
            .map(|n| {
                self.param_shape(n)
                    .expect("param_names() yields only known params")
                    .iter()
                    .product::<usize>()
            })
            .sum()
    }

    /// Distinct prunable linear shapes (rows, cols).
    pub fn linear_shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes = vec![
            (self.dim, self.dim),
            (self.kv_dim(), self.dim),
            (self.hidden, self.dim),
            (self.dim, self.hidden),
        ];
        shapes.sort_unstable();
        shapes.dedup();
        shapes
    }

    /// The seven prunable linears of one block **with multiplicity**, in
    /// `BLOCK_LINEAR` order (`wq wk wv wo wg wu wd`) — the weight
    /// operands one decode step streams per layer.
    pub fn block_linear_shapes(&self) -> Vec<(usize, usize)> {
        let (d, h, kv) = (self.dim, self.hidden, self.kv_dim());
        vec![(d, d), (kv, d), (kv, d), (d, d), (h, d), (h, d), (d, h)]
    }

    /// Every block linear one decode step streams, across all layers —
    /// the shape list behind the [`crate::hwsim`] decode-phase traffic
    /// model (the measured counterpart is
    /// [`super::SparseLm::linear_operand_bytes`]).
    pub fn decode_linear_shapes(&self) -> Vec<(usize, usize)> {
        let blk = self.block_linear_shapes();
        (0..self.n_layers).flat_map(|_| blk.iter().copied()).collect()
    }

    /// Tokens per forward batch.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn test_config() -> ModelConfig {
        ModelConfig {
            name: "testcfg".into(),
            dim: 256,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            hidden: 512,
            vocab: 1024,
            seq: 64,
            batch: 2,
            rope_theta: 10000.0,
            adam_b1: 0.9,
            adam_b2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.01,
        }
    }

    #[test]
    fn presets_mirror_configs_py() {
        let tiny = ModelConfig::preset("tiny").unwrap();
        assert_eq!((tiny.dim, tiny.n_layers, tiny.vocab), (256, 4, 2048));
        let gqa = ModelConfig::preset("gqa").unwrap();
        assert_eq!((gqa.n_heads, gqa.n_kv_heads), (8, 2));
        assert_eq!(gqa.kv_dim(), 64);
        assert!(ModelConfig::preset("nope").is_none());
        // every preset keeps linear inputs 256-aligned for k:256 outliers
        for name in ["tiny", "small", "gqa", "wide", "e2e"] {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.dim % 256, 0, "{name}");
            assert_eq!(c.hidden % 256, 0, "{name}");
        }
    }

    #[test]
    fn param_names_ordering() {
        let cfg = test_config();
        let names = cfg.param_names();
        assert_eq!(names[0], "tok_emb");
        assert_eq!(names[1], "blk0.ln1");
        assert_eq!(names[9], "blk0.wd");
        assert_eq!(names[10], "blk1.ln1");
        assert_eq!(names.last().unwrap(), "ln_f");
        assert_eq!(names.len(), 1 + 2 * 9 + 1);
    }

    #[test]
    fn shapes_gqa() {
        let cfg = test_config();
        assert_eq!(cfg.head_dim(), 64);
        assert_eq!(cfg.kv_dim(), 128);
        assert_eq!(cfg.param_shape("blk0.wk").unwrap(), vec![128, 256]);
        assert_eq!(cfg.param_shape("blk1.wd").unwrap(), vec![256, 512]);
        assert_eq!(cfg.param_shape("tok_emb").unwrap(), vec![1024, 256]);
    }

    #[test]
    fn unknown_param_is_a_typed_error_not_a_panic() {
        let cfg = test_config();
        let err = cfg.param_shape("blk0.wx").unwrap_err();
        match err.downcast_ref::<crate::Error>() {
            Some(crate::Error::UnknownParam(name)) => assert_eq!(name, "blk0.wx"),
            other => panic!("want UnknownParam, got {other:?}"),
        }
    }

    #[test]
    fn decode_shapes_cover_every_block_linear() {
        let cfg = test_config();
        let blk = cfg.block_linear_shapes();
        assert_eq!(blk.len(), 7);
        let all = cfg.decode_linear_shapes();
        assert_eq!(all.len(), 7 * cfg.n_layers);
        // per-step dense weight bytes = sum over shapes × 2 (bf16)
        let dense: usize = all.iter().map(|&(r, c)| r * c * 2).sum();
        assert!(dense > 0);
    }

    #[test]
    fn linear_shapes_deduped() {
        let cfg = test_config();
        let shapes = cfg.linear_shapes();
        assert_eq!(
            shapes,
            vec![(128, 256), (256, 256), (256, 512), (512, 256)]
        );
    }

    #[test]
    fn from_manifest_roundtrip() {
        let j = Json::parse(
            r#"{"config": {"name": "x", "dim": 256, "n_layers": 4,
                "n_heads": 4, "n_kv_heads": 4, "hidden": 512, "vocab": 2048,
                "seq": 128, "batch": 4, "rope_theta": 10000.0,
                "adam_b1": 0.9, "adam_b2": 0.95, "adam_eps": 1e-8,
                "weight_decay": 0.01}}"#,
        )
        .unwrap();
        let cfg = ModelConfig::from_manifest(&j);
        assert_eq!(cfg.dim, 256);
        assert_eq!(cfg.n_params(), cfg.param_names().iter()
            .map(|n| cfg.param_shape(n).unwrap().iter().product::<usize>()).sum::<usize>());
    }
}
