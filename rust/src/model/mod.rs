//! Model substrate: configuration, parameter registry, checkpoint IO.
//!
//! The architecture itself (fwd/bwd) lives in the L2 JAX graphs; this
//! module owns the *weights* on the Rust side — naming, shapes, block
//! structure, initialization mirroring `model.init_params`, and a binary
//! checkpoint format so trained/compressed models round-trip without
//! Python.

mod checkpoint;
mod config;
mod params;

pub use checkpoint::{load_checkpoint, save_checkpoint};
pub use config::ModelConfig;
pub use params::{ParamSet, BLOCK_LINEAR, BLOCK_PARAMS};
