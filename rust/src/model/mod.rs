//! Model substrate: configuration, parameter registry, checkpoint IO,
//! and the host forward pass.
//!
//! The artifact path (fwd/bwd through PJRT) lives in the L2 JAX graphs;
//! this module owns the *weights* on the Rust side — naming, shapes,
//! block structure, initialization mirroring `model.init_params`, a
//! binary checkpoint format so trained/compressed models round-trip
//! without Python — **and** [`SparseLm`], a host-resident forward whose
//! linear layers run through [`crate::sparse::Kernel`], so packed N:M
//! weights are served decode-free (see `docs/ARCHITECTURE.md`).
//!
//! The forward has two consumers: the batch scorer
//! ([`SparseLm::lm_nll`], fixed `(B, S+1)` windows) and the KV-cached
//! incremental path ([`SparseLm::prefill`] / [`SparseLm::decode_step`]
//! over a [`KvCache`]) that powers autoregressive generation and the
//! continuous-batching server.

mod checkpoint;
mod config;
mod decode;
mod forward;
mod kv;
mod params;
mod spec;

pub use checkpoint::{load_checkpoint, save_checkpoint};
pub(crate) use checkpoint::{config_from_json, config_json};
pub use config::ModelConfig;
pub use forward::{BlockWeights, SparseLm, RMS_EPS};
pub use kv::KvCache;
pub use params::{ParamSet, BLOCK_LINEAR, BLOCK_PARAMS};
pub use spec::{SpecDecoder, SpecState, K_MAX, K_MIN};
