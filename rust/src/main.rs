//! `sparselm` binary — see `sparselm help`.

fn main() {
    if let Err(e) = sparselm::cli::main_entry() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
