//! Decode-free packed sparse GEMM — the serving hot path.
//!
//! Every consumer of the packed formats used to round-trip through
//! `to_dense()` + a dense matmul, which re-materializes exactly the bytes
//! the format saved. The kernels here compute `y = x @ Wᵀ` **from the
//! packed representation**: combinadic pattern ranks are unranked
//! per-block on the fly ([`Unranker`]), bf16 values are widened once per
//! block, and products accumulate into f32 — so the weight-side memory
//! traffic of a GEMM is the packed footprint ([`Kernel::operand_bytes`]),
//! not the dense one. `cargo bench --bench f2_spmm` ties the measured
//! bytes to the [`crate::hwsim`] roofline prediction.
//!
//! Topology:
//!
//! * [`Kernel`] impls for [`PackedNm`] (per-row N:M), [`PackedQnm`]
//!   (N:M with int-quantized values, dequantized in-kernel),
//!   [`PackedTnm`] (1.58-bit ternary values), [`PackedVnm`] (V-row
//!   tiles) — all four are thin adapters over the codec-generic loop
//!   bodies in [`mod@super::codec`] — plus [`StructuredOutliers`] and
//!   [`Csr`] (salient side streams), dense [`Tensor`] (reference),
//!   [`PackedLinear`] (N:M base + structured outliers — the paper's
//!   full format), [`PackedQuantLinear`] (quantized base + bf16
//!   outliers — the memory-equivalent deployment) and
//!   [`PackedTernaryLinear`] (ternary base + bf16 outliers — the
//!   sub-2-bit deployment);
//! * [`spmm()`] — single-thread driver;
//! * [`spmm_vec()`] — one-activation-row GEMV driver (the decode step;
//!   [`Kernel::accumulate_vec`] skips the batch indirection entirely);
//! * [`spmm_parallel()`] — row-blocked fan-out on the **persistent
//!   worker pool** ([`crate::util::pool::global`]): deterministic
//!   [`crate::util::pool::chunk_ranges`] chunking, long-lived workers,
//!   no per-call thread spawn (the old scoped-spawn driver survives as
//!   [`spmm_parallel_scoped`], the baseline `perf_hotpath` measures
//!   the spawn tax against), with a serial fallback below
//!   [`PARALLEL_MIN_MACS`].
//!
//! Multi-row kernels are **cache-blocked and register-blocked**: a
//! runtime dispatch table ([`dispatch`], keyed on activation rows —
//! each format maps the family to its best loop order) picks between
//! the GEMV path ([`MicroKernel::Gemv`]), a small-batch order that
//! decodes each weight block once and sweeps [`ROW_TILE`]-wide groups
//! of activation rows over it ([`MicroKernel::SmallBatch`]), and a
//! prefill-GEMM order that additionally tiles [`WEIGHT_TILE`] weight
//! rows so an activation column-block is streamed once per weight tile
//! instead of once per weight row ([`MicroKernel::TiledGemm`]). All
//! three accumulate every output element in the same floating-point
//! order, so the paths are **bitwise interchangeable** — continuous
//! batching moves sequences between them freely, and
//! `tests/spmm_tiling.rs` property-checks the equality across formats,
//! batch sizes and worker counts. Loop order still obeys the paper's
//! economics: patterns and values decode **once per weight block** and
//! are reused across every activation row, so decode cost amortizes
//! with batch size while the dense path's traffic does not.

use super::bits::read_bits;
use super::codec::{accumulate_rows_codec, accumulate_vec_codec};
use super::csr::Csr;
use super::nm::PackedNm;
use super::outliers::StructuredOutliers;
use super::patterns::Unranker;
use super::qnm::PackedQnm;
use super::tnm::PackedTnm;
use super::vnm::PackedVnm;
use super::Kernel;
use crate::pruning::{mask_excluding, mask_topn_per_block};
use crate::quant::QuantSpec;
use crate::tensor::{bf16_to_f32, dot, Tensor};
use crate::util::pool::{self, chunk_ranges, scoped_map};
use crate::util::perf;
use crate::util::trace;
use std::sync::Mutex;

// ------------------------------------------------------ dispatch table

/// Micro-kernel families the runtime dispatch table selects between.
/// Each [`Kernel`] maps the family to its own best loop order (the
/// V-tiled format always weight-tiles by `v`; dense rows have no
/// decode step to tile, so both multi-row families share one order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroKernel {
    /// One activation row: the [`spmm_vec`] GEMV loop, no batch
    /// indirection.
    Gemv,
    /// Few activation rows (decode batch): decode each weight block
    /// once, register-block the activation rows [`ROW_TILE`] wide.
    SmallBatch,
    /// Many activation rows (prefill GEMM): additionally tile
    /// [`WEIGHT_TILE`] weight rows per decoded stack tile so the
    /// activation stream is reused across the tile.
    TiledGemm,
}

/// Activation rows per register block — the j-loop unroll width. Four
/// independent accumulators amortize each decoded (value, index) pair
/// over four activation rows and give the CPU independent FMA chains.
pub const ROW_TILE: usize = 4;

/// Weight rows decoded per stack tile in the [`MicroKernel::TiledGemm`]
/// order: an activation column-block is streamed once per tile instead
/// of once per weight row, an 8× cut in activation re-reads for
/// prefill-sized batches.
pub const WEIGHT_TILE: usize = 8;

/// Activation-row count at which [`MicroKernel::TiledGemm`] overtakes
/// the small-batch order (the activation working set stops fitting the
/// innermost cache level).
pub const GEMM_MIN_ROWS: usize = 16;

/// The runtime dispatch rule: micro-kernel family by activation-row
/// count. `(rows, format)` together choose the concrete loop — each
/// [`Kernel`] impl consults this table in `accumulate_rows`. A zero-row
/// batch maps to `SmallBatch`, whose loops degenerate to no-ops.
pub fn dispatch(rows: usize) -> MicroKernel {
    if rows == 1 {
        MicroKernel::Gemv
    } else if rows < GEMM_MIN_ROWS {
        MicroKernel::SmallBatch
    } else {
        MicroKernel::TiledGemm
    }
}

// ------------------------------------------------------------- drivers

/// Trace span for one driver call, named by the dispatch family and
/// tagged with codec kind + operand bytes. Inert (two thread-local
/// reads) when the calling thread isn't serving a traced request —
/// the ≤2% overhead budget `benches/f7_trace.rs` gates lives here.
fn spmm_span(rows: usize, w: &dyn Kernel) -> trace::Span {
    let name = match dispatch(rows) {
        MicroKernel::Gemv => "spmm.gemv",
        MicroKernel::SmallBatch => "spmm.small_batch",
        MicroKernel::TiledGemm => "spmm.tiled_gemm",
    };
    let mut sp = trace::span(name);
    if sp.active() {
        sp.arg("codec", w.kind());
        sp.arg("operand_bytes", w.operand_bytes());
        sp.arg("rows", rows);
    }
    sp
}

/// `y (b, out) = x (b, in) @ Wᵀ`, single-threaded.
pub fn spmm(x: &Tensor, w: &dyn Kernel) -> Tensor {
    let _p = perf::phase(perf::Phase::Spmm);
    let (rows, cols) = w.dims();
    let (b, cin) = x.dims2();
    assert_eq!(cin, cols, "spmm: x has {cin} features, W expects {cols}");
    let _t = spmm_span(b, w);
    let mut out = vec![0.0f32; b * rows];
    w.accumulate_rows(x, 0, rows, &mut out);
    perf::record_spmm(w.operand_bytes(), w.decode_blocks());
    Tensor::new(vec![b, rows], out)
}

/// `y (out,) = x (in,) @ Wᵀ` — the GEMV-shaped decode step. One
/// activation row streams the whole packed operand
/// ([`Kernel::operand_bytes`]) for a single output token, which is
/// exactly the bandwidth-bound regime where the packed footprint *is*
/// the win (the `hwsim` decode roofline; asserted measured-vs-modeled
/// by `cargo bench --bench f3_decode`). Dispatches to
/// [`Kernel::accumulate_vec`], which packed formats implement without
/// the batch indirection of the matrix path.
pub fn spmm_vec(x: &[f32], w: &dyn Kernel) -> Vec<f32> {
    let _p = perf::phase(perf::Phase::Spmm);
    let (rows, cols) = w.dims();
    assert_eq!(
        x.len(),
        cols,
        "spmm_vec: x has {} features, W expects {cols}",
        x.len()
    );
    let _t = spmm_span(1, w);
    let mut out = vec![0.0f32; rows];
    w.accumulate_vec(x, 0, rows, &mut out);
    perf::record_gemv(w.operand_bytes(), w.decode_blocks());
    out
}

/// Work-size floor below which the parallel drivers stay serial: even a
/// pool wake costs more than the kernel itself for the small per-layer
/// GEMMs of the stand-in configs. ~64k MACs ≈ the break-even point
/// observed on laptop-class CPUs.
pub const PARALLEL_MIN_MACS: usize = 1 << 16;

/// [`spmm()`] with the output rows split into aligned blocks
/// ([`chunk_ranges`] — deterministic, so the stitched result is bitwise
/// identical to the serial path no matter which worker runs which
/// chunk) and fanned out on the **persistent**
/// [`crate::util::pool::WorkerPool`]. `threads` bounds the chunk
/// count; execution uses the global pool plus the calling thread, so a
/// decode step pays a condvar wake instead of `threads` OS-thread
/// spawns. Small GEMMs (below [`PARALLEL_MIN_MACS`]) run serial.
pub fn spmm_parallel(x: &Tensor, w: &dyn Kernel, threads: usize) -> Tensor {
    let (rows, cols) = w.dims();
    let (b, cin) = x.dims2();
    assert_eq!(cin, cols, "spmm: x has {cin} features, W expects {cols}");
    let threads = threads.max(1);
    let align = w.row_align().max(1);
    if threads == 1 || rows <= align || b * rows * cols < PARALLEL_MIN_MACS {
        return spmm(x, w);
    }
    let ranges = chunk_ranges(rows, align, threads);
    if ranges.len() == 1 {
        return spmm(x, w);
    }
    let _p = perf::phase(perf::Phase::Spmm);
    let _t = spmm_span(b, w);
    // per-chunk buffers behind (uncontended) mutexes: each task locks
    // its own index exactly once, keeping the fan-out closure safe Rust
    let parts: Vec<Mutex<Vec<f32>>> = ranges
        .iter()
        .map(|&(a, z)| Mutex::new(vec![0.0f32; b * (z - a)]))
        .collect();
    pool::global().run(ranges.len(), &|i| {
        let (a, z) = ranges[i];
        let mut buf = parts[i].lock().unwrap();
        w.accumulate_rows(x, a, z, &mut buf);
    });
    let mut out = vec![0.0f32; b * rows];
    for (&(a, z), part) in ranges.iter().zip(parts) {
        let part = part.into_inner().unwrap();
        let width = z - a;
        for i in 0..b {
            out[i * rows + a..i * rows + z]
                .copy_from_slice(&part[i * width..(i + 1) * width]);
        }
    }
    perf::record_spmm(w.operand_bytes(), w.decode_blocks());
    Tensor::new(vec![b, rows], out)
}

/// The pre-pool parallel driver: identical chunking, but fork-join on
/// scoped OS threads spawned **per call**
/// ([`crate::util::pool::scoped_map`]). Retained as the measured
/// baseline for the thread-spawn tax — `cargo bench --bench
/// perf_hotpath` reports the p50 latency of this driver against
/// [`spmm_parallel`] on the same shapes. Output is bitwise identical
/// to both the serial and pool paths.
pub fn spmm_parallel_scoped(x: &Tensor, w: &dyn Kernel, threads: usize) -> Tensor {
    let (rows, cols) = w.dims();
    let (b, cin) = x.dims2();
    assert_eq!(cin, cols, "spmm: x has {cin} features, W expects {cols}");
    let threads = threads.max(1);
    let align = w.row_align().max(1);
    if threads == 1 || rows <= align || b * rows * cols < PARALLEL_MIN_MACS {
        return spmm(x, w);
    }
    let ranges = chunk_ranges(rows, align, threads);
    if ranges.len() == 1 {
        return spmm(x, w);
    }
    let _p = perf::phase(perf::Phase::Spmm);
    let _t = spmm_span(b, w);
    let parts = scoped_map(threads, ranges.clone(), |(a, z)| {
        let mut buf = vec![0.0f32; b * (z - a)];
        w.accumulate_rows(x, a, z, &mut buf);
        buf
    });
    let mut out = vec![0.0f32; b * rows];
    for ((a, z), part) in ranges.into_iter().zip(parts) {
        let width = z - a;
        for i in 0..b {
            out[i * rows + a..i * rows + z]
                .copy_from_slice(&part[i * width..(i + 1) * width]);
        }
    }
    perf::record_spmm(w.operand_bytes(), w.decode_blocks());
    Tensor::new(vec![b, rows], out)
}

// ------------------------------------------------------------- PackedNm

impl PackedNm {
    /// The pre-tiling multi-row kernel: one output row at a time, one
    /// accumulator per activation row. Kept as the reference the tiled
    /// path is property-checked against (bitwise — the per-element
    /// accumulation order is identical) and as the "per-row kernel"
    /// baseline `perf_hotpath` prices the tiling win against.
    pub fn accumulate_rows_rowwise(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let (bsz, cin) = x.dims2();
        debug_assert_eq!(cin, self.cols);
        debug_assert!(r1 <= self.rows && r0 <= r1);
        debug_assert_eq!(out.len(), bsz * (r1 - r0));
        let bpr = self.cols / m;
        let unranker = Unranker::new(m, n);
        let width = r1 - r0;
        let xd = x.data();
        let values = self.values_raw();
        let meta = self.meta_words();
        let mut idx = vec![0usize; n];
        let mut vals = vec![0.0f32; n];
        for r in r0..r1 {
            let mut pos = r * bpr * bits as usize;
            let mut vi = r * bpr * n;
            for bblk in 0..bpr {
                let rank = read_bits(meta, pos, bits);
                pos += bits as usize;
                unranker.unrank_into(rank, &mut idx);
                for t in 0..n {
                    vals[t] = bf16_to_f32(values[vi + t]);
                }
                vi += n;
                let base = bblk * m;
                for i in 0..bsz {
                    let xrow = &xd[i * cin + base..i * cin + base + m];
                    let mut acc = 0.0f32;
                    for t in 0..n {
                        acc += vals[t] * xrow[idx[t]];
                    }
                    out[i * width + (r - r0)] += acc;
                }
            }
        }
    }

}

impl Kernel for PackedNm {
    fn kind(&self) -> &'static str {
        "nm"
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.bytes()
    }

    fn decode_blocks(&self) -> usize {
        self.n_blocks()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (bsz, _) = x.dims2();
        match dispatch(bsz) {
            MicroKernel::Gemv => self.accumulate_vec(&x.data()[..self.cols], r0, r1, out),
            MicroKernel::SmallBatch => accumulate_rows_codec(self, x, r0, r1, out, 1),
            MicroKernel::TiledGemm => accumulate_rows_codec(self, x, r0, r1, out, WEIGHT_TILE),
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        accumulate_vec_codec(self, x, r0, r1, out)
    }
}

// ------------------------------------------------------------ PackedQnm

impl PackedQnm {
    /// Per-row reference kernel for the quantized format: one output row
    /// at a time, one accumulator per activation row. The tiled paths
    /// below are property-checked bitwise against this (and against the
    /// GEMV oracle in `tests/spmm_tiling.rs`).
    pub fn accumulate_rows_rowwise(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let (bsz, cin) = x.dims2();
        debug_assert_eq!(cin, self.cols);
        debug_assert!(r1 <= self.rows && r0 <= r1);
        debug_assert_eq!(out.len(), bsz * (r1 - r0));
        let bpr = self.cols / m;
        let unranker = Unranker::new(m, n);
        let width = r1 - r0;
        let xd = x.data();
        let meta = self.meta_words();
        let mut idx = vec![0usize; n];
        let mut vals = vec![0.0f32; n];
        for r in r0..r1 {
            let mut pos = r * bpr * bits as usize;
            for bblk in 0..bpr {
                let rank = read_bits(meta, pos, bits);
                pos += bits as usize;
                unranker.unrank_into(rank, &mut idx);
                self.dequant_block_into(r, bblk, &mut vals);
                let base = bblk * m;
                for i in 0..bsz {
                    let xrow = &xd[i * cin + base..i * cin + base + m];
                    let mut acc = 0.0f32;
                    for t in 0..n {
                        acc += vals[t] * xrow[idx[t]];
                    }
                    out[i * width + (r - r0)] += acc;
                }
            }
        }
    }

}

impl Kernel for PackedQnm {
    fn kind(&self) -> &'static str {
        "qnm"
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.bytes()
    }

    fn decode_blocks(&self) -> usize {
        self.n_blocks()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (bsz, _) = x.dims2();
        match dispatch(bsz) {
            MicroKernel::Gemv => self.accumulate_vec(&x.data()[..self.cols], r0, r1, out),
            MicroKernel::SmallBatch => accumulate_rows_codec(self, x, r0, r1, out, 1),
            MicroKernel::TiledGemm => accumulate_rows_codec(self, x, r0, r1, out, WEIGHT_TILE),
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        accumulate_vec_codec(self, x, r0, r1, out)
    }
}

// ------------------------------------------------------------ PackedVnm

impl Kernel for PackedVnm {
    fn kind(&self) -> &'static str {
        "vnm"
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.bytes()
    }

    fn decode_blocks(&self) -> usize {
        self.n_tiles()
    }

    fn row_align(&self) -> usize {
        self.v
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (bsz, _) = x.dims2();
        if dispatch(bsz) == MicroKernel::Gemv {
            return self.accumulate_vec(&x.data()[..self.cols], r0, r1, out);
        }
        // the V-row tile IS the natural weight tile here: one pattern
        // decode serves v rows (the generic loop's shared-rank copy), so
        // both multi-row families share the tiled-by-v order
        accumulate_rows_codec(self, x, r0, r1, out, self.v);
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        accumulate_vec_codec(self, x, r0, r1, out)
    }
}

// ------------------------------------------------------------ PackedTnm

impl Kernel for PackedTnm {
    fn kind(&self) -> &'static str {
        "tnm"
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.bytes()
    }

    fn decode_blocks(&self) -> usize {
        self.n_blocks()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (bsz, _) = x.dims2();
        match dispatch(bsz) {
            MicroKernel::Gemv => self.accumulate_vec(&x.data()[..self.cols], r0, r1, out),
            MicroKernel::SmallBatch => accumulate_rows_codec(self, x, r0, r1, out, 1),
            MicroKernel::TiledGemm => accumulate_rows_codec(self, x, r0, r1, out, WEIGHT_TILE),
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        accumulate_vec_codec(self, x, r0, r1, out)
    }
}

// --------------------------------------------------- StructuredOutliers

impl Kernel for StructuredOutliers {
    fn kind(&self) -> &'static str {
        "outliers"
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.bytes()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        if self.k == 0 {
            return;
        }
        let (bsz, cin) = x.dims2();
        debug_assert_eq!(cin, self.cols);
        debug_assert_eq!(out.len(), bsz * (r1 - r0));
        if dispatch(bsz) == MicroKernel::Gemv {
            return self.accumulate_vec(&x.data()[..self.cols], r0, r1, out);
        }
        let bpr = self.cols / self.m;
        let width = r1 - r0;
        let xd = x.data();
        let values = self.values_raw();
        let indices = self.indices_raw();
        let mut vals = vec![0.0f32; self.k];
        for r in r0..r1 {
            for bblk in 0..bpr {
                let bi = r * bpr + bblk;
                let vs = &values[bi * self.k..(bi + 1) * self.k];
                let is = &indices[bi * self.k..(bi + 1) * self.k];
                for t in 0..self.k {
                    vals[t] = bf16_to_f32(vs[t]);
                }
                let base = bblk * self.m;
                let mut i = 0usize;
                while i + ROW_TILE <= bsz {
                    let x0 = &xd[i * cin + base..i * cin + base + self.m];
                    let x1 = &xd[(i + 1) * cin + base..(i + 1) * cin + base + self.m];
                    let x2 = &xd[(i + 2) * cin + base..(i + 2) * cin + base + self.m];
                    let x3 = &xd[(i + 3) * cin + base..(i + 3) * cin + base + self.m];
                    let (mut a0, mut a1) = (0.0f32, 0.0f32);
                    let (mut a2, mut a3) = (0.0f32, 0.0f32);
                    for t in 0..self.k {
                        let v = vals[t];
                        let j = is[t] as usize;
                        a0 += v * x0[j];
                        a1 += v * x1[j];
                        a2 += v * x2[j];
                        a3 += v * x3[j];
                    }
                    let o = r - r0;
                    out[i * width + o] += a0;
                    out[(i + 1) * width + o] += a1;
                    out[(i + 2) * width + o] += a2;
                    out[(i + 3) * width + o] += a3;
                    i += ROW_TILE;
                }
                while i < bsz {
                    let xrow = &xd[i * cin + base..i * cin + base + self.m];
                    let mut acc = 0.0f32;
                    for t in 0..self.k {
                        acc += vals[t] * xrow[is[t] as usize];
                    }
                    out[i * width + (r - r0)] += acc;
                    i += 1;
                }
            }
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        if self.k == 0 {
            return;
        }
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(out.len(), r1 - r0);
        let bpr = self.cols / self.m;
        let values = self.values_raw();
        let indices = self.indices_raw();
        for r in r0..r1 {
            for bblk in 0..bpr {
                let bi = r * bpr + bblk;
                let vs = &values[bi * self.k..(bi + 1) * self.k];
                let is = &indices[bi * self.k..(bi + 1) * self.k];
                let xblk = &x[bblk * self.m..(bblk + 1) * self.m];
                let mut acc = 0.0f32;
                for t in 0..self.k {
                    acc += bf16_to_f32(vs[t]) * xblk[is[t] as usize];
                }
                out[r - r0] += acc;
            }
        }
    }
}

// ------------------------------------------------------------------ Csr

impl Kernel for Csr {
    fn kind(&self) -> &'static str {
        "csr"
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.bytes()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (bsz, cin) = x.dims2();
        debug_assert_eq!(cin, self.cols);
        debug_assert_eq!(out.len(), bsz * (r1 - r0));
        if dispatch(bsz) == MicroKernel::Gemv {
            return self.accumulate_vec(&x.data()[..self.cols], r0, r1, out);
        }
        let (row_ptr, col_idx, values) = self.raw_parts();
        let width = r1 - r0;
        let xd = x.data();
        for r in r0..r1 {
            let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
            if lo == hi {
                continue;
            }
            let mut i = 0usize;
            while i + ROW_TILE <= bsz {
                let x0 = &xd[i * cin..(i + 1) * cin];
                let x1 = &xd[(i + 1) * cin..(i + 2) * cin];
                let x2 = &xd[(i + 2) * cin..(i + 3) * cin];
                let x3 = &xd[(i + 3) * cin..(i + 4) * cin];
                let (mut a0, mut a1) = (0.0f32, 0.0f32);
                let (mut a2, mut a3) = (0.0f32, 0.0f32);
                for t in lo..hi {
                    let v = bf16_to_f32(values[t]);
                    let j = col_idx[t] as usize;
                    a0 += v * x0[j];
                    a1 += v * x1[j];
                    a2 += v * x2[j];
                    a3 += v * x3[j];
                }
                let o = r - r0;
                out[i * width + o] += a0;
                out[(i + 1) * width + o] += a1;
                out[(i + 2) * width + o] += a2;
                out[(i + 3) * width + o] += a3;
                i += ROW_TILE;
            }
            while i < bsz {
                let xrow = &xd[i * cin..(i + 1) * cin];
                let mut acc = 0.0f32;
                for t in lo..hi {
                    acc += bf16_to_f32(values[t]) * xrow[col_idx[t] as usize];
                }
                out[i * width + (r - r0)] += acc;
                i += 1;
            }
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(out.len(), r1 - r0);
        let (row_ptr, col_idx, values) = self.raw_parts();
        for r in r0..r1 {
            let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
            let mut acc = 0.0f32;
            for t in lo..hi {
                acc += bf16_to_f32(values[t]) * x[col_idx[t] as usize];
            }
            out[r - r0] += acc;
        }
    }
}

// -------------------------------------------------------- dense Tensor

/// Dense reference kernel: the same contract over an unpacked weight
/// matrix. `operand_bytes` reports the bf16 deployment footprint (2
/// bytes/element) so packed-vs-dense ratios follow the paper's
/// accounting, not the host f32 mirror. Dense rows have no decode step
/// to amortize, so both multi-row dispatch families share the plain
/// row-major order (per-element math is [`dot`] on every path — the
/// bitwise contract holds trivially).
impl Kernel for Tensor {
    fn kind(&self) -> &'static str {
        "dense"
    }

    fn dims(&self) -> (usize, usize) {
        self.dims2()
    }

    fn operand_bytes(&self) -> usize {
        self.len() * 2
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        let (bsz, cin) = x.dims2();
        let (_, cols) = self.dims2();
        debug_assert_eq!(cin, cols);
        debug_assert_eq!(out.len(), bsz * (r1 - r0));
        if dispatch(bsz) == MicroKernel::Gemv {
            return self.accumulate_vec(&x.data()[..cols], r0, r1, out);
        }
        let width = r1 - r0;
        let xd = x.data();
        for r in r0..r1 {
            let wrow = self.row(r);
            for i in 0..bsz {
                out[i * width + (r - r0)] += dot(&xd[i * cin..(i + 1) * cin], wrow);
            }
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dims2().1);
        debug_assert_eq!(out.len(), r1 - r0);
        for r in r0..r1 {
            out[r - r0] += dot(x, self.row(r));
        }
    }
}

// --------------------------------------------------------- PackedLinear

/// The §4 selection order, shared by [`PackedLinear::compress`] and
/// [`PackedQuantLinear::compress`] so the bf16 and quantized layers can
/// never select different weight sets: top-`k_out` per 256 block
/// structured outliers first (when `k_out > 0`), then the N:M keep mask
/// on the remaining positions. Returns the salient side stream and the
/// base keep mask.
fn select_outliers_and_keep(
    w: &Tensor,
    score: &Tensor,
    n: usize,
    m: usize,
    k_out: usize,
) -> (Option<StructuredOutliers>, Tensor) {
    let (omask, outliers) = if k_out > 0 {
        let om = mask_topn_per_block(score, k_out, super::outliers::OUTLIER_M);
        let so = StructuredOutliers::from_dense_mask(w, &om, k_out, super::outliers::OUTLIER_M);
        (Some(om), Some(so))
    } else {
        (None, None)
    };
    let keep = match &omask {
        Some(om) => mask_excluding(score, om, n, m),
        None => mask_topn_per_block(score, n, m),
    };
    (outliers, keep)
}

/// The paper's full per-layer format: a [`PackedNm`] non-salient base
/// plus an optional [`StructuredOutliers`] salient side stream, applied
/// as one fused kernel (`W_eff = W_ns + W_salient`).
#[derive(Clone, Debug)]
pub struct PackedLinear {
    pub weights: PackedNm,
    pub outliers: Option<StructuredOutliers>,
}

impl PackedLinear {
    pub fn new(weights: PackedNm, outliers: Option<StructuredOutliers>) -> Self {
        if let Some(o) = &outliers {
            assert_eq!((o.rows, o.cols), (weights.rows, weights.cols));
        }
        PackedLinear { weights, outliers }
    }

    /// Prune + pack a dense weight under `score` via the §4 selection
    /// order ([`select_outliers_and_keep`]).
    pub fn compress(w: &Tensor, score: &Tensor, n: usize, m: usize, k_out: usize) -> Self {
        let (outliers, keep) = select_outliers_and_keep(w, score, n, m, k_out);
        PackedLinear {
            weights: PackedNm::from_dense_mask(w, &keep, n, m),
            outliers,
        }
    }

    /// Effective dense weight (reconstruction-error reporting only).
    pub fn to_dense(&self) -> Tensor {
        let mut d = self.weights.to_dense();
        if let Some(o) = &self.outliers {
            o.add_into(&mut d);
        }
        d
    }
}

impl Kernel for PackedLinear {
    fn kind(&self) -> &'static str {
        "linear"
    }

    fn dims(&self) -> (usize, usize) {
        (self.weights.rows, self.weights.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.weights.bytes() + self.outliers.as_ref().map_or(0, |o| o.bytes())
    }

    fn decode_blocks(&self) -> usize {
        self.weights.n_blocks()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        self.weights.accumulate_rows(x, r0, r1, out);
        if let Some(o) = &self.outliers {
            o.accumulate_rows(x, r0, r1, out);
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        self.weights.accumulate_vec(x, r0, r1, out);
        if let Some(o) = &self.outliers {
            o.accumulate_vec(x, r0, r1, out);
        }
    }
}

// ---------------------------------------------------- PackedQuantLinear

/// The memory-equivalent per-layer format: a [`PackedQnm`] non-salient
/// base (mask meta + int-quantized kept values, dequantized in-kernel)
/// plus an optional [`StructuredOutliers`] salient side stream kept at
/// bf16 — the SPQR discipline (salient weights stay high-precision, and
/// carving them out *before* quantization keeps them from stretching
/// the per-group scales) fused with the paper's 8:16 pattern.
#[derive(Clone, Debug)]
pub struct PackedQuantLinear {
    pub weights: PackedQnm,
    pub outliers: Option<StructuredOutliers>,
}

impl PackedQuantLinear {
    pub fn new(weights: PackedQnm, outliers: Option<StructuredOutliers>) -> Self {
        if let Some(o) = &outliers {
            assert_eq!((o.rows, o.cols), (weights.rows, weights.cols));
        }
        PackedQuantLinear { weights, outliers }
    }

    /// Prune + quantize + pack a dense weight under `score`: the same §4
    /// selection as [`PackedLinear::compress`] (one shared
    /// [`select_outliers_and_keep`] body), with the surviving base
    /// values group-quantized under `spec` (group fitted to the row's
    /// kept count via [`PackedQnm::fit_spec`]).
    pub fn compress(
        w: &Tensor,
        score: &Tensor,
        n: usize,
        m: usize,
        k_out: usize,
        spec: QuantSpec,
    ) -> Self {
        let (_, cols) = w.dims2();
        let (outliers, keep) = select_outliers_and_keep(w, score, n, m, k_out);
        let spec = PackedQnm::fit_spec(spec, n, m, cols);
        PackedQuantLinear {
            weights: PackedQnm::from_dense_mask(w, &keep, n, m, spec),
            outliers,
        }
    }

    /// Effective dense weight (reconstruction-error reporting only).
    pub fn to_dense(&self) -> Tensor {
        let mut d = self.weights.to_dense();
        if let Some(o) = &self.outliers {
            o.add_into(&mut d);
        }
        d
    }
}

impl Kernel for PackedQuantLinear {
    fn kind(&self) -> &'static str {
        "qlinear"
    }

    fn dims(&self) -> (usize, usize) {
        (self.weights.rows, self.weights.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.weights.bytes() + self.outliers.as_ref().map_or(0, |o| o.bytes())
    }

    fn decode_blocks(&self) -> usize {
        self.weights.n_blocks()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        self.weights.accumulate_rows(x, r0, r1, out);
        if let Some(o) = &self.outliers {
            o.accumulate_rows(x, r0, r1, out);
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        self.weights.accumulate_vec(x, r0, r1, out);
        if let Some(o) = &self.outliers {
            o.accumulate_vec(x, r0, r1, out);
        }
    }
}

// -------------------------------------------------- PackedTernaryLinear

/// The sub-2-bit per-layer format: a [`PackedTnm`] non-salient base
/// (mask meta + 1.58-bit ternary trits + per-group bf16 scales, decoded
/// in-kernel through the [`super::codec::ValueCodec`] seam) plus an
/// optional [`StructuredOutliers`] salient side stream kept at bf16 —
/// the same SPQR discipline as [`PackedQuantLinear`], pushed past int4:
/// carving the salient weights out *before* ternarization is what keeps
/// a three-level grid viable at all.
#[derive(Clone, Debug)]
pub struct PackedTernaryLinear {
    pub weights: PackedTnm,
    pub outliers: Option<StructuredOutliers>,
}

impl PackedTernaryLinear {
    pub fn new(weights: PackedTnm, outliers: Option<StructuredOutliers>) -> Self {
        if let Some(o) = &outliers {
            assert_eq!((o.rows, o.cols), (weights.rows, weights.cols));
        }
        PackedTernaryLinear { weights, outliers }
    }

    /// Prune + ternarize + pack a dense weight under `score`: the same
    /// §4 selection as [`PackedLinear::compress`] (one shared
    /// [`select_outliers_and_keep`] body), with the surviving base
    /// values ternary-quantized per `group` kept values (fitted to the
    /// row's kept count via [`PackedTnm::fit_group`]).
    pub fn compress(
        w: &Tensor,
        score: &Tensor,
        n: usize,
        m: usize,
        k_out: usize,
        group: usize,
    ) -> Self {
        let (_, cols) = w.dims2();
        let (outliers, keep) = select_outliers_and_keep(w, score, n, m, k_out);
        let group = PackedTnm::fit_group(group, n, m, cols);
        PackedTernaryLinear {
            weights: PackedTnm::from_dense_mask(w, &keep, n, m, group),
            outliers,
        }
    }

    /// Effective dense weight (reconstruction-error reporting only).
    pub fn to_dense(&self) -> Tensor {
        let mut d = self.weights.to_dense();
        if let Some(o) = &self.outliers {
            o.add_into(&mut d);
        }
        d
    }
}

impl Kernel for PackedTernaryLinear {
    fn kind(&self) -> &'static str {
        "tlinear"
    }

    fn dims(&self) -> (usize, usize) {
        (self.weights.rows, self.weights.cols)
    }

    fn operand_bytes(&self) -> usize {
        self.weights.bytes() + self.outliers.as_ref().map_or(0, |o| o.bytes())
    }

    fn decode_blocks(&self) -> usize {
        self.weights.n_blocks()
    }

    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]) {
        self.weights.accumulate_rows(x, r0, r1, out);
        if let Some(o) = &self.outliers {
            o.accumulate_rows(x, r0, r1, out);
        }
    }

    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        self.weights.accumulate_vec(x, r0, r1, out);
        if let Some(o) = &self.outliers {
            o.accumulate_vec(x, r0, r1, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_wt, rel_error};
    use crate::util::propcheck::{assert_allclose, check, Gen};
    use crate::util::Rng;

    fn dense_ref(x: &Tensor, w_dense: &Tensor) -> Tensor {
        matmul_wt(x, w_dense)
    }

    #[test]
    fn packed_nm_matches_dense_reference_all_patterns() {
        let mut rng = Rng::new(101);
        for (n, m) in [(2usize, 4usize), (4, 8), (8, 16), (16, 32)] {
            let w = Tensor::randn_outliers(vec![48, 256], 0.05, 0.01, 8.0, &mut rng);
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let packed = PackedNm::from_dense_mask(&w, &mask, n, m);
            let x = Tensor::randn(vec![5, 256], 1.0, &mut rng);
            let got = spmm(&x, &packed);
            let want = dense_ref(&x, &packed.to_dense());
            assert!(
                rel_error(&got, &want) < 1e-5,
                "{n}:{m} rel {}",
                rel_error(&got, &want)
            );
        }
    }

    #[test]
    fn property_spmm_matches_dense_with_and_without_outliers() {
        check("spmm == x @ to_dense^T", 25, |g: &mut Gen| {
            let (n, m) = *g.choose(&[(2usize, 4usize), (4, 8), (8, 16)]);
            let rows = g.int(1, 12).max(1);
            // in-features must fit a 256-block when outliers are on
            let with_outliers = g.bool();
            let cols = if with_outliers {
                256 * g.int(1, 2).max(1)
            } else {
                m * g.int(1, 12).max(1)
            };
            let bsz = g.int(1, 6).max(1);
            let w = Tensor::new(vec![rows, cols], g.vec_normal(rows * cols));
            let score = w.map(f32::abs);
            let k_out = if with_outliers { *g.choose(&[4usize, 8, 16]) } else { 0 };
            let layer = PackedLinear::compress(&w, &score, n, m, k_out);
            let x = Tensor::new(vec![bsz, cols], g.vec_normal(bsz * cols));
            let got = spmm(&x, &layer);
            let want = dense_ref(&x, &layer.to_dense());
            assert_allclose(got.data(), want.data(), 1e-4, 1e-4)
        });
    }

    #[test]
    fn deficient_blocks_fewer_than_n_survivors() {
        // outlier exclusion ate 3 of the 4 keep slots in block 1: the
        // packed block holds zero-padded slots, and spmm must reproduce
        // the dense product exactly
        let w = Tensor::new(vec![1, 8], vec![5., 6., 7., 8., 1., 2., 3., 4.]);
        let mask = Tensor::new(vec![1, 8], vec![0., 1., 0., 0., 0., 0., 1., 1.]);
        let p = PackedNm::from_dense_mask(&w, &mask, 2, 4);
        let x = Tensor::new(vec![2, 8], vec![1., 1., 1., 1., 1., 1., 1., 1.,
                                             0.5, -1., 2., 0., 1., 3., -2., 1.]);
        let got = spmm(&x, &p);
        let want = dense_ref(&x, &p.to_dense());
        assert_allclose(got.data(), want.data(), 1e-6, 1e-6).unwrap();
        assert_eq!(got.at2(0, 0), 6. + 3. + 4.);
    }

    #[test]
    fn vnm_matches_dense_reference() {
        let mut rng = Rng::new(103);
        let w = Tensor::randn(vec![16, 128], 0.05, &mut rng);
        let mask = vnm_mask(&w, 4, 8, 16);
        let p = PackedVnm::from_dense_mask(&w, &mask, 4, 8, 16);
        let x = Tensor::randn(vec![3, 128], 1.0, &mut rng);
        let got = spmm(&x, &p);
        let want = dense_ref(&x, &p.to_dense());
        assert!(rel_error(&got, &want) < 1e-5, "{}", rel_error(&got, &want));
    }

    fn vnm_mask(w: &Tensor, v: usize, n: usize, m: usize) -> Tensor {
        super::super::vnm::vnm_select(&w.map(f32::abs), v, n, m)
    }

    #[test]
    fn csr_matches_dense_reference() {
        let mut rng = Rng::new(104);
        let w = Tensor::randn(vec![24, 96], 0.05, &mut rng);
        let csr = Csr::from_topk_global(&w, &w.map(f32::abs), 150);
        let x = Tensor::randn(vec![4, 96], 1.0, &mut rng);
        let got = spmm(&x, &csr);
        let want = dense_ref(&x, &csr.to_dense());
        assert_allclose(got.data(), want.data(), 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(105);
        let w = Tensor::randn_outliers(vec![67, 512], 0.05, 0.01, 8.0, &mut rng);
        let layer = PackedLinear::compress(&w, &w.map(f32::abs), 8, 16, 16);
        let x = Tensor::randn(vec![7, 512], 1.0, &mut rng);
        let serial = spmm(&x, &layer);
        for threads in [2usize, 3, 8] {
            let par = spmm_parallel(&x, &layer, threads);
            assert_eq!(par, serial, "pool threads={threads}");
            let scoped = spmm_parallel_scoped(&x, &layer, threads);
            assert_eq!(scoped, serial, "scoped threads={threads}");
        }
    }

    #[test]
    fn parallel_respects_vnm_tile_alignment() {
        let mut rng = Rng::new(106);
        // large enough to clear PARALLEL_MIN_MACS so the fan-out path
        // actually runs, with rows not divisible by most thread counts
        let w = Tensor::randn(vec![132, 256], 0.05, &mut rng);
        let mask = vnm_mask(&w, 4, 2, 4);
        let p = PackedVnm::from_dense_mask(&w, &mask, 4, 2, 4);
        let x = Tensor::randn(vec![4, 256], 1.0, &mut rng);
        assert!(4 * 132 * 256 >= PARALLEL_MIN_MACS);
        let serial = spmm(&x, &p);
        for threads in [2usize, 5, 24] {
            assert_eq!(spmm_parallel(&x, &p, threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn tiled_kernel_bitwise_matches_rowwise_reference() {
        // the tiling refactor's core contract: SmallBatch and TiledGemm
        // orders reproduce the pre-tiling per-row kernel bit for bit
        let mut rng = Rng::new(111);
        let w = Tensor::randn_outliers(vec![37, 512], 0.05, 0.02, 8.0, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let packed = PackedNm::from_dense_mask(&w, &mask, 8, 16);
        for bsz in [2usize, 3, 4, 5, 8, 16, 33] {
            let x = Tensor::randn(vec![bsz, 512], 1.0, &mut rng);
            let mut want = vec![0.0f32; bsz * 37];
            packed.accumulate_rows_rowwise(&x, 0, 37, &mut want);
            let got = spmm(&x, &packed);
            assert_eq!(got.data(), want.as_slice(), "bsz={bsz}");
            // and on a sub-range, as the parallel driver slices it
            let mut want_part = vec![0.0f32; bsz * 20];
            packed.accumulate_rows_rowwise(&x, 9, 29, &mut want_part);
            let mut got_part = vec![0.0f32; bsz * 20];
            packed.accumulate_rows(&x, 9, 29, &mut got_part);
            assert_eq!(got_part, want_part, "bsz={bsz} subrange");
        }
    }

    #[test]
    fn dispatch_table_thresholds() {
        assert_eq!(dispatch(1), MicroKernel::Gemv);
        assert_eq!(dispatch(2), MicroKernel::SmallBatch);
        assert_eq!(dispatch(GEMM_MIN_ROWS - 1), MicroKernel::SmallBatch);
        assert_eq!(dispatch(GEMM_MIN_ROWS), MicroKernel::TiledGemm);
        assert_eq!(dispatch(1024), MicroKernel::TiledGemm);
    }

    #[test]
    fn outlier_side_stream_composes() {
        let mut rng = Rng::new(107);
        let w = Tensor::randn_outliers(vec![16, 512], 0.05, 0.02, 10.0, &mut rng);
        let layer = PackedLinear::compress(&w, &w.map(f32::abs), 8, 16, 16);
        let x = Tensor::randn(vec![3, 512], 1.0, &mut rng);
        // base alone + outliers alone == fused
        let base = spmm(&x, &layer.weights);
        let side = spmm(&x, layer.outliers.as_ref().unwrap());
        let fused = spmm(&x, &layer);
        assert_allclose(fused.data(), base.add(&side).data(), 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn operand_bytes_beats_dense_at_8_16() {
        let mut rng = Rng::new(108);
        let w = Tensor::randn(vec![256, 512], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let packed = PackedNm::from_dense_mask(&w, &mask, 8, 16);
        let dense_bytes = Kernel::operand_bytes(&w);
        // acceptance: packed weight+metadata ≤ 0.60× dense bf16 traffic
        assert!(
            (packed.operand_bytes() as f64) <= 0.60 * dense_bytes as f64,
            "{} vs dense {}",
            packed.operand_bytes(),
            dense_bytes
        );
    }

    #[test]
    fn decode_blocks_counts_pattern_blocks() {
        let mut rng = Rng::new(112);
        let w = Tensor::randn(vec![48, 256], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let packed = PackedNm::from_dense_mask(&w, &mask, 8, 16);
        assert_eq!(Kernel::decode_blocks(&packed), 48 * 256 / 16);
        assert_eq!(Kernel::decode_blocks(&w), 0, "dense has no patterns");
        let layer = PackedLinear::new(packed.clone(), None);
        assert_eq!(Kernel::decode_blocks(&layer), packed.n_blocks());
    }

    #[test]
    fn dense_kernel_matches_matmul_wt() {
        let mut rng = Rng::new(109);
        let w = Tensor::randn(vec![33, 70], 1.0, &mut rng);
        let x = Tensor::randn(vec![4, 70], 1.0, &mut rng);
        let got = spmm(&x, &w);
        assert_allclose(got.data(), matmul_wt(&x, &w).data(), 1e-6, 1e-6).unwrap();
    }

    #[test]
    #[should_panic(expected = "features")]
    fn shape_mismatch_panics() {
        let w = Tensor::ones(vec![4, 16]);
        let mask = mask_topn_per_block(&w, 2, 4);
        let p = PackedNm::from_dense_mask(&w, &mask, 2, 4);
        let x = Tensor::ones(vec![2, 8]);
        spmm(&x, &p);
    }

    #[test]
    fn spmm_vec_bitwise_matches_single_row_spmm() {
        // the decode GEMV fast path must be indistinguishable from the
        // matrix path with one activation row, for every kernel kind —
        // continuous batching moves sequences between the two freely
        let mut rng = Rng::new(110);
        let w = Tensor::randn_outliers(vec![48, 512], 0.05, 0.02, 8.0, &mut rng);
        let x = Tensor::randn(vec![1, 512], 1.0, &mut rng);
        let layer = PackedLinear::compress(&w, &w.map(f32::abs), 8, 16, 16);
        let qlayer =
            PackedQuantLinear::compress(&w, &w.map(f32::abs), 8, 16, 16, QuantSpec::int4_g128());
        let vmask = vnm_mask(&w, 4, 2, 4);
        let vnm = PackedVnm::from_dense_mask(&w, &vmask, 4, 2, 4);
        let csr = Csr::from_topk_global(&w, &w.map(f32::abs), 300);
        let kernels: Vec<&dyn Kernel> = vec![
            &layer.weights,
            layer.outliers.as_ref().unwrap(),
            &layer,
            &qlayer.weights,
            &qlayer,
            &vnm,
            &csr,
            &w,
        ];
        for (ki, k) in kernels.into_iter().enumerate() {
            let want = spmm(&x, k);
            let got = spmm_vec(x.row(0), k);
            assert_eq!(got.as_slice(), want.data(), "kernel #{ki}");
        }
    }

    #[test]
    #[should_panic(expected = "features")]
    fn spmm_vec_shape_mismatch_panics() {
        let w = Tensor::ones(vec![4, 16]);
        spmm_vec(&[1.0; 8], &w);
    }

    #[test]
    fn qnm_matches_dense_of_dequantized() {
        // the quantized kernel must reproduce exactly the product of its
        // own dequantized expansion — quantization error lives in the
        // *stored values*, never in the kernel math
        let mut rng = Rng::new(113);
        let w = Tensor::randn_outliers(vec![48, 256], 0.05, 0.01, 8.0, &mut rng);
        for (n, m) in [(2usize, 4usize), (4, 8), (8, 16)] {
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let spec = PackedQnm::fit_spec(QuantSpec::int4_g128(), n, m, 256);
            let packed = PackedQnm::from_dense_mask(&w, &mask, n, m, spec);
            let x = Tensor::randn(vec![5, 256], 1.0, &mut rng);
            let got = spmm(&x, &packed);
            let want = dense_ref(&x, &packed.to_dense());
            assert!(
                rel_error(&got, &want) < 1e-5,
                "{n}:{m} rel {}",
                rel_error(&got, &want)
            );
        }
    }

    #[test]
    fn qnm_tiled_bitwise_matches_rowwise_reference() {
        // SmallBatch and TiledGemm orders over the quantized format
        // reproduce the per-row kernel bit for bit, full range and
        // sub-range — the same contract the bf16 format holds
        let mut rng = Rng::new(114);
        let w = Tensor::randn_outliers(vec![37, 512], 0.05, 0.02, 8.0, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let spec = PackedQnm::fit_spec(QuantSpec::int4_g128(), 8, 16, 512);
        let packed = PackedQnm::from_dense_mask(&w, &mask, 8, 16, spec);
        for bsz in [2usize, 3, 4, 5, 8, 16, 33] {
            let x = Tensor::randn(vec![bsz, 512], 1.0, &mut rng);
            let mut want = vec![0.0f32; bsz * 37];
            packed.accumulate_rows_rowwise(&x, 0, 37, &mut want);
            let got = spmm(&x, &packed);
            assert_eq!(got.data(), want.as_slice(), "bsz={bsz}");
            let mut want_part = vec![0.0f32; bsz * 20];
            packed.accumulate_rows_rowwise(&x, 9, 29, &mut want_part);
            let mut got_part = vec![0.0f32; bsz * 20];
            packed.accumulate_rows(&x, 9, 29, &mut got_part);
            assert_eq!(got_part, want_part, "bsz={bsz} subrange");
        }
    }

    #[test]
    fn quant_linear_outlier_side_stream_composes() {
        let mut rng = Rng::new(115);
        let w = Tensor::randn_outliers(vec![16, 512], 0.05, 0.02, 10.0, &mut rng);
        let layer =
            PackedQuantLinear::compress(&w, &w.map(f32::abs), 8, 16, 16, QuantSpec::int4_g128());
        let x = Tensor::randn(vec![3, 512], 1.0, &mut rng);
        let base = spmm(&x, &layer.weights);
        let side = spmm(&x, layer.outliers.as_ref().unwrap());
        let fused = spmm(&x, &layer);
        assert_allclose(fused.data(), base.add(&side).data(), 1e-5, 1e-6).unwrap();
        // and the fused product tracks the dequantized-dense reference
        let want = dense_ref(&x, &layer.to_dense());
        assert_allclose(fused.data(), want.data(), 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn quant_operand_bytes_le_020_dense_at_8_16() {
        let mut rng = Rng::new(116);
        let w = Tensor::randn(vec![256, 512], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let packed = PackedQnm::from_dense_mask(&w, &mask, 8, 16, QuantSpec::int4_g128());
        let dense_bytes = Kernel::operand_bytes(&w);
        // acceptance: mask meta + int4 codes + scales ≤ 0.20× dense bf16
        assert!(
            (packed.operand_bytes() as f64) <= 0.20 * dense_bytes as f64,
            "{} vs dense {}",
            packed.operand_bytes(),
            dense_bytes
        );
        // and the quantized format beats its own bf16 parent by > 2.5×
        let bf16 = PackedNm::from_dense_mask(&w, &mask, 8, 16);
        assert!((bf16.operand_bytes() as f64) > 2.5 * packed.operand_bytes() as f64);
    }

    #[test]
    fn qnm_parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(117);
        let w = Tensor::randn_outliers(vec![67, 512], 0.05, 0.01, 8.0, &mut rng);
        let layer =
            PackedQuantLinear::compress(&w, &w.map(f32::abs), 8, 16, 16, QuantSpec::int4_g128());
        let x = Tensor::randn(vec![7, 512], 1.0, &mut rng);
        let serial = spmm(&x, &layer);
        for threads in [2usize, 3, 8] {
            assert_eq!(spmm_parallel(&x, &layer, threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn tnm_matches_dense_of_dequantized() {
        // the ternary kernel must reproduce exactly the product of its
        // own decoded expansion — ternarization error lives in the
        // *stored values*, never in the kernel math
        let mut rng = Rng::new(118);
        let w = Tensor::randn_outliers(vec![48, 256], 0.05, 0.01, 8.0, &mut rng);
        for (n, m) in [(2usize, 4usize), (4, 8), (8, 16)] {
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let group = PackedTnm::fit_group(128, n, m, 256);
            let packed = PackedTnm::from_dense_mask(&w, &mask, n, m, group);
            let x = Tensor::randn(vec![5, 256], 1.0, &mut rng);
            let got = spmm(&x, &packed);
            let want = dense_ref(&x, &packed.to_dense());
            assert!(
                rel_error(&got, &want) < 1e-5,
                "{n}:{m} rel {}",
                rel_error(&got, &want)
            );
        }
    }

    #[test]
    fn ternary_linear_outlier_side_stream_composes() {
        let mut rng = Rng::new(119);
        let w = Tensor::randn_outliers(vec![16, 512], 0.05, 0.02, 10.0, &mut rng);
        let layer = PackedTernaryLinear::compress(&w, &w.map(f32::abs), 8, 16, 16, 128);
        let x = Tensor::randn(vec![3, 512], 1.0, &mut rng);
        let base = spmm(&x, &layer.weights);
        let side = spmm(&x, layer.outliers.as_ref().unwrap());
        let fused = spmm(&x, &layer);
        assert_allclose(fused.data(), base.add(&side).data(), 1e-5, 1e-6).unwrap();
        // and the fused product tracks the decoded-dense reference
        let want = dense_ref(&x, &layer.to_dense());
        assert_allclose(fused.data(), want.data(), 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn ternary_operand_bytes_le_012_dense_at_8_16() {
        let mut rng = Rng::new(120);
        let w = Tensor::randn(vec![256, 512], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let packed = PackedTnm::from_dense_mask(&w, &mask, 8, 16, 128);
        let dense_bytes = Kernel::operand_bytes(&w);
        // acceptance: mask meta + trits + scales ≤ 0.12× dense bf16
        assert!(
            (packed.operand_bytes() as f64) <= 0.12 * dense_bytes as f64,
            "{} vs dense {}",
            packed.operand_bytes(),
            dense_bytes
        );
        // and the ternary format beats the int4 format by > 1.5×
        let spec = PackedQnm::fit_spec(QuantSpec::int4_g128(), 8, 16, 512);
        let q4 = PackedQnm::from_dense_mask(&w, &mask, 8, 16, spec);
        assert!((q4.operand_bytes() as f64) > 1.5 * packed.operand_bytes() as f64);
    }

    #[test]
    fn tnm_parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(121);
        let w = Tensor::randn_outliers(vec![67, 512], 0.05, 0.01, 8.0, &mut rng);
        let layer = PackedTernaryLinear::compress(&w, &w.map(f32::abs), 8, 16, 16, 128);
        let x = Tensor::randn(vec![7, 512], 1.0, &mut rng);
        let serial = spmm(&x, &layer);
        for threads in [2usize, 3, 8] {
            assert_eq!(spmm_parallel(&x, &layer, threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn codec_generic_loops_bitwise_match_retained_rowwise_references() {
        // the ValueCodec refactor's core contract: the shared generic
        // loop bodies reproduce the retained pre-seam per-row kernels
        // bit for bit, for both formats that kept a reference, at every
        // dispatch family and on parallel-driver sub-ranges
        let mut rng = Rng::new(122);
        let w = Tensor::randn_outliers(vec![37, 512], 0.05, 0.02, 8.0, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let nm = PackedNm::from_dense_mask(&w, &mask, 8, 16);
        let spec = PackedQnm::fit_spec(QuantSpec::int4_g128(), 8, 16, 512);
        let qnm = PackedQnm::from_dense_mask(&w, &mask, 8, 16, spec);
        for bsz in [1usize, 2, 5, 15, 16, 33, 64] {
            let x = Tensor::randn(vec![bsz, 512], 1.0, &mut rng);
            let mut want = vec![0.0f32; bsz * 37];
            nm.accumulate_rows_rowwise(&x, 0, 37, &mut want);
            assert_eq!(spmm(&x, &nm).data(), want.as_slice(), "nm bsz={bsz}");
            let mut want_q = vec![0.0f32; bsz * 37];
            qnm.accumulate_rows_rowwise(&x, 0, 37, &mut want_q);
            assert_eq!(spmm(&x, &qnm).data(), want_q.as_slice(), "qnm bsz={bsz}");
            let mut want_part = vec![0.0f32; bsz * 20];
            nm.accumulate_rows_rowwise(&x, 9, 29, &mut want_part);
            let mut got_part = vec![0.0f32; bsz * 20];
            nm.accumulate_rows(&x, 9, 29, &mut got_part);
            assert_eq!(got_part, want_part, "nm bsz={bsz} subrange");
        }
    }
}
