//! Bit-packing primitives shared by the packed sparse formats.
//!
//! Pattern ids are `ceil(log2 C(M,N))` bits each and packed contiguously
//! into little-endian `u64` words; ids freely straddle word boundaries
//! (8:16 uses 14-bit ids — not a divisor of 64). Previously `nm.rs` and
//! `vnm.rs` carried private copies of these helpers; the decode-free
//! spmm path reads the same streams, so the codec now lives here once.

/// Append the `bits` low bits of `v` at bit offset `*pos`, growing `buf`
/// as needed and advancing `*pos`.
pub(crate) fn push_bits(buf: &mut Vec<u64>, pos: &mut usize, v: u64, bits: u32) {
    if bits == 0 {
        return;
    }
    let word = *pos / 64;
    let off = (*pos % 64) as u32;
    while buf.len() <= word + 1 {
        buf.push(0);
    }
    buf[word] |= v << off;
    if off + bits > 64 {
        buf[word + 1] |= v >> (64 - off);
    }
    *pos += bits as usize;
}

/// Exact `u64` word count [`push_bits`] produces for `blocks` ids of
/// `bits` each: the growth rule (`buf.len() <= word + 1` → push) always
/// leaves one spare word after the word the last id starts in. Shared
/// by the packers, the `.spak` container accounting and the
/// [`crate::hwsim`] artifact-size model, so on-disk stream lengths
/// round-trip to byte-identical in-memory layouts.
pub(crate) fn packed_words(blocks: usize, bits: u32) -> usize {
    if blocks == 0 || bits == 0 {
        0
    } else {
        (blocks * bits as usize - bits as usize) / 64 + 2
    }
}

/// Read `bits` bits at bit offset `pos`.
#[inline]
pub(crate) fn read_bits(buf: &[u64], pos: usize, bits: u32) -> u64 {
    if bits == 0 {
        return 0;
    }
    let word = pos / 64;
    let off = (pos % 64) as u32;
    let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut v = buf[word] >> off;
    if off + bits > 64 {
        v |= buf[word + 1] << (64 - off);
    }
    v & mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_across_word_boundaries() {
        // 14-bit ids (the 8:16 width) exercise every straddle offset
        let ids: Vec<u64> = (0..200).map(|i| (i * 37) % (1 << 14)).collect();
        let mut buf = Vec::new();
        let mut pos = 0;
        for &id in &ids {
            push_bits(&mut buf, &mut pos, id, 14);
        }
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(read_bits(&buf, i * 14, 14), id, "id {i}");
        }
    }

    #[test]
    fn zero_width_is_noop() {
        let mut buf = Vec::new();
        let mut pos = 0;
        push_bits(&mut buf, &mut pos, 123, 0);
        assert_eq!(pos, 0);
        assert_eq!(read_bits(&buf, 0, 0), 0);
    }

    #[test]
    fn packed_words_matches_push_bits_growth() {
        for bits in [1u32, 3, 7, 13, 14, 30, 63, 64] {
            for blocks in [1usize, 2, 3, 7, 64, 65, 100] {
                let mut buf = Vec::new();
                let mut pos = 0;
                for i in 0..blocks {
                    let v = (i as u64 * 0x9E37) & ((1u128 << bits) - 1) as u64;
                    push_bits(&mut buf, &mut pos, v, bits);
                }
                assert_eq!(
                    buf.len(),
                    packed_words(blocks, bits),
                    "blocks={blocks} bits={bits}"
                );
            }
        }
        assert_eq!(packed_words(0, 14), 0);
        assert_eq!(packed_words(100, 0), 0);
    }

    #[test]
    fn mixed_widths() {
        let mut buf = Vec::new();
        let mut pos = 0;
        let items = [(5u64, 3u32), (16_000, 14), (1, 1), (0x3FFF_FFFF, 30), (7, 3)];
        for &(v, b) in &items {
            push_bits(&mut buf, &mut pos, v, b);
        }
        let mut p = 0;
        for &(v, b) in &items {
            assert_eq!(read_bits(&buf, p, b), v);
            p += b as usize;
        }
    }
}
