//! The **value-codec seam**: one set of micro-kernel loop bodies for
//! every combinadic-masked packed format.
//!
//! [`PackedNm`](super::PackedNm), [`PackedQnm`](super::PackedQnm),
//! [`PackedVnm`](super::PackedVnm) and [`PackedTnm`](super::PackedTnm)
//! share the entire spmm loop structure — enumerate `(1, m)` blocks
//! row-major, unrank the combinadic pattern id, sweep activation rows
//! over the decoded block — and differ **only** in how a block's `n`
//! kept values are materialized as f32 (bf16 widen, int dequant, tile
//! lookup, trit decode) and where the block's rank lives in the pattern
//! stream (per-row for the row-major formats, shared across `v` rows
//! for the V-tiled one). [`ValueCodec`] captures exactly that
//! difference; [`accumulate_rows_codec`] / [`accumulate_vec_codec`] are
//! the Gemv / small-batch / prefill-GEMM loop orders written **once**,
//! generic over the codec. The per-format [`super::Kernel`] impls in
//! [`mod@super::spmm`] are thin adapters onto these two functions.
//!
//! Bitwise contract: for every output element the generic loops
//! accumulate blocks ascending, in-block terms ascending — the same
//! order as the retained per-row reference kernels
//! (`PackedNm::accumulate_rows_rowwise`,
//! `PackedQnm::accumulate_rows_rowwise`) and the pre-seam per-format
//! loop bodies they replaced. `tests/spmm_tiling.rs` and
//! `tests/quant_pack.rs` property-check the equality across formats ×
//! batch 1..64 × worker counts 1..8.

use super::bits::read_bits;
use super::patterns::{PatternInfo, Unranker};
use super::spmm::ROW_TILE;
use crate::tensor::Tensor;

/// The only thing the packed combinadic formats differ in: where a
/// block's pattern rank lives and how its kept values widen to f32.
///
/// Implementations also expose their value-side storage accounting
/// (`values_bytes`, `bits_per_kept`) so stream-breakdown reporting (the
/// `inspect` CLI, [`crate::store`]) needs no per-format matches. Length
/// validation of decoder-side streams stays on each format's
/// `from_raw_parts` (all of them share the
/// `super::bits::packed_words` rule for the pattern stream).
pub trait ValueCodec: Send + Sync {
    /// The N:M pattern of the combinadic mask stream.
    fn pattern(&self) -> &PatternInfo;

    /// `(out_features, in_features)` dense shape.
    fn dims(&self) -> (usize, usize);

    /// Bit-packed combinadic pattern ids, `codebook_bits` each.
    fn meta_words(&self) -> &[u64];

    /// Index of block `(r, bblk)`'s rank within the pattern stream —
    /// `r * (cols/m) + bblk` for the row-major formats, shared across
    /// the `v` rows of a tile for the V-tiled one.
    fn rank_index(&self, r: usize, bblk: usize) -> usize;

    /// Materialize the `n` kept values of block `(r, bblk)` as f32 —
    /// the per-format decode step every loop order shares, so all
    /// dispatch paths see identical floats.
    fn decode_block_into(&self, r: usize, bblk: usize, out: &mut [f32]);

    /// Serialized bytes of the value-side streams (values / codes +
    /// scales / trits + scales — everything except the pattern stream).
    fn values_bytes(&self) -> usize;

    /// Stored bits per kept value of the value-side streams (16 for
    /// bf16, `bits + 16/group` quantized, 1.6 + 16/group ternary).
    fn bits_per_kept(&self) -> f64;
}

/// Cache-blocked multi-row loop order, generic over the codec: decode
/// `wt` weight rows' worth of one block column into a stack tile
/// (`wt == 1` is the small-batch order, `wt == WEIGHT_TILE` the
/// prefill-GEMM order, `wt == v` the V-tiled format's natural tile),
/// then sweep [`ROW_TILE`]-wide groups of activation rows over the
/// decoded tile. Consecutive tile rows sharing one rank (the V:N:M
/// layout) reuse the previous row's unranked indices instead of
/// re-unranking.
pub(crate) fn accumulate_rows_codec<C: ValueCodec + ?Sized>(
    c: &C,
    x: &Tensor,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    wt: usize,
) {
    let p = c.pattern();
    let (n, m) = (p.n, p.m);
    let bits = p.codebook_bits();
    let (rows, cols) = c.dims();
    let (bsz, cin) = x.dims2();
    debug_assert_eq!(cin, cols);
    debug_assert!(r1 <= rows && r0 <= r1);
    debug_assert_eq!(out.len(), bsz * (r1 - r0));
    let bpr = cols / m;
    let unranker = Unranker::new(m, n);
    let width = r1 - r0;
    let xd = x.data();
    let meta = c.meta_words();
    // decoded (indices, materialized values) for one weight tile × block
    let mut tidx = vec![0usize; wt * n];
    let mut tval = vec![0.0f32; wt * n];
    let mut rt = r0;
    while rt < r1 {
        let hi = (rt + wt).min(r1);
        let th = hi - rt;
        for bblk in 0..bpr {
            let mut prev_ri = usize::MAX;
            for (ti, r) in (rt..hi).enumerate() {
                let ri = c.rank_index(r, bblk);
                if ti > 0 && ri == prev_ri {
                    // tile-shared rank: copy the previous row's indices
                    let (done, rest) = tidx.split_at_mut(ti * n);
                    rest[..n].copy_from_slice(&done[(ti - 1) * n..]);
                } else {
                    let rank = read_bits(meta, ri * bits as usize, bits);
                    unranker.unrank_into(rank, &mut tidx[ti * n..ti * n + n]);
                }
                prev_ri = ri;
                c.decode_block_into(r, bblk, &mut tval[ti * n..ti * n + n]);
            }
            let base = bblk * m;
            let mut i = 0usize;
            while i + ROW_TILE <= bsz {
                let x0 = &xd[i * cin + base..i * cin + base + m];
                let x1 = &xd[(i + 1) * cin + base..(i + 1) * cin + base + m];
                let x2 = &xd[(i + 2) * cin + base..(i + 2) * cin + base + m];
                let x3 = &xd[(i + 3) * cin + base..(i + 3) * cin + base + m];
                for ti in 0..th {
                    let iv = &tidx[ti * n..ti * n + n];
                    let vv = &tval[ti * n..ti * n + n];
                    let (mut a0, mut a1) = (0.0f32, 0.0f32);
                    let (mut a2, mut a3) = (0.0f32, 0.0f32);
                    for t in 0..n {
                        let v = vv[t];
                        let j = iv[t];
                        a0 += v * x0[j];
                        a1 += v * x1[j];
                        a2 += v * x2[j];
                        a3 += v * x3[j];
                    }
                    let o = rt + ti - r0;
                    out[i * width + o] += a0;
                    out[(i + 1) * width + o] += a1;
                    out[(i + 2) * width + o] += a2;
                    out[(i + 3) * width + o] += a3;
                }
                i += ROW_TILE;
            }
            while i < bsz {
                let xr = &xd[i * cin + base..i * cin + base + m];
                for ti in 0..th {
                    let iv = &tidx[ti * n..ti * n + n];
                    let vv = &tval[ti * n..ti * n + n];
                    let mut acc = 0.0f32;
                    for t in 0..n {
                        acc += vv[t] * xr[iv[t]];
                    }
                    out[i * width + (rt + ti - r0)] += acc;
                }
                i += 1;
            }
        }
        rt = hi;
    }
}

/// The GEMV loop order, generic over the codec — the decode-step path.
/// Allocation-free: one block's scratch lives on the stack (every
/// packed format asserts `m ≤ 64` ⇒ `n ≤ 64` at pack time). Per output
/// row the accumulation order (blocks ascending, in-block terms
/// ascending) matches [`accumulate_rows_codec`] exactly.
pub(crate) fn accumulate_vec_codec<C: ValueCodec + ?Sized>(
    c: &C,
    x: &[f32],
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let p = c.pattern();
    let (n, m) = (p.n, p.m);
    let bits = p.codebook_bits();
    let (rows, cols) = c.dims();
    debug_assert_eq!(x.len(), cols);
    debug_assert!(r1 <= rows && r0 <= r1);
    debug_assert_eq!(out.len(), r1 - r0);
    let bpr = cols / m;
    let unranker = Unranker::new(m, n);
    let meta = c.meta_words();
    let mut idx_buf = [0usize; 64];
    let mut val_buf = [0.0f32; 64];
    let idx = &mut idx_buf[..n];
    let vals = &mut val_buf[..n];
    for r in r0..r1 {
        for bblk in 0..bpr {
            let ri = c.rank_index(r, bblk);
            let rank = read_bits(meta, ri * bits as usize, bits);
            unranker.unrank_into(rank, idx);
            c.decode_block_into(r, bblk, vals);
            let xblk = &x[bblk * m..(bblk + 1) * m];
            let mut acc = 0.0f32;
            for t in 0..n {
                acc += vals[t] * xblk[idx[t]];
            }
            out[r - r0] += acc;
        }
    }
}
