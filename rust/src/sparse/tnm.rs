//! Packed **ternary** N:M weight storage — 1.58-bit values under the
//! unchanged combinadic mask.
//!
//! Same block structure as [`super::PackedNm`]: for every `(1, M)` block
//! along the input-channel axis the keep-pattern is a combinadic rank in
//! `ceil(log2 C(M,N))` bits. The kept values, though, are quantized to
//! {-1, 0, +1} against a per-group bf16 scale (the same grouping
//! discipline as [`crate::quant::GroupQuant`], `group` *kept* values per
//! scale) and packed **5 trits per byte** in base-3: a byte holds digits
//! `d0..d4` (each `q + 1 ∈ {0, 1, 2}`) as
//! `d0 + 3·d1 + 9·d2 + 27·d3 + 81·d4` (3^5 = 243 ≤ 256), i.e.
//! 8/5 = 1.6 bits per kept value. Trit bytes are **row-aligned** —
//! each weight row starts on a fresh byte, `ceil(kept_per_row / 5)`
//! bytes per row — so a row decode touches one contiguous byte range
//! and the mmap accounting stays exact per row.
//!
//! At 8:16 with group 128 the full decode stream is
//! 0.875 (mask) + 1.6/2 (trits) + 16/128/2 (scales) ≈ 1.74 bits/param
//! (1.75 exact with row padding at kept-per-row = 128), and the
//! value-side streams alone are ≈ 0.875 bits/param — versus 8.875 for
//! bf16 values and 2.9375 for int4 ([`super::PackedQnm`]). The spmm
//! kernel is the codec-generic loop of [`super::codec`]; this file only
//! supplies the trit decode ([`PackedTnm::decode_block_into`]).
//!
//! Quantization rule (mirrors `GroupQuant` with `qmax = 1`): per group
//! of `group` kept values, `scale = bf16(absmax)`,
//! `q = round(v / scale).clamp(-1, 1)`, decode `q · scale`. Padded
//! slots of deficient blocks carry `q = 0` and decode to exact `0.0`.

use super::bits::{packed_words, push_bits, read_bits};
use super::codec::ValueCodec;
use super::nm::keep_indices_for_block;
use super::patterns::{rank_combination, unrank_combination, PatternInfo};
use super::qnm::gcd;
use super::storage::Storage;
use crate::tensor::{bf16_to_f32, f32_to_bf16, Tensor};

/// Trits packed per byte (base-3 digits; 3^5 = 243 fits u8).
pub const TRITS_PER_BYTE: usize = 5;

/// `POW3[i] = 3^i` — the base-3 digit weights of one trit byte.
const POW3: [u8; TRITS_PER_BYTE] = [1, 3, 9, 27, 81];

/// A rank-2 weight matrix with ternary kept values under an N:M mask.
#[derive(Clone, Debug)]
pub struct PackedTnm {
    pub pattern: PatternInfo,
    pub rows: usize,
    pub cols: usize,
    /// kept values sharing one bf16 scale — counts **kept** values like
    /// [`crate::quant::QuantSpec::group`], and must divide kept-per-row
    /// (use [`Self::fit_group`])
    pub group: usize,
    /// base-3 packed ternary digits, 5 per byte, row-aligned:
    /// `ceil(kept_per_row / 5)` bytes per weight row
    trits: Storage<u8>,
    /// per-group bf16 absmax scales, `kept_per_row / group` per row
    scales: Storage<u16>,
    /// bit-packed combinadic pattern ids, `codebook_bits` per block
    meta: Storage<u64>,
}

impl PackedTnm {
    /// Largest divisor of `group` that divides kept-per-row — the same
    /// gcd fitting rule as [`super::PackedQnm::fit_spec`], so awkward
    /// layer widths shrink the group instead of failing to pack.
    pub fn fit_group(group: usize, n: usize, m: usize, cols: usize) -> usize {
        let kept_per_row = cols / m * n;
        gcd(group, kept_per_row).max(1)
    }

    /// Trit-stream bytes of one weight row (row-aligned packing).
    pub fn trit_row_bytes(kept_per_row: usize) -> usize {
        (kept_per_row + TRITS_PER_BYTE - 1) / TRITS_PER_BYTE
    }

    /// Pack `dense * mask`, quantizing kept values to ternary.
    ///
    /// Deficient blocks (outlier exclusion left fewer than N survivors)
    /// are padded with zero-valued slots exactly like [`super::PackedNm`]
    /// — both packers share [`keep_indices_for_block`], so the meta
    /// streams cannot diverge. `group` must divide kept-per-row
    /// (pre-fit with [`Self::fit_group`]).
    pub fn from_dense_mask(
        dense: &Tensor,
        mask: &Tensor,
        n: usize,
        m: usize,
        group: usize,
    ) -> Self {
        assert!(m <= 64, "PackedTnm stores u64 combinadic ranks (m <= 64), got m={m}");
        let pattern = PatternInfo::new(n, m);
        let (rows, cols) = dense.dims2();
        assert_eq!(dense.shape(), mask.shape(), "mask shape mismatch");
        assert_eq!(cols % m, 0, "cols {cols} not divisible by m {m}");
        let kept_per_row = cols / m * n;
        assert!(
            group > 0 && kept_per_row % group == 0,
            "group {group} does not divide kept-per-row {kept_per_row} (use fit_group)"
        );
        let bits = pattern.codebook_bits();
        let row_bytes = Self::trit_row_bytes(kept_per_row);
        let mut trits = vec![0u8; rows * row_bytes];
        let mut scales = Vec::with_capacity(rows * kept_per_row / group);
        let mut meta = Vec::new();
        let mut pos = 0usize;
        let mut idx_buf = Vec::with_capacity(n);
        let mut kept = vec![0.0f32; kept_per_row];
        for r in 0..rows {
            let drow = dense.row(r);
            let mrow = mask.row(r);
            for b in 0..cols / m {
                keep_indices_for_block(mrow, r, b, n, m, &mut idx_buf);
                for (t, &j) in idx_buf.iter().enumerate() {
                    // padded slots carry a zero value
                    kept[b * n + t] =
                        if mrow[b * m + j] != 0.0 { drow[b * m + j] } else { 0.0 };
                }
                push_bits(&mut meta, &mut pos, rank_combination(&idx_buf, m), bits);
            }
            // per-group bf16 absmax scale, RTN to {-1, 0, +1} — the
            // GroupQuant rule with qmax = 1
            for (g, chunk) in kept.chunks(group).enumerate() {
                let absmax = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let scale_bits = f32_to_bf16(absmax);
                let scale = bf16_to_f32(scale_bits);
                scales.push(scale_bits);
                let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
                for (t, &v) in chunk.iter().enumerate() {
                    let q = (v * inv).round().clamp(-1.0, 1.0) as i32;
                    let k = g * group + t;
                    trits[r * row_bytes + k / TRITS_PER_BYTE] +=
                        (q + 1) as u8 * POW3[k % TRITS_PER_BYTE];
                }
            }
        }
        PackedTnm {
            pattern,
            rows,
            cols,
            group,
            trits: trits.into(),
            scales: scales.into(),
            meta: meta.into(),
        }
    }

    /// Reassemble from decoder-side streams — the `.spak` mmap reader
    /// path ([`crate::store`]). Stream lengths must be exactly what a
    /// pack of the same `(rows, cols, n, m, group)` produces
    /// ([`Self::trits_len`] / [`Self::scales_len`] /
    /// [`Self::meta_words_len`]), so the reconstructed operand is
    /// byte-identical (including [`Self::bytes`] accounting) to the
    /// in-memory original.
    pub fn from_raw_parts(
        n: usize,
        m: usize,
        rows: usize,
        cols: usize,
        group: usize,
        trits: Storage<u8>,
        scales: Storage<u16>,
        meta: Storage<u64>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(m <= 64, "PackedTnm stores u64 combinadic ranks (m <= 64), got m={m}");
        anyhow::ensure!(n <= m && m > 0 && cols % m == 0, "bad pattern {n}:{m} for cols {cols}");
        let kept_per_row = cols / m * n;
        anyhow::ensure!(
            group > 0 && kept_per_row % group == 0,
            "PackedTnm group {group} does not divide kept-per-row {kept_per_row}"
        );
        let pattern = PatternInfo::new(n, m);
        anyhow::ensure!(
            trits.len() == Self::trits_len(rows, cols, n, m),
            "PackedTnm trit stream: {} bytes, want {}",
            trits.len(),
            Self::trits_len(rows, cols, n, m)
        );
        anyhow::ensure!(
            scales.len() == Self::scales_len(rows, cols, n, m, group),
            "PackedTnm scale stream: {} entries, want {}",
            scales.len(),
            Self::scales_len(rows, cols, n, m, group)
        );
        anyhow::ensure!(
            meta.len() == Self::meta_words_len(rows, cols, n, m),
            "PackedTnm meta stream: {} words, want {}",
            meta.len(),
            Self::meta_words_len(rows, cols, n, m)
        );
        Ok(PackedTnm {
            pattern,
            rows,
            cols,
            group,
            trits,
            scales,
            meta,
        })
    }

    /// Exact trit-stream length in bytes (row-aligned 5-per-byte).
    pub fn trits_len(rows: usize, cols: usize, n: usize, m: usize) -> usize {
        rows * Self::trit_row_bytes(cols / m * n)
    }

    /// Exact scale-stream length in bf16 entries.
    pub fn scales_len(rows: usize, cols: usize, n: usize, m: usize, group: usize) -> usize {
        rows * (cols / m * n) / group
    }

    /// Exact `u64` word count of the pattern stream (the shared
    /// `sparse::bits` word-growth rule).
    pub fn meta_words_len(rows: usize, cols: usize, n: usize, m: usize) -> usize {
        packed_words(rows * cols / m, PatternInfo::new(n, m).codebook_bits())
    }

    /// Decode the `n` dequantized values of block `(r, bblk)` — the
    /// [`ValueCodec`] decode step. Hot path: hoists the scale lookup
    /// when the whole block falls inside one quant group (always true
    /// when `group >= n` divides into block-aligned offsets).
    #[inline]
    pub(crate) fn decode_block_into(&self, r: usize, bblk: usize, out: &mut [f32]) {
        let n = self.pattern.n;
        let kept_per_row = self.cols / self.pattern.m * n;
        let row_bytes = Self::trit_row_bytes(kept_per_row);
        let gpr = kept_per_row / self.group;
        let base = bblk * n;
        let trow = &self.trits[r * row_bytes..(r + 1) * row_bytes];
        if base % self.group + n <= self.group {
            // whole block inside one group: single scale
            let scale = bf16_to_f32(self.scales[r * gpr + base / self.group]);
            for (t, o) in out.iter_mut().enumerate().take(n) {
                let k = base + t;
                let digit = (trow[k / TRITS_PER_BYTE] / POW3[k % TRITS_PER_BYTE]) % 3;
                *o = (digit as f32 - 1.0) * scale;
            }
        } else {
            // group boundary straddles the block: per-element lookup
            for (t, o) in out.iter_mut().enumerate().take(n) {
                let k = base + t;
                let digit = (trow[k / TRITS_PER_BYTE] / POW3[k % TRITS_PER_BYTE]) % 3;
                let scale = bf16_to_f32(self.scales[r * gpr + k / self.group]);
                *o = (digit as f32 - 1.0) * scale;
            }
        }
    }

    /// Expand back to a dense tensor (ternary-quantized values) via the
    /// same decode step the kernels use, so dense reconstruction and
    /// spmm see bit-identical floats.
    pub fn to_dense(&self) -> Tensor {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut pos = 0usize;
        let mut vals = vec![0.0f32; n];
        for r in 0..self.rows {
            for b in 0..self.cols / m {
                let rank = read_bits(&self.meta, pos, bits);
                pos += bits as usize;
                let idx = unrank_combination(rank, m, n);
                self.decode_block_into(r, b, &mut vals);
                for (t, &j) in idx.iter().enumerate() {
                    out[r * self.cols + b * m + j] = vals[t];
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// The dense 0/1 keep mask encoded by the metadata.
    pub fn mask(&self) -> Tensor {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut pos = 0usize;
        for r in 0..self.rows {
            for b in 0..self.cols / m {
                let rank = read_bits(&self.meta, pos, bits);
                pos += bits as usize;
                for &j in &unrank_combination(rank, m, n) {
                    out[r * self.cols + b * m + j] = 1.0;
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// Storage in bytes: trit bytes + bf16 scales + packed metadata.
    pub fn bytes(&self) -> usize {
        self.value_bytes() + self.meta_bytes()
    }

    /// Value-side stream bytes (trits + scales) — what ternary changes
    /// versus the bf16/int4 formats.
    pub fn value_bytes(&self) -> usize {
        self.trits.len() + self.scales.len() * 2
    }

    /// Pattern-stream bytes (same `min` accounting rule as
    /// [`super::PackedNm::bytes`]: exact bits rounded up, capped by the
    /// backing word count).
    pub fn meta_bytes(&self) -> usize {
        (self.meta.len() * 8).min(self.meta_bits() / 8 + 8)
    }

    /// Exact metadata footprint in bits.
    pub fn meta_bits(&self) -> usize {
        (self.rows * self.cols / self.pattern.m) * self.pattern.codebook_bits() as usize
    }

    /// Dense bf16 storage this replaces, in bytes.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 2
    }

    /// Compression ratio vs dense bf16 (>1 means smaller).
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.bytes() as f64
    }

    /// Total stored bits per dense parameter (mask + trits + scales).
    pub fn bits_per_param(&self) -> f64 {
        (self.bytes() * 8) as f64 / (self.rows * self.cols) as f64
    }

    /// Pattern blocks this matrix stores (`rows * cols / m`).
    pub fn n_blocks(&self) -> usize {
        self.rows * (self.cols / self.pattern.m)
    }

    /// Decoder-side view of the trit stream: base-3 packed bytes,
    /// row-aligned ([`Self::trit_row_bytes`] per weight row).
    pub fn trits_raw(&self) -> &[u8] {
        &self.trits
    }

    /// Decoder-side view of the scale stream: bf16 bits, row-major,
    /// `kept_per_row / group` per row.
    pub fn scales_raw(&self) -> &[u16] {
        &self.scales
    }

    /// Decoder-side view of the pattern stream.
    pub fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    /// `true` when all three streams read straight from a live mmap.
    pub fn is_mapped(&self) -> bool {
        self.trits.is_mapped() && self.scales.is_mapped() && self.meta.is_mapped()
    }
}

impl ValueCodec for PackedTnm {
    fn pattern(&self) -> &PatternInfo {
        &self.pattern
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    #[inline]
    fn rank_index(&self, r: usize, bblk: usize) -> usize {
        r * (self.cols / self.pattern.m) + bblk
    }

    #[inline]
    fn decode_block_into(&self, r: usize, bblk: usize, out: &mut [f32]) {
        PackedTnm::decode_block_into(self, r, bblk, out)
    }

    fn values_bytes(&self) -> usize {
        self.value_bytes()
    }

    fn bits_per_kept(&self) -> f64 {
        let kept_per_row = self.cols / self.pattern.m * self.pattern.n;
        8.0 * Self::trit_row_bytes(kept_per_row) as f64 / kept_per_row as f64
            + 16.0 / self.group as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask_topn_per_block;
    use crate::util::Rng;

    /// Reference: per-group absmax scale, RTN ternary — recomputed
    /// independently of the packer's loop structure.
    fn expected_ternary(w: &Tensor, mask: &Tensor, n: usize, m: usize, group: usize) -> Tensor {
        let (rows, cols) = w.dims2();
        let kpr = cols / m * n;
        let mut out = vec![0.0f32; rows * cols];
        let mut idx_buf = Vec::new();
        for r in 0..rows {
            let mut kept = vec![0.0f32; kpr];
            let mut kept_j = vec![usize::MAX; kpr];
            for b in 0..cols / m {
                keep_indices_for_block(mask.row(r), r, b, n, m, &mut idx_buf);
                for (t, &j) in idx_buf.iter().enumerate() {
                    kept_j[b * n + t] = b * m + j;
                    if mask.at2(r, b * m + j) != 0.0 {
                        kept[b * n + t] = w.at2(r, b * m + j);
                    }
                }
            }
            for g in 0..kpr / group {
                let chunk = &kept[g * group..(g + 1) * group];
                let absmax = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let scale = crate::tensor::bf16_to_f32(crate::tensor::f32_to_bf16(absmax));
                let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
                for (t, &v) in chunk.iter().enumerate() {
                    let q = (v * inv).round().clamp(-1.0, 1.0);
                    out[r * cols + kept_j[g * group + t]] = q * scale;
                }
            }
        }
        Tensor::new(vec![rows, cols], out)
    }

    #[test]
    fn roundtrip_matches_independent_reference() {
        let mut rng = Rng::new(9);
        let w = Tensor::randn(vec![8, 256], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let group = PackedTnm::fit_group(128, 8, 16, 256);
        let p = PackedTnm::from_dense_mask(&w, &mask, 8, 16, group);
        assert_eq!(p.to_dense(), expected_ternary(&w, &mask, 8, 16, group));
        assert_eq!(p.mask(), mask);
    }

    #[test]
    fn quantization_error_bounded_by_half_scale() {
        let mut rng = Rng::new(17);
        let w = Tensor::randn(vec![4, 128], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let group = PackedTnm::fit_group(128, 8, 16, 128);
        assert_eq!(group, 64);
        let p = PackedTnm::from_dense_mask(&w, &mask, 8, 16, group);
        let d = p.to_dense();
        // RTN to {-s, 0, +s}: |err| <= s/2 (+bf16 rounding of s)
        for r in 0..4 {
            let mut absmax = 0.0f32;
            for c in 0..128 {
                absmax = absmax.max((w.at2(r, c) * mask.at2(r, c)).abs());
            }
            for c in 0..128 {
                let want = w.at2(r, c) * mask.at2(r, c);
                let got = d.at2(r, c);
                assert!(
                    (want - got).abs() <= absmax * 0.505 + 1e-6,
                    "({r},{c}): {want} vs {got}, absmax {absmax}"
                );
            }
        }
    }

    #[test]
    fn five_trits_per_byte_worked_example() {
        // one 2:4 block, kept values [0.5, -0.5] with group absmax 0.5:
        // q = [+1, -1] -> digits [2, 0] -> byte = 2*1 + 0*3 = 2
        let w = Tensor::new(vec![1, 4], vec![0.5, 0.0, 0.0, -0.5]);
        let mask = Tensor::new(vec![1, 4], vec![1.0, 0.0, 0.0, 1.0]);
        let p = PackedTnm::from_dense_mask(&w, &mask, 2, 4, 2);
        assert_eq!(p.trits_raw(), &[2u8]);
        assert_eq!(p.scales_raw(), &[f32_to_bf16(0.5)]);
        assert_eq!(p.to_dense().data(), &[0.5, 0.0, 0.0, -0.5]);
    }

    #[test]
    fn all_zero_rows_decode_to_exact_zero() {
        // adversarial: zero rows produce zero scales; decode must be
        // exactly 0.0 (not NaN from 0/0, not -0.0 artifacts)
        let w = Tensor::zeros(vec![3, 64]);
        let mask = mask_topn_per_block(&Tensor::ones(vec![3, 64]), 8, 16);
        let p = PackedTnm::from_dense_mask(&w, &mask, 8, 16, 32);
        let d = p.to_dense();
        for &v in d.data() {
            assert!(v == 0.0 && v.is_sign_positive(), "got {v}");
        }
    }

    #[test]
    fn max_magnitude_runs_decode_to_signed_scale() {
        // adversarial: ±absmax runs must survive exactly (q = ±1, scale
        // = bf16(absmax)); alternating signs exercise every trit digit
        let vals: Vec<f32> = (0..64).map(|i| if i % 2 == 0 { 3.0 } else { -3.0 }).collect();
        let w = Tensor::new(vec![1, 64], vals);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let p = PackedTnm::from_dense_mask(&w, &mask, 8, 16, 16);
        let d = p.to_dense();
        for c in 0..64 {
            let want = w.at2(0, c) * mask.at2(0, c);
            assert_eq!(d.at2(0, c), want, "col {c}");
        }
    }

    #[test]
    fn group_straddling_blocks_use_per_element_scales() {
        // group 4 < n 8: every 8-kept block straddles two scale groups,
        // forcing the non-hoisted decode path
        let mut rng = Rng::new(23);
        let w = Tensor::randn(vec![2, 16], 1.0, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let p = PackedTnm::from_dense_mask(&w, &mask, 8, 16, 4);
        assert_eq!(p.to_dense(), expected_ternary(&w, &mask, 8, 16, 4));
    }

    #[test]
    fn property_adversarial_distributions_roundtrip() {
        use crate::util::propcheck::{check, Gen};
        check("ternary encode/decode", 30, |g: &mut Gen| {
            let (n, m) = *g.choose(&[(2usize, 4usize), (4, 8), (8, 16)]);
            let rows = g.int(1, 8);
            let blocks = g.int(1, 6);
            let cols = blocks * m;
            let kind = g.int(0, 3);
            let data: Vec<f32> = match kind {
                0 => vec![0.0; rows * cols], // all-zero
                1 => (0..rows * cols) // ±max runs
                    .map(|i| if (i / 7) % 2 == 0 { 2.5 } else { -2.5 })
                    .collect(),
                _ => g.vec_normal(rows * cols),
            };
            let w = Tensor::new(vec![rows, cols], data);
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            // groups that straddle block boundaries included (gcd fit)
            let group = PackedTnm::fit_group(*g.choose(&[3usize, 4, 64, 128]), n, m, cols);
            let p = PackedTnm::from_dense_mask(&w, &mask, n, m, group);
            let want = expected_ternary(&w, &mask, n, m, group);
            if p.to_dense() != want {
                return Err(format!("{n}:{m} g{group} {rows}x{cols} kind {kind} mismatch"));
            }
            Ok(())
        });
    }

    #[test]
    fn raw_parts_reassembly_is_identical() {
        let mut rng = Rng::new(41);
        let w = Tensor::randn(vec![8, 128], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let p = PackedTnm::from_dense_mask(&w, &mask, 8, 16, 64);
        assert_eq!(p.trits_raw().len(), PackedTnm::trits_len(8, 128, 8, 16));
        assert_eq!(p.scales_raw().len(), PackedTnm::scales_len(8, 128, 8, 16, 64));
        assert_eq!(p.meta_words().len(), PackedTnm::meta_words_len(8, 128, 8, 16));
        let back = PackedTnm::from_raw_parts(
            8,
            16,
            8,
            128,
            64,
            p.trits_raw().to_vec().into(),
            p.scales_raw().to_vec().into(),
            p.meta_words().to_vec().into(),
        )
        .unwrap();
        assert_eq!(back.to_dense(), p.to_dense());
        assert_eq!(back.bytes(), p.bytes());
        // wrong lengths are typed errors, not panics
        assert!(PackedTnm::from_raw_parts(
            8,
            16,
            8,
            128,
            64,
            vec![0u8; 3].into(),
            p.scales_raw().to_vec().into(),
            p.meta_words().to_vec().into()
        )
        .is_err());
        assert!(PackedTnm::from_raw_parts(
            8,
            16,
            8,
            128,
            7, // does not divide kept-per-row
            p.trits_raw().to_vec().into(),
            p.scales_raw().to_vec().into(),
            p.meta_words().to_vec().into()
        )
        .is_err());
    }

    #[test]
    fn storage_accounting_8_16_g128() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(vec![128, 256], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let p = PackedTnm::from_dense_mask(&w, &mask, 8, 16, 128);
        // kept/row = 128 -> 26 trit bytes/row, 1 scale/row
        assert_eq!(p.trits_raw().len(), 128 * 26);
        assert_eq!(p.scales_raw().len(), 128);
        // value-side: (26*8 + 16) / 256 = 0.875 bits/param <= 1.5
        let value_bits_per_param =
            (p.value_bytes() * 8) as f64 / (128.0 * 256.0);
        assert!((value_bits_per_param - 0.875).abs() < 1e-9);
        // total: 0.875 mask + 0.875 values = 1.75 bits/param exact
        // (asymptotic 1.7375; row padding adds the 26 vs 25.6 sliver)
        assert!(p.bits_per_param() < 1.7501 + 8.0 * 8.0 / (128.0 * 256.0));
        assert!(p.bits_per_param() >= 1.74);
        // ~9x smaller than dense bf16
        assert!(p.compression_ratio() > 8.5);
    }

    #[test]
    #[should_panic(expected = "does not divide")]
    fn unfitted_group_rejected() {
        let w = Tensor::ones(vec![2, 32]);
        let mask = mask_topn_per_block(&w, 8, 16);
        // kept/row = 16, group 5 does not divide it
        PackedTnm::from_dense_mask(&w, &mask, 8, 16, 5);
    }
}
