//! N:M pattern codebook: configuration counts, metadata bits, and
//! combinadic (combinatorial-number-system) ranking of keep-patterns.
//!
//! Table 1 of the paper compares patterns by the number of valid
//! configurations `C(M, N)` and the metadata overhead in bits/element.
//! Two encodings matter:
//!
//! * **index encoding** — store each kept element's in-block index with
//!   `ceil(log2 M)` bits: `N * ceil(log2 M) / M` bits/element.  This is
//!   what NVIDIA 2:4 hardware does (2 bits × 2 / 4 = 1.0... the marketed
//!   0.75 counts the 2-bit index per *kept* pair over the 4-block — see
//!   `bits_per_element_*` docs).
//! * **codebook encoding** — store the rank of the keep-set among all
//!   `C(M, N)` combinations: `ceil(log2 C(M,N)) / M` bits/element.  This
//!   is the paper's Table 1 column: 2:4 → 3/4 = 0.75, 4:8 → 7/8 ≈ 0.875
//!   (table rounds 0.81 from log2(70)=6.13), 8:16 → 14/16 = 0.875,
//!   16:32 → 30/32 ≈ 0.94 (table reports 1.00 with alignment).
//!
//! The combinadic rank/unrank here is the actual codec used by
//! [`crate::sparse::PackedNm`].

/// Static description of an N:M sparsity pattern.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PatternInfo {
    pub n: usize,
    pub m: usize,
}

impl PatternInfo {
    pub fn new(n: usize, m: usize) -> Self {
        // m ≤ 64 for weight patterns (packable as u64 ranks); outlier
        // statistics go up to m = 256 (PackedNm separately enforces ≤ 64).
        assert!(n <= m && m > 0 && m <= 256, "invalid pattern {n}:{m}");
        PatternInfo { n, m }
    }

    /// Number of valid keep-configurations, `C(M, N)`.
    pub fn configurations(&self) -> u128 {
        binomial(self.m as u128, self.n as u128)
    }

    /// Bits to store one block's pattern id in the codebook encoding.
    pub fn codebook_bits(&self) -> u32 {
        let c = self.configurations();
        if c <= 1 {
            0
        } else {
            128 - (c - 1).leading_zeros()
        }
    }

    /// Codebook metadata overhead in bits per (dense) element.
    pub fn bits_per_element_codebook(&self) -> f64 {
        self.codebook_bits() as f64 / self.m as f64
    }

    /// Index-encoding metadata overhead in bits per element.
    pub fn bits_per_element_index(&self) -> f64 {
        let idx_bits = (usize::BITS - (self.m - 1).leading_zeros()) as f64;
        self.n as f64 * idx_bits / self.m as f64
    }

    /// Fraction of weights kept.
    pub fn density(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }

    pub fn label(&self) -> String {
        format!("{}:{}", self.n, self.m)
    }
}

/// Exact binomial coefficient. The sequential form `c = c*(m-i)/(i+1)`
/// stays integral at every step (prefix products are binomials), so no
/// gcd bookkeeping is needed; intermediates fit u128 for every (m, n)
/// this crate uses (m ≤ 256, n ≤ 16; plus m ≤ 64 arbitrary n).
pub fn binomial(m: u128, n: u128) -> u128 {
    if n > m {
        return 0;
    }
    let n = n.min(m - n);
    let mut c: u128 = 1;
    for i in 0..n {
        c = c * (m - i) / (i + 1);
    }
    c
}

/// Combinadic rank of a strictly-ascending index set within `C(m, k)`.
///
/// Orders combinations lexicographically by their sorted index vector;
/// `rank` and `unrank` are exact inverses for every m ≤ 64.
pub fn rank_combination(indices: &[usize], m: usize) -> u64 {
    let k = indices.len();
    debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
    let mut rank: u128 = 0;
    let mut prev: isize = -1;
    let mut remaining = k;
    for &idx in indices {
        // count combinations whose next element is smaller than idx
        for j in (prev + 1) as usize..idx {
            rank += binomial((m - j - 1) as u128, (remaining - 1) as u128);
        }
        prev = idx as isize;
        remaining -= 1;
    }
    rank as u64
}

/// Table-driven combinadic decoder for one `(m, k)` pattern.
///
/// [`unrank_combination`] recomputes binomial coefficients with a
/// multiply/divide chain on every step — fine for packing, too slow for
/// the decode-free spmm hot loop, which unranks **every block of every
/// weight row on every GEMM**. `Unranker` precomputes the Pascal triangle
/// once per kernel invocation so a block decode is `k` table walks with
/// one lookup and one subtraction each.
pub struct Unranker {
    m: usize,
    k: usize,
    /// `binom[j * (k + 1) + r] = C(j, r)`, j ≤ m, r ≤ k
    binom: Vec<u64>,
}

impl Unranker {
    pub fn new(m: usize, k: usize) -> Self {
        assert!(k <= m && m <= 64, "unranker patterns are (k <= m <= 64)");
        let kw = k + 1;
        let mut binom = vec![0u64; (m + 1) * kw];
        for j in 0..=m {
            binom[j * kw] = 1;
            for r in 1..=k.min(j) {
                let below = binom[(j - 1) * kw + r - 1];
                let carry = binom[(j - 1) * kw + r];
                binom[j * kw + r] = below + carry;
            }
        }
        Unranker { m, k, binom }
    }

    #[inline]
    fn c(&self, n: usize, r: usize) -> u64 {
        self.binom[n * (self.k + 1) + r]
    }

    /// Decode `rank` into the ascending index set it names, writing into
    /// `out` (length `k`). Matches [`unrank_combination`] exactly.
    #[inline]
    pub fn unrank_into(&self, rank: u64, out: &mut [usize]) {
        debug_assert_eq!(out.len(), self.k);
        let mut r = rank;
        let mut start = 0usize;
        let mut remaining = self.k;
        let mut oi = 0usize;
        while remaining > 0 {
            for j in start..self.m {
                let c = self.c(self.m - j - 1, remaining - 1);
                if r < c {
                    out[oi] = j;
                    oi += 1;
                    start = j + 1;
                    remaining -= 1;
                    break;
                }
                r -= c;
            }
        }
    }
}

/// Inverse of [`rank_combination`].
pub fn unrank_combination(rank: u64, m: usize, k: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    let mut remaining = k;
    let mut r = rank as u128;
    while remaining > 0 {
        for j in start..m {
            let c = binomial((m - j - 1) as u128, (remaining - 1) as u128);
            if r < c {
                out.push(j);
                start = j + 1;
                remaining -= 1;
                break;
            }
            r -= c;
        }
    }
    out
}

/// The sparsity patterns of Table 1 plus the structured outlier patterns.
pub const WEIGHT_PATTERNS: [(usize, usize); 4] = [(2, 4), (4, 8), (8, 16), (16, 32)];
pub const OUTLIER_PATTERNS: [(usize, usize); 3] = [(4, 256), (8, 256), (16, 256)];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomials_match_table1() {
        // Table 1 "Configurations" column
        assert_eq!(PatternInfo::new(2, 4).configurations(), 6);
        assert_eq!(PatternInfo::new(4, 8).configurations(), 70);
        assert_eq!(PatternInfo::new(8, 16).configurations(), 12_870);
        assert_eq!(PatternInfo::new(16, 32).configurations(), 601_080_390);
    }

    #[test]
    fn bits_per_element_match_table1() {
        // codebook encoding: 2:4 → 0.75, 8:16 → 0.875 (the paper's 0.75
        // vs 0.88 comparison in the abstract)
        assert!((PatternInfo::new(2, 4).bits_per_element_codebook() - 0.75).abs() < 1e-9);
        assert!((PatternInfo::new(8, 16).bits_per_element_codebook() - 0.875).abs() < 1e-9);
        // 4:8 → ceil(log2 70)=7 bits / 8
        assert!((PatternInfo::new(4, 8).bits_per_element_codebook() - 0.875).abs() < 1e-9);
        // 16:32 → 30/32
        assert!((PatternInfo::new(16, 32).bits_per_element_codebook() - 0.9375).abs() < 1e-9);
    }

    #[test]
    fn density_is_half_for_weight_patterns() {
        for (n, m) in WEIGHT_PATTERNS {
            assert_eq!(PatternInfo::new(n, m).density(), 0.5);
        }
    }

    #[test]
    fn outlier_pattern_sparsity_levels() {
        // §1: 4:256, 8:256, 16:256 ↔ 1.5%, 3.1%, 6.25% salient fractions
        assert!((PatternInfo::new(4, 256).density() - 0.015625).abs() < 1e-9);
        assert!((PatternInfo::new(8, 256).density() - 0.03125).abs() < 1e-9);
        assert!((PatternInfo::new(16, 256).density() - 0.0625).abs() < 1e-9);
    }

    #[test]
    fn rank_unrank_roundtrip_exhaustive_2_4() {
        let m = 4;
        let mut seen = std::collections::HashSet::new();
        for a in 0..m {
            for b in (a + 1)..m {
                let r = rank_combination(&[a, b], m);
                assert!(r < 6);
                assert!(seen.insert(r), "duplicate rank {r}");
                assert_eq!(unrank_combination(r, m, 2), vec![a, b]);
            }
        }
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn rank_unrank_roundtrip_8_16_sampled() {
        use crate::util::Rng;
        let mut rng = Rng::new(42);
        for _ in 0..500 {
            let mut idx = rng.sample_indices(16, 8);
            idx.sort_unstable();
            let r = rank_combination(&idx, 16);
            assert!(r < 12_870);
            assert_eq!(unrank_combination(r, 16, 8), idx);
        }
    }

    #[test]
    fn rank_is_lexicographic() {
        // first combination ranks 0, last ranks C-1
        assert_eq!(rank_combination(&[0, 1], 4), 0);
        assert_eq!(rank_combination(&[2, 3], 4), 5);
        let first: Vec<usize> = (0..8).collect();
        assert_eq!(rank_combination(&first, 16), 0);
        let last: Vec<usize> = (8..16).collect();
        assert_eq!(rank_combination(&last, 16), 12_869);
    }

    #[test]
    fn index_encoding_bits() {
        // NVIDIA-style 2:4: 2 indices × 2 bits / 4 elements = 1.0
        assert!((PatternInfo::new(2, 4).bits_per_element_index() - 1.0).abs() < 1e-9);
        // 8:16: 8 × 4 / 16 = 2.0 — why the codebook encoding wins at 8:16
        assert!((PatternInfo::new(8, 16).bits_per_element_index() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn unranker_matches_unrank_combination() {
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        for (n, m) in [(2usize, 4usize), (4, 8), (8, 16), (16, 32)] {
            let unr = Unranker::new(m, n);
            let total = PatternInfo::new(n, m).configurations() as u64;
            let mut buf = vec![0usize; n];
            for _ in 0..200 {
                let rank = rng.below(total.min(1 << 30) as usize) as u64;
                unr.unrank_into(rank, &mut buf);
                assert_eq!(buf, unrank_combination(rank, m, n), "{n}:{m} rank {rank}");
                assert_eq!(rank_combination(&buf, m), rank);
            }
        }
    }

    #[test]
    fn binomial_edge_cases() {
        assert_eq!(binomial(0, 0), 1);
        assert_eq!(binomial(5, 0), 1);
        assert_eq!(binomial(5, 5), 1);
        assert_eq!(binomial(5, 6), 0);
        assert_eq!(binomial(256, 16), 10078751602022313874633200);
    }
}
