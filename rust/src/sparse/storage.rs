//! Borrowed-or-owned backing storage for the packed weight streams.
//!
//! Every packed format used to own its streams as plain `Vec`s, which
//! forced the `.spak` cold-start path to copy each weight stream onto
//! the heap before a kernel could touch it. [`Storage<T>`] is the
//! load-bearing abstraction that removes that copy: a stream is either
//! `Owned` (the pack-time path — `push_bits` and friends still build
//! `Vec`s) or `Mapped` (a typed window into an [`MappedFile`], i.e. the
//! page cache). `Deref<Target = [T]>` makes the two indistinguishable to
//! the spmm kernels, so `spmm`/`spmm_vec`/the tiled micro-kernels stream
//! weights directly out of a memory-mapped artifact — zero per-linear
//! heap copies, and multiple server processes share one physical copy.
//!
//! The mapped view reinterprets raw little-endian file bytes as `[T]`
//! (the `.spak` format is declared little-endian, like the checkpoint
//! format before it); [`Storage::mapped`] checks alignment and bounds
//! once at construction so the hot path carries no checks.

use std::marker::PhantomData;
use std::ops::Deref;
use std::sync::Arc;

use crate::util::mmap::MappedFile;

/// Plain-old-data element types a mapped stream may be viewed as. The
/// trait is sealed to the fixed set of stream dtypes the packed formats
/// use, all of which tolerate any bit pattern.
pub trait Pod: Copy + Send + Sync + 'static {}
impl Pod for u8 {}
impl Pod for u16 {}
impl Pod for u32 {}
impl Pod for u64 {}
impl Pod for f32 {}

/// A typed window into a shared [`MappedFile`].
#[derive(Clone)]
pub struct MappedSlice<T: Pod> {
    map: Arc<MappedFile>,
    byte_off: usize,
    len: usize,
    _elem: PhantomData<T>,
}

/// A packed weight stream: owned by the packer, or a zero-copy view of
/// a memory-mapped artifact. Dereferences to `[T]` either way.
#[derive(Clone)]
pub enum Storage<T: Pod> {
    Owned(Vec<T>),
    Mapped(MappedSlice<T>),
}

impl<T: Pod> Storage<T> {
    /// View `len` elements of `map` starting at `byte_off` — zero-copy.
    /// Fails (typed, recoverable) on a misaligned offset or a window
    /// that leaves the file, both of which mean a corrupt or
    /// wrongly-indexed artifact rather than a programming error.
    pub fn mapped(map: Arc<MappedFile>, byte_off: usize, len: usize) -> crate::Result<Storage<T>> {
        let elem = std::mem::size_of::<T>();
        // the map base is page-aligned (mmap) or 8-byte aligned (owned
        // fallback), so checking the resolved address covers both
        anyhow::ensure!(
            (map.bytes().as_ptr() as usize + byte_off) % std::mem::align_of::<T>() == 0,
            "mapped stream offset {byte_off} misaligned for {}-byte elements",
            elem
        );
        let end = byte_off
            .checked_add(len.checked_mul(elem).ok_or_else(|| {
                anyhow::anyhow!("mapped stream length {len} overflows")
            })?)
            .ok_or_else(|| anyhow::anyhow!("mapped stream offset {byte_off} overflows"))?;
        anyhow::ensure!(
            end <= map.len(),
            "mapped stream [{byte_off}, {end}) exceeds file of {} bytes",
            map.len()
        );
        Ok(Storage::Mapped(MappedSlice {
            map,
            byte_off,
            len,
            _elem: PhantomData,
        }))
    }

    /// `true` when this stream reads straight from a live mmap (the
    /// zero-copy serving property the store tests assert).
    pub fn is_mapped(&self) -> bool {
        match self {
            Storage::Owned(_) => false,
            Storage::Mapped(m) => m.map.is_mapped(),
        }
    }
}

impl<T: Pod> Deref for Storage<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        match self {
            Storage::Owned(v) => v,
            Storage::Mapped(m) => {
                // SAFETY: bounds and alignment were validated in
                // `Storage::mapped`; the map lives as long as `self`
                // (Arc), is immutable, and T tolerates any bit pattern.
                unsafe {
                    std::slice::from_raw_parts(
                        m.map.bytes().as_ptr().add(m.byte_off) as *const T,
                        m.len,
                    )
                }
            }
        }
    }
}

impl<T: Pod> From<Vec<T>> for Storage<T> {
    fn from(v: Vec<T>) -> Storage<T> {
        Storage::Owned(v)
    }
}

impl<T: Pod> std::fmt::Debug for Storage<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Storage::Owned(v) => write!(f, "Storage::Owned(len={})", v.len()),
            Storage::Mapped(m) => {
                write!(f, "Storage::Mapped(off={}, len={})", m.byte_off, m.len)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparselm-storage-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn owned_derefs_to_slice() {
        let s: Storage<u16> = vec![1u16, 2, 3].into();
        assert_eq!(&s[..], &[1, 2, 3]);
        assert!(!s.is_mapped());
    }

    #[test]
    fn mapped_view_reads_little_endian_words() {
        let mut bytes = Vec::new();
        for w in [0x1122u16, 0x3344, 0xAABB] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        bytes.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        let path = fixture("words.bin", &bytes);
        let map = MappedFile::open(&path).unwrap();
        let u16s: Storage<u16> = Storage::mapped(Arc::clone(&map), 0, 3).unwrap();
        assert_eq!(&u16s[..], &[0x1122, 0x3344, 0xAABB]);
        // cloning a mapped stream is an Arc bump pointing at the same bytes
        let clone = u16s.clone();
        assert_eq!(&clone[..], &u16s[..]);
        // the zero-copy property: the slice points inside the mapping
        #[cfg(unix)]
        {
            assert!(u16s.is_mapped());
            let base = map.bytes().as_ptr() as usize;
            let p = u16s.as_ptr() as usize;
            assert!(p >= base && p < base + map.len());
        }
        let u32s: Storage<u32> = Storage::mapped(Arc::clone(&map), 8, 1).unwrap();
        assert_eq!(u32s[0], 0xDEADBEEF);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn misaligned_or_out_of_bounds_rejected() {
        let path = fixture("bounds.bin", &[0u8; 32]);
        let map = MappedFile::open(&path).unwrap();
        assert!(Storage::<u64>::mapped(Arc::clone(&map), 4, 1).is_err(), "misaligned");
        assert!(Storage::<u64>::mapped(Arc::clone(&map), 0, 5).is_err(), "past end");
        assert!(Storage::<u8>::mapped(Arc::clone(&map), 0, 32).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
