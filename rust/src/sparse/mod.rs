//! Sparse storage substrates: the N:M pattern codebook, packed N:M weight
//! storage, the structured k:256 outlier format, and CSR for the
//! unstructured baseline.
//!
//! These implement the storage-accounting side of the paper's §2 (Table 1
//! bits/element, configuration counts) and the formats contrasted in
//! Table 7 (structured vs unstructured salient weights). Packing runs on
//! the Rust hot path after each per-layer prune job.

pub mod csr;
pub mod nm;
pub mod outliers;
pub mod patterns;
pub mod vnm;

pub use csr::Csr;
pub use nm::PackedNm;
pub use outliers::StructuredOutliers;
pub use patterns::PatternInfo;
pub use vnm::{vnm_select, PackedVnm};
