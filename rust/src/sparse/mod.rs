//! Sparse storage substrates **and the decode-free GEMM that consumes
//! them**: the N:M pattern codebook, packed N:M weight storage (bf16
//! values in [`PackedNm`], int-quantized values in [`PackedQnm`],
//! 1.58-bit ternary values in [`PackedTnm`]), V:N:M tiles, the
//! structured k:256 outlier format, CSR for the unstructured baseline,
//! and the [`Kernel`] trait + [`spmm()`]/[`spmm_parallel()`] hot path
//! that computes `y = x @ Wᵀ` straight from packed bits. The packed
//! formats differ only in their value decode step, captured by the
//! [`ValueCodec`] seam ([`mod@codec`]) — the micro-kernel loop bodies
//! exist once, generic over the codec.
//!
//! The formats implement the storage-accounting side of the paper's §2
//! (Table 1 bits/element, configuration counts) and the formats
//! contrasted in Table 7 (structured vs unstructured salient weights);
//! [`spmm()`] is what makes the accounting real at run time — packed
//! weights are never expanded on the request path, so the bytes a GEMM
//! streams are exactly the bytes the format stores (cross-checked against
//! the [`crate::hwsim`] roofline model by `cargo bench --bench f2_spmm`).
//! Every format's streams live behind [`Storage`] (owned at pack time,
//! zero-copy mmap-backed when loaded from a `.spak` artifact by
//! [`crate::store`]). Layout spec: `docs/FORMAT.md`; hot-path
//! walkthrough: `docs/ARCHITECTURE.md`.

pub(crate) mod bits;
pub mod codec;
pub mod csr;
pub mod nm;
pub mod outliers;
pub mod patterns;
pub mod qnm;
pub mod spmm;
pub mod storage;
pub mod tnm;
pub mod vnm;

pub use codec::ValueCodec;
pub use csr::Csr;
pub use nm::PackedNm;
pub use outliers::StructuredOutliers;
pub use patterns::PatternInfo;
pub use qnm::PackedQnm;
pub use storage::Storage;
pub use spmm::{
    dispatch, spmm, spmm_parallel, spmm_parallel_scoped, spmm_vec, MicroKernel, PackedLinear,
    PackedQuantLinear, PackedTernaryLinear, GEMM_MIN_ROWS, ROW_TILE, WEIGHT_TILE,
};
pub use tnm::PackedTnm;
pub use vnm::{vnm_select, PackedVnm};

use crate::tensor::Tensor;

/// A linear-layer weight operand `W (out_features, in_features)` that can
/// apply itself to activations as `y = x @ Wᵀ` **directly from its
/// storage format** — no dense materialization.
///
/// Implementations accumulate (`+=`) into the output, so side streams
/// compose: a [`PackedNm`] base and a [`StructuredOutliers`] salient
/// matrix run over the same output buffer and the sum is the effective
/// compressed weight (`W_ns + W_salient`). [`spmm()`] drives a kernel
/// serially, [`spmm_parallel()`] row-blocks it across the worker pool.
///
/// The dense reference implementation lives on [`Tensor`] itself, so any
/// call site can swap a packed kernel for its dense equivalent in tests.
pub trait Kernel: Send + Sync {
    /// `(out_features, in_features)` — the dense shape of `W`.
    fn dims(&self) -> (usize, usize);

    /// Accumulate `x (b, in) @ W[r0..r1, :]ᵀ` into `out`, a row-major
    /// `(b, r1 - r0)` block: `out[i * (r1-r0) + (r - r0)] += Σ_c x[i,c] * W[r,c]`.
    ///
    /// `out` is *added to*, never overwritten — callers zero it (or chain
    /// kernels over it).
    fn accumulate_rows(&self, x: &Tensor, r0: usize, r1: usize, out: &mut [f32]);

    /// Accumulate `x (in,) @ W[r0..r1, :]ᵀ` into `out` (`r1 - r0` floats)
    /// for **one** activation row — the decode-step GEMV
    /// ([`spmm_vec()`]). Implementations must accumulate per output row
    /// in the same order as [`Self::accumulate_rows`] so a sequence
    /// decoded alone is bitwise identical to one decoded in a batch.
    /// The default wraps `x` in a 1-row tensor; the packed formats
    /// override it with allocation-free single-row loops.
    fn accumulate_vec(&self, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        let xt = Tensor::new(vec![1, x.len()], x.to_vec());
        self.accumulate_rows(&xt, r0, r1, out);
    }

    /// Bytes a decoder streams for this weight operand (values +
    /// metadata) — the *measured* side of the [`crate::hwsim::HwModel`]
    /// traffic model. Dense kernels report their bf16 deployment
    /// footprint so ratios match the paper's accounting.
    fn operand_bytes(&self) -> usize;

    /// Pattern-metadata blocks one full application of this kernel
    /// decodes (combinadic unranks) — the [`crate::util::perf`]
    /// telemetry side. Formats without pattern metadata (dense, CSR,
    /// structured outliers) report 0.
    fn decode_blocks(&self) -> usize {
        0
    }

    /// Output-row partition granularity for parallel row-blocking
    /// ([`PackedVnm`] tiles span `v` consecutive rows).
    fn row_align(&self) -> usize {
        1
    }

    /// Short codec label for observability — trace spans and logs tag
    /// each spmm dispatch with the operand's format (`"nm"`, `"qnm"`,
    /// `"tnm"`, `"dense"`, ...). Purely diagnostic; never dispatched on.
    fn kind(&self) -> &'static str {
        "kernel"
    }
}
