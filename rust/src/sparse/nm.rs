//! Packed N:M weight storage.
//!
//! For every contiguous `(1, M)` block along the input-channel axis the
//! format stores the `N` kept values (bf16) plus the block's keep-pattern
//! as a combinadic rank in `ceil(log2 C(M,N))` bits (the codebook encoding
//! of Table 1 — 0.75 bits/elt for 2:4, 0.875 for 8:16).  Pattern ids are
//! bit-packed contiguously; values are laid out block-major so a decoder
//! streams both arrays linearly.
//!
//! The production consumer is the **decode-free GEMM** in
//! [`mod@super::spmm`]: `PackedNm` implements [`super::Kernel`], unranking
//! each block's keep-pattern on the fly and accumulating into f32 —
//! [`Self::to_dense`] exists for reconstruction-error reporting and
//! tests, not the request path. The byte-exact layout (with a worked
//! 8:16 block) is specified in `docs/FORMAT.md`; where the format sits in
//! the serving hot path is covered by `docs/ARCHITECTURE.md`.

use super::bits::{packed_words, push_bits, read_bits};
use super::patterns::{rank_combination, unrank_combination, PatternInfo};
use super::storage::Storage;
use crate::tensor::{bf16_to_f32, f32_to_bf16, Tensor};

/// Collect the (ascending, padded) keep-set of block `b` of one mask
/// row into `idx_buf`: the masked indices, padded with zero-valued
/// slots at the lowest free indices when outlier exclusion left fewer
/// than `n` survivors — exactly like fixed-slot hardware formats, so
/// the pattern id always encodes an N-subset. This is the **one copy**
/// of the pad discipline; the bf16 ([`PackedNm`]) and quantized
/// ([`super::PackedQnm`]) packers both call it, so their meta streams
/// cannot diverge. `r` is for the panic message only.
pub(crate) fn keep_indices_for_block(
    mrow: &[f32],
    r: usize,
    b: usize,
    n: usize,
    m: usize,
    idx_buf: &mut Vec<usize>,
) {
    idx_buf.clear();
    for j in 0..m {
        if mrow[b * m + j] != 0.0 {
            idx_buf.push(j);
        }
    }
    assert!(
        idx_buf.len() <= n,
        "block ({r},{b}) holds {} kept values, pattern allows {n}",
        idx_buf.len()
    );
    // pad deficient blocks with zero-valued slots (lowest free indices)
    let mut j = 0;
    while idx_buf.len() < n {
        if mrow[b * m + j] == 0.0 && !idx_buf.contains(&j) {
            idx_buf.push(j);
        }
        j += 1;
    }
    idx_buf.sort_unstable();
}

/// A rank-2 weight matrix stored in packed N:M form.
#[derive(Clone, Debug)]
pub struct PackedNm {
    pub pattern: PatternInfo,
    pub rows: usize,
    pub cols: usize,
    /// kept values, bf16, block-major: `rows * cols / m * n` entries —
    /// owned when freshly packed, mmap-backed when loaded from a `.spak`
    values: Storage<u16>,
    /// bit-packed combinadic pattern ids, `codebook_bits` per block
    meta: Storage<u64>,
}

impl PackedNm {
    /// Pack `dense * mask`.
    ///
    /// Each block must hold **at most** N kept entries. Blocks with fewer
    /// (possible when structured outliers consumed positions of the block
    /// — they live in their own matrix) are padded with zero-valued slots
    /// at the lowest free indices, exactly like fixed-slot hardware
    /// formats: the pattern id always encodes an N-subset.
    pub fn from_dense_mask(dense: &Tensor, mask: &Tensor, n: usize, m: usize) -> Self {
        assert!(m <= 64, "PackedNm stores u64 combinadic ranks (m <= 64), got m={m}");
        let pattern = PatternInfo::new(n, m);
        let (rows, cols) = dense.dims2();
        assert_eq!(dense.shape(), mask.shape(), "mask shape mismatch");
        assert_eq!(cols % m, 0, "cols {cols} not divisible by m {m}");
        let bits = pattern.codebook_bits();
        let blocks = rows * cols / m;
        let mut values = Vec::with_capacity(blocks * n);
        let mut meta = Vec::with_capacity((blocks * bits as usize + 63) / 64 + 1);
        let mut pos = 0usize;
        let mut idx_buf = Vec::with_capacity(n);
        for r in 0..rows {
            let drow = dense.row(r);
            let mrow = mask.row(r);
            for b in 0..cols / m {
                keep_indices_for_block(mrow, r, b, n, m, &mut idx_buf);
                for &j in &idx_buf {
                    // padded slots carry a zero value
                    let v = if mrow[b * m + j] != 0.0 { drow[b * m + j] } else { 0.0 };
                    values.push(f32_to_bf16(v));
                }
                push_bits(&mut meta, &mut pos, rank_combination(&idx_buf, m), bits);
            }
        }
        PackedNm {
            pattern,
            rows,
            cols,
            values: values.into(),
            meta: meta.into(),
        }
    }

    /// Reassemble from decoder-side streams — the `.spak` mmap reader
    /// path ([`crate::store`]). Stream lengths must be exactly what a
    /// pack of the same `(rows, cols, n, m)` produces
    /// ([`Self::values_len`] / [`Self::meta_words_len`]), so the
    /// reconstructed operand is byte-identical (including
    /// [`Self::bytes`] accounting) to the in-memory original.
    pub fn from_raw_parts(
        n: usize,
        m: usize,
        rows: usize,
        cols: usize,
        values: Storage<u16>,
        meta: Storage<u64>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(m <= 64, "PackedNm stores u64 combinadic ranks (m <= 64), got m={m}");
        anyhow::ensure!(n <= m && m > 0 && cols % m == 0, "bad pattern {n}:{m} for cols {cols}");
        let pattern = PatternInfo::new(n, m);
        anyhow::ensure!(
            values.len() == Self::values_len(rows, cols, n, m),
            "PackedNm values stream: {} entries, want {}",
            values.len(),
            Self::values_len(rows, cols, n, m)
        );
        anyhow::ensure!(
            meta.len() == Self::meta_words_len(rows, cols, n, m),
            "PackedNm meta stream: {} words, want {}",
            meta.len(),
            Self::meta_words_len(rows, cols, n, m)
        );
        Ok(PackedNm {
            pattern,
            rows,
            cols,
            values,
            meta,
        })
    }

    /// Exact kept-value stream length of a `(rows, cols)` matrix.
    pub fn values_len(rows: usize, cols: usize, n: usize, m: usize) -> usize {
        rows * cols / m * n
    }

    /// Exact `u64` word count of the pattern stream (the shared
    /// `sparse::bits` word-growth rule — what `from_dense_mask`
    /// produces).
    pub fn meta_words_len(rows: usize, cols: usize, n: usize, m: usize) -> usize {
        packed_words(rows * cols / m, PatternInfo::new(n, m).codebook_bits())
    }

    /// Expand back to a dense tensor (bf16-rounded values).
    pub fn to_dense(&self) -> Tensor {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut pos = 0usize;
        let mut vi = 0usize;
        for r in 0..self.rows {
            for b in 0..self.cols / m {
                let rank = read_bits(&self.meta, pos, bits);
                pos += bits as usize;
                let idx = unrank_combination(rank, m, n);
                for &j in &idx {
                    out[r * self.cols + b * m + j] = bf16_to_f32(self.values[vi]);
                    vi += 1;
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// The dense 0/1 keep mask encoded by the metadata.
    pub fn mask(&self) -> Tensor {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut pos = 0usize;
        for r in 0..self.rows {
            for b in 0..self.cols / m {
                let rank = read_bits(&self.meta, pos, bits);
                pos += bits as usize;
                for &j in &unrank_combination(rank, m, n) {
                    out[r * self.cols + b * m + j] = 1.0;
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// Storage in bytes: bf16 values + packed metadata.
    pub fn bytes(&self) -> usize {
        self.values.len() * 2 + (self.meta.len() * 8).min(self.meta_bits() / 8 + 8)
    }

    /// Exact metadata footprint in bits.
    pub fn meta_bits(&self) -> usize {
        (self.rows * self.cols / self.pattern.m) * self.pattern.codebook_bits() as usize
    }

    /// Dense bf16 storage this replaces, in bytes.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 2
    }

    /// Compression ratio vs dense bf16 (>1 means smaller).
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.bytes() as f64
    }

    pub fn n_values(&self) -> usize {
        self.values.len()
    }

    /// Pattern blocks this matrix stores (`rows * cols / m`) — each is
    /// one combinadic unrank for the decoder, the unit the
    /// [`crate::util::perf`] decoded-blocks counter counts.
    pub fn n_blocks(&self) -> usize {
        self.rows * (self.cols / self.pattern.m)
    }

    /// Decoder-side view of the kept values: raw bf16 words, block-major
    /// (`n` per block, `rows * cols / m` blocks row-major).
    pub fn values_raw(&self) -> &[u16] {
        &self.values
    }

    /// Decoder-side view of the pattern stream: bit-packed combinadic
    /// ranks, [`PatternInfo::codebook_bits`] bits per block, in the same
    /// block order as [`Self::values_raw`].
    pub fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    /// `true` when both streams read straight from a live mmap (the
    /// `.spak` zero-copy serving property; see [`Storage::is_mapped`]).
    pub fn is_mapped(&self) -> bool {
        self.values.is_mapped() && self.meta.is_mapped()
    }

    /// Widen the `n` bf16 values of block `(r, bblk)` into f32 — the
    /// [`super::codec::ValueCodec`] decode step.
    #[inline]
    pub(crate) fn decode_block_into(&self, r: usize, bblk: usize, out: &mut [f32]) {
        let n = self.pattern.n;
        let vi = (r * (self.cols / self.pattern.m) + bblk) * n;
        for (t, o) in out.iter_mut().enumerate().take(n) {
            *o = bf16_to_f32(self.values[vi + t]);
        }
    }
}

impl super::codec::ValueCodec for PackedNm {
    fn pattern(&self) -> &PatternInfo {
        &self.pattern
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    #[inline]
    fn rank_index(&self, r: usize, bblk: usize) -> usize {
        r * (self.cols / self.pattern.m) + bblk
    }

    #[inline]
    fn decode_block_into(&self, r: usize, bblk: usize, out: &mut [f32]) {
        PackedNm::decode_block_into(self, r, bblk, out)
    }

    fn values_bytes(&self) -> usize {
        self.values.len() * 2
    }

    fn bits_per_kept(&self) -> f64 {
        16.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask_topn_per_block;
    use crate::util::Rng;

    fn pack_roundtrip(n: usize, m: usize, rows: usize, cols: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
        let packed = PackedNm::from_dense_mask(&w, &mask, n, m);
        let dense = packed.to_dense();
        // bf16 rounding is the only loss
        for r in 0..rows {
            for c in 0..cols {
                let want = w.at2(r, c) * mask.at2(r, c);
                let got = dense.at2(r, c);
                assert!(
                    (want - got).abs() <= want.abs() * 0.01 + 1e-6,
                    "({r},{c}): {want} vs {got}"
                );
            }
        }
        assert_eq!(packed.mask(), mask);
    }

    #[test]
    fn roundtrip_all_patterns() {
        for (i, (n, m)) in [(2usize, 4usize), (4, 8), (8, 16), (16, 32)]
            .into_iter()
            .enumerate()
        {
            pack_roundtrip(n, m, 32, 256, i as u64 + 1);
        }
    }

    #[test]
    fn raw_parts_reassembly_is_identical() {
        let mut rng = Rng::new(31);
        let w = Tensor::randn(vec![16, 256], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let p = PackedNm::from_dense_mask(&w, &mask, 8, 16);
        // the declared stream lengths are what the packer produced
        assert_eq!(p.values_raw().len(), PackedNm::values_len(16, 256, 8, 16));
        assert_eq!(p.meta_words().len(), PackedNm::meta_words_len(16, 256, 8, 16));
        let back = PackedNm::from_raw_parts(
            8,
            16,
            16,
            256,
            p.values_raw().to_vec().into(),
            p.meta_words().to_vec().into(),
        )
        .unwrap();
        assert_eq!(back.to_dense(), p.to_dense());
        assert_eq!(back.bytes(), p.bytes());
        // wrong lengths are typed errors, not panics
        assert!(PackedNm::from_raw_parts(
            8,
            16,
            16,
            256,
            vec![0u16; 3].into(),
            p.meta_words().to_vec().into()
        )
        .is_err());
    }

    #[test]
    fn bitpacking_boundary_crossing() {
        // 8:16 uses 14-bit ids: not a divisor of 64, so ids straddle words
        pack_roundtrip(8, 16, 3, 1024, 99);
    }

    #[test]
    fn storage_accounting_8_16() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(vec![256, 256], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let p = PackedNm::from_dense_mask(&w, &mask, 8, 16);
        // values: half the elements at 2 bytes
        assert_eq!(p.n_values(), 256 * 256 / 2);
        // metadata: 14 bits per 16-block = 0.875 bits/element
        assert_eq!(p.meta_bits(), 256 * 256 / 16 * 14);
        let bits_per_elt = p.meta_bits() as f64 / (256.0 * 256.0);
        assert!((bits_per_elt - 0.875).abs() < 1e-9);
        // ~2x compression minus metadata
        assert!(p.compression_ratio() > 1.8 && p.compression_ratio() < 2.0);
    }

    #[test]
    #[should_panic(expected = "pattern allows")]
    fn rejects_wrong_mask_cardinality() {
        let w = Tensor::ones(vec![1, 16]);
        let mask = Tensor::ones(vec![1, 16]); // 16 kept, pattern wants 8
        PackedNm::from_dense_mask(&w, &mask, 8, 16);
    }

    #[test]
    fn deficient_blocks_padded_with_zero_slots() {
        // 2:4 block where outlier exclusion left only 1 survivor
        let w = Tensor::new(vec![1, 8], vec![5., 6., 7., 8., 1., 2., 3., 4.]);
        let mask = Tensor::new(vec![1, 8], vec![0., 1., 0., 0., 0., 0., 1., 1.]);
        let p = PackedNm::from_dense_mask(&w, &mask, 2, 4);
        let d = p.to_dense();
        assert_eq!(d.data(), &[0., 6., 0., 0., 0., 0., 3., 4.]);
        // the stored pattern still names exactly 2 slots per block
        let pm = p.mask();
        assert_eq!(pm.data().iter().filter(|&&x| x != 0.0).count(), 4);
    }

    #[test]
    fn property_pack_preserves_masked_values() {
        use crate::util::propcheck::{check, Gen};
        check("packed nm roundtrip", 20, |g: &mut Gen| {
            let (n, m) = *g.choose(&[(2usize, 4usize), (4, 8), (8, 16)]);
            let rows = g.int(1, 16);
            let blocks = g.int(1, 8);
            let cols = blocks * m;
            let w = Tensor::new(
                vec![rows, cols],
                g.vec_normal(rows * cols),
            );
            let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
            let p = PackedNm::from_dense_mask(&w, &mask, n, m);
            let d = p.to_dense();
            for i in 0..rows * cols {
                let want = w.data()[i] * mask.data()[i];
                let got = d.data()[i];
                if (want - got).abs() > want.abs() * 0.01 + 1e-6 {
                    return Err(format!("elem {i}: {want} vs {got}"));
                }
            }
            Ok(())
        });
    }
}
