//! V:N:M two-level sparsity (Zhao et al. 2024, "Beyond 2:4").
//!
//! The paper's related-work section positions V:N:M as the other road
//! past 2:4: instead of finer selection *within* a block (this paper's
//! 8:16), V:N:M shares one N-of-M column pattern across a **vector of V
//! consecutive rows**, amortizing the metadata V× and letting hardware
//! fetch V×N dense panels. This module implements selection, packed
//! storage and accounting so the `a3_vnm` ablation can place both
//! generalizations on the same flexibility/overhead axis:
//!
//! * metadata: `ceil(log2 C(M,N)) / (V·M)` bits/element — 8:16 costs
//!   0.875, V=4:2:4 costs 0.1875;
//! * flexibility: one pattern per V rows — strictly fewer masks than
//!   per-row N:M, so reconstruction error is never lower at equal N:M.

use super::bits::{packed_words, push_bits, read_bits};
use super::patterns::{rank_combination, unrank_combination, PatternInfo};
use super::storage::Storage;
use crate::tensor::{bf16_to_f32, f32_to_bf16, Tensor};

/// A rank-2 matrix stored V:N:M packed: for every `(V, M)` tile one
/// N-subset of columns is kept.
#[derive(Clone, Debug)]
pub struct PackedVnm {
    pub v: usize,
    pub pattern: PatternInfo,
    pub rows: usize,
    pub cols: usize,
    /// kept values bf16, tile-major then row-major inside the tile —
    /// owned when freshly packed, mmap-backed when loaded from a `.spak`
    values: Storage<u16>,
    /// one combinadic rank per (V, M) tile, bit-packed
    meta: Storage<u64>,
    meta_bits_used: usize,
}

/// Choose the kept columns of each `(V, M)` tile by **group saliency** —
/// the sum of scores down the V rows of each candidate column (the
/// vector-granular analogue of per-row top-N).
pub fn vnm_select(score: &Tensor, v: usize, n: usize, m: usize) -> Tensor {
    let (rows, cols) = score.dims2();
    assert!(rows % v == 0, "rows {rows} not divisible by v {v}");
    assert!(cols % m == 0, "cols {cols} not divisible by m {m}");
    let mut mask = vec![0.0f32; rows * cols];
    let mut col_sal = vec![0.0f32; m];
    for t0 in (0..rows).step_by(v) {
        for b in 0..cols / m {
            col_sal.iter_mut().for_each(|x| *x = 0.0);
            for r in t0..t0 + v {
                let row = score.row(r);
                for (j, cs) in col_sal.iter_mut().enumerate() {
                    *cs += row[b * m + j];
                }
            }
            let mut order: Vec<usize> = (0..m).collect();
            order.sort_by(|&i, &j| {
                col_sal[j]
                    .partial_cmp(&col_sal[i])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            for &j in order.iter().take(n) {
                for r in t0..t0 + v {
                    mask[r * cols + b * m + j] = 1.0;
                }
            }
        }
    }
    Tensor::new(vec![rows, cols], mask)
}

impl PackedVnm {
    /// Pack `dense * mask` where `mask` keeps the same N columns across
    /// every V-row group (as produced by [`vnm_select`]).
    pub fn from_dense_mask(dense: &Tensor, mask: &Tensor, v: usize, n: usize, m: usize) -> Self {
        assert!(m <= 64, "combinadic ranks stored in u64 (m <= 64)");
        let pattern = PatternInfo::new(n, m);
        let (rows, cols) = dense.dims2();
        assert_eq!(dense.shape(), mask.shape());
        assert!(rows % v == 0 && cols % m == 0);
        let bits = pattern.codebook_bits();
        let tiles = (rows / v) * (cols / m);
        let mut values = Vec::with_capacity(tiles * v * n);
        let mut meta = Vec::with_capacity((tiles * bits as usize + 63) / 64 + 1);
        let mut pos = 0usize;
        for t0 in (0..rows).step_by(v) {
            for b in 0..cols / m {
                // the tile's column subset comes from its first row; all
                // rows must agree (that is the format)
                let mut idx = Vec::with_capacity(n);
                for j in 0..m {
                    if mask.at2(t0, b * m + j) != 0.0 {
                        idx.push(j);
                    }
                }
                assert_eq!(
                    idx.len(),
                    n,
                    "tile ({t0},{b}): {} kept columns, want {n}",
                    idx.len()
                );
                for r in t0..t0 + v {
                    for &j in &idx {
                        assert!(
                            mask.at2(r, b * m + j) != 0.0,
                            "tile ({t0},{b}) row {r} disagrees with tile pattern"
                        );
                        values.push(f32_to_bf16(dense.at2(r, b * m + j)));
                    }
                }
                push_bits(&mut meta, &mut pos, rank_combination(&idx, m), bits);
            }
        }
        PackedVnm {
            v,
            pattern,
            rows,
            cols,
            values: values.into(),
            meta: meta.into(),
            meta_bits_used: pos,
        }
    }

    /// Reassemble from decoder-side streams (the `.spak` mmap reader
    /// path) — lengths must match [`Self::values_len`] /
    /// [`Self::meta_words_len`] exactly.
    pub fn from_raw_parts(
        v: usize,
        n: usize,
        m: usize,
        rows: usize,
        cols: usize,
        values: Storage<u16>,
        meta: Storage<u64>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(m <= 64, "combinadic ranks stored in u64 (m <= 64), got m={m}");
        anyhow::ensure!(v > 0 && rows % v == 0, "rows {rows} not divisible by v {v}");
        anyhow::ensure!(n <= m && m > 0 && cols % m == 0, "bad pattern {n}:{m} for cols {cols}");
        let pattern = PatternInfo::new(n, m);
        anyhow::ensure!(
            values.len() == Self::values_len(v, rows, cols, n, m),
            "PackedVnm values stream: {} entries, want {}",
            values.len(),
            Self::values_len(v, rows, cols, n, m)
        );
        anyhow::ensure!(
            meta.len() == Self::meta_words_len(v, rows, cols, n, m),
            "PackedVnm meta stream: {} words, want {}",
            meta.len(),
            Self::meta_words_len(v, rows, cols, n, m)
        );
        let tiles = (rows / v) * (cols / m);
        Ok(PackedVnm {
            v,
            pattern,
            rows,
            cols,
            values,
            meta,
            meta_bits_used: tiles * pattern.codebook_bits() as usize,
        })
    }

    /// Exact kept-value stream length (`v * n` per `(V, M)` tile).
    pub fn values_len(v: usize, rows: usize, cols: usize, n: usize, m: usize) -> usize {
        (rows / v) * (cols / m) * v * n
    }

    /// Exact `u64` word count of the tile-pattern stream.
    pub fn meta_words_len(v: usize, rows: usize, cols: usize, n: usize, m: usize) -> usize {
        packed_words((rows / v) * (cols / m), PatternInfo::new(n, m).codebook_bits())
    }

    /// Expand back to dense (bf16-rounded values).
    pub fn to_dense(&self) -> Tensor {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut pos = 0usize;
        let mut vi = 0usize;
        for t0 in (0..self.rows).step_by(self.v) {
            for b in 0..self.cols / m {
                let rank = read_bits(&self.meta, pos, bits);
                pos += bits as usize;
                let idx = unrank_combination(rank, m, n);
                for r in t0..t0 + self.v {
                    for &j in &idx {
                        out[r * self.cols + b * m + j] = bf16_to_f32(self.values[vi]);
                        vi += 1;
                    }
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// Exact metadata footprint in bits.
    pub fn meta_bits(&self) -> usize {
        self.meta_bits_used
    }

    /// Metadata bits per dense element — the V× amortization.
    pub fn bits_per_element(&self) -> f64 {
        self.meta_bits() as f64 / (self.rows * self.cols) as f64
    }

    /// Pattern entries this matrix stores (one combinadic rank per
    /// `(V, M)` tile) — the decoder's unrank count for one full pass,
    /// the unit the [`crate::util::perf`] decoded-blocks counter counts.
    pub fn n_tiles(&self) -> usize {
        ((self.rows + self.v - 1) / self.v) * (self.cols / self.pattern.m)
    }

    /// Storage in bytes: bf16 values + packed metadata.
    pub fn bytes(&self) -> usize {
        self.values.len() * 2 + (self.meta_bits() + 7) / 8
    }

    pub fn compression_ratio(&self) -> f64 {
        (self.rows * self.cols * 2) as f64 / self.bytes() as f64
    }

    /// Decoder-side view of the kept values: bf16 words, tile-major, then
    /// row-major inside each `(V, M)` tile (`v * n` per tile).
    pub fn values_raw(&self) -> &[u16] {
        &self.values
    }

    /// Decoder-side view of the pattern stream: one bit-packed combinadic
    /// rank per tile, in tile order.
    pub fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    /// `true` when both streams read straight from a live mmap (the
    /// `.spak` zero-copy serving property).
    pub fn is_mapped(&self) -> bool {
        self.values.is_mapped() && self.meta.is_mapped()
    }
}

impl super::codec::ValueCodec for PackedVnm {
    fn pattern(&self) -> &PatternInfo {
        &self.pattern
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    /// One rank per `(V, M)` tile: rows of a tile share their pattern
    /// id, which the generic loops exploit by copying the previous
    /// row's unranked indices when consecutive rows resolve to the same
    /// index.
    #[inline]
    fn rank_index(&self, r: usize, bblk: usize) -> usize {
        (r / self.v) * (self.cols / self.pattern.m) + bblk
    }

    #[inline]
    fn decode_block_into(&self, r: usize, bblk: usize, out: &mut [f32]) {
        let n = self.pattern.n;
        let tile = (r / self.v) * (self.cols / self.pattern.m) + bblk;
        let vi = tile * self.v * n + (r % self.v) * n;
        for (t, o) in out.iter_mut().enumerate().take(n) {
            *o = bf16_to_f32(self.values[vi + t]);
        }
    }

    fn values_bytes(&self) -> usize {
        self.values.len() * 2
    }

    fn bits_per_kept(&self) -> f64 {
        16.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_error;
    use crate::util::Rng;

    #[test]
    fn select_budget_and_row_agreement() {
        let mut rng = Rng::new(61);
        let s = Tensor::randn(vec![16, 64], 1.0, &mut rng).map(f32::abs);
        let mask = vnm_select(&s, 4, 2, 4);
        for t0 in (0..16).step_by(4) {
            for b in 0..64 / 4 {
                let cols: Vec<usize> = (0..4)
                    .filter(|&j| mask.at2(t0, b * 4 + j) != 0.0)
                    .collect();
                assert_eq!(cols.len(), 2);
                for r in t0..t0 + 4 {
                    for j in 0..4 {
                        let want = cols.contains(&j);
                        assert_eq!(mask.at2(r, b * 4 + j) != 0.0, want);
                    }
                }
            }
        }
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(62);
        let w = Tensor::randn(vec![8, 128], 0.05, &mut rng);
        let mask = vnm_select(&w.map(f32::abs), 4, 8, 16);
        let p = PackedVnm::from_dense_mask(&w, &mask, 4, 8, 16);
        let d = p.to_dense();
        let want = w.mul(&mask);
        assert!(rel_error(&d, &want) < 0.01, "{}", rel_error(&d, &want));
    }

    #[test]
    fn metadata_amortized_v_times() {
        let mut rng = Rng::new(63);
        let w = Tensor::randn(vec![64, 256], 0.05, &mut rng);
        let mask = vnm_select(&w.map(f32::abs), 4, 8, 16);
        let p = PackedVnm::from_dense_mask(&w, &mask, 4, 8, 16);
        // 14 bits per (4,16) tile = 0.875/4 bits per element
        assert!((p.bits_per_element() - 0.875 / 4.0).abs() < 1e-9);
        let nm_mask = crate::pruning::mask_topn_per_block(&w.map(f32::abs), 8, 16);
        let nm = crate::sparse::PackedNm::from_dense_mask(&w, &nm_mask, 8, 16);
        assert!(p.bytes() < nm.bytes());
    }

    #[test]
    fn per_row_nm_never_worse_than_vnm() {
        // V:N:M is a restriction of N:M → reconstruction error >= N:M's
        let mut rng = Rng::new(64);
        let w = Tensor::randn_outliers(vec![32, 256], 0.05, 0.01, 8.0, &mut rng);
        let score = w.map(f32::abs);
        let nm_mask = crate::pruning::mask_topn_per_block(&score, 8, 16);
        let vnm_mask = vnm_select(&score, 8, 8, 16);
        let e_nm = rel_error(&w.mul(&nm_mask), &w);
        let e_vnm = rel_error(&w.mul(&vnm_mask), &w);
        assert!(e_nm <= e_vnm + 1e-9, "{e_nm} !<= {e_vnm}");
        // both keep the same element count
        assert_eq!(nm_mask.count_nonzero(), vnm_mask.count_nonzero());
    }

    #[test]
    fn v1_equals_per_row_nm() {
        let mut rng = Rng::new(65);
        let w = Tensor::randn(vec![8, 64], 1.0, &mut rng);
        let score = w.map(f32::abs);
        let a = vnm_select(&score, 1, 2, 4);
        let b = crate::pruning::mask_topn_per_block(&score, 2, 4);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "disagrees")]
    fn rejects_rowwise_mask() {
        let w = Tensor::ones(vec![2, 4]);
        // row 0 keeps cols {0,1}, row 1 keeps {2,3} — not a V:N:M mask
        let mask = Tensor::new(vec![2, 4], vec![1., 1., 0., 0., 0., 0., 1., 1.]);
        PackedVnm::from_dense_mask(&w, &mask, 2, 2, 4);
    }
}
