//! CSR storage — the *unstructured* salient-weight baseline (SPQR-style,
//! Dettmers et al. 2023b) that Table 7 contrasts with the structured
//! k:256 format.
//!
//! Per nonzero: bf16 value + u32 column index; per row: one u32 row
//! pointer. Metadata grows linearly with nonzeros and access is irregular
//! — exactly the inefficiency §1 motivates structured outliers with.

use crate::tensor::{bf16_to_f32, f32_to_bf16, Tensor};

#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    values: Vec<u16>,
}

impl Csr {
    /// Compress the nonzeros of `dense * mask`.
    pub fn from_dense_mask(dense: &Tensor, mask: &Tensor) -> Self {
        let (rows, cols) = dense.dims2();
        assert_eq!(dense.shape(), mask.shape());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            let drow = dense.row(r);
            let mrow = mask.row(r);
            for c in 0..cols {
                if mrow[c] != 0.0 {
                    col_idx.push(c as u32);
                    values.push(f32_to_bf16(drow[c]));
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr {
            rows,
            cols,
            row_ptr,
            col_idx,
            values,
        }
    }

    /// Keep the top-`count` entries of `score` with no structural
    /// constraint — the unstructured selection used for the Table 7
    /// baseline at a matched salient budget.
    pub fn from_topk_global(dense: &Tensor, score: &Tensor, count: usize) -> Self {
        let (rows, cols) = dense.dims2();
        assert_eq!(dense.shape(), score.shape());
        let mut idx: Vec<usize> = (0..rows * cols).collect();
        idx.sort_unstable_by(|&a, &b| {
            score.data()[b]
                .partial_cmp(&score.data()[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut keep = vec![false; rows * cols];
        for &i in idx.iter().take(count) {
            keep[i] = true;
        }
        let mask = Tensor::new(
            vec![rows, cols],
            keep.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
        );
        Csr::from_dense_mask(dense, &mask)
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for i in lo..hi {
                out[r * self.cols + self.col_idx[i] as usize] = bf16_to_f32(self.values[i]);
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// Add the stored values onto `dst` in place.
    pub fn add_into(&self, dst: &mut Tensor) {
        assert_eq!(dst.shape(), [self.rows, self.cols]);
        let cols = self.cols;
        let data = dst.data_mut();
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for i in lo..hi {
                data[r * cols + self.col_idx[i] as usize] += bf16_to_f32(self.values[i]);
            }
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Storage bytes: values (2) + column indices (4) + row pointers (4).
    pub fn bytes(&self) -> usize {
        self.values.len() * 2 + self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Decoder-side views: `(row_ptr, col_idx, values)` in the classic
    /// CSR layout (bf16 value words).
    pub fn raw_parts(&self) -> (&[u32], &[u32], &[u16]) {
        (&self.row_ptr, &self.col_idx, &self.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask_topn_per_block;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(13);
        let w = Tensor::randn(vec![16, 64], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 2, 4);
        let csr = Csr::from_dense_mask(&w, &mask);
        assert_eq!(csr.nnz(), 16 * 64 / 2);
        let d = csr.to_dense();
        for i in 0..w.len() {
            let want = w.data()[i] * mask.data()[i];
            assert!((d.data()[i] - want).abs() <= want.abs() * 0.01 + 1e-6);
        }
    }

    #[test]
    fn topk_global_exact_budget() {
        let mut rng = Rng::new(17);
        let w = Tensor::randn(vec![8, 128], 0.05, &mut rng);
        let csr = Csr::from_topk_global(&w, &w.map(f32::abs), 37);
        assert_eq!(csr.nnz(), 37);
        // every kept |value| >= every dropped |value| (bf16-rounded check)
        let dense = csr.to_dense();
        let kept_min = dense
            .data()
            .iter()
            .filter(|x| **x != 0.0)
            .fold(f32::INFINITY, |a, &x| a.min(x.abs()));
        let mut alldrop: Vec<f32> = w
            .data()
            .iter()
            .zip(dense.data())
            .filter(|(_, &d)| d == 0.0)
            .map(|(&x, _)| x.abs())
            .collect();
        alldrop.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(kept_min * 1.01 >= alldrop[0]);
    }

    #[test]
    fn empty_matrix() {
        let w = Tensor::zeros(vec![4, 16]);
        let csr = Csr::from_dense_mask(&w, &Tensor::zeros(vec![4, 16]));
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), Tensor::zeros(vec![4, 16]));
    }

    #[test]
    fn add_into_accumulates() {
        let w = Tensor::new(vec![1, 4], vec![1., 2., 3., 4.]);
        let mask = Tensor::new(vec![1, 4], vec![0., 1., 0., 1.]);
        let csr = Csr::from_dense_mask(&w, &mask);
        let mut dst = Tensor::ones(vec![1, 4]);
        csr.add_into(&mut dst);
        assert_eq!(dst.data(), &[1., 3., 1., 5.]);
    }
}
