//! Structured k:256 salient-weight (outlier) storage — the paper's
//! "SSP for SW" contribution (§1 contribution 2, Tables 2/3/5/7).
//!
//! Per `(1, 256)` block: `k` bf16 values + `k` one-byte in-block indices,
//! ascending.  Fixed stride per block ⇒ predictable memory access and
//! O(k/256) metadata, versus CSR's per-nonzero 4-byte column index and
//! irregular row lengths ([`crate::sparse::Csr`], contrasted in Table 7
//! and `hwsim`).

use super::storage::Storage;
use crate::tensor::{bf16_to_f32, f32_to_bf16, Tensor};

pub const OUTLIER_M: usize = 256;

/// Salient weights of one matrix in structured k:256 form.
#[derive(Clone, Debug)]
pub struct StructuredOutliers {
    pub k: usize,
    pub m: usize,
    pub rows: usize,
    pub cols: usize,
    /// bf16 values, block-major, `k` per block — owned when freshly
    /// packed, mmap-backed when loaded from a `.spak`
    values: Storage<u16>,
    /// in-block indices, `k` per block, strictly ascending
    indices: Storage<u8>,
}

impl StructuredOutliers {
    /// Extract `dense * mask` where `mask` holds exactly `k` entries per
    /// `(1, m)` block (selection-kernel invariant).
    pub fn from_dense_mask(dense: &Tensor, mask: &Tensor, k: usize, m: usize) -> Self {
        assert!(m <= 256, "in-block index is one byte");
        let (rows, cols) = dense.dims2();
        assert_eq!(cols % m, 0, "cols {cols} not divisible by m {m}");
        let blocks = rows * cols / m;
        let mut values = Vec::with_capacity(blocks * k);
        let mut indices = Vec::with_capacity(blocks * k);
        for r in 0..rows {
            let drow = dense.row(r);
            let mrow = mask.row(r);
            for b in 0..cols / m {
                let mut cnt = 0;
                for j in 0..m {
                    if mrow[b * m + j] != 0.0 {
                        values.push(f32_to_bf16(drow[b * m + j]));
                        indices.push(j as u8);
                        cnt += 1;
                    }
                }
                assert_eq!(cnt, k, "block ({r},{b}) holds {cnt} salient values, expected {k}");
            }
        }
        StructuredOutliers {
            k,
            m,
            rows,
            cols,
            values: values.into(),
            indices: indices.into(),
        }
    }

    /// Reassemble from decoder-side streams (the `.spak` mmap reader
    /// path) — both streams hold exactly `rows * cols / m * k` entries.
    pub fn from_raw_parts(
        k: usize,
        m: usize,
        rows: usize,
        cols: usize,
        values: Storage<u16>,
        indices: Storage<u8>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(m > 0 && m <= 256, "in-block index is one byte (m <= 256), got {m}");
        anyhow::ensure!(cols % m == 0, "cols {cols} not divisible by m {m}");
        let want = rows * cols / m * k;
        anyhow::ensure!(
            values.len() == want && indices.len() == want,
            "outlier streams: {} values / {} indices, want {want} each",
            values.len(),
            indices.len()
        );
        Ok(StructuredOutliers {
            k,
            m,
            rows,
            cols,
            values,
            indices,
        })
    }

    /// Zero-outlier placeholder (the "0%" rows of Table 5).
    pub fn empty(rows: usize, cols: usize) -> Self {
        StructuredOutliers {
            k: 0,
            m: OUTLIER_M,
            rows,
            cols,
            values: Vec::new().into(),
            indices: Vec::new().into(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.k == 0
    }

    /// Scatter back to a dense matrix.
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        if self.k > 0 {
            let bpr = self.cols / self.m;
            for (bi, chunk) in self.values.chunks(self.k).enumerate() {
                let r = bi / bpr;
                let b = bi % bpr;
                for (t, &v) in chunk.iter().enumerate() {
                    let j = self.indices[bi * self.k + t] as usize;
                    out[r * self.cols + b * self.m + j] = bf16_to_f32(v);
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// Add the salient values onto `dst` in place (building the effective
    /// compressed weight `W_ns + W_salient`).
    pub fn add_into(&self, dst: &mut Tensor) {
        assert_eq!(dst.shape(), [self.rows, self.cols]);
        if self.k == 0 {
            return;
        }
        let bpr = self.cols / self.m;
        let cols = self.cols;
        let data = dst.data_mut();
        for (bi, chunk) in self.values.chunks(self.k).enumerate() {
            let r = bi / bpr;
            let b = bi % bpr;
            for (t, &v) in chunk.iter().enumerate() {
                let j = self.indices[bi * self.k + t] as usize;
                data[r * cols + b * self.m + j] += bf16_to_f32(v);
            }
        }
    }

    /// Storage bytes: bf16 value + 1-byte index per salient entry.
    pub fn bytes(&self) -> usize {
        self.values.len() * 2 + self.indices.len()
    }

    pub fn n_salient(&self) -> usize {
        self.values.len()
    }

    /// Salient fraction of the full matrix.
    pub fn density(&self) -> f64 {
        self.n_salient() as f64 / (self.rows * self.cols) as f64
    }

    /// Decoder-side view of the salient values: bf16 words, block-major,
    /// `k` per `(1, m)` block.
    pub fn values_raw(&self) -> &[u16] {
        &self.values
    }

    /// Decoder-side view of the in-block indices (ascending, `k` per
    /// block, same block order as [`Self::values_raw`]).
    pub fn indices_raw(&self) -> &[u8] {
        &self.indices
    }

    /// `true` when both streams read straight from a live mmap (the
    /// `.spak` zero-copy serving property).
    pub fn is_mapped(&self) -> bool {
        self.values.is_mapped() && self.indices.is_mapped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask_topn_per_block;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_outlier_patterns() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(vec![16, 512], 0.05, &mut rng);
        for k in [4usize, 8, 16] {
            let mask = mask_topn_per_block(&w.map(f32::abs), k, 256);
            let so = StructuredOutliers::from_dense_mask(&w, &mask, k, 256);
            assert_eq!(so.n_salient(), 16 * 2 * k);
            let dense = so.to_dense();
            for i in 0..w.len() {
                let want = w.data()[i] * mask.data()[i];
                assert!((dense.data()[i] - want).abs() <= want.abs() * 0.01 + 1e-6);
            }
        }
    }

    #[test]
    fn density_matches_pattern() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(vec![32, 1024], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 4, 256);
        let so = StructuredOutliers::from_dense_mask(&w, &mask, 4, 256);
        assert!((so.density() - 4.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn add_into_composes_effective_weight() {
        let mut rng = Rng::new(7);
        let w = Tensor::randn(vec![8, 256], 0.05, &mut rng);
        let omask = mask_topn_per_block(&w.map(f32::abs), 8, 256);
        let so = StructuredOutliers::from_dense_mask(&w, &omask, 8, 256);
        let mut acc = Tensor::zeros(vec![8, 256]);
        so.add_into(&mut acc);
        for i in 0..w.len() {
            let want = w.data()[i] * omask.data()[i];
            assert!((acc.data()[i] - want).abs() <= want.abs() * 0.01 + 1e-6);
        }
    }

    #[test]
    fn empty_is_noop() {
        let so = StructuredOutliers::empty(4, 256);
        assert!(so.is_empty());
        assert_eq!(so.bytes(), 0);
        let mut t = Tensor::ones(vec![4, 256]);
        so.add_into(&mut t);
        assert_eq!(t, Tensor::ones(vec![4, 256]));
    }

    #[test]
    fn bytes_smaller_than_csr_for_same_content() {
        // the Table 7 / hwsim storage argument: 3 bytes/entry vs CSR's ~6
        let mut rng = Rng::new(11);
        let w = Tensor::randn(vec![64, 512], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), 16, 256);
        let so = StructuredOutliers::from_dense_mask(&w, &mask, 16, 256);
        let csr = crate::sparse::Csr::from_dense_mask(&w, &mask);
        assert!(so.bytes() < csr.bytes(), "{} vs {}", so.bytes(), csr.bytes());
    }
}
