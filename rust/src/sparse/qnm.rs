//! Packed N:M weight storage with **int-quantized kept values** — the
//! memory-equivalent format the paper's comparison actually argues for.
//!
//! [`super::PackedNm`] stores the mask in 0.875 bits/element (8:16
//! codebook ranks) but ships the kept values as full bf16, so a packed
//! operand still streams ~8.9 bits/param. The paper's abstract pairs
//! sparsification with quantization ("quantization maintains performance
//! with reduced precision"); `PackedQnm` is that composition as a
//! storage format: the same combinadic pattern stream, with the kept
//! values stored as symmetric `bits`-wide group-quantized codes
//! ([`GroupQuant`]'s bit-packing, one bf16 scale per `group` kept
//! values) and **dequantized inside the spmm kernel** — never expanded
//! on the request path. At 8:16 / int4 / g128 the whole operand is
//! 0.875 + 4·½ + 16/128·½ = **2.9375 bits/param**, 0.18× the dense bf16
//! traffic (`docs/FORMAT.md` has the worked block; the
//! [`mod@super::spmm`] kernel and the `hwsim` `sparse_nm_quant` model
//! tie the accounting to measured bytes).
//!
//! Layout invariants shared with [`super::PackedNm`]: blocks are
//! enumerated row-major, each block's pattern id is a combinadic rank in
//! `codebook_bits` bits, kept values are block-major ascending by
//! in-block index, and deficient blocks (outlier exclusion) pad with
//! zero-valued slots. Quantization groups cover `group` **consecutive
//! kept values of one row** — groups never straddle rows, so row-ranged
//! kernels decode without neighbouring-row state.

use super::bits::{packed_words, push_bits, read_bits};
use super::nm::keep_indices_for_block;
use super::patterns::{rank_combination, unrank_combination, PatternInfo};
use super::storage::Storage;
use crate::quant::{GroupQuant, QuantSpec};
use crate::tensor::{bf16_to_f32, Tensor};

/// Greatest common divisor (used to fit a quant group to a row's kept
/// count — here and by [`super::PackedTnm::fit_group`]).
pub(crate) fn gcd(a: usize, b: usize) -> usize {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// A rank-2 weight matrix stored packed N:M with group-quantized values.
#[derive(Clone, Debug)]
pub struct PackedQnm {
    pub pattern: PatternInfo,
    pub rows: usize,
    pub cols: usize,
    /// kept values as a group-quantized `(rows, cols/m*n)` matrix —
    /// codes + scales exactly as [`GroupQuant`] lays them out
    quant: GroupQuant,
    /// bit-packed combinadic pattern ids, `codebook_bits` per block —
    /// owned when freshly packed, mmap-backed when loaded from a `.spak`
    meta: Storage<u64>,
}

impl PackedQnm {
    /// Kept values per row under pattern `n:m` over `cols` columns.
    pub fn kept_per_row(n: usize, m: usize, cols: usize) -> usize {
        cols / m * n
    }

    /// Largest group ≤ `spec.group` that divides the kept-value count of
    /// one row — the adjustment [`Self::from_dense_mask`] requires.
    /// Layers whose kept row length the preferred group does not divide
    /// (e.g. gqa's 384-wide kept rows under g=256) shrink to the gcd so
    /// scales still tile rows exactly.
    pub fn fit_spec(spec: QuantSpec, n: usize, m: usize, cols: usize) -> QuantSpec {
        let kept = Self::kept_per_row(n, m, cols).max(1);
        QuantSpec::new(spec.bits, gcd(spec.group, kept).max(1))
    }

    /// Pack `dense * mask`, quantizing the kept values.
    ///
    /// Mask discipline is identical to [`super::PackedNm::from_dense_mask`]:
    /// at most `n` kept entries per `(1, m)` block, deficient blocks
    /// padded with zero-valued slots (which quantize to code 0).
    /// `spec.group` must divide the kept values per row
    /// (`cols / m * n`) — see [`Self::fit_spec`].
    pub fn from_dense_mask(
        dense: &Tensor,
        mask: &Tensor,
        n: usize,
        m: usize,
        spec: QuantSpec,
    ) -> Self {
        assert!(m <= 64, "PackedQnm stores u64 combinadic ranks (m <= 64), got m={m}");
        let pattern = PatternInfo::new(n, m);
        let (rows, cols) = dense.dims2();
        assert_eq!(dense.shape(), mask.shape(), "mask shape mismatch");
        assert_eq!(cols % m, 0, "cols {cols} not divisible by m {m}");
        let kpr = Self::kept_per_row(n, m, cols);
        assert_eq!(
            kpr % spec.group,
            0,
            "quant group {} does not divide {kpr} kept values/row (use fit_spec)",
            spec.group
        );
        let bits = pattern.codebook_bits();
        let blocks = rows * cols / m;
        let mut kept = Vec::with_capacity(blocks * n);
        let mut meta = Vec::with_capacity((blocks * bits as usize + 63) / 64 + 1);
        let mut pos = 0usize;
        let mut idx_buf = Vec::with_capacity(n);
        for r in 0..rows {
            let drow = dense.row(r);
            let mrow = mask.row(r);
            for b in 0..cols / m {
                keep_indices_for_block(mrow, r, b, n, m, &mut idx_buf);
                for &j in &idx_buf {
                    // padded slots carry a zero value (quantizes to code 0)
                    let v = if mrow[b * m + j] != 0.0 { drow[b * m + j] } else { 0.0 };
                    kept.push(v);
                }
                push_bits(&mut meta, &mut pos, rank_combination(&idx_buf, m), bits);
            }
        }
        let quant = GroupQuant::quantize(&Tensor::new(vec![rows, kpr], kept), spec);
        PackedQnm {
            pattern,
            rows,
            cols,
            quant,
            meta: meta.into(),
        }
    }

    /// Reassemble from decoder-side streams (the `.spak` mmap reader
    /// path): the group-quantized kept-value matrix (codes + scales,
    /// validated by [`GroupQuant::from_raw_parts`] over the
    /// `(rows, kept_per_row)` shape) plus the pattern stream
    /// ([`Self::meta_words_len`]). `spec` must already be row-fitted
    /// ([`Self::fit_spec`]) — exactly what pack time stored.
    pub fn from_raw_parts(
        n: usize,
        m: usize,
        rows: usize,
        cols: usize,
        spec: QuantSpec,
        codes: Storage<u32>,
        scales: Storage<u16>,
        meta: Storage<u64>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(m <= 64, "PackedQnm stores u64 combinadic ranks (m <= 64), got m={m}");
        anyhow::ensure!(n <= m && m > 0 && cols % m == 0, "bad pattern {n}:{m} for cols {cols}");
        let pattern = PatternInfo::new(n, m);
        let kpr = Self::kept_per_row(n, m, cols);
        anyhow::ensure!(
            spec.group > 0 && kpr % spec.group == 0,
            "quant group {} does not divide {kpr} kept values/row (spec not fitted?)",
            spec.group
        );
        let quant = GroupQuant::from_raw_parts(spec, rows, kpr, codes, scales)?;
        anyhow::ensure!(
            meta.len() == Self::meta_words_len(rows, cols, n, m),
            "PackedQnm meta stream: {} words, want {}",
            meta.len(),
            Self::meta_words_len(rows, cols, n, m)
        );
        Ok(PackedQnm {
            pattern,
            rows,
            cols,
            quant,
            meta,
        })
    }

    /// Exact `u64` word count of the pattern stream (same rule as
    /// [`super::PackedNm::meta_words_len`]).
    pub fn meta_words_len(rows: usize, cols: usize, n: usize, m: usize) -> usize {
        packed_words(rows * cols / m, PatternInfo::new(n, m).codebook_bits())
    }

    /// Widen the `n` quantized values of block `(r, bblk)` into f32 —
    /// the in-kernel dequant step (`value = code * bf16(scale)`), shared
    /// by every spmm loop order so all paths see identical floats.
    #[inline]
    pub(crate) fn dequant_block_into(&self, r: usize, bblk: usize, out: &mut [f32]) {
        let n = self.pattern.n;
        let spec = self.quant.spec;
        let bits = spec.bits as usize;
        let qmask = (1u32 << bits) - 1;
        let qsign = 1u32 << (bits - 1);
        let codes = self.quant.codes_raw();
        let scales = self.quant.scales_raw();
        let kpr = self.quant.cols;
        let gpr = kpr / spec.group;
        let base = bblk * n;
        let mut bitpos = (r * kpr + base) * bits;
        // a block's values are consecutive in the kept stream, so they
        // touch at most two scale groups; hoist the common single-group
        // case out of the inner loop
        let g0 = base / spec.group;
        let s0 = bf16_to_f32(scales[r * gpr + g0]);
        let single = base % spec.group + n <= spec.group;
        for (t, o) in out.iter_mut().enumerate().take(n) {
            let word = bitpos / 32;
            let off = bitpos % 32;
            let mut u = codes[word] >> off;
            if off + bits > 32 {
                u |= codes[word + 1] << (32 - off);
            }
            u &= qmask;
            let q = if u & qsign != 0 { (u | !qmask) as i32 } else { u as i32 };
            let scale = if single {
                s0
            } else {
                bf16_to_f32(scales[r * gpr + (base + t) / spec.group])
            };
            *o = q as f32 * scale;
            bitpos += bits;
        }
    }

    /// Expand back to a dense tensor (dequantized values). Error
    /// reporting and tests only — the spmm kernel never calls this.
    pub fn to_dense(&self) -> Tensor {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let vals = self.quant.dequantize();
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut pos = 0usize;
        let mut vi = 0usize;
        for r in 0..self.rows {
            for b in 0..self.cols / m {
                let rank = read_bits(&self.meta, pos, bits);
                pos += bits as usize;
                for &j in &unrank_combination(rank, m, n) {
                    out[r * self.cols + b * m + j] = vals.data()[vi];
                    vi += 1;
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// The dense 0/1 keep mask encoded by the metadata.
    pub fn mask(&self) -> Tensor {
        let (n, m) = (self.pattern.n, self.pattern.m);
        let bits = self.pattern.codebook_bits();
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut pos = 0usize;
        for r in 0..self.rows {
            for b in 0..self.cols / m {
                let rank = read_bits(&self.meta, pos, bits);
                pos += bits as usize;
                for &j in &unrank_combination(rank, m, n) {
                    out[r * self.cols + b * m + j] = 1.0;
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// The quantization parameters actually stored (group may have been
    /// fitted down from the requested spec).
    pub fn spec(&self) -> QuantSpec {
        self.quant.spec
    }

    /// Storage in bytes: packed codes + bf16 scales + packed metadata.
    pub fn bytes(&self) -> usize {
        self.value_bytes() + self.meta_bytes()
    }

    /// Codes + scales alone — exactly [`GroupQuant::bytes`] of the kept
    /// value matrix (the storage-accounting cross-check in
    /// `tests/quant_pack.rs` holds this equality).
    pub fn value_bytes(&self) -> usize {
        self.quant.bytes()
    }

    /// Pattern metadata footprint (same u64-word padding rule as
    /// [`super::PackedNm::bytes`]).
    pub fn meta_bytes(&self) -> usize {
        (self.meta.len() * 8).min(self.meta_bits() / 8 + 8)
    }

    /// Exact metadata footprint in bits.
    pub fn meta_bits(&self) -> usize {
        (self.rows * self.cols / self.pattern.m) * self.pattern.codebook_bits() as usize
    }

    /// Dense bf16 storage this replaces, in bytes.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 2
    }

    /// Compression ratio vs dense bf16 (>1 means smaller).
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.bytes() as f64
    }

    /// Stored bits per (dense) parameter — mask meta + codes + scales.
    pub fn bits_per_param(&self) -> f64 {
        8.0 * self.bytes() as f64 / (self.rows * self.cols) as f64
    }

    /// Pattern blocks this matrix stores (one combinadic unrank + one
    /// block dequant each for the decoder).
    pub fn n_blocks(&self) -> usize {
        self.rows * (self.cols / self.pattern.m)
    }

    /// Decoder-side view of the pattern stream (bit-packed combinadic
    /// ranks, [`PatternInfo::codebook_bits`] bits per block, row-major
    /// block order).
    pub fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    /// Decoder-side view of the packed int codes
    /// ([`GroupQuant::codes_raw`] of the kept-value matrix).
    pub fn codes_raw(&self) -> &[u32] {
        self.quant.codes_raw()
    }

    /// Decoder-side view of the per-group bf16 scales
    /// ([`GroupQuant::scales_raw`] of the kept-value matrix).
    pub fn scales_raw(&self) -> &[u16] {
        self.quant.scales_raw()
    }

    /// `true` when every stream (codes, scales, pattern meta) reads
    /// straight from a live mmap (the `.spak` zero-copy property).
    pub fn is_mapped(&self) -> bool {
        self.quant.is_mapped() && self.meta.is_mapped()
    }
}

impl super::codec::ValueCodec for PackedQnm {
    fn pattern(&self) -> &PatternInfo {
        &self.pattern
    }

    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn meta_words(&self) -> &[u64] {
        &self.meta
    }

    #[inline]
    fn rank_index(&self, r: usize, bblk: usize) -> usize {
        r * (self.cols / self.pattern.m) + bblk
    }

    #[inline]
    fn decode_block_into(&self, r: usize, bblk: usize, out: &mut [f32]) {
        self.dequant_block_into(r, bblk, out);
    }

    fn values_bytes(&self) -> usize {
        self.value_bytes()
    }

    fn bits_per_kept(&self) -> f64 {
        let spec = self.quant.spec;
        spec.bits as f64 + 16.0 / spec.group as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask_topn_per_block;
    use crate::tensor::rel_error;
    use crate::util::Rng;

    fn pack(
        n: usize,
        m: usize,
        rows: usize,
        cols: usize,
        seed: u64,
    ) -> (Tensor, Tensor, PackedQnm) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(vec![rows, cols], 0.05, &mut rng);
        let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
        let spec = PackedQnm::fit_spec(QuantSpec::int4_g128(), n, m, cols);
        let p = PackedQnm::from_dense_mask(&w, &mask, n, m, spec);
        (w, mask, p)
    }

    #[test]
    fn roundtrip_is_quantized_masked_weight() {
        for (i, (n, m)) in [(2usize, 4usize), (4, 8), (8, 16)].into_iter().enumerate() {
            let (w, mask, p) = pack(n, m, 16, 256, i as u64 + 1);
            let d = p.to_dense();
            // zeros stay exactly zero, kept values carry only quant error
            let masked = w.mul(&mask);
            for j in 0..w.len() {
                if mask.data()[j] == 0.0 {
                    assert_eq!(d.data()[j], 0.0, "elem {j} must stay pruned");
                }
            }
            // int4 RTN over g≤128 gaussian groups: half-step error rms is
            // ~10% of the kept-value rms — bound it loosely, the exact
            // grid behaviour is groupq.rs's job
            let err = rel_error(&d, &masked);
            assert!(err < 0.2, "{n}:{m} quant roundtrip err {err}");
            assert_eq!(p.mask(), mask);
        }
    }

    #[test]
    fn matches_groupquant_of_kept_values() {
        // the stored codes/scales ARE GroupQuant of the kept-value
        // matrix: dequantized kept values agree element-for-element
        let (w, mask, p) = pack(8, 16, 8, 512, 9);
        let kpr = PackedQnm::kept_per_row(8, 16, 512);
        let mut kept = Vec::new();
        for r in 0..8 {
            for c in 0..512 {
                if mask.at2(r, c) != 0.0 {
                    kept.push(w.at2(r, c));
                }
            }
        }
        let gq = GroupQuant::quantize(&Tensor::new(vec![8, kpr], kept), p.spec());
        assert_eq!(p.value_bytes(), gq.bytes());
        let want = gq.dequantize();
        let d = p.to_dense();
        let mut vi = 0usize;
        for r in 0..8 {
            for c in 0..512 {
                if mask.at2(r, c) != 0.0 {
                    assert_eq!(d.at2(r, c), want.data()[vi], "kept ({r},{c})");
                    vi += 1;
                }
            }
        }
    }

    #[test]
    fn storage_accounting_8_16_int4() {
        let (_, _, p) = pack(8, 16, 256, 512, 5);
        let elems = 256 * 512;
        // mask meta: 14 bits per 16-block = 0.875 bits/element
        assert_eq!(p.meta_bits(), elems / 16 * 14);
        // codes: 4 bits per kept value (half the elements)
        // scales: one bf16 per 128 kept values
        assert_eq!(p.value_bytes(), elems / 2 / 2 + elems / 2 / 128 * 2);
        // combined ≈ 2.9375 bits/param (+ the ≤8-byte meta word padding)
        let want = crate::quant::nm_quant_bits_per_param(8, 16, 4, 128);
        assert!((want - 2.9375).abs() < 1e-12);
        let got = p.bits_per_param();
        assert!(
            got >= want && got - want < 0.002,
            "bits/param {got} vs analytic {want}"
        );
        assert!(p.compression_ratio() > 5.0, "{}", p.compression_ratio());
    }

    #[test]
    fn fit_spec_divides_awkward_rows() {
        // gqa hidden 768 at 8:16 keeps 384/row: g128 fits, g256 must
        // shrink to gcd(256, 384) = 128
        let s = PackedQnm::fit_spec(QuantSpec::new(4, 256), 8, 16, 768);
        assert_eq!(s.group, 128);
        let s = PackedQnm::fit_spec(QuantSpec::int4_g128(), 8, 16, 256);
        assert_eq!(s.group, 128);
        // degenerate tiny rows never panic
        let s = PackedQnm::fit_spec(QuantSpec::new(4, 128), 2, 4, 12);
        assert_eq!(s.group, gcd(128, 6).max(1));
    }

    #[test]
    fn deficient_blocks_quantize_padding_to_zero() {
        let w = Tensor::new(vec![1, 8], vec![5., 6., 7., 8., 1., 2., 3., 4.]);
        let mask = Tensor::new(vec![1, 8], vec![0., 1., 0., 0., 0., 0., 1., 1.]);
        let spec = PackedQnm::fit_spec(QuantSpec::new(4, 128), 2, 4, 8);
        let p = PackedQnm::from_dense_mask(&w, &mask, 2, 4, spec);
        let d = p.to_dense();
        for (j, (&got, &m)) in d.data().iter().zip(mask.data()).enumerate() {
            if m == 0.0 {
                assert_eq!(got, 0.0, "elem {j}");
            } else {
                // int4 half-step: |err| ≤ absmax/7/2 (+ bf16 scale slack)
                let want = w.data()[j];
                assert!((got - want).abs() <= 6.0 / 7.0 * 0.51, "elem {j}: {got} vs {want}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "does not divide")]
    fn unfitted_group_rejected() {
        let w = Tensor::ones(vec![2, 16]);
        let mask = mask_topn_per_block(&w, 8, 16);
        // 8 kept values/row, group 128 does not divide
        PackedQnm::from_dense_mask(&w, &mask, 8, 16, QuantSpec::int4_g128());
    }
}
