//! Host tensor substrate: a small dense row-major f32 tensor used by the
//! coordinator for weight management, packing, scoring mirrors, and the
//! reference math the HLO artifacts are cross-checked against.
//!
//! Heavy compute (model fwd/bwd, the pruning kernels) runs through PJRT;
//! this type exists so the Rust side can *own* parameters, masks and
//! sparse formats without round-tripping through Python.

mod ops;

pub use ops::*;

use crate::util::Rng;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn ones(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![1.0; n],
        }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![v; n],
        }
    }

    /// i.i.d. N(0, std²).
    pub fn randn(shape: Vec<usize>, std: f32, rng: &mut Rng) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: (0..n).map(|_| rng.normal_f32() * std).collect(),
        }
    }

    /// Heavy-tailed init mirroring trained-LLM weight distributions:
    /// Gaussian body with a fraction `p_out` of `scale`× outliers.
    pub fn randn_outliers(
        shape: Vec<usize>,
        std: f32,
        p_out: f64,
        scale: f64,
        rng: &mut Rng,
    ) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: (0..n)
                .map(|_| (rng.outlier_normal(p_out, scale) as f32) * std)
                .collect(),
        }
    }

    // ------------------------------------------------------------ accessors

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// (rows, cols) of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "expected rank-2, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        let (_, cols) = (self.shape[0], self.shape[1]);
        self.data[r * cols + c]
    }

    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.shape[1];
        self.data[r * cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let cols = self.shape[1];
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let cols = self.shape[1];
        &mut self.data[r * cols..(r + 1) * cols]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        self.shape = shape;
        self
    }

    // ----------------------------------------------------------- reductions

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    /// Population variance over all elements.
    pub fn var(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let mu = self.mean();
        self.data
            .iter()
            .map(|&x| {
                let d = x as f64 - mu;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Fraction of zero entries.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        1.0 - self.count_nonzero() as f64 / self.data.len() as f64
    }

    // ------------------------------------------------------------- mapping

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }
}

/// bf16 round-trip helpers — the packed sparse formats store values in
/// bf16 (like the paper's storage accounting assumes 16-bit weights).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    // round-to-nearest-even on the truncated mantissa
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits + round) >> 16) as u16
}

pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.dims2(), (2, 3));
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        assert_eq!(t.sum(), 10.0);
        assert_eq!(t.mean(), 2.5);
        assert!((t.var() - 1.25).abs() < 1e-12);
        assert_eq!(t.abs_max(), 4.0);
    }

    #[test]
    fn sparsity_accounting() {
        let t = Tensor::new(vec![4], vec![0., 2., 0., 4.]);
        assert_eq!(t.count_nonzero(), 2);
        assert_eq!(t.sparsity(), 0.5);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::new(5);
        let t = Tensor::randn(vec![100, 100], 0.1, &mut rng);
        assert!(t.mean().abs() < 0.01);
        assert!((t.var().sqrt() - 0.1).abs() < 0.01);
    }

    #[test]
    fn map_zip() {
        let a = Tensor::new(vec![2], vec![1., 2.]);
        let b = Tensor::new(vec![2], vec![10., 20.]);
        assert_eq!(a.add(&b).data(), &[11., 22.]);
        assert_eq!(a.mul(&b).data(), &[10., 40.]);
        assert_eq!(a.scale(3.0).data(), &[3., 6.]);
    }

    #[test]
    fn bf16_roundtrip_monotone() {
        for &x in &[0.0f32, 1.0, -1.5, 3.14159, 1e-3, 65504.0] {
            let y = bf16_to_f32(f32_to_bf16(x));
            assert!((x - y).abs() <= x.abs() * 0.01 + 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn randn_outliers_heavier_tail() {
        let mut rng = Rng::new(7);
        let plain = Tensor::randn(vec![50_000], 1.0, &mut rng);
        let heavy = Tensor::randn_outliers(vec![50_000], 1.0, 0.01, 10.0, &mut rng);
        assert!(heavy.abs_max() > plain.abs_max());
    }
}
