//! Dense linear-algebra ops for the host tensor.
//!
//! The coordinator needs matmul/transpose/softmax-scale math for the Rust
//! mirrors of the scoring path and for packing throughput; it is written
//! cache-blocked (the hot loops feed `perf_hotpath` in the perf pass) but
//! model-scale GEMMs always run through PJRT, not here.

use super::Tensor;

/// Blocked matrix multiply `a (m,k) @ b (k,n) -> (m,n)`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    const BK: usize = 64;
    const BN: usize = 256;
    let ad = a.data();
    let bd = b.data();
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for n0 in (0..n).step_by(BN) {
            let n1 = (n0 + BN).min(n);
            for i in 0..m {
                let arow = &ad[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n..kk * n + n1];
                    for nn in n0..n1 {
                        orow[nn] += av * brow[nn];
                    }
                }
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// `x (b, cin) @ w^T (cout, cin) -> (b, cout)` — the linear-layer shape.
pub fn matmul_wt(x: &Tensor, w: &Tensor) -> Tensor {
    let (b, cin) = x.dims2();
    let (cout, cin2) = w.dims2();
    assert_eq!(cin, cin2, "matmul_wt inner dims {cin} vs {cin2}");
    let mut out = vec![0.0f32; b * cout];
    let xd = x.data();
    let wd = w.data();
    for i in 0..b {
        let xrow = &xd[i * cin..(i + 1) * cin];
        let orow = &mut out[i * cout..(i + 1) * cout];
        for (j, o) in orow.iter_mut().enumerate() {
            let wrow = &wd[j * cin..(j + 1) * cin];
            *o = dot(xrow, wrow);
        }
    }
    Tensor::new(vec![b, cout], out)
}

/// Unrolled dot product (the packing/eval hot loop).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Transpose a rank-2 tensor.
pub fn transpose(t: &Tensor) -> Tensor {
    let (r, c) = t.dims2();
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = t.data()[i * c + j];
        }
    }
    Tensor::new(vec![c, r], out)
}

/// Per-column max of |x| over rows — SmoothQuant's activation statistic.
pub fn col_absmax(t: &Tensor) -> Vec<f32> {
    let (r, c) = t.dims2();
    let mut out = vec![0.0f32; c];
    for i in 0..r {
        let row = t.row(i);
        for j in 0..c {
            out[j] = out[j].max(row[j].abs());
        }
    }
    out
}

/// Per-column L2 norm over rows — RIA/Wanda's activation statistic.
pub fn col_l2(t: &Tensor) -> Vec<f32> {
    let (r, c) = t.dims2();
    let mut out = vec![0.0f32; c];
    for i in 0..r {
        let row = t.row(i);
        for j in 0..c {
            out[j] += row[j] * row[j];
        }
    }
    for v in &mut out {
        *v = v.sqrt();
    }
    out
}

/// Per-column sum of |w| over rows.
pub fn col_abssum(t: &Tensor) -> Vec<f32> {
    let (r, c) = t.dims2();
    let mut out = vec![0.0f32; c];
    for i in 0..r {
        let row = t.row(i);
        for j in 0..c {
            out[j] += row[j].abs();
        }
    }
    out
}

/// Per-row sum of |w|.
pub fn row_abssum(t: &Tensor) -> Vec<f32> {
    let (r, _) = t.dims2();
    (0..r)
        .map(|i| t.row(i).iter().map(|x| x.abs()).sum())
        .collect()
}

/// Cholesky factorization of a symmetric positive-definite matrix:
/// returns lower-triangular `L` with `A = L Lᵀ`. Errors if `A` is not
/// numerically positive definite (non-positive pivot).
pub fn cholesky(a: &Tensor) -> Result<Tensor, String> {
    let (n, n2) = a.dims2();
    assert_eq!(n, n2, "cholesky needs a square matrix, got {n}x{n2}");
    let ad = a.data();
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = ad[i * n + j] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(format!("cholesky: non-PD pivot {s:.3e} at row {i}"));
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(Tensor::new(
        vec![n, n],
        l.into_iter().map(|x| x as f32).collect(),
    ))
}

/// Invert a symmetric positive-definite matrix via its Cholesky factor.
pub fn spd_inverse(a: &Tensor) -> Result<Tensor, String> {
    let l = cholesky(a)?;
    let (n, _) = l.dims2();
    let ld = l.data();
    // invert L by forward substitution (column by column)
    let mut linv = vec![0.0f64; n * n];
    for j in 0..n {
        linv[j * n + j] = 1.0 / ld[j * n + j] as f64;
        for i in j + 1..n {
            let mut s = 0.0f64;
            for k in j..i {
                s += ld[i * n + k] as f64 * linv[k * n + j];
            }
            linv[i * n + j] = -s / ld[i * n + i] as f64;
        }
    }
    // A^{-1} = L^{-T} L^{-1}
    let mut out = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0f64;
            // sum over k >= max(i,j): linv[k,i] * linv[k,j]
            for k in i.max(j)..n {
                s += linv[k * n + i] * linv[k * n + j];
            }
            out[i * n + j] = s as f32;
            out[j * n + i] = s as f32;
        }
    }
    Ok(Tensor::new(vec![n, n], out))
}

/// Upper-triangular Cholesky factor `U` of a SPD matrix (`A = Uᵀ U`),
/// i.e. the transpose of [`cholesky`]'s output. SparseGPT consumes the
/// upper Cholesky factor of the *inverse* Hessian.
pub fn cholesky_upper(a: &Tensor) -> Result<Tensor, String> {
    Ok(transpose(&cholesky(a)?))
}

/// `aᵀ a` (Gram matrix) of a rank-2 tensor — the Hessian accumulator
/// `H = Σ xᵀx` used by the OBS/SparseGPT scorer.
pub fn gram(x: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    let mut out = vec![0.0f32; c * c];
    let xd = x.data();
    for i in 0..r {
        let row = &xd[i * c..(i + 1) * c];
        for a in 0..c {
            let va = row[a];
            if va == 0.0 {
                continue;
            }
            let orow = &mut out[a * c..(a + 1) * c];
            for (o, &vb) in orow.iter_mut().zip(row.iter()) {
                *o += va * vb;
            }
        }
    }
    Tensor::new(vec![c, c], out)
}

/// Relative Frobenius error ||a-b|| / ||b||.
pub fn rel_error(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.data().iter().zip(b.data().iter()) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]);
        assert_eq!(matmul(&a, &b).data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_wt_matches_matmul() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(vec![7, 33], 1.0, &mut rng);
        let w = Tensor::randn(vec![13, 33], 1.0, &mut rng);
        let got = matmul_wt(&x, &w);
        let want = matmul(&x, &transpose(&w));
        for (g, w_) in got.data().iter().zip(want.data().iter()) {
            assert!((g - w_).abs() < 1e-4, "{g} vs {w_}");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let t = Tensor::randn(vec![5, 9], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&t)), t);
    }

    #[test]
    fn col_stats() {
        let t = Tensor::new(vec![2, 3], vec![1., -4., 0., -3., 2., 0.]);
        assert_eq!(col_absmax(&t), vec![3., 4., 0.]);
        assert_eq!(col_abssum(&t), vec![4., 6., 0.]);
        let l2 = col_l2(&t);
        assert!((l2[0] - 10f32.sqrt()).abs() < 1e-6);
        assert_eq!(row_abssum(&t), vec![5., 5.]);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(9);
        for n in [0usize, 1, 3, 4, 17, 256] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3);
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(21);
        let x = Tensor::randn(vec![24, 12], 1.0, &mut rng);
        let mut a = gram(&x);
        for i in 0..12 {
            let v = a.at2(i, i) + 0.5;
            a.set2(i, i, v); // damp for PD
        }
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &transpose(&l));
        assert!(rel_error(&rec, &a) < 1e-4, "{}", rel_error(&rec, &a));
        // lower triangular: everything above diagonal is exactly 0
        for i in 0..12 {
            for j in i + 1..12 {
                assert_eq!(l.at2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalue -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_inverse_identity() {
        let mut rng = Rng::new(22);
        let x = Tensor::randn(vec![40, 16], 1.0, &mut rng);
        let mut a = gram(&x);
        for i in 0..16 {
            let v = a.at2(i, i) + 1.0;
            a.set2(i, i, v);
        }
        let ainv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &ainv);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod.at2(i, j) - want).abs() < 1e-3,
                    "({i},{j}) {}",
                    prod.at2(i, j)
                );
            }
        }
    }

    #[test]
    fn gram_matches_naive() {
        let mut rng = Rng::new(23);
        let x = Tensor::randn(vec![9, 7], 1.0, &mut rng);
        let want = matmul(&transpose(&x), &x);
        let got = gram(&x);
        assert!(rel_error(&got, &want) < 1e-5);
    }

    #[test]
    fn rel_error_zero_for_identical() {
        let mut rng = Rng::new(11);
        let t = Tensor::randn(vec![8, 8], 1.0, &mut rng);
        assert!(rel_error(&t, &t) < 1e-12);
    }
}
