//! HTTP/1.1 request-head parsing — pure functions, no sockets.
//!
//! Deliberately small: the front end serves four routes to cooperating
//! clients (load balancers, Prometheus, test harnesses), so the parser
//! implements the subset of RFC 9112 those speak — request line +
//! header fields, `Content-Length` bodies, keep-alive/close semantics —
//! and answers everything else with a *typed* error status instead of
//! guessing: chunked bodies are 501, unknown versions 505, oversized
//! heads 431 (sized in the connection loop), malformed syntax 400.
//! Every reject path is a value, never a panic; the property test
//! (`tests/http_parser_prop.rs`) holds it against a reference
//! implementation on generated heads.

/// A typed parse/route failure: HTTP status + human-readable detail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
    /// `Allow:` header value for 405 replies.
    pub allow: Option<&'static str>,
}

impl HttpError {
    pub fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError {
            status,
            msg: msg.into(),
            allow: None,
        }
    }
}

/// A parsed request head: method, target, `HTTP/1.<minor>`, and header
/// fields with **lowercased names** and obs-folds already joined.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Head {
    pub method: String,
    pub target: String,
    pub minor: u8,
    pub headers: Vec<(String, String)>,
}

impl Head {
    /// First value of `name` (ASCII case-insensitive lookup).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Declared body length. Duplicate `Content-Length` fields must
    /// agree (RFC 9112 §6.3: conflicting values are unrecoverable),
    /// and the value must be a plain decimal that fits `usize`.
    pub fn content_length(&self) -> Result<Option<usize>, HttpError> {
        let mut seen: Option<usize> = None;
        for (n, v) in &self.headers {
            if n != "content-length" {
                continue;
            }
            // one field may itself carry a duplicated list value
            for part in v.split(',') {
                let part = part.trim();
                let parsed = parse_decimal(part).ok_or_else(|| {
                    HttpError::new(400, format!("bad content-length {part:?}"))
                })?;
                match seen {
                    None => seen = Some(parsed),
                    Some(prev) if prev == parsed => {}
                    Some(prev) => {
                        return Err(HttpError::new(
                            400,
                            format!("conflicting content-length ({prev} vs {parsed})"),
                        ))
                    }
                }
            }
        }
        Ok(seen)
    }

    /// Does `Transfer-Encoding` name `chunked`? (Answered with 501 by
    /// the connection loop — cooperating clients send sized bodies.)
    pub fn is_chunked(&self) -> bool {
        self.header("transfer-encoding")
            .map(|v| {
                v.split(',')
                    .any(|t| t.trim().eq_ignore_ascii_case("chunked"))
            })
            .unwrap_or(false)
    }

    /// Should the connection close after this exchange?
    /// `Connection: close` always closes; HTTP/1.0 closes unless the
    /// client opted into `keep-alive`.
    pub fn wants_close(&self) -> bool {
        let conn = self.header("connection").unwrap_or("");
        let has = |tok: &str| {
            conn.split(',').any(|t| t.trim().eq_ignore_ascii_case(tok))
        };
        if has("close") {
            return true;
        }
        self.minor == 0 && !has("keep-alive")
    }
}

/// Decimal parse without `+`/`-`/whitespace liberality: HTTP lengths
/// are plain digit strings. `None` on empty, non-digit, or overflow.
fn parse_decimal(s: &str) -> Option<usize> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    let mut out: usize = 0;
    for b in s.bytes() {
        out = out
            .checked_mul(10)?
            .checked_add((b - b'0') as usize)?;
    }
    Some(out)
}

/// RFC 9110 `tchar` — the characters legal in methods and field names.
fn is_tchar(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b)
}

/// Index **one past** the blank line terminating the head (`CRLFCRLF`
/// or bare `LFLF`), or `None` if the head is still incomplete.
pub fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] != b'\n' {
            i += 1;
            continue;
        }
        // line ending at i; is the next line empty?
        let rest = &buf[i + 1..];
        if rest.first() == Some(&b'\n') {
            return Some(i + 2);
        }
        if rest.len() >= 2 && rest[0] == b'\r' && rest[1] == b'\n' {
            return Some(i + 3);
        }
        i += 1;
    }
    None
}

/// Parse a complete request head (everything up to and including the
/// blank line). Accepts both CRLF and bare-LF line endings; rejects
/// with 400 on malformed syntax and 505 on versions other than
/// HTTP/1.0 / HTTP/1.1.
pub fn parse_head(head: &[u8]) -> Result<Head, HttpError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))?;
    let mut lines = text.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));

    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ').filter(|p| !p.is_empty());
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m, t, v),
            _ => {
                return Err(HttpError::new(
                    400,
                    format!("malformed request line {request_line:?}"),
                ))
            }
        };
    if method.is_empty() || !method.bytes().all(is_tchar) {
        return Err(HttpError::new(400, format!("bad method {method:?}")));
    }
    let minor = match version {
        "HTTP/1.1" => 1,
        "HTTP/1.0" => 0,
        _ => {
            return Err(HttpError::new(
                505,
                format!("unsupported version {version:?}"),
            ))
        }
    };

    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        if line.is_empty() {
            break; // the blank line terminating the head
        }
        if line.starts_with(' ') || line.starts_with('\t') {
            // obs-fold: continuation of the previous field value
            let Some(last) = headers.last_mut() else {
                return Err(HttpError::new(400, "header continuation before any header"));
            };
            if !last.1.is_empty() {
                last.1.push(' ');
            }
            last.1.push_str(line.trim_matches([' ', '\t']));
            continue;
        }
        let Some(colon) = line.find(':') else {
            return Err(HttpError::new(400, format!("header without colon {line:?}")));
        };
        let name = &line[..colon];
        if name.is_empty() || !name.bytes().all(is_tchar) {
            // also catches whitespace before the colon (RFC 9112 §5.1:
            // must be rejected, it enables request smuggling)
            return Err(HttpError::new(400, format!("bad header name {name:?}")));
        }
        let value = line[colon + 1..].trim_matches([' ', '\t']).to_string();
        headers.push((name.to_ascii_lowercase(), value));
    }

    Ok(Head {
        method: method.to_string(),
        target: target.to_string(),
        minor,
        headers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Head, HttpError> {
        parse_head(s.as_bytes())
    }

    #[test]
    fn parses_a_simple_post() {
        let h = parse(
            "POST /score HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\n\r\n",
        )
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.target, "/score");
        assert_eq!(h.minor, 1);
        assert_eq!(h.header("HOST"), Some("x"), "lookup is case-insensitive");
        assert_eq!(h.content_length().unwrap(), Some(12));
        assert!(!h.wants_close());
    }

    #[test]
    fn find_head_end_handles_both_line_endings() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(18));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\n\nbody"), Some(16));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\nHost: x\r\n"), None);
    }

    #[test]
    fn obs_fold_joins_into_previous_value() {
        let h = parse("GET / HTTP/1.1\r\nX-A: one\r\n two\r\n\r\n").unwrap();
        assert_eq!(h.header("x-a"), Some("one two"));
        let e = parse("GET / HTTP/1.1\r\n folded-first\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
    }

    #[test]
    fn rejects_malformed_heads_with_typed_statuses() {
        for (head, status) in [
            ("GET\r\n\r\n", 400),
            ("GET / HTTP/1.1 extra\r\n\r\n", 400),
            ("G\u{7f}T / HTTP/1.1\r\n\r\n", 400),
            ("GET / HTTP/2.0\r\n\r\n", 505),
            ("GET / SPDY/3\r\n\r\n", 505),
            ("GET / HTTP/1.1\r\nBad Header: v\r\n\r\n", 400),
            ("GET / HTTP/1.1\r\nName : v\r\n\r\n", 400),
            ("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n", 400),
        ] {
            let e = parse(head).unwrap_err();
            assert_eq!(e.status, status, "{head:?}: {e:?}");
        }
    }

    #[test]
    fn content_length_duplicates_must_agree() {
        let ok = parse(
            "POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 5\r\n\r\n",
        )
        .unwrap();
        assert_eq!(ok.content_length().unwrap(), Some(5));
        let listed = parse("POST / HTTP/1.1\r\nContent-Length: 5, 5\r\n\r\n").unwrap();
        assert_eq!(listed.content_length().unwrap(), Some(5));
        for bad in [
            "POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 6\r\n\r\n",
            "POST / HTTP/1.1\r\nContent-Length: -1\r\n\r\n",
            "POST / HTTP/1.1\r\nContent-Length: 12x\r\n\r\n",
            "POST / HTTP/1.1\r\nContent-Length: 99999999999999999999999\r\n\r\n",
        ] {
            let h = parse(bad).unwrap();
            assert_eq!(h.content_length().unwrap_err().status, 400, "{bad:?}");
        }
        let none = parse("GET / HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(none.content_length().unwrap(), None);
    }

    #[test]
    fn connection_semantics() {
        let close = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(close.wants_close());
        let old = parse("GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(old.wants_close(), "HTTP/1.0 defaults to close");
        let ka = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(!ka.wants_close());
        let chunked = parse(
            "POST / HTTP/1.1\r\nTransfer-Encoding: gzip, Chunked\r\n\r\n",
        )
        .unwrap();
        assert!(chunked.is_chunked());
    }
}
