//! Route table and HTTP response shaping.
//!
//! The router is a pure mapping in both directions: `(method, target)`
//! → [`Route`] (or a typed 404/405), request body → the *same*
//! [`Request`] values the TCP line protocol produces (via the shared
//! validators on [`Request`]), and [`Response`] → an [`HttpResponse`]
//! whose JSON body is exactly `Response::to_json().to_string()`. That
//! last identity is what makes the two ingresses byte-compatible: the
//! parity integration test compares an HTTP `/score` body against a TCP
//! `{"op":"nll"}` line and they must match to the byte.

use std::io::Write;

use super::parser::HttpError;
use crate::serve::protocol::{Request, Response};
use crate::util::json::Json;
use crate::util::trace;

/// The endpoints the front end serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// `GET /health` — liveness/readiness (503 while draining).
    Health,
    /// `GET /metrics` — Prometheus text exposition.
    Metrics,
    /// `POST /score` — `nll` (or `choice` when the body has `choices`).
    Score,
    /// `POST /generate` — KV-cached generation.
    Generate,
    /// `GET /debug/trace` — Chrome-trace export from the flight
    /// recorder (`?id=<hex>[,<hex>..]` or `?last=K`).
    Trace,
}

impl Route {
    /// Label used in `http_requests_total{route=...}`.
    pub fn label(&self) -> &'static str {
        match self {
            Route::Health => "health",
            Route::Metrics => "metrics",
            Route::Score => "score",
            Route::Generate => "generate",
            Route::Trace => "trace",
        }
    }
}

/// Resolve `(method, target)` to a route. The query string is ignored;
/// a known path with the wrong method is 405 (+ `Allow`), an unknown
/// path is 404.
pub fn route(method: &str, target: &str) -> Result<Route, HttpError> {
    let path = target.split(['?', '#']).next().unwrap_or(target);
    let (want, matched) = match path {
        "/health" => ("GET", Route::Health),
        "/metrics" => ("GET", Route::Metrics),
        "/score" => ("POST", Route::Score),
        "/generate" => ("POST", Route::Generate),
        "/debug/trace" => ("GET", Route::Trace),
        _ => {
            return Err(HttpError::new(404, format!("no route for {path:?}")));
        }
    };
    if method != want {
        let mut e = HttpError::new(
            405,
            format!("{path} only accepts {want}, got {method}"),
        );
        e.allow = Some(want);
        return Err(e);
    }
    Ok(matched)
}

/// Map a request body to the protocol [`Request`] a TCP client would
/// have sent — same validators, same error strings. `/score` dispatches
/// on the presence of `"choices"`: with it, the lm-eval `choice` op;
/// without, plain `nll`.
pub fn body_to_request(route: Route, body: &[u8]) -> Result<Request, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = Json::parse(text).map_err(|e| format!("bad json: {e}"))?;
    match route {
        Route::Score => {
            if v.get("choices").is_some() {
                Request::choice_from_json(&v)
            } else {
                Request::nll_from_json(&v)
            }
        }
        Route::Generate => Request::generate_from_json(&v),
        Route::Health | Route::Metrics => Err("route carries no body".into()),
    }
}

/// Parse the `/debug/trace` query string into the protocol [`Request`]
/// a TCP client would send over the `{"op":"trace"}` line — same
/// normalization (explicit `id`s win over `last`, `last` in 1..=1024,
/// default 1), so the two ingresses export identical pages.
pub fn trace_query(target: &str) -> Result<Request, String> {
    let query = target
        .splitn(2, '?')
        .nth(1)
        .unwrap_or("")
        .split('#')
        .next()
        .unwrap_or("");
    let mut ids: Vec<u64> = Vec::new();
    let mut last = 1usize;
    for pair in query.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match k {
            "id" => {
                for part in v.split(',').filter(|p| !p.is_empty()) {
                    let id = trace::parse_hex(part)
                        .ok_or_else(|| format!("bad trace id {part:?}"))?;
                    ids.push(id);
                }
            }
            "last" => {
                let n: usize = v.parse().map_err(|_| format!("bad last {v:?}"))?;
                if n == 0 || n > 1024 {
                    return Err(format!("last must be in 1..=1024, got {n}"));
                }
                last = n;
            }
            other => return Err(format!("unknown trace query key {other:?}")),
        }
    }
    if !ids.is_empty() {
        last = 1;
    }
    Ok(Request::Trace { ids, last })
}

/// Reason phrase for the statuses this server emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// A response ready to serialize: status, body, extra headers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// extra headers, e.g. `Retry-After` on 429 or `Allow` on 405
    pub extra: Vec<(&'static str, String)>,
}

impl HttpResponse {
    pub fn json(status: u16, v: &Json) -> HttpResponse {
        HttpResponse {
            status,
            content_type: "application/json",
            body: v.to_string().into_bytes(),
            extra: Vec::new(),
        }
    }

    /// Prometheus text page (content type fixed by the exposition
    /// format spec).
    pub fn metrics(page: String) -> HttpResponse {
        HttpResponse {
            status: 200,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: page.into_bytes(),
            extra: Vec::new(),
        }
    }

    /// JSON error body in the wire protocol's error shape.
    pub fn error(status: u16, msg: &str) -> HttpResponse {
        HttpResponse::json(
            status,
            &Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(msg)),
            ]),
        )
    }

    pub fn from_http_error(e: &HttpError) -> HttpResponse {
        let mut r = HttpResponse::error(e.status, &e.msg);
        if let Some(allow) = e.allow {
            r.extra.push(("Allow", allow.to_string()));
        }
        r
    }

    /// A protocol [`Response`] as HTTP: body is byte-for-byte the TCP
    /// reply line (sans newline); a typed `Error` maps to 400.
    pub fn from_protocol(resp: &Response) -> HttpResponse {
        let status = match resp {
            Response::Error(_) => 400,
            _ => 200,
        };
        HttpResponse::json(status, &resp.to_json())
    }

    pub fn with_header(mut self, name: &'static str, value: String) -> HttpResponse {
        self.extra.push((name, value));
        self
    }

    /// Serialize head + body. `close` controls the `Connection` header.
    pub fn write_to(&self, w: &mut impl Write, close: bool) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len(),
        );
        for (name, value) in &self.extra {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str(if close {
            "Connection: close\r\n\r\n"
        } else {
            "Connection: keep-alive\r\n\r\n"
        });
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_resolve_with_query_strings_ignored() {
        assert_eq!(route("GET", "/health").unwrap(), Route::Health);
        assert_eq!(route("GET", "/metrics?format=prom").unwrap(), Route::Metrics);
        assert_eq!(route("POST", "/score").unwrap(), Route::Score);
        assert_eq!(route("POST", "/generate").unwrap(), Route::Generate);
        assert_eq!(route("GET", "/debug/trace?last=3").unwrap(), Route::Trace);
        assert_eq!(route("POST", "/debug/trace").unwrap_err().status, 405);
    }

    #[test]
    fn trace_query_mirrors_protocol_normalization() {
        assert_eq!(
            trace_query("/debug/trace").unwrap(),
            Request::Trace { ids: vec![], last: 1 }
        );
        assert_eq!(
            trace_query("/debug/trace?last=5").unwrap(),
            Request::Trace { ids: vec![], last: 5 }
        );
        // explicit ids win: last resets to 1 like trace_from_json
        assert_eq!(
            trace_query("/debug/trace?id=0a,ff&last=9").unwrap(),
            Request::Trace { ids: vec![0x0a, 0xff], last: 1 }
        );
        assert!(trace_query("/debug/trace?last=0").is_err());
        assert!(trace_query("/debug/trace?last=2000").is_err());
        assert!(trace_query("/debug/trace?id=zz").is_err());
        assert!(trace_query("/debug/trace?frob=1").is_err());
    }

    #[test]
    fn wrong_method_is_405_with_allow() {
        let e = route("POST", "/health").unwrap_err();
        assert_eq!(e.status, 405);
        assert_eq!(e.allow, Some("GET"));
        let e = route("GET", "/score").unwrap_err();
        assert_eq!(e.status, 405);
        assert_eq!(e.allow, Some("POST"));
        assert_eq!(route("DELETE", "/nope").unwrap_err().status, 404);
    }

    #[test]
    fn score_body_dispatches_on_choices_presence() {
        let r = body_to_request(Route::Score, b"{\"text\":\"hi\"}").unwrap();
        assert!(matches!(r, Request::Nll { .. }));
        let r = body_to_request(
            Route::Score,
            b"{\"context\":\"c\",\"choices\":[\"a\",\"b\"]}",
        )
        .unwrap();
        assert!(matches!(r, Request::Choice { .. }));
        // shared validators: same error text as the TCP protocol
        let e = body_to_request(Route::Score, b"{}").unwrap_err();
        assert_eq!(e, "nll needs \"text\"");
        let e = body_to_request(Route::Generate, b"{}").unwrap_err();
        assert_eq!(e, "generate needs \"prompt\"");
    }

    #[test]
    fn protocol_response_body_matches_tcp_line() {
        let resp = Response::Choice {
            best: 1,
            scores: vec![2.0, 1.0],
            latency_ms: 0.0,
        };
        let http = HttpResponse::from_protocol(&resp);
        assert_eq!(http.status, 200);
        assert_eq!(http.body, resp.to_json().to_string().into_bytes());
        let err = HttpResponse::from_protocol(&Response::Error("bad".into()));
        assert_eq!(err.status, 400);
    }

    #[test]
    fn serialization_carries_extra_headers_and_connection() {
        let r = HttpResponse::error(429, "full").with_header("Retry-After", "1".into());
        let mut out = Vec::new();
        r.write_to(&mut out, true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.contains("Content-Length: "));
        assert!(text.ends_with("{\"error\":\"full\",\"ok\":false}"), "{text}");
    }
}
