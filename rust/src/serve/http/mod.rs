//! Production HTTP/1.1 front end — `/score`, `/generate`, `/health`,
//! and Prometheus `/metrics` over the same [`OpExecutor`] the TCP line
//! protocol runs on (a single-process [`Service`] or a fleet router —
//! the front end cannot tell the difference).
//!
//! [`Service`]: super::service::Service
//!
//! Hand-rolled on `std` TCP like everything else in this repo (the
//! offline registry carries no HTTP crate), which keeps the surface
//! exactly as small as the deployment needs:
//!
//! * **Routing** ([`router`]) — `POST /score` and `POST /generate`
//!   validate bodies with the *same* functions as the TCP ops, so the
//!   two ingresses return byte-identical JSON; `GET /health` answers
//!   readiness (503 while draining); `GET /metrics` renders the full
//!   telemetry page ([`metrics`]).
//! * **Hardening** — request heads over `max_head` → 431, bodies over
//!   `max_body` → 413, chunked transfer → 501, unknown versions → 505,
//!   malformed syntax → 400, a request that trickles in longer than
//!   `read_timeout` (slow-loris) → 408 + close. Parse failures close
//!   the connection (framing is untrustworthy after one); routing
//!   failures (404/405) keep it alive. Pipelined requests are served
//!   in order from the same buffer.
//! * **Backpressure** ([`limits`]) — at most `max_inflight` model
//!   requests execute concurrently; excess traffic is rejected
//!   *immediately* with `429 + Retry-After` instead of queueing, so
//!   client-observed latency stays honest. `max_conns` bounds sockets
//!   the same way the TCP server does.
//! * **Graceful drain** — [`HttpHandle::begin_drain`] flips `/health`
//!   to 503 and rejects new model work (503 + `Connection: close`)
//!   while in-flight requests finish; [`HttpHandle::shutdown`] waits
//!   for the gate to empty (bounded by `drain_grace`), stops the
//!   acceptor, joins every connection thread and logs the final
//!   counter flush. Scrapes keep working during the drain window so
//!   the last metrics are observable, not lost.

pub mod client;
pub mod limits;
pub mod metrics;
pub mod parser;
pub mod router;

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub use client::{HttpClient, HttpReply};
pub use limits::Gate;
pub use metrics::HttpStats;

use super::ops::OpExecutor;
use super::protocol::Response;
use crate::util::json::Json;
use crate::util::{logging, trace};
use parser::{find_head_end, parse_head};
use router::{HttpResponse, Route};

/// HTTP front-end tuning.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// bind address; port 0 lets the OS pick (tests)
    pub addr: String,
    /// max simultaneous sockets
    pub max_conns: usize,
    /// max request body bytes (413 beyond)
    pub max_body: usize,
    /// max request head bytes (431 beyond)
    pub max_head: usize,
    /// max concurrently executing model requests (429 beyond)
    pub max_inflight: usize,
    /// total time a request may take to arrive (408 beyond)
    pub read_timeout: Duration,
    /// socket write timeout
    pub write_timeout: Duration,
    /// `Retry-After` seconds advertised on 429
    pub retry_after_secs: u64,
    /// how long [`HttpHandle::shutdown`] waits for in-flight requests
    pub drain_grace: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:7080".into(),
            max_conns: 64,
            max_body: 1 << 20,
            max_head: 16 << 10,
            max_inflight: 16,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            retry_after_secs: 1,
            drain_grace: Duration::from_secs(5),
        }
    }
}

/// Handle to a running HTTP front end.
pub struct HttpHandle {
    pub addr: SocketAddr,
    service: Arc<dyn OpExecutor>,
    stats: Arc<HttpStats>,
    gate: Arc<Gate>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    drain_grace: Duration,
    acceptor: Mutex<Option<JoinHandle<()>>>,
}

impl HttpHandle {
    /// Enter drain mode: `/health` answers 503, new `/score`/`/generate`
    /// requests are refused, in-flight requests keep running, scrapes
    /// keep working.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    pub fn stats(&self) -> Arc<HttpStats> {
        Arc::clone(&self.stats)
    }

    /// Model requests currently executing (the gate's reading).
    pub fn inflight(&self) -> usize {
        self.gate.inflight()
    }

    /// Render the metrics page without a socket round-trip (the final
    /// flush on shutdown uses this).
    pub fn metrics_text(&self) -> String {
        self.service.metrics_page(
            &self.stats,
            &self.gate,
            self.draining.load(Ordering::SeqCst),
        )
    }

    /// Graceful stop: drain, wait for in-flight work (bounded by
    /// `drain_grace`), stop the acceptor, join every connection thread,
    /// and log the final counter flush. Idempotent — a second call is a
    /// no-op, so the CLI's signal watcher and its main thread can both
    /// call it without coordination.
    pub fn shutdown(&self) -> crate::Result<()> {
        self.begin_drain();
        let deadline = Instant::now() + self.drain_grace;
        while self.gate.inflight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptor out of accept()
        let _ = TcpStream::connect(self.addr);
        let acceptor = self.acceptor.lock().unwrap().take();
        let Some(a) = acceptor else {
            return Ok(()); // already shut down
        };
        let _ = a.join();
        log::info!(
            "http front end stopped: {} requests ({} admitted, {} rejected 429) \
             over {} connections, p99 {:.1}us",
            self.stats.requests_total(),
            self.stats.admitted(),
            self.stats.rejected(),
            self.stats.connections(),
            self.stats.latency_percentile(99.0) * 1e6,
        );
        Ok(())
    }
}

/// Everything a connection thread needs, bundled once.
struct ConnCtx {
    service: Arc<dyn OpExecutor>,
    stats: Arc<HttpStats>,
    gate: Arc<Gate>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    cfg: HttpConfig,
}

/// Start the HTTP front end over any op executor — a single-process
/// [`super::service::Service`] or a [`super::fleet::FleetRouter`].
/// Returns after the socket is bound; the acceptor and connection
/// threads run until [`HttpHandle::shutdown`].
pub fn serve_http(service: Arc<dyn OpExecutor>, cfg: HttpConfig) -> crate::Result<HttpHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let draining = Arc::new(AtomicBool::new(false));
    let stats = Arc::new(HttpStats::default());
    let gate = Gate::new(cfg.max_inflight);
    let drain_grace = cfg.drain_grace;

    let ctx = Arc::new(ConnCtx {
        service: Arc::clone(&service),
        stats: Arc::clone(&stats),
        gate: Arc::clone(&gate),
        stop: Arc::clone(&stop),
        draining: Arc::clone(&draining),
        cfg,
    });

    let acceptor = {
        let ctx = Arc::clone(&ctx);
        std::thread::spawn(move || {
            let live = Mutex::new(Vec::<JoinHandle<()>>::new());
            for conn in listener.incoming() {
                if ctx.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                {
                    let mut v = live.lock().unwrap();
                    v.retain(|h| !h.is_finished());
                    if v.len() >= ctx.cfg.max_conns {
                        let resp =
                            HttpResponse::error(503, "server at connection capacity")
                                .with_header("X-Request-Id", trace::id_hex(trace::mint_id()));
                        let mut s = stream;
                        let _ = resp.write_to(&mut s, true);
                        continue;
                    }
                }
                ctx.stats.record_connection();
                let ctx2 = Arc::clone(&ctx);
                let h = std::thread::spawn(move || handle_conn(stream, &ctx2));
                live.lock().unwrap().push(h);
            }
            for h in live.lock().unwrap().drain(..) {
                let _ = h.join();
            }
        })
    };

    log::info!("http front end listening on {addr}");
    Ok(HttpHandle {
        addr,
        service,
        stats,
        gate,
        stop,
        draining,
        drain_grace,
        acceptor: Mutex::new(Some(acceptor)),
    })
}

/// Outcome of one attempt to serve a buffered request.
enum Step {
    /// head or body incomplete — read more bytes
    NeedMore,
    /// request answered, connection stays open
    Continue,
    /// connection must close (protocol damage or `Connection: close`)
    Close,
}

fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    // short poll timeout so the handler notices `stop` while idle;
    // the *request* deadline (slow-loris) is enforced separately below
    if stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .is_err()
    {
        return;
    }
    let _ = stream.set_write_timeout(Some(ctx.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let mut buf: Vec<u8> = Vec::new();
    // when the current (incomplete) request started arriving
    let mut started: Option<Instant> = None;
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        match step(&mut buf, &mut stream, ctx) {
            Step::Close => break,
            Step::Continue => {
                // a pipelined follow-up may already be buffered; its
                // clock starts now
                started = if buf.is_empty() { None } else { Some(Instant::now()) };
                continue;
            }
            Step::NeedMore => {}
        }
        if let Some(t) = started {
            if t.elapsed() > ctx.cfg.read_timeout {
                let resp = HttpResponse::error(408, "request timed out")
                    .with_header("X-Request-Id", trace::id_hex(trace::mint_id()));
                let _ = resp.write_to(&mut stream, true);
                ctx.stats.observe("other", 408, t.elapsed());
                break;
            }
        }
        let mut chunk = [0u8; 8192];
        match stream.read(&mut chunk) {
            Ok(0) => {
                if !buf.is_empty() {
                    // mid-request EOF: best-effort error, then close
                    let resp = HttpResponse::error(400, "truncated request")
                        .with_header("X-Request-Id", trace::id_hex(trace::mint_id()));
                    let _ = resp.write_to(&mut stream, true);
                    ctx.stats.observe("other", 400, Duration::ZERO);
                }
                break;
            }
            Ok(n) => {
                if started.is_none() {
                    started = Some(Instant::now());
                }
                buf.extend_from_slice(&chunk[..n]);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        }
    }
}

/// Write `resp` (stamped with the request's trace ID), record the
/// observation, and translate into a [`Step`]. Every reply that leaves
/// through here — success or typed error — echoes `X-Request-Id`, so a
/// client can always hand the ID to `/debug/trace` or grep the slow log.
fn finish(
    stream: &mut TcpStream,
    ctx: &ConnCtx,
    label: &'static str,
    resp: HttpResponse,
    close: bool,
    t0: Instant,
    rid: u64,
) -> Step {
    let resp = resp.with_header("X-Request-Id", trace::id_hex(rid));
    let wrote = resp.write_to(stream, close).is_ok();
    ctx.stats.observe(label, resp.status, t0.elapsed());
    if close || !wrote {
        Step::Close
    } else {
        Step::Continue
    }
}

/// The request's trace ID: honor a client-supplied `X-Request-Id`
/// (hex IDs pass through verbatim, anything else hashes to a stable
/// ID), mint a fresh one otherwise.
fn request_trace_id(head: &parser::Head) -> u64 {
    match head.header("x-request-id").map(str::trim) {
        Some(v) if !v.is_empty() => {
            trace::parse_hex(v).unwrap_or_else(|| trace::id_from_label(v))
        }
        _ => trace::mint_id(),
    }
}

/// Try to carve one complete request out of `buf` and answer it.
fn step(buf: &mut Vec<u8>, stream: &mut TcpStream, ctx: &ConnCtx) -> Step {
    let t0 = Instant::now();
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > ctx.cfg.max_head {
            let resp = HttpResponse::error(431, "request head too large");
            return finish(stream, ctx, "other", resp, true, t0, trace::mint_id());
        }
        return Step::NeedMore;
    };
    if head_end > ctx.cfg.max_head {
        let resp = HttpResponse::error(431, "request head too large");
        return finish(stream, ctx, "other", resp, true, t0, trace::mint_id());
    }
    let head = match parse_head(&buf[..head_end]) {
        Ok(h) => h,
        Err(e) => {
            // after a malformed head the request framing is unknowable;
            // answer and close rather than guess at a resync point
            let resp = HttpResponse::from_http_error(&e);
            return finish(stream, ctx, "other", resp, true, t0, trace::mint_id());
        }
    };
    let rid = request_trace_id(&head);
    if head.is_chunked() {
        let resp = HttpResponse::error(501, "chunked transfer encoding not supported");
        return finish(stream, ctx, "other", resp, true, t0, rid);
    }
    let body_len = match head.content_length() {
        Ok(n) => n.unwrap_or(0),
        Err(e) => {
            let resp = HttpResponse::from_http_error(&e);
            return finish(stream, ctx, "other", resp, true, t0, rid);
        }
    };
    if body_len > ctx.cfg.max_body {
        let resp = HttpResponse::error(413, "request body too large");
        return finish(stream, ctx, "other", resp, true, t0, rid);
    }
    if buf.len() < head_end + body_len {
        return Step::NeedMore;
    }

    let body: Vec<u8> = buf[head_end..head_end + body_len].to_vec();
    buf.drain(..head_end + body_len);
    let (label, resp, force_close) = dispatch(&head, &body, ctx, rid);
    let close = force_close || head.wants_close();
    finish(stream, ctx, label, resp, close, t0, rid)
}

/// Route and execute one well-framed request. Returns the route label
/// for metrics, the response, and whether the connection must close.
fn dispatch(
    head: &parser::Head,
    body: &[u8],
    ctx: &ConnCtx,
    rid: u64,
) -> (&'static str, HttpResponse, bool) {
    let route = match router::route(&head.method, &head.target) {
        Ok(r) => r,
        Err(e) => return ("other", HttpResponse::from_http_error(&e), false),
    };
    let label = route.label();
    let draining = ctx.draining.load(Ordering::SeqCst);
    match route {
        Route::Health => {
            let resp = if draining {
                HttpResponse::json(
                    503,
                    &Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("status", Json::str("draining")),
                    ]),
                )
            } else {
                HttpResponse::json(
                    200,
                    &Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("status", Json::str("ok")),
                        ("generate", Json::Bool(ctx.service.has_generator())),
                    ]),
                )
            };
            (label, resp, false)
        }
        Route::Metrics => {
            let page = ctx.service.metrics_page(&ctx.stats, &ctx.gate, draining);
            (label, HttpResponse::metrics(page), false)
        }
        Route::Trace => {
            // debug read: no gate, and it keeps working while draining
            // (like /metrics) so the last requests stay inspectable
            let resp = match router::trace_query(&head.target) {
                Err(msg) => HttpResponse::error(400, &msg),
                Ok(req) => match ctx.service.execute(&req) {
                    Response::Trace(page) => HttpResponse::json(200, &page),
                    Response::Error(e) => HttpResponse::error(400, &e),
                    other => HttpResponse::from_protocol(&other),
                },
            };
            (label, resp, false)
        }
        Route::Score | Route::Generate => {
            if draining {
                // close so load balancers stop reusing this socket
                return (label, HttpResponse::error(503, "server is draining"), true);
            }
            let Some(_slot) = ctx.gate.try_acquire() else {
                ctx.stats.record_rejected();
                let resp = HttpResponse::error(429, "server at capacity, retry later")
                    .with_header("Retry-After", ctx.cfg.retry_after_secs.to_string());
                return (label, resp, false);
            };
            ctx.stats.record_admitted();
            let resp = match router::body_to_request(route, body) {
                Err(msg) => HttpResponse::error(400, &msg),
                Ok(req) => {
                    let t0 = Instant::now();
                    let reply = {
                        let mut root = trace::root("ingress.http", rid, 0);
                        root.arg("route", label);
                        root.arg("op", req.op());
                        let _in_req = trace::scope(trace::Ctx {
                            trace: root.trace(),
                            span: root.id(),
                        });
                        ctx.service.execute(&req)
                    };
                    let ms = t0.elapsed().as_millis() as u64;
                    if ms >= trace::slow_ms() {
                        logging::kv(
                            log::Level::Warn,
                            "serve::http",
                            "slow_request",
                            &[
                                ("trace", trace::id_hex(rid)),
                                ("route", label.to_string()),
                                ("op", req.op().to_string()),
                                ("ms", ms.to_string()),
                            ],
                        );
                    }
                    HttpResponse::from_protocol(&reply)
                }
            };
            (label, resp, false)
        }
    }
}
