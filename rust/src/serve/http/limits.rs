//! Admission control: a lock-free in-flight gate.
//!
//! The HTTP front end bounds *concurrently executing* model requests
//! (`/score`, `/generate`) separately from open sockets: a Prometheus
//! scrape or health probe must never queue behind a slow decode, and a
//! burst of scoring traffic must turn into fast `429 + Retry-After`
//! rejections instead of an unbounded pile of blocked threads. The
//! [`Gate`] is that bound — acquire on admission, release on drop, so
//! an early return or handler panic can never leak a slot.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Counting admission gate with a hard capacity.
#[derive(Debug)]
pub struct Gate {
    cap: usize,
    inflight: AtomicUsize,
}

impl Gate {
    pub fn new(cap: usize) -> Arc<Gate> {
        Arc::new(Gate {
            cap: cap.max(1),
            inflight: AtomicUsize::new(0),
        })
    }

    /// Try to claim a slot. `None` means the caller must reject with
    /// 429 — there is deliberately no blocking variant: backpressure
    /// is pushed to the client, not hidden in a queue.
    pub fn try_acquire(self: &Arc<Gate>) -> Option<GateGuard> {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.cap {
                return None;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(GateGuard { gate: Arc::clone(self) }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Requests currently holding a slot (the `http_inflight` gauge).
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Configured capacity (the `http_inflight_limit` gauge).
    pub fn cap(&self) -> usize {
        self.cap
    }
}

/// RAII slot: releases the gate when dropped.
pub struct GateGuard {
    gate: Arc<Gate>,
}

impl Drop for GateGuard {
    fn drop(&mut self) {
        self.gate.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_admits_up_to_cap_and_releases_on_drop() {
        let g = Gate::new(2);
        let a = g.try_acquire().unwrap();
        let b = g.try_acquire().unwrap();
        assert_eq!(g.inflight(), 2);
        assert!(g.try_acquire().is_none(), "over cap must reject");
        drop(a);
        assert_eq!(g.inflight(), 1);
        let c = g.try_acquire();
        assert!(c.is_some(), "slot freed by drop is reusable");
        drop(b);
        drop(c);
        assert_eq!(g.inflight(), 0);
    }

    #[test]
    fn gate_is_race_free_under_contention() {
        let g = Gate::new(4);
        let peak = Arc::new(AtomicUsize::new(0));
        let admitted = Arc::new(AtomicUsize::new(0));
        let mut threads = Vec::new();
        for _ in 0..8 {
            let g = Arc::clone(&g);
            let peak = Arc::clone(&peak);
            let admitted = Arc::clone(&admitted);
            threads.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    if let Some(_slot) = g.try_acquire() {
                        admitted.fetch_add(1, Ordering::Relaxed);
                        peak.fetch_max(g.inflight(), Ordering::Relaxed);
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(g.inflight(), 0, "all slots released");
        assert!(peak.load(Ordering::Relaxed) <= 4, "cap never exceeded");
        assert!(admitted.load(Ordering::Relaxed) > 0);
    }
}
