//! `/metrics` — Prometheus text exposition for the whole serving stack.
//!
//! One render pulls together every telemetry source the process has:
//! the global kernel counters ([`crate::util::perf`], via its
//! [`PromExport`] impl), the line-protocol server counters
//! ([`crate::serve::ServerStats`]), the scoring-queue and decode
//! schedulers (via [`crate::serve::Service`]), and the HTTP front end's
//! own [`HttpStats`]. Families are properly typed — monotone totals are
//! counters, point-in-time readings are gauges, latencies and the
//! decode batch-fill distribution are real histograms with cumulative
//! `le` buckets — because a mistyped family silently breaks `rate()`
//! in every dashboard built on it.
//!
//! The page is validated in-repo: the scrape tests and the `http_load`
//! bench feed every emitted page back through
//! [`crate::util::prom::parse_text`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::limits::Gate;
use crate::serve::service::Service;
use crate::util::prom::{PromExport, PromKind, PromWriter};
use crate::util::timer::LatencyRing;

/// Request-duration histogram bounds (seconds). Spread for a serving
/// path whose fast ops are sub-millisecond and whose generate calls can
/// run for seconds.
const BOUNDS: [f64; 7] = [0.001, 0.005, 0.025, 0.1, 0.5, 2.5, 10.0];

/// Retained latency samples for the p50/p99 gauges (recent window, the
/// operationally useful read — matches `GenScheduler`'s ring).
const LATENCY_WINDOW: usize = 4096;

struct Inner {
    /// `(route label, status code)` → request count
    by_route: BTreeMap<(&'static str, u16), u64>,
    latency: LatencyRing,
    /// per-bucket (non-cumulative) counts; last slot is the overflow
    bucket_counts: [u64; BOUNDS.len() + 1],
    duration_sum: f64,
    duration_count: u64,
    /// per-route duration histograms: non-cumulative bucket counts + sum
    route_duration: BTreeMap<&'static str, ([u64; BOUNDS.len() + 1], f64)>,
}

/// HTTP front-end counters, shared by every connection thread.
pub struct HttpStats {
    connections: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    inner: Mutex<Inner>,
}

impl Default for HttpStats {
    fn default() -> HttpStats {
        HttpStats {
            connections: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                by_route: BTreeMap::new(),
                latency: LatencyRing::new(LATENCY_WINDOW),
                bucket_counts: [0; BOUNDS.len() + 1],
                duration_sum: 0.0,
                duration_count: 0,
                route_duration: BTreeMap::new(),
            }),
        }
    }
}

impl HttpStats {
    /// One socket accepted.
    pub fn record_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// One model request admitted through the gate.
    pub fn record_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// One model request rejected with 429.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One request answered: count it under `{route, code}` and feed
    /// the duration into the histogram + percentile window.
    pub fn observe(&self, route: &'static str, status: u16, took: Duration) {
        let secs = took.as_secs_f64();
        let mut inner = self.inner.lock().unwrap();
        *inner.by_route.entry((route, status)).or_insert(0) += 1;
        inner.latency.record_secs(secs);
        let slot = BOUNDS
            .iter()
            .position(|&b| secs <= b)
            .unwrap_or(BOUNDS.len());
        inner.bucket_counts[slot] += 1;
        inner.duration_sum += secs;
        inner.duration_count += 1;
        let (buckets, sum) = inner
            .route_duration
            .entry(route)
            .or_insert(([0; BOUNDS.len() + 1], 0.0));
        buckets[slot] += 1;
        *sum += secs;
    }

    pub fn connections(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Total requests answered, every route and status included — the
    /// exactness contract with the load generator.
    pub fn requests_total(&self) -> u64 {
        self.inner.lock().unwrap().by_route.values().sum()
    }

    /// Latency percentile (seconds) over the retained window.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.inner.lock().unwrap().latency.percentile(p)
    }
}

/// Render the complete scrape page.
pub fn render(service: &Service, http: &HttpStats, gate: &Gate, draining: bool) -> String {
    let mut w = PromWriter::new();

    // ---- kernel telemetry (global perf counters) ----------------------
    crate::util::perf::snapshot().prom_export(&mut w);

    // ---- line-protocol server + shared op counters --------------------
    let ss = service.server_stats();
    w.metric(
        "sparselm_requests_total",
        "line-protocol requests received over TCP",
        PromKind::Counter,
    );
    w.sample(
        "sparselm_requests_total",
        &[],
        ss.requests.load(Ordering::Relaxed) as f64,
    );
    w.metric(
        "sparselm_request_errors_total",
        "requests rejected as malformed",
        PromKind::Counter,
    );
    w.sample(
        "sparselm_request_errors_total",
        &[],
        ss.errors.load(Ordering::Relaxed) as f64,
    );
    w.metric(
        "sparselm_tcp_connections_total",
        "TCP connections accepted by the line-protocol server",
        PromKind::Counter,
    );
    w.sample(
        "sparselm_tcp_connections_total",
        &[],
        ss.connections.load(Ordering::Relaxed) as f64,
    );
    w.metric(
        "sparselm_ops_total",
        "model operations executed, by op (both ingresses)",
        PromKind::Counter,
    );
    for (op, count) in [
        ("nll", ss.nll_ops.load(Ordering::Relaxed)),
        ("choice", ss.choice_ops.load(Ordering::Relaxed)),
        ("generate", ss.generate_ops.load(Ordering::Relaxed)),
    ] {
        w.sample("sparselm_ops_total", &[("op", op)], count as f64);
    }

    // ---- scoring queue ------------------------------------------------
    let bs = service.batcher_stats();
    w.metric(
        "sparselm_score_batches_total",
        "coalesced scoring batches executed",
        PromKind::Counter,
    );
    w.sample("sparselm_score_batches_total", &[], bs.batches as f64);
    w.metric(
        "sparselm_score_rows_total",
        "scoring rows executed across all batches",
        PromKind::Counter,
    );
    w.sample("sparselm_score_rows_total", &[], bs.rows_scored as f64);
    w.metric(
        "sparselm_score_timeout_flushes_total",
        "batches flushed by the max-wait deadline rather than fill",
        PromKind::Counter,
    );
    w.sample(
        "sparselm_score_timeout_flushes_total",
        &[],
        bs.timeout_flushes as f64,
    );
    w.metric(
        "sparselm_score_queue_depth",
        "scoring requests currently queued",
        PromKind::Gauge,
    );
    w.sample("sparselm_score_queue_depth", &[], service.queue_depth() as f64);

    // ---- decode scheduler ---------------------------------------------
    if service.has_generator() {
        let gs = service.gen_stats();
        w.metric(
            "sparselm_gen_requests_total",
            "generation requests accepted by the scheduler",
            PromKind::Counter,
        );
        w.sample("sparselm_gen_requests_total", &[], gs.requests as f64);
        w.metric(
            "sparselm_gen_completed_total",
            "generation requests completed",
            PromKind::Counter,
        );
        w.sample("sparselm_gen_completed_total", &[], gs.completed as f64);
        w.metric(
            "sparselm_decode_steps_total",
            "shared decode steps executed",
            PromKind::Counter,
        );
        w.sample("sparselm_decode_steps_total", &[], gs.decode_steps as f64);
        w.metric(
            "sparselm_tokens_generated_total",
            "tokens emitted by the decode engine",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_tokens_generated_total",
            &[],
            gs.tokens_generated as f64,
        );
        w.metric(
            "sparselm_prefill_seconds_total",
            "wall seconds spent in prompt prefill",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_prefill_seconds_total",
            &[],
            gs.prefill_nanos as f64 / 1e9,
        );
        w.metric(
            "sparselm_decode_seconds_total",
            "wall seconds spent in shared decode steps",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_decode_seconds_total",
            &[],
            gs.decode_nanos as f64 / 1e9,
        );
        w.metric(
            "sparselm_decode_step_p50_us",
            "median decode-step latency over the recent window",
            PromKind::Gauge,
        );
        w.sample("sparselm_decode_step_p50_us", &[], gs.decode_p50_us);
        w.metric(
            "sparselm_decode_step_p99_us",
            "p99 decode-step latency over the recent window",
            PromKind::Gauge,
        );
        w.sample("sparselm_decode_step_p99_us", &[], gs.decode_p99_us);

        // batch-fill distribution: `batch_fill[i]` = steps run with i
        // sequences in flight, re-shaped into a cumulative histogram
        w.metric(
            "sparselm_decode_batch_fill",
            "decode steps by number of in-flight sequences",
            PromKind::Histogram,
        );
        let mut cum = 0u64;
        let mut fill_sum = 0u64;
        for (fill, &steps) in gs.batch_fill.iter().enumerate() {
            cum += steps;
            fill_sum += fill as u64 * steps;
            let le = fill.to_string();
            w.sample(
                "sparselm_decode_batch_fill_bucket",
                &[("le", &le)],
                cum as f64,
            );
        }
        w.sample(
            "sparselm_decode_batch_fill_bucket",
            &[("le", "+Inf")],
            cum as f64,
        );
        w.sample("sparselm_decode_batch_fill_sum", &[], fill_sum as f64);
        w.sample("sparselm_decode_batch_fill_count", &[], cum as f64);

        w.metric(
            "sparselm_gen_queue_depth",
            "generation requests currently queued",
            PromKind::Gauge,
        );
        w.sample(
            "sparselm_gen_queue_depth",
            &[],
            service.gen_queue_depth() as f64,
        );

        // queue-age histogram: how long requests waited before the
        // scheduler admitted them into the decode batch
        use crate::serve::generate::QUEUE_AGE_BOUNDS;
        w.metric(
            "sparselm_queue_age_seconds",
            "queue wait before admission to the decode batch",
            PromKind::Histogram,
        );
        let age_counts: Vec<u64> = if gs.queue_age.len() == QUEUE_AGE_BOUNDS.len() + 1 {
            gs.queue_age.clone()
        } else {
            vec![0; QUEUE_AGE_BOUNDS.len() + 1]
        };
        w.histogram_series(
            "sparselm_queue_age_seconds",
            &[],
            &QUEUE_AGE_BOUNDS,
            &age_counts,
            gs.queue_age_sum_secs,
        );
    }

    // ---- per-op latency percentiles (both ingresses) ------------------
    w.metric(
        "sparselm_op_latency_seconds",
        "per-op request latency percentiles over the recent window",
        PromKind::Gauge,
    );
    for (i, op) in crate::serve::service::LAT_OPS.into_iter().enumerate() {
        let (p50, p99, _n) = service.op_latency(i);
        w.sample(
            "sparselm_op_latency_seconds",
            &[("op", op), ("quantile", "0.5")],
            p50,
        );
        w.sample(
            "sparselm_op_latency_seconds",
            &[("op", op), ("quantile", "0.99")],
            p99,
        );
    }

    // ---- HTTP front end -----------------------------------------------
    render_http_families(&mut w, http, gate, draining);

    w.finish()
}

/// The HTTP front end's own families — shared verbatim by the
/// single-process page above and the fleet router's aggregated page
/// ([`crate::serve::fleet`]), so dashboards read one schema whichever
/// topology is behind the scrape. Lives here because it reads
/// [`HttpStats`]' private histogram state.
pub(crate) fn render_http_families(
    w: &mut PromWriter,
    http: &HttpStats,
    gate: &Gate,
    draining: bool,
) {
    w.metric(
        "http_requests_total",
        "HTTP requests answered, by route and status code",
        PromKind::Counter,
    );
    {
        let inner = http.inner.lock().unwrap();
        for (&(route, status), &count) in &inner.by_route {
            let code = status.to_string();
            w.sample(
                "http_requests_total",
                &[("route", route), ("code", &code)],
                count as f64,
            );
        }
    }
    w.metric(
        "http_connections_total",
        "HTTP connections accepted",
        PromKind::Counter,
    );
    w.sample("http_connections_total", &[], http.connections() as f64);
    w.metric(
        "http_admitted_total",
        "model requests admitted through the in-flight gate",
        PromKind::Counter,
    );
    w.sample("http_admitted_total", &[], http.admitted() as f64);
    w.metric(
        "http_rejected_total",
        "model requests rejected with 429 (gate full)",
        PromKind::Counter,
    );
    w.sample("http_rejected_total", &[], http.rejected() as f64);
    w.metric(
        "http_inflight",
        "model requests currently executing",
        PromKind::Gauge,
    );
    w.sample("http_inflight", &[], gate.inflight() as f64);
    w.metric(
        "http_inflight_limit",
        "configured in-flight admission cap",
        PromKind::Gauge,
    );
    w.sample("http_inflight_limit", &[], gate.cap() as f64);
    w.metric(
        "http_draining",
        "1 while the server is draining, else 0",
        PromKind::Gauge,
    );
    w.sample("http_draining", &[], if draining { 1.0 } else { 0.0 });

    w.metric(
        "http_request_duration_seconds",
        "request wall time from full receipt to response written",
        PromKind::Histogram,
    );
    {
        let inner = http.inner.lock().unwrap();
        let mut cum = 0u64;
        for (i, &bound) in BOUNDS.iter().enumerate() {
            cum += inner.bucket_counts[i];
            let le = format!("{bound}");
            w.sample(
                "http_request_duration_seconds_bucket",
                &[("le", &le)],
                cum as f64,
            );
        }
        cum += inner.bucket_counts[BOUNDS.len()];
        w.sample(
            "http_request_duration_seconds_bucket",
            &[("le", "+Inf")],
            cum as f64,
        );
        w.sample(
            "http_request_duration_seconds_sum",
            &[],
            inner.duration_sum,
        );
        w.sample(
            "http_request_duration_seconds_count",
            &[],
            inner.duration_count as f64,
        );
    }
    w.metric(
        "http_route_duration_seconds",
        "request wall time by route",
        PromKind::Histogram,
    );
    {
        let inner = http.inner.lock().unwrap();
        for (route, (buckets, sum)) in &inner.route_duration {
            w.histogram_series(
                "http_route_duration_seconds",
                &[("route", route)],
                &BOUNDS,
                buckets,
                *sum,
            );
        }
    }
    w.metric(
        "http_request_p50_us",
        "median request latency over the recent window",
        PromKind::Gauge,
    );
    w.sample("http_request_p50_us", &[], http.latency_percentile(50.0) * 1e6);
    w.metric(
        "http_request_p99_us",
        "p99 request latency over the recent window",
        PromKind::Gauge,
    );
    w.sample("http_request_p99_us", &[], http.latency_percentile(99.0) * 1e6);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batcher::{Batcher, BatcherConfig};
    use crate::util::prom::parse_text;
    use std::sync::Arc;

    fn test_service() -> Service {
        Service::new(
            Arc::new(Batcher::new(BatcherConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            })),
            None,
            Arc::new(crate::data::Tokenizer::fit("a b c d", 32)),
            Arc::new(crate::serve::ServerStats::default()),
            8,
        )
    }

    #[test]
    fn rendered_page_parses_and_carries_http_families() {
        let service = test_service();
        let http = HttpStats::default();
        let gate = Gate::new(4);
        http.record_connection();
        http.record_admitted();
        http.observe("score", 200, Duration::from_millis(3));
        http.observe("score", 200, Duration::from_millis(40));
        http.observe("health", 200, Duration::from_micros(50));
        http.record_rejected();
        http.observe("score", 429, Duration::from_micros(10));

        let page = render(&service, &http, &gate, false);
        let s = parse_text(&page).expect("page must be valid prometheus text");
        assert_eq!(
            s.value("http_requests_total", &[("route", "score"), ("code", "200")]),
            Some(2.0)
        );
        assert_eq!(s.sum("http_requests_total", &[]), 4.0);
        assert_eq!(s.value("http_rejected_total", &[]), Some(1.0));
        assert_eq!(s.value("http_inflight", &[]), Some(0.0));
        assert_eq!(s.value("http_inflight_limit", &[]), Some(4.0));
        assert_eq!(s.value("http_draining", &[]), Some(0.0));
        assert_eq!(
            s.value("http_request_duration_seconds_count", &[]),
            Some(4.0)
        );
        assert_eq!(
            s.value("http_request_duration_seconds_bucket", &[("le", "+Inf")]),
            Some(4.0)
        );
        // kernel + scheduler families ride along on the same page
        assert!(s.value("sparselm_spmm_calls_total", &[]).is_some());
        assert_eq!(s.value("sparselm_score_queue_depth", &[]), Some(0.0));
        assert_eq!(s.value("sparselm_ops_total", &[("op", "nll")]), Some(0.0));
        // per-route duration histogram: score saw 3 requests (2x200 + 429)
        assert_eq!(
            s.value(
                "http_route_duration_seconds_bucket",
                &[("route", "score"), ("le", "+Inf")]
            ),
            Some(3.0)
        );
        assert_eq!(
            s.value(
                "http_route_duration_seconds_count",
                &[("route", "health")]
            ),
            Some(1.0)
        );
        // per-op latency percentiles are always present (0 when idle)
        assert_eq!(
            s.value(
                "sparselm_op_latency_seconds",
                &[("op", "nll"), ("quantile", "0.5")]
            ),
            Some(0.0)
        );
        assert_eq!(
            s.value(
                "sparselm_op_latency_seconds",
                &[("op", "generate"), ("quantile", "0.99")]
            ),
            Some(0.0)
        );
    }

    #[test]
    fn gen_queue_depth_gauge_tracks_queued_requests() {
        use crate::serve::generate::{GenRequest, GenScheduler};
        let gen = Arc::new(GenScheduler::new());
        let service = Service::new(
            Arc::new(Batcher::new(BatcherConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            })),
            Some(gen.clone()),
            Arc::new(crate::data::Tokenizer::fit("a b c d", 32)),
            Arc::new(crate::serve::ServerStats::default()),
            8,
        );
        // no engine thread is draining the queue, so a submitted
        // request sits in it — exactly what the admission gauge reads
        let _rx = gen.submit(GenRequest {
            id: 1,
            prompt: vec![1],
            max_tokens: 1,
            temperature: 0.0,
            seed: 0,
            stop: None,
            trace: crate::util::trace::Ctx::NONE,
        });
        let http = HttpStats::default();
        let gate = Gate::new(2);
        let page = render(&service, &http, &gate, false);
        let s = parse_text(&page).expect("page must be valid prometheus text");
        assert_eq!(s.value("sparselm_gen_queue_depth", &[]), Some(1.0));
        // queue-age histogram renders (all-zero: nothing admitted yet)
        assert_eq!(
            s.value("sparselm_queue_age_seconds_bucket", &[("le", "+Inf")]),
            Some(0.0)
        );
        assert_eq!(s.value("sparselm_queue_age_seconds_count", &[]), Some(0.0));
        // the speculative-decode counter families ride along via the
        // global perf exporter on the same page
        assert!(s.value("sparselm_spec_rounds_total", &[]).is_some());
        assert!(s.value("sparselm_spec_drafted_total", &[]).is_some());
        assert!(s.value("sparselm_spec_accepted_total", &[]).is_some());
        assert!(s.value("sparselm_spec_mispredicts_total", &[]).is_some());
    }

    #[test]
    fn draining_flag_flips_the_gauge() {
        let service = test_service();
        let http = HttpStats::default();
        let gate = Gate::new(1);
        let page = render(&service, &http, &gate, true);
        let s = parse_text(&page).unwrap();
        assert_eq!(s.value("http_draining", &[]), Some(1.0));
    }

    #[test]
    fn requests_total_counts_every_status() {
        let http = HttpStats::default();
        http.observe("score", 200, Duration::from_millis(1));
        http.observe("other", 404, Duration::from_micros(5));
        http.observe("score", 429, Duration::from_micros(5));
        assert_eq!(http.requests_total(), 3);
        assert!(http.latency_percentile(99.0) > 0.0);
    }
}
