//! Minimal blocking HTTP/1.1 client — the test harness and load
//! generator's side of the wire.
//!
//! Deliberately not a general client: it speaks exactly what the front
//! end serves (keep-alive, `Content-Length` bodies) plus the raw-bytes
//! escape hatch ([`HttpClient::send_raw`]) the conformance tests use to
//! send malformed and pipelined traffic. Replies are parsed with the
//! same head-scanning primitive as the server
//! ([`super::parser::find_head_end`]), and leftover bytes stay in the
//! client buffer so pipelined responses read back one at a time.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::parser::find_head_end;
use crate::util::json::Json;

/// One parsed HTTP response.
#[derive(Debug, Clone)]
pub struct HttpReply {
    pub status: u16,
    /// header pairs with lowercased names
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpReply {
    /// First value of `name` (ASCII case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    pub fn json(&self) -> Result<Json, String> {
        Json::parse(&self.text()).map_err(|e| format!("bad json body: {e}"))
    }
}

/// Blocking client over one keep-alive connection.
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient {
            stream,
            buf: Vec::new(),
        })
    }

    /// Cap how long a single reply read may block.
    pub fn set_timeout(&mut self, d: Duration) -> std::io::Result<()> {
        self.stream.set_read_timeout(Some(d))
    }

    /// `GET path` and read the reply.
    pub fn get(&mut self, path: &str) -> std::io::Result<HttpReply> {
        let req = format!("GET {path} HTTP/1.1\r\nHost: sparselm\r\n\r\n");
        self.send_raw(req.as_bytes())?;
        self.read_reply()
    }

    /// `POST path` with a JSON body and read the reply.
    pub fn post_json(&mut self, path: &str, body: &str) -> std::io::Result<HttpReply> {
        let req = format!(
            "POST {path} HTTP/1.1\r\nHost: sparselm\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len(),
        );
        self.send_raw(req.as_bytes())?;
        self.read_reply()
    }

    /// Write raw bytes — the conformance tests' malformed traffic.
    pub fn send_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    /// Read exactly one response (head + `Content-Length` body); bytes
    /// past it stay buffered for the next call (pipelining).
    pub fn read_reply(&mut self) -> std::io::Result<HttpReply> {
        let head_end = loop {
            if let Some(end) = find_head_end(&self.buf) {
                break end;
            }
            self.fill()?;
        };
        let head = String::from_utf8_lossy(&self.buf[..head_end]).into_owned();
        let mut lines = head.lines();
        let status_line = lines.next().unwrap_or("");
        let status = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad status line {status_line:?}"),
                )
            })?;
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                break;
            }
            if let Some((name, value)) = line.split_once(':') {
                headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
            }
        }
        let len = headers
            .iter()
            .find(|(n, _)| n == "content-length")
            .and_then(|(_, v)| v.parse::<usize>().ok())
            .unwrap_or(0);
        while self.buf.len() < head_end + len {
            self.fill()?;
        }
        let body = self.buf[head_end..head_end + len].to_vec();
        self.buf.drain(..head_end + len);
        Ok(HttpReply {
            status,
            headers,
            body,
        })
    }

    fn fill(&mut self) -> std::io::Result<()> {
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-reply",
            ));
        }
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(())
    }
}
