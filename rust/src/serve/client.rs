//! Blocking client for the scoring server — used by the integration
//! tests, the `serve_demo` example and the `serve-bench` CLI load
//! generator.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{Request, Response};
use crate::util::trace;

/// One TCP connection speaking the line protocol.
pub struct ServeClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl ServeClient {
    pub fn connect(addr: impl ToSocketAddrs) -> crate::Result<ServeClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(ServeClient { stream, reader })
    }

    pub fn set_timeout(&self, d: Duration) -> crate::Result<()> {
        self.stream.set_read_timeout(Some(d))?;
        Ok(())
    }

    /// Send one request, await its response line.
    pub fn call(&mut self, req: &Request) -> crate::Result<Response> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Response::parse(buf.trim_end()).map_err(|e| anyhow::anyhow!(e))
    }

    /// [`Self::call`] with a trace context riding the wire as transport
    /// metadata — the receiving ingress parents its spans under
    /// `ctx.span`. With an inactive context the line is byte-identical
    /// to [`Self::call`]'s.
    pub fn call_traced(&mut self, req: &Request, ctx: trace::Ctx) -> crate::Result<Response> {
        let mut line = req.to_json_traced(ctx).to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Response::parse(buf.trim_end()).map_err(|e| anyhow::anyhow!(e))
    }

    /// Export traces from the server's flight recorder as a Chrome
    /// trace-event page: explicit `ids` win; otherwise the most recent
    /// `last` completed traces.
    pub fn trace_export(
        &mut self,
        ids: &[u64],
        last: usize,
    ) -> crate::Result<crate::util::json::Json> {
        let req = Request::Trace {
            ids: ids.to_vec(),
            last: if ids.is_empty() { last.max(1) } else { 1 },
        };
        match self.call(&req)? {
            Response::Trace(j) => Ok(j),
            Response::Error(e) => anyhow::bail!("server error: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Send a raw line (protocol fuzzing / tests) and parse the reply.
    pub fn call_raw(&mut self, raw: &str) -> crate::Result<Response> {
        self.stream.write_all(raw.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Response::parse(buf.trim_end()).map_err(|e| anyhow::anyhow!(e))
    }

    pub fn ping(&mut self) -> crate::Result<bool> {
        Ok(matches!(self.call(&Request::Ping)?, Response::Pong))
    }

    /// Mean NLL of `text` under the served model.
    pub fn nll(&mut self, text: &str) -> crate::Result<(f64, usize)> {
        match self.call(&Request::Nll { text: text.into() })? {
            Response::Nll {
                mean_nll, tokens, ..
            } => Ok((mean_nll, tokens)),
            Response::Error(e) => anyhow::bail!("server error: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Index of the best (lowest length-normalized NLL) continuation.
    pub fn choice(&mut self, context: &str, choices: &[&str]) -> crate::Result<(usize, Vec<f64>)> {
        let req = Request::Choice {
            context: context.into(),
            choices: choices.iter().map(|s| s.to_string()).collect(),
        };
        match self.call(&req)? {
            Response::Choice { best, scores, .. } => Ok((best, scores)),
            Response::Error(e) => anyhow::bail!("server error: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Autoregressive continuation of `prompt` (greedy when
    /// `temperature == 0`, sampling seed 0 otherwise). Returns
    /// `(text, generated_tokens)`.
    pub fn generate(
        &mut self,
        prompt: &str,
        max_tokens: usize,
        temperature: f64,
    ) -> crate::Result<(String, usize)> {
        self.generate_seeded(prompt, max_tokens, temperature, 0)
    }

    /// [`Self::generate`] with an explicit sampling seed — distinct
    /// seeds give independent sample paths at `temperature > 0`. Seeds
    /// must stay below 2^53: the json wire format carries numbers as
    /// f64, and larger integers would silently alias to a different
    /// sample path.
    pub fn generate_seeded(
        &mut self,
        prompt: &str,
        max_tokens: usize,
        temperature: f64,
        seed: u64,
    ) -> crate::Result<(String, usize)> {
        anyhow::ensure!(
            seed < (1 << 53),
            "seed {seed} >= 2^53 cannot survive the json f64 transport"
        );
        let req = Request::Generate {
            prompt: prompt.into(),
            max_tokens,
            temperature,
            seed,
        };
        match self.call(&req)? {
            Response::Generate { text, tokens, .. } => Ok((text, tokens)),
            Response::Error(e) => anyhow::bail!("server error: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Raw stats object.
    pub fn stats(&mut self) -> crate::Result<crate::util::json::Json> {
        match self.call(&Request::Stats)? {
            Response::Stats(j) => Ok(j),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> crate::Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}
