//! Worker processes: spawning, the readiness handshake, liveness and
//! reaping.
//!
//! A fleet worker is an ordinary `sparselm fleet-worker` process — a
//! full single-process server (own [`GenScheduler`], KV arena, perf
//! counters) that mmaps the shared `.spak` and announces its
//! OS-assigned port on stdout with one line:
//!
//! ```text
//! FLEET_WORKER_READY 127.0.0.1:41234
//! ```
//!
//! The router blocks on that line at boot (bounded by `boot_timeout`),
//! then keeps draining the child's stdout on a background thread so
//! the pipe never fills and the worker's own log lines surface under a
//! `[worker N]` prefix.
//!
//! [`GenScheduler`]: crate::serve::generate::GenScheduler

use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Context;

/// The stdout handshake prefix `sparselm fleet-worker` prints once its
/// socket is bound (the address follows on the same line).
pub const READY_PREFIX: &str = "FLEET_WORKER_READY ";

/// Boots one worker (slot index → ready worker). The router calls it
/// at fleet start and again whenever the supervisor replaces a dead
/// worker, so the spawner owns everything about *how* a worker comes
/// up: binary, argv, environment, handshake deadline.
pub type Spawner = Box<dyn Fn(usize) -> crate::Result<Worker> + Send + Sync>;

/// A supervised worker process and the address it answered on.
pub struct Worker {
    pub addr: SocketAddr,
    child: Option<Child>,
}

impl Worker {
    /// Adopt a freshly spawned child: wait (bounded) for the readiness
    /// line on its piped stdout, then keep forwarding the rest of its
    /// output from a drain thread.
    pub fn adopt(mut child: Child, idx: usize, boot_timeout: Duration) -> crate::Result<Worker> {
        let stdout = child
            .stdout
            .take()
            .ok_or_else(|| anyhow::anyhow!("worker {idx}: stdout was not piped"))?;
        let (tx, rx) = mpsc::channel::<String>();
        std::thread::spawn(move || {
            let reader = BufReader::new(stdout);
            let mut announced = false;
            for line in reader.lines() {
                let Ok(line) = line else { break };
                if !announced {
                    if let Some(rest) = line.strip_prefix(READY_PREFIX) {
                        announced = true;
                        let _ = tx.send(rest.trim().to_string());
                        continue;
                    }
                }
                println!("[worker {idx}] {line}");
            }
        });
        let addr_text = match rx.recv_timeout(boot_timeout) {
            Ok(a) => a,
            Err(_) => {
                // no handshake: the child is wedged or already dead —
                // never leave it running unsupervised
                let _ = child.kill();
                let _ = child.wait();
                anyhow::bail!(
                    "worker {idx}: no {READY_PREFIX:?} handshake within {boot_timeout:?}"
                );
            }
        };
        let addr: SocketAddr = addr_text
            .parse()
            .with_context(|| format!("worker {idx}: bad handshake address {addr_text:?}"))?;
        Ok(Worker {
            addr,
            child: Some(child),
        })
    }

    pub fn pid(&self) -> Option<u32> {
        self.child.as_ref().map(|c| c.id())
    }

    /// Has the process exited? (`try_wait`, so an exited child is
    /// reaped — no zombies accumulate across restarts.)
    pub fn has_exited(&mut self) -> bool {
        match &mut self.child {
            None => true,
            Some(c) => matches!(c.try_wait(), Ok(Some(_))),
        }
    }

    /// Kill and reap immediately (chaos hook + boot-failure cleanup).
    pub fn kill(&mut self) {
        if let Some(c) = &mut self.child {
            let _ = c.kill();
            let _ = c.wait();
        }
    }

    /// Wait up to `grace` for a voluntary exit, then kill. Returns
    /// whether the worker left on its own.
    pub fn reap(&mut self, grace: Duration) -> bool {
        let Some(c) = &mut self.child else { return true };
        let deadline = Instant::now() + grace;
        loop {
            match c.try_wait() {
                Ok(Some(_)) => return true,
                Ok(None) => {
                    if Instant::now() >= deadline {
                        let _ = c.kill();
                        let _ = c.wait();
                        return false;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => {
                    let _ = c.kill();
                    let _ = c.wait();
                    return false;
                }
            }
        }
    }
}

/// The standard spawner: re-exec `bin fleet-worker <args..>` with stdout
/// piped for the handshake, stderr inherited, and `envs` applied (tests
/// pass `SPARSELM_FAST=1` through here so workers fit the same fast
/// tokenizer as the in-process reference server).
pub fn process_spawner(
    bin: PathBuf,
    args: Vec<String>,
    envs: Vec<(String, String)>,
    boot_timeout: Duration,
) -> Spawner {
    Box::new(move |idx| {
        let child = Command::new(&bin)
            .arg("fleet-worker")
            .args(&args)
            .envs(envs.iter().map(|(k, v)| (k.as_str(), v.as_str())))
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning fleet worker {idx} from {}", bin.display()))?;
        Worker::adopt(child, idx, boot_timeout)
    })
}
