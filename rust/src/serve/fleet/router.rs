//! The fleet router: least-inflight op routing, sticky generate
//! placement, redispatch of idempotent ops, supervision, and fleet-wide
//! `/metrics` aggregation.
//!
//! The router holds no model state at all — every op is forwarded over
//! the line protocol to one of K worker processes and the worker's
//! reply is re-serialized through the typed [`Reply`]. Because the wire
//! form is canonical (see [`crate::serve::ops`]), the bytes a client
//! receives through the router are identical to what the worker itself
//! would have written.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::worker::{Spawner, Worker};
use super::FleetConfig;
use crate::serve::client::ServeClient;
use crate::serve::http::{Gate, HttpStats};
use crate::serve::ops::{OpExecutor, Reply, Request};
use crate::util::json::Json;
use crate::util::prom::{PromKind, PromWriter};
use crate::util::{logging, trace};

/// One supervised worker slot. `gen` bumps on every restart so pooled
/// connections to the previous incarnation are never reused.
struct Slot {
    gen: u64,
    worker: Worker,
    up: bool,
    strikes: u32,
    inflight: Arc<AtomicUsize>,
}

/// An idle forwarding connection, keyed by (slot, incarnation).
struct PooledConn {
    idx: usize,
    gen: u64,
    client: ServeClient,
}

/// Why a forward attempt failed — connect failures happen before any
/// bytes reach the worker, so they are safe to retry for *every* op;
/// mid-op failures are only retried for idempotent requests.
enum ForwardFail {
    Connect(String),
    MidOp(String),
}

/// Routes ops across the worker fleet. Shared by the fleet's TCP
/// acceptor and (as an [`OpExecutor`]) by the HTTP front end.
pub struct FleetRouter {
    cfg: FleetConfig,
    spawner: Spawner,
    slots: Mutex<Vec<Slot>>,
    pool: Mutex<Vec<PooledConn>>,
    draining: AtomicBool,
    requests: AtomicU64,
    parse_errors: AtomicU64,
    forwarded: AtomicU64,
    redispatched: AtomicU64,
    rejected: AtomicU64,
    restarts: AtomicU64,
}

impl FleetRouter {
    pub(super) fn new(cfg: FleetConfig, spawner: Spawner, workers: Vec<Worker>) -> FleetRouter {
        let slots = workers
            .into_iter()
            .map(|worker| Slot {
                gen: 0,
                worker,
                up: true,
                strikes: 0,
                inflight: Arc::new(AtomicUsize::new(0)),
            })
            .collect();
        FleetRouter {
            cfg,
            spawner,
            slots: Mutex::new(slots),
            pool: Mutex::new(Vec::new()),
            draining: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            parse_errors: AtomicU64::new(0),
            forwarded: AtomicU64::new(0),
            redispatched: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn worker_addrs(&self) -> Vec<SocketAddr> {
        self.slots.lock().unwrap().iter().map(|s| s.worker.addr).collect()
    }

    pub fn worker_pids(&self) -> Vec<Option<u32>> {
        self.slots.lock().unwrap().iter().map(|s| s.worker.pid()).collect()
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn redispatches(&self) -> u64 {
        self.redispatched.load(Ordering::Relaxed)
    }

    pub fn total_inflight(&self) -> usize {
        let slots = self.slots.lock().unwrap();
        slots.iter().map(|s| s.inflight.load(Ordering::SeqCst)).sum()
    }

    /// Chaos hook: SIGKILL one worker without telling the router. The
    /// supervisor notices on its next tick and respawns it; in-flight
    /// ops against it fail over per the redispatch policy.
    pub fn kill_worker(&self, idx: usize) -> bool {
        let mut slots = self.slots.lock().unwrap();
        match slots.get_mut(idx) {
            Some(s) => {
                s.worker.kill();
                true
            }
            None => false,
        }
    }

    /// Stop admitting new ops (every subsequent request gets an
    /// explicit error reply; nothing queues behind a dying fleet).
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    pub(super) fn note_parse_error(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.parse_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Route one request. `affinity` is the slot that served the
    /// previous generate op on the same client connection — generate
    /// streams stay on their worker (warm KV arena) as long as it is up
    /// and under its inflight cap. Returns the reply and the slot that
    /// produced it (the caller's next affinity).
    pub fn route_with_affinity(
        &self,
        req: &Request,
        affinity: Option<usize>,
    ) -> (Reply, Option<usize>) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if matches!(req, Request::Shutdown) {
            // mirrors Service::execute — shutdown is connection-level,
            // intercepted by the ingress, never routed
            return (Reply::Error("shutdown is a connection-level op".into()), affinity);
        }
        if let Request::Trace { ids, last } = req {
            // answered by the router itself: its flight recorder holds
            // the ingress + dispatch spans, and the workers' pages are
            // merged in by trace id. Stays available while draining so
            // the last traces of a dying fleet remain inspectable.
            return (self.merged_trace(ids, *last), affinity);
        }
        if self.is_draining() {
            return (Reply::Error("fleet is draining".into()), affinity);
        }
        let total = self.workers();
        let mut excluded: Vec<usize> = Vec::new();
        loop {
            let Some((idx, gen, addr, inflight)) = self.pick(req, affinity, &excluded) else {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return (Reply::Error("fleet at capacity, retry later".into()), affinity);
            };
            inflight.fetch_add(1, Ordering::SeqCst);
            let outcome = self.forward_once(idx, gen, addr, req, excluded.len());
            inflight.fetch_sub(1, Ordering::SeqCst);
            match outcome {
                Ok(reply) => {
                    self.forwarded.fetch_add(1, Ordering::Relaxed);
                    return (reply, Some(idx));
                }
                Err(fail) => {
                    excluded.push(idx);
                    let (retryable, msg) = match fail {
                        ForwardFail::Connect(m) => (true, m),
                        ForwardFail::MidOp(m) => (req.is_idempotent(), m),
                    };
                    if retryable && excluded.len() < total {
                        self.redispatched.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    // never a silent drop: the client always gets an
                    // explicit error reply when failover is unsafe
                    return (Reply::Error(format!("worker {idx} failed: {msg}")), None);
                }
            }
        }
    }

    fn pick(
        &self,
        req: &Request,
        affinity: Option<usize>,
        excluded: &[usize],
    ) -> Option<(usize, u64, SocketAddr, Arc<AtomicUsize>)> {
        let slots = self.slots.lock().unwrap();
        let usable = |i: usize, s: &Slot| {
            s.up
                && !excluded.contains(&i)
                && s.inflight.load(Ordering::SeqCst) < self.cfg.worker_inflight
        };
        if matches!(req, Request::Generate { .. }) {
            if let Some(i) = affinity {
                if let Some(s) = slots.get(i) {
                    if usable(i, s) {
                        return Some((i, s.gen, s.worker.addr, Arc::clone(&s.inflight)));
                    }
                }
            }
        }
        slots
            .iter()
            .enumerate()
            .filter(|(i, s)| usable(*i, s))
            .min_by_key(|(_, s)| s.inflight.load(Ordering::SeqCst))
            .map(|(i, s)| (i, s.gen, s.worker.addr, Arc::clone(&s.inflight)))
    }

    fn forward_once(
        &self,
        idx: usize,
        gen: u64,
        addr: SocketAddr,
        req: &Request,
        attempt: usize,
    ) -> Result<Reply, ForwardFail> {
        let mut conn = match self.checkout(idx, gen, addr) {
            Ok(c) => c,
            Err(e) => return Err(ForwardFail::Connect(e.to_string())),
        };
        // The dispatch span rides the wire as `trace/span` transport
        // metadata, so the worker's ingress root parents under it and a
        // redispatch shows up as a second `router.dispatch` child of
        // the same ingress span.
        let mut sp = trace::span("router.dispatch");
        sp.arg("worker", idx as u64);
        sp.arg("attempt", attempt as u64);
        let ctx = trace::Ctx { trace: sp.trace(), span: sp.id() };
        match conn.client.call_traced(req, ctx) {
            Ok(reply) => {
                self.checkin(conn);
                Ok(reply)
            }
            Err(e) => {
                let msg = e.to_string();
                sp.arg("error", msg.clone());
                Err(ForwardFail::MidOp(msg))
            }
        }
    }

    /// Answer a `trace` op fleet-wide: export the router's own page,
    /// poll each live worker for the same trace ids, and merge with one
    /// process lane per contributor. `ids` win over `last`, mirroring
    /// the single-process selection semantics.
    fn merged_trace(&self, ids: &[u64], last: usize) -> Reply {
        let keep: Vec<u64> = if ids.is_empty() {
            // "last K" is resolved against the *router's* completed
            // ring — the router saw every request, so its ring is the
            // fleet-wide notion of recency
            let done = trace::completed_ids();
            let skip = done.len().saturating_sub(last.max(1));
            done[skip..].to_vec()
        } else {
            ids.to_vec()
        };
        let own = trace::export_chrome(&trace::Selection { ids: keep.clone(), last: 1 });
        if keep.is_empty() {
            return Reply::Trace(own);
        }
        let addrs: Vec<(SocketAddr, bool)> = {
            let slots = self.slots.lock().unwrap();
            slots.iter().map(|s| (s.worker.addr, s.up)).collect()
        };
        let mut pages = vec![own];
        for (addr, up) in addrs {
            if !up {
                continue;
            }
            if let Ok(page) = Self::poll_trace(addr, &keep) {
                pages.push(page);
            }
        }
        Reply::Trace(trace::merge_chrome(&pages, &keep))
    }

    fn poll_trace(addr: SocketAddr, ids: &[u64]) -> crate::Result<Json> {
        let mut c = ServeClient::connect(addr)?;
        c.set_timeout(Duration::from_secs(2))?;
        c.trace_export(ids, 1)
    }

    fn checkout(&self, idx: usize, gen: u64, addr: SocketAddr) -> crate::Result<PooledConn> {
        {
            let mut pool = self.pool.lock().unwrap();
            if let Some(p) = pool.iter().position(|c| c.idx == idx && c.gen == gen) {
                return Ok(pool.swap_remove(p));
            }
        }
        let client = ServeClient::connect(addr)?;
        client.set_timeout(self.cfg.op_timeout)?;
        Ok(PooledConn { idx, gen, client })
    }

    fn checkin(&self, conn: PooledConn) {
        let mut pool = self.pool.lock().unwrap();
        let cap = self.cfg.workers * self.cfg.worker_inflight;
        if pool.len() < cap.max(4) {
            pool.push(conn);
        }
    }

    /// One supervisor tick: reap/restart crashed workers; when `probe`
    /// is set, also health-check live ones over the wire (a worker that
    /// fails `probe_strikes` consecutive pings is killed and replaced).
    pub fn supervise_tick(&self, probe: bool) {
        if self.is_draining() {
            return;
        }
        let mut dead: Vec<usize> = Vec::new();
        {
            let mut slots = self.slots.lock().unwrap();
            for (i, s) in slots.iter_mut().enumerate() {
                if s.worker.has_exited() {
                    if s.up {
                        logging::kv(
                            log::Level::Warn,
                            "fleet",
                            "worker_exit",
                            &[("worker", i.to_string())],
                        );
                    }
                    s.up = false;
                    dead.push(i);
                    continue;
                }
                if !probe {
                    continue;
                }
                match Self::probe_worker(s.worker.addr) {
                    Ok(()) => {
                        s.strikes = 0;
                        s.up = true;
                    }
                    Err(_) => {
                        s.strikes += 1;
                        if s.strikes >= self.cfg.probe_strikes {
                            logging::kv(
                                log::Level::Warn,
                                "fleet",
                                "worker_unresponsive",
                                &[
                                    ("worker", i.to_string()),
                                    ("strikes", s.strikes.to_string()),
                                ],
                            );
                            s.up = false;
                            s.worker.kill();
                            dead.push(i);
                        }
                    }
                }
            }
        }
        for i in dead {
            self.respawn(i);
        }
    }

    fn probe_worker(addr: SocketAddr) -> crate::Result<()> {
        let mut c = ServeClient::connect(addr)?;
        c.set_timeout(Duration::from_secs(2))?;
        c.ping()?;
        Ok(())
    }

    fn respawn(&self, idx: usize) {
        if self.is_draining() {
            return;
        }
        // boot outside the slots lock: packing a replacement takes real
        // time and the rest of the fleet keeps routing meanwhile
        match (self.spawner)(idx) {
            Ok(w) => {
                self.pool.lock().unwrap().retain(|c| c.idx != idx);
                let mut slots = self.slots.lock().unwrap();
                let s = &mut slots[idx];
                s.worker = w;
                s.gen += 1;
                s.up = true;
                s.strikes = 0;
                self.restarts.fetch_add(1, Ordering::Relaxed);
                logging::kv(
                    log::Level::Info,
                    "fleet",
                    "worker_restart",
                    &[("worker", idx.to_string()), ("gen", s.gen.to_string())],
                );
            }
            Err(e) => logging::kv(
                log::Level::Warn,
                "fleet",
                "worker_respawn_failed",
                &[("worker", idx.to_string()), ("error", e.to_string())],
            ),
        }
    }

    /// Drain-phase teardown: politely ask every worker to shut down
    /// (each drains its own scheduler), then reap with a bounded grace.
    /// The supervisor must already be stopped or it would respawn them.
    pub(super) fn shutdown_workers(&self, grace: Duration) {
        let mut slots = self.slots.lock().unwrap();
        for s in slots.iter_mut() {
            s.up = false;
            if let Ok(mut c) = ServeClient::connect(s.worker.addr) {
                let _ = c.set_timeout(Duration::from_secs(2));
                let _ = c.shutdown();
            }
        }
        for (i, s) in slots.iter_mut().enumerate() {
            if !s.worker.reap(grace) {
                log::warn!("fleet worker {i} did not exit within {grace:?}; killed");
            }
        }
    }

    /// Poll every worker's `stats` op for the scrape page. Unreachable
    /// workers yield `None` — the page stays scrapable throughout a
    /// crash/restart window.
    fn snapshot_workers(&self) -> Vec<WorkerSnap> {
        let metas: Vec<(SocketAddr, bool, usize)> = {
            let slots = self.slots.lock().unwrap();
            slots
                .iter()
                .map(|s| (s.worker.addr, s.up, s.inflight.load(Ordering::SeqCst)))
                .collect()
        };
        metas
            .into_iter()
            .map(|(addr, up, inflight)| {
                let stats = if up { Self::poll_stats(addr).ok() } else { None };
                WorkerSnap { up, inflight, stats }
            })
            .collect()
    }

    fn poll_stats(addr: SocketAddr) -> crate::Result<Json> {
        let mut c = ServeClient::connect(addr)?;
        c.set_timeout(Duration::from_secs(2))?;
        c.stats()
    }
}

struct WorkerSnap {
    up: bool,
    inflight: usize,
    stats: Option<Json>,
}

fn stat_f64(stats: &Json, key: &str) -> Option<f64> {
    stats.get(key).and_then(|v| v.as_f64())
}

/// Emit one per-worker family (`worker="<idx>"` label), skipping
/// workers whose extractor has nothing (e.g. stats poll failed).
fn worker_family(
    w: &mut PromWriter,
    name: &str,
    help: &str,
    kind: PromKind,
    snaps: &[WorkerSnap],
    get: impl Fn(usize, &WorkerSnap) -> Option<f64>,
) {
    let samples: Vec<(usize, f64)> = snaps
        .iter()
        .enumerate()
        .filter_map(|(i, s)| get(i, s).map(|v| (i, v)))
        .collect();
    if samples.is_empty() {
        return;
    }
    w.metric(name, help, kind);
    for (i, v) in samples {
        let idx = i.to_string();
        w.sample(name, &[("worker", &idx)], v);
    }
}

impl OpExecutor for FleetRouter {
    fn execute(&self, req: &Request) -> Reply {
        // stateless ingress (HTTP): no connection to pin affinity to
        self.route_with_affinity(req, None).0
    }

    fn has_generator(&self) -> bool {
        // fleet workers always serve a packed artifact, which carries
        // the full generate path
        true
    }

    fn metrics_page(&self, http: &HttpStats, gate: &Gate, draining: bool) -> String {
        let snaps = self.snapshot_workers();
        let up = snaps.iter().filter(|s| s.up).count();
        let mut w = PromWriter::new();

        w.metric("sparselm_fleet_workers", "Configured fleet size", PromKind::Gauge);
        w.sample("sparselm_fleet_workers", &[], snaps.len() as f64);
        w.metric(
            "sparselm_fleet_workers_up",
            "Workers currently believed healthy",
            PromKind::Gauge,
        );
        w.sample("sparselm_fleet_workers_up", &[], up as f64);
        w.metric(
            "sparselm_fleet_inflight",
            "Ops currently forwarded and awaiting a worker reply",
            PromKind::Gauge,
        );
        w.sample("sparselm_fleet_inflight", &[], self.total_inflight() as f64);
        w.metric(
            "sparselm_fleet_requests_total",
            "Requests admitted by the router (all ingresses)",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_fleet_requests_total",
            &[],
            self.requests.load(Ordering::Relaxed) as f64,
        );
        w.metric(
            "sparselm_fleet_request_errors_total",
            "Malformed requests answered with an error reply",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_fleet_request_errors_total",
            &[],
            self.parse_errors.load(Ordering::Relaxed) as f64,
        );
        w.metric(
            "sparselm_fleet_forwarded_total",
            "Ops answered by a worker",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_fleet_forwarded_total",
            &[],
            self.forwarded.load(Ordering::Relaxed) as f64,
        );
        w.metric(
            "sparselm_fleet_redispatches_total",
            "Ops retried on another worker after a failure",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_fleet_redispatches_total",
            &[],
            self.redispatched.load(Ordering::Relaxed) as f64,
        );
        w.metric(
            "sparselm_fleet_rejected_total",
            "Ops refused because every worker was saturated",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_fleet_rejected_total",
            &[],
            self.rejected.load(Ordering::Relaxed) as f64,
        );
        w.metric(
            "sparselm_fleet_restarts_total",
            "Workers respawned after a crash or failed health checks",
            PromKind::Counter,
        );
        w.sample(
            "sparselm_fleet_restarts_total",
            &[],
            self.restarts.load(Ordering::Relaxed) as f64,
        );

        worker_family(
            &mut w,
            "sparselm_fleet_worker_up",
            "Per-worker health (1 = routable)",
            PromKind::Gauge,
            &snaps,
            |_, s| Some(if s.up { 1.0 } else { 0.0 }),
        );
        worker_family(
            &mut w,
            "sparselm_fleet_worker_inflight",
            "Ops in flight against each worker",
            PromKind::Gauge,
            &snaps,
            |_, s| Some(s.inflight as f64),
        );
        worker_family(
            &mut w,
            "sparselm_fleet_worker_requests_total",
            "Requests served by each worker (its own counter)",
            PromKind::Counter,
            &snaps,
            |_, s| s.stats.as_ref().and_then(|j| stat_f64(j, "requests")),
        );
        worker_family(
            &mut w,
            "sparselm_fleet_worker_errors_total",
            "Error replies issued by each worker",
            PromKind::Counter,
            &snaps,
            |_, s| s.stats.as_ref().and_then(|j| stat_f64(j, "errors")),
        );
        worker_family(
            &mut w,
            "sparselm_fleet_worker_score_queue_depth",
            "Scoring requests queued inside each worker",
            PromKind::Gauge,
            &snaps,
            |_, s| s.stats.as_ref().and_then(|j| stat_f64(j, "queue_depth")),
        );
        worker_family(
            &mut w,
            "sparselm_fleet_worker_gen_queue_depth",
            "Generate requests queued inside each worker",
            PromKind::Gauge,
            &snaps,
            |_, s| s.stats.as_ref().and_then(|j| stat_f64(j, "gen_queue_depth")),
        );
        worker_family(
            &mut w,
            "sparselm_fleet_worker_tokens_generated_total",
            "Tokens generated by each worker",
            PromKind::Counter,
            &snaps,
            |_, s| s.stats.as_ref().and_then(|j| stat_f64(j, "tokens_generated")),
        );

        crate::serve::http::metrics::render_http_families(&mut w, http, gate, draining);
        w.finish()
    }
}
