//! `serve::fleet` — a router process supervising K single-process
//! workers behind one ingress.
//!
//! ```text
//!                      ┌────────────┐ line protocol ┌───────────────┐
//!  TCP  ──┐            │            │ ─────────────▶│ worker 0      │
//!         ├──▶ gate ──▶│ FleetRouter│ ─────────────▶│ worker 1      │
//!  HTTP ──┘            │            │      …        │ …  (K procs)  │
//!                      └────────────┘               └───────────────┘
//! ```
//!
//! Every worker is a full `sparselm serve` equivalent (own
//! [`GenScheduler`], KV arena, perf counters) mmap-ing the *same*
//! `.spak`, so K workers cost roughly one copy of the weights in
//! physical memory plus K copies of the activation state. The router
//! holds no model state: ops fan out over the existing line protocol
//! with least-inflight placement, generate streams stick to the worker
//! that holds their warm KV arena, and idempotent ops (score / choice /
//! ping / stats) transparently redispatch when a worker dies mid-op.
//! Non-idempotent failures surface as explicit error replies — an
//! accepted request is never silently dropped.
//!
//! Teardown ordering (see [`FleetHandle::shutdown`]): stop admitting →
//! wait for in-flight ops (bounded by `drain_grace`) → stop the
//! supervisor → ask each worker to drain and exit, reap with
//! `reap_grace` → join the acceptor. A SIGTERM against the router walks
//! the same path, so workers are never orphaned.
//!
//! [`GenScheduler`]: crate::serve::generate::GenScheduler

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

mod router;
mod worker;

pub use router::FleetRouter;
pub use worker::{process_spawner, READY_PREFIX, Spawner, Worker};

use super::ops::{Reply, Request};
use crate::util::{logging, trace};

/// Fleet topology and timing knobs.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Router TCP bind address (workers bind their own OS-assigned
    /// ports on loopback).
    pub addr: String,
    /// Number of worker processes (K).
    pub workers: usize,
    /// Router-side concurrent client connection cap.
    pub max_conns: usize,
    /// Per-worker in-flight op cap; with every worker at this cap the
    /// fleet is saturated and new ops are rejected (TCP: typed error
    /// reply; HTTP: the gate's 429).
    pub worker_inflight: usize,
    /// Socket timeout on forwarded ops (generous — a full generate on a
    /// debug-build worker is slow).
    pub op_timeout: Duration,
    /// Supervisor tick (crash detection via `try_wait`).
    pub health_interval: Duration,
    /// How often the supervisor also pings workers over the wire.
    pub probe_interval: Duration,
    /// Consecutive failed pings before a live-but-wedged worker is
    /// killed and replaced.
    pub probe_strikes: u32,
    /// How long a worker gets to print its readiness handshake.
    pub boot_timeout: Duration,
    /// Drain phase: how long shutdown waits for in-flight ops.
    pub drain_grace: Duration,
    /// Reap phase: how long a worker gets to exit voluntarily after the
    /// shutdown op before it is killed.
    pub reap_grace: Duration,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            addr: "127.0.0.1:7433".into(),
            workers: 2,
            max_conns: 64,
            worker_inflight: 32,
            op_timeout: Duration::from_secs(120),
            health_interval: Duration::from_millis(200),
            probe_interval: Duration::from_secs(2),
            probe_strikes: 3,
            boot_timeout: Duration::from_secs(300),
            drain_grace: Duration::from_secs(5),
            reap_grace: Duration::from_secs(5),
        }
    }
}

/// A running fleet: TCP acceptor + supervisor + K workers.
pub struct FleetHandle {
    pub addr: SocketAddr,
    router: Arc<FleetRouter>,
    stop: Arc<AtomicBool>,
    drain_grace: Duration,
    reap_grace: Duration,
    stopped: AtomicBool,
    shutdown_lock: Mutex<()>,
    acceptor: Mutex<Option<JoinHandle<()>>>,
    supervisor: Mutex<Option<JoinHandle<()>>>,
}

/// Boot K workers (in parallel — cold-starting a worker costs real
/// time) and start the router's acceptor and supervisor threads.
pub fn start_fleet(cfg: FleetConfig, spawner: Spawner) -> crate::Result<FleetHandle> {
    anyhow::ensure!(cfg.workers >= 1, "a fleet needs at least one worker");
    trace::set_process_name("router");
    log::info!("booting fleet of {} workers", cfg.workers);
    let results: Vec<crate::Result<Worker>> = std::thread::scope(|scope| {
        let sp = &spawner;
        let joins: Vec<_> = (0..cfg.workers).map(|i| scope.spawn(move || sp(i))).collect();
        joins
            .into_iter()
            .map(|j| {
                j.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("worker boot thread panicked")))
            })
            .collect()
    });
    let mut workers = Vec::with_capacity(results.len());
    let mut failure: Option<anyhow::Error> = None;
    for r in results {
        match r {
            Ok(w) => workers.push(w),
            Err(e) => failure = Some(e),
        }
    }
    if let Some(e) = failure {
        // partial boot: kill what did come up rather than orphaning it
        for mut w in workers {
            w.kill();
        }
        return Err(e.context("fleet boot failed"));
    }

    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let drain_grace = cfg.drain_grace;
    let reap_grace = cfg.reap_grace;
    let health_interval = cfg.health_interval;
    let probe_interval = cfg.probe_interval;
    let max_conns = cfg.max_conns;
    let router = Arc::new(FleetRouter::new(cfg, spawner, workers));

    let supervisor = {
        let router = Arc::clone(&router);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last_probe = Instant::now();
            while !stop.load(Ordering::SeqCst) {
                let probe = last_probe.elapsed() >= probe_interval;
                if probe {
                    last_probe = Instant::now();
                }
                router.supervise_tick(probe);
                std::thread::sleep(health_interval);
            }
        })
    };

    let acceptor = {
        let router = Arc::clone(&router);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let live: Mutex<Vec<JoinHandle<()>>> = Mutex::new(Vec::new());
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                {
                    let mut v = live.lock().unwrap();
                    v.retain(|h| !h.is_finished());
                    if v.len() >= max_conns {
                        let _ = respond(
                            &stream,
                            &Reply::Error("fleet at connection capacity".into()),
                        );
                        continue;
                    }
                }
                let router2 = Arc::clone(&router);
                let stop2 = Arc::clone(&stop);
                let h = std::thread::spawn(move || handle_conn(stream, &router2, &stop2));
                live.lock().unwrap().push(h);
            }
            for h in live.lock().unwrap().drain(..) {
                let _ = h.join();
            }
        })
    };

    Ok(FleetHandle {
        addr,
        router,
        stop,
        drain_grace,
        reap_grace,
        stopped: AtomicBool::new(false),
        shutdown_lock: Mutex::new(()),
        acceptor: Mutex::new(Some(acceptor)),
        supervisor: Mutex::new(Some(supervisor)),
    })
}

impl FleetHandle {
    /// The router as an executor — hand this to
    /// [`super::http::serve_http`] to put the HTTP front end (with its
    /// admission gate and 429s) in front of the fleet.
    pub fn router(&self) -> Arc<FleetRouter> {
        Arc::clone(&self.router)
    }

    pub fn workers(&self) -> usize {
        self.router.workers()
    }

    pub fn worker_addrs(&self) -> Vec<SocketAddr> {
        self.router.worker_addrs()
    }

    pub fn worker_pids(&self) -> Vec<Option<u32>> {
        self.router.worker_pids()
    }

    /// Chaos hook: SIGKILL worker `idx` (the supervisor restarts it).
    pub fn kill_worker(&self, idx: usize) -> bool {
        self.router.kill_worker(idx)
    }

    pub fn restarts(&self) -> u64 {
        self.router.restarts()
    }

    /// Block until a client `shutdown` op (or [`FleetHandle::shutdown`]
    /// from another thread) stops the fleet, then run the drain.
    pub fn join(&self) -> crate::Result<()> {
        while !self.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(100));
        }
        self.shutdown()
    }

    /// Graceful fleet-wide drain. Ordering matters: admission stops
    /// first, in-flight ops get `drain_grace` to finish, the supervisor
    /// stops *before* workers are reaped (or it would respawn them),
    /// and only then are children asked to exit and reaped. Idempotent;
    /// concurrent callers block until the first drain completes.
    pub fn shutdown(&self) -> crate::Result<()> {
        let _g = self.shutdown_lock.lock().unwrap();
        if self.stopped.load(Ordering::SeqCst) {
            return Ok(());
        }

        // 1. stop admitting new ops
        self.router.begin_drain();

        // 2. bounded wait for in-flight ops to complete
        let deadline = Instant::now() + self.drain_grace;
        while self.router.total_inflight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }

        // 3. stop the supervisor so it cannot resurrect drained workers
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.supervisor.lock().unwrap().take() {
            let _ = h.join();
        }

        // 4. drain and reap every child — never orphan a worker
        self.router.shutdown_workers(self.reap_grace);

        // 5. unblock and join the acceptor (conn handlers see `stop`)
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.lock().unwrap().take() {
            let _ = h.join();
        }

        self.stopped.store(true, Ordering::SeqCst);
        Ok(())
    }
}

fn respond(mut stream: &TcpStream, reply: &Reply) -> std::io::Result<()> {
    let mut line = reply.to_json().to_string();
    line.push('\n');
    stream.write_all(line.as_bytes())
}

/// Per-connection loop for the fleet's TCP ingress — the same line
/// protocol as a single server, with per-connection generate affinity.
fn handle_conn(stream: TcpStream, router: &FleetRouter, stop: &AtomicBool) {
    if stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .is_err()
    {
        return;
    }
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut buf = String::new();
    let mut affinity: Option<usize> = None;
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_line(&mut buf) {
            Ok(0) => break,
            Ok(_) if buf.ends_with('\n') => {}
            Ok(_) => continue,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        }
        let line = std::mem::take(&mut buf);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let reply = match Request::parse_traced(line) {
            Err(e) => {
                router.note_parse_error();
                Reply::Error(e)
            }
            Ok((Request::Shutdown, _)) => {
                // lifecycle op, owned by the ingress: acknowledge, then
                // let join()/shutdown() run the fleet-wide drain
                let _ = respond(&stream, &Reply::ShuttingDown);
                stop.store(true, Ordering::SeqCst);
                return;
            }
            Ok((req, wire)) => {
                let trace_id = if wire.active() { wire.trace } else { trace::mint_id() };
                let sticky = matches!(req, Request::Generate { .. });
                let t0 = Instant::now();
                let (reply, used) = {
                    let mut root = trace::root("ingress.tcp", trace_id, wire.span);
                    root.arg("op", req.op());
                    let _in_req = trace::scope(trace::Ctx {
                        trace: root.trace(),
                        span: root.id(),
                    });
                    router.route_with_affinity(&req, if sticky { affinity } else { None })
                };
                let ms = t0.elapsed().as_millis() as u64;
                if ms >= trace::slow_ms() {
                    logging::kv(
                        log::Level::Warn,
                        "fleet",
                        "slow_request",
                        &[
                            ("trace", trace::id_hex(trace_id)),
                            ("op", req.op().to_string()),
                            ("ms", ms.to_string()),
                        ],
                    );
                }
                if sticky {
                    affinity = used;
                }
                reply
            }
        };
        if respond(&stream, &reply).is_err() {
            break;
        }
    }
}
