//! Compatibility surface for the wire protocol, which now lives in
//! [`super::ops`] as the typed `Request`/`Reply` vocabulary shared by
//! the TCP handler, the HTTP router and the fleet router.
//!
//! Existing call sites (and external readers of the protocol docs)
//! keep working through these re-exports; new code should import from
//! [`super::ops`] directly.

pub use super::ops::{Reply, Request};

/// Former name of [`Reply`], kept so the server/client/test call sites
/// that predate the typed-ops split keep compiling unchanged.
pub type Response = Reply;
