//! TCP front end: newline-delimited JSON requests, dynamically batched
//! model scoring **and KV-cached generation** behind them.
//!
//! Layout: one acceptor thread, one OS thread per connection (bounded by
//! `max_conns`), one scoring thread owning the scorer state and draining
//! the [`Batcher`], and — when a generation engine is supplied via
//! [`serve_generate`] — one decode thread owning the KV caches and
//! draining the continuous-batching [`GenScheduler`]. The server takes
//! **factories**: `Send` closures invoked *on* their worker thread to
//! build the scorer / decode engine (PJRT handles are `!Send` — the
//! `xla` crate wraps `Rc`s over C pointers — and the factory pattern
//! also lets tests pass fakes). Production factories: [`spmm_scorer`] +
//! [`spmm_generator`] share one packed model via `Arc` (offline, the
//! default deployment); [`pjrt_scorer`] serves HLO artifacts through
//! PJRT (`--features xla`, scoring only). Shutdown is cooperative:
//! `{"op":"shutdown"}` (or [`ServerHandle::shutdown`]) closes both
//! queues, unblocks the acceptor and joins every thread.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::batcher::{Batcher, BatcherConfig, ScoreRequest};
use super::generate::{DecodeEngine, GenScheduler, SpecEngine, SpmmEngine};
use super::protocol::{Request, Response};
use super::service::Service;
use crate::data::batch::pack_windows;
use crate::data::Tokenizer;
use crate::util::{logging, trace};

/// Server tuning.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// bind address; use port 0 to let the OS pick (tests)
    pub addr: String,
    /// max simultaneous connections
    pub max_conns: usize,
    /// PJRT batch rows coalesced per scoring call (the model's batch dim)
    pub max_batch: usize,
    /// batching deadline (see [`BatcherConfig::max_wait`])
    pub max_wait: Duration,
    /// hard cap on per-request `max_tokens` (generation)
    pub max_gen_tokens: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7433".into(),
            max_conns: 32,
            max_batch: 4,
            max_wait: Duration::from_millis(15),
            max_gen_tokens: 512,
        }
    }
}

/// Live server counters (exposed by `{"op":"stats"}`).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub nll_ops: AtomicU64,
    pub choice_ops: AtomicU64,
    pub generate_ops: AtomicU64,
}

/// Handle returned by [`serve`] / [`serve_generate`]: join or stop the
/// server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    batcher: Arc<Batcher>,
    generator: Option<Arc<GenScheduler>>,
    service: Arc<Service>,
    threads: Vec<JoinHandle<()>>,
    scorer: Option<JoinHandle<crate::Result<()>>>,
    gen_thread: Option<JoinHandle<crate::Result<()>>>,
    pub stats: Arc<ServerStats>,
}

impl ServerHandle {
    fn close_workers(&self) {
        self.batcher.close();
        if let Some(g) = &self.generator {
            g.close();
        }
    }

    fn join_workers(&mut self) -> crate::Result<()> {
        let mut first_err = None;
        if let Some(s) = self.scorer.take() {
            match s.join() {
                Ok(r) => {
                    if let Err(e) = r {
                        first_err.get_or_insert(e);
                    }
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow::anyhow!("scorer panicked"));
                }
            }
        }
        if let Some(g) = self.gen_thread.take() {
            match g.join() {
                Ok(r) => {
                    if let Err(e) = r {
                        first_err.get_or_insert(e);
                    }
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow::anyhow!("decode engine panicked"));
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Signal shutdown and join all threads.
    pub fn shutdown(mut self) -> crate::Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        self.close_workers();
        // poke the acceptor out of accept()
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.join_workers()
    }

    /// Block until the scoring thread exits (e.g. after a client sent
    /// `shutdown`), then stop and join the rest. A scorer error is
    /// reported only *after* the acceptor, connection and decode
    /// threads are stopped — an early return here would leak a live
    /// half-broken server (bound port, running threads) into the
    /// embedding process.
    pub fn join(mut self) -> crate::Result<()> {
        let mut first_err = None;
        if let Some(s) = self.scorer.take() {
            match s.join() {
                Ok(Err(e)) => {
                    first_err = Some(e);
                }
                Err(_) => {
                    first_err = Some(anyhow::anyhow!("scorer panicked"));
                }
                Ok(Ok(())) => {}
            }
        }
        self.stop.store(true, Ordering::SeqCst);
        self.close_workers();
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        if let Some(g) = self.gen_thread.take() {
            match g.join() {
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow::anyhow!("decode engine panicked"));
                }
                Ok(Ok(())) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    pub fn batcher_stats(&self) -> super::batcher::BatcherStats {
        self.batcher.stats()
    }

    /// The transport-independent op executor this server runs on —
    /// hand it to [`super::http::serve_http`] (or use
    /// [`ServerHandle::attach_http`]) to expose the same model over
    /// HTTP.
    pub fn service(&self) -> Arc<Service> {
        Arc::clone(&self.service)
    }

    /// Start an HTTP/1.1 front end over this server's [`Service`]. The
    /// returned handle has its own lifecycle: drain/shut it down before
    /// (or after) this TCP handle — the two ingresses share workers but
    /// not sockets.
    pub fn attach_http(
        &self,
        cfg: super::http::HttpConfig,
    ) -> crate::Result<super::http::HttpHandle> {
        super::http::serve_http(self.service(), cfg)
    }

    /// Continuous-batching generation counters (empty default when the
    /// server was started without a generation engine).
    pub fn gen_stats(&self) -> super::generate::GenStats {
        self.generator
            .as_ref()
            .map(|g| g.stats())
            .unwrap_or_default()
    }
}

/// A batch scorer: rows in arrival order → per-row `(sum_nll, tokens)`.
pub type Scorer = Box<dyn FnMut(&[ScoreRequest]) -> crate::Result<Vec<(f64, usize)>>>;

/// Reduce a `(B, S)` NLL tensor + scored-position mask back to per-row
/// `(sum_nll, scored_tokens)` for the first `n` rows.
fn rows_from_nll(
    nll: &crate::tensor::Tensor,
    mask: &[f32],
    n: usize,
    s: usize,
) -> Vec<(f64, usize)> {
    (0..n)
        .map(|r| {
            let row = &nll.data()[r * s..(r + 1) * s];
            let mrow = &mask[r * s..(r + 1) * s];
            let sum: f64 = row
                .iter()
                .zip(mrow)
                .map(|(&n_, &m)| n_ as f64 * m as f64)
                .sum();
            let count = mrow.iter().filter(|&&m| m != 0.0).count();
            (sum, count)
        })
        .collect()
}

/// PJRT scorer factory: builds the engine, loads `config_name` artifacts,
/// uploads `params`, and scores via the `lm_nll` executable. Invoke
/// *inside* the scoring thread (PJRT is thread-bound). Requires the real
/// xla backend (`--features xla`); under the offline stub every scoring
/// call reports the stub's execution error.
pub fn pjrt_scorer(
    artifacts: String,
    config_name: String,
    params: crate::model::ParamSet,
) -> impl FnOnce() -> crate::Result<Scorer> + Send {
    move || {
        let engine = Arc::new(crate::runtime::Engine::new(&artifacts)?);
        let exec = crate::coordinator::ModelExec::new(engine, &config_name)?;
        let lits = exec.upload(&params)?;
        let (b, s) = (exec.config.batch, exec.config.seq);
        Ok(Box::new(move |reqs: &[ScoreRequest]| {
            let items: Vec<(Vec<i32>, usize)> = reqs
                .iter()
                .map(|r| (r.tokens.clone(), r.scored_from))
                .collect();
            let (ids, mask) = pack_windows(&items, b, s);
            let nll = exec.lm_nll(&lits, &ids)?;
            Ok(rows_from_nll(&nll, &mask, reqs.len(), s))
        }) as Scorer)
    }
}

/// Decode-free packed scorer factory: every request is scored by the
/// host forward ([`crate::model::SparseLm`]), whose linear layers apply
/// packed N:M + structured-outlier weights directly via
/// [`crate::sparse::spmm_parallel()`] — weights stay packed end-to-end
/// (tokens → batcher → packed spmm → logits → NLL), no PJRT, no
/// artifacts, fully offline. Takes an `Arc` so the same packed weights
/// can back the generation engine ([`spmm_generator`]) without a copy.
pub fn spmm_scorer(
    model: Arc<crate::model::SparseLm>,
) -> impl FnOnce() -> crate::Result<Scorer> + Send {
    move || {
        let (b, s) = (model.config.batch, model.config.seq);
        Ok(Box::new(move |reqs: &[ScoreRequest]| {
            let items: Vec<(Vec<i32>, usize)> = reqs
                .iter()
                .map(|r| (r.tokens.clone(), r.scored_from))
                .collect();
            let (ids, mask) = pack_windows(&items, b, s);
            let nll = model.lm_nll(&ids)?;
            Ok(rows_from_nll(&nll, &mask, reqs.len(), s))
        }) as Scorer)
    }
}

/// A boxed decode engine, built on the decode thread.
pub type GenEngine = Box<dyn DecodeEngine>;

/// Continuous-batching generation engine over the same packed model the
/// scorer serves: per-slot KV caches, prefill on admission, shared
/// decode steps ([`SpmmEngine`]). `max_seqs` bounds the decode batch.
pub fn spmm_generator(
    model: Arc<crate::model::SparseLm>,
    max_seqs: usize,
) -> impl FnOnce() -> crate::Result<GenEngine> + Send {
    move || Ok(Box::new(SpmmEngine::new(model, max_seqs)) as GenEngine)
}

/// Self-speculative generation engine: int4 draft + bf16 verify behind
/// the same [`GenScheduler`] interface ([`SpecEngine`]). Emits the same
/// token stream as [`spmm_generator`] over the decoder's target model —
/// speculation only changes latency, never output.
pub fn spec_generator(
    spec: Arc<crate::model::SpecDecoder>,
    max_seqs: usize,
) -> impl FnOnce() -> crate::Result<GenEngine> + Send {
    move || Ok(Box::new(SpecEngine::new(spec, max_seqs)) as GenEngine)
}

/// Start a scoring-only server (`generate` requests answer with a
/// typed error). `factory` runs on the scoring thread; returns after
/// the socket is bound **and** the factory succeeded (its error is
/// propagated here otherwise).
pub fn serve(
    factory: impl FnOnce() -> crate::Result<Scorer> + Send + 'static,
    tokenizer: Arc<Tokenizer>,
    cfg: ServerConfig,
) -> crate::Result<ServerHandle> {
    serve_inner(factory, None, tokenizer, cfg)
}

/// Start a server with both scoring **and** KV-cached generation: the
/// scorer factory feeds the [`Batcher`] thread, the engine factory
/// feeds the continuous-batching [`GenScheduler`] thread, and both run
/// concurrently over their own queues (an `Arc`-shared model makes the
/// weights common; see [`spmm_scorer`] / [`spmm_generator`]).
pub fn serve_generate(
    factory: impl FnOnce() -> crate::Result<Scorer> + Send + 'static,
    gen_factory: impl FnOnce() -> crate::Result<GenEngine> + Send + 'static,
    tokenizer: Arc<Tokenizer>,
    cfg: ServerConfig,
) -> crate::Result<ServerHandle> {
    serve_inner(factory, Some(Box::new(gen_factory)), tokenizer, cfg)
}

type BoxedGenFactory = Box<dyn FnOnce() -> crate::Result<GenEngine> + Send>;

fn serve_inner(
    factory: impl FnOnce() -> crate::Result<Scorer> + Send + 'static,
    gen_factory: Option<BoxedGenFactory>,
    tokenizer: Arc<Tokenizer>,
    cfg: ServerConfig,
) -> crate::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stats = Arc::new(ServerStats::default());
    let batcher = Arc::new(Batcher::new(BatcherConfig {
        max_batch: cfg.max_batch,
        max_wait: cfg.max_wait,
    }));

    // ---- scoring thread: builds PJRT state, drains the batcher --------
    let (ready_tx, ready_rx) = sync_channel::<crate::Result<()>>(1);
    let scorer_thread = {
        let batcher = Arc::clone(&batcher);
        std::thread::spawn(move || -> crate::Result<()> {
            let mut scorer = match factory() {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                    return Err(e);
                }
            };
            batcher.run(move |reqs| scorer(reqs))
        })
    };
    if let Err(e) = ready_rx
        .recv()
        .map_err(|_| anyhow::anyhow!("scorer thread died during startup"))?
    {
        let _ = scorer_thread.join();
        return Err(e);
    }

    // ---- decode thread: builds the engine, drains the scheduler -------
    let (generator, gen_thread) = match gen_factory {
        None => (None, None),
        Some(build) => {
            let sched = Arc::new(GenScheduler::new());
            let (gready_tx, gready_rx) = sync_channel::<crate::Result<()>>(1);
            let thread = {
                let sched = Arc::clone(&sched);
                let batcher = Arc::clone(&batcher);
                std::thread::spawn(move || -> crate::Result<()> {
                    let engine = match build() {
                        Ok(e) => {
                            let _ = gready_tx.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = gready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                            return Err(e);
                        }
                    };
                    let r = sched.run(engine);
                    if r.is_err() {
                        // a dead decode engine must take the server down
                        // observably, exactly like a dead scorer does:
                        // closing the batcher lets the scoring thread
                        // exit so ServerHandle::join() unblocks and
                        // surfaces this error instead of serving broken
                        // generation forever
                        batcher.close();
                    }
                    r
                })
            };
            // a factory panic drops gready_tx without sending: treat it
            // like a factory error and tear down the scoring thread too,
            // instead of leaking it blocked on the batcher condvar
            let ready = gready_rx
                .recv()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("decode thread died during startup")));
            if let Err(e) = ready {
                batcher.close();
                let _ = scorer_thread.join();
                let _ = thread.join();
                return Err(e);
            }
            (Some(sched), Some(thread))
        }
    };

    // ---- the shared op executor ---------------------------------------
    let service = Arc::new(Service::new(
        Arc::clone(&batcher),
        generator.clone(),
        tokenizer,
        Arc::clone(&stats),
        cfg.max_gen_tokens,
    ));

    // ---- acceptor + per-connection threads ----------------------------
    let acceptor = {
        let stop = Arc::clone(&stop);
        let stats = Arc::clone(&stats);
        let service = Arc::clone(&service);
        let max_conns = cfg.max_conns;
        std::thread::spawn(move || {
            let live = Arc::new(Mutex::new(Vec::<JoinHandle<()>>::new()));
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // reap finished handlers; enforce the connection cap
                {
                    let mut v = live.lock().unwrap();
                    v.retain(|h| !h.is_finished());
                    if v.len() >= max_conns {
                        let _ = respond(
                            &stream,
                            &Response::Error("server at connection capacity".into()),
                        );
                        continue;
                    }
                }
                stats.connections.fetch_add(1, Ordering::Relaxed);
                let stop2 = Arc::clone(&stop);
                let service2 = Arc::clone(&service);
                let h = std::thread::spawn(move || handle_conn(stream, &stop2, &service2));
                live.lock().unwrap().push(h);
            }
            for h in live.lock().unwrap().drain(..) {
                let _ = h.join();
            }
        })
    };

    Ok(ServerHandle {
        addr,
        stop,
        batcher,
        generator,
        service,
        threads: vec![acceptor],
        scorer: Some(scorer_thread),
        gen_thread,
        stats,
    })
}

fn respond(mut stream: &TcpStream, resp: &Response) -> std::io::Result<()> {
    let mut line = resp.to_json().to_string();
    line.push('\n');
    stream.write_all(line.as_bytes())
}

fn handle_conn(stream: TcpStream, stop: &AtomicBool, service: &Service) {
    let stats = service.server_stats();
    // read with a timeout so the handler notices `stop` even while the
    // client keeps the connection open — shutdown() joins these threads
    if stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .is_err()
    {
        return;
    }
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut buf = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // read_line appends: a timeout mid-line keeps the partial prefix
        // in `buf` and the next pass completes it
        match reader.read_line(&mut buf) {
            Ok(0) => break, // EOF
            Ok(_) if buf.ends_with('\n') => {}
            Ok(_) => continue, // partial line before EOF-less timeout
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        }
        let line = std::mem::take(&mut buf);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let resp = match Request::parse_traced(line) {
            Err(e) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                Response::Error(e)
            }
            Ok((Request::Shutdown, _)) => {
                // lifecycle op: tear down here, where the sockets and
                // worker queues are owned — not in Service::execute
                let _ = respond(&stream, &Response::ShuttingDown);
                stop.store(true, Ordering::SeqCst);
                service.close();
                return;
            }
            Ok((req, wire)) => {
                // a wire tag means an upstream hop (the fleet router)
                // already owns the trace: parent under its dispatch
                // span; otherwise this ingress mints the trace ID
                let trace_id = if wire.active() { wire.trace } else { trace::mint_id() };
                let t0 = std::time::Instant::now();
                let resp = {
                    let mut root = trace::root("ingress.tcp", trace_id, wire.span);
                    root.arg("op", req.op());
                    let _in_req = trace::scope(trace::Ctx {
                        trace: root.trace(),
                        span: root.id(),
                    });
                    service.execute(&req)
                };
                let ms = t0.elapsed().as_millis() as u64;
                if ms >= trace::slow_ms() {
                    logging::kv(
                        log::Level::Warn,
                        "serve::tcp",
                        "slow_request",
                        &[
                            ("trace", trace::id_hex(trace_id)),
                            ("op", req.op().to_string()),
                            ("ms", ms.to_string()),
                        ],
                    );
                }
                resp
            }
        };
        if respond(&stream, &resp).is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ServeClient;

    /// fake scorer: sum_nll = number of scored positions (so mean = 1)
    fn fake_factory() -> crate::Result<Scorer> {
        Ok(Box::new(|reqs: &[ScoreRequest]| {
            Ok(reqs
                .iter()
                .map(|r| {
                    let scored = r.tokens.len().saturating_sub(r.scored_from.max(1));
                    (scored as f64, scored)
                })
                .collect())
        }))
    }

    fn test_tokenizer() -> Arc<Tokenizer> {
        let text = "the quick brown fox jumps over the lazy dog . \
                    a stitch in time saves nine . all that glitters is not gold .";
        Arc::new(Tokenizer::fit(text, 256))
    }

    fn test_server() -> ServerHandle {
        serve(
            fake_factory,
            test_tokenizer(),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                max_conns: 8,
                max_batch: 3,
                max_wait: Duration::from_millis(3),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn ping_nll_choice_stats_roundtrip() {
        let h = test_server();
        let mut c = ServeClient::connect(h.addr).unwrap();
        assert!(c.ping().unwrap());
        let (mean, tokens) = c.nll("the quick brown fox").unwrap();
        assert!(tokens > 0);
        assert!((mean - 1.0).abs() < 1e-9, "fake scorer yields mean 1");
        let (_best, scores) = c
            .choice("the quick", &["brown fox", "lazy dog jumps"])
            .unwrap();
        assert_eq!(scores.len(), 2);
        let stats = c.stats().unwrap();
        assert!(stats.at("requests").as_f64().unwrap() >= 3.0);
        h.shutdown().unwrap();
    }

    #[test]
    fn malformed_requests_keep_connection_alive() {
        let h = test_server();
        let mut c = ServeClient::connect(h.addr).unwrap();
        for bad in ["garbage", "{}", "{\"op\":\"nope\"}"] {
            // a valid call works...
            let resp = c.call(&Request::Nll { text: "x".into() }).unwrap();
            assert!(!matches!(resp, Response::Error(_)), "{resp:?}");
            // ...and raw garbage yields an error, not a hangup
            let r = c.call_raw(bad).unwrap();
            assert!(matches!(r, Response::Error(_)), "{bad}");
        }
        assert!(c.ping().unwrap(), "connection survived the garbage");
        assert_eq!(h.stats.errors.load(Ordering::Relaxed), 3);
        h.shutdown().unwrap();
    }

    #[test]
    fn garbage_ops_and_fields_never_kill_a_worker() {
        // the request-path panic audit's regression net: every malformed
        // op/field shape a client can send must come back as a typed
        // error reply on a connection that keeps serving
        let h = test_server();
        let mut c = ServeClient::connect(h.addr).unwrap();
        let garbage = [
            // unknown / mistyped ops
            "{\"op\":\"frobnicate\"}",
            "{\"op\":5}",
            "{\"op\":null}",
            "[1,2,3]",
            "\"nll\"",
            // mistyped fields
            "{\"op\":\"nll\",\"text\":12}",
            "{\"op\":\"nll\",\"text\":{\"a\":1}}",
            "{\"op\":\"choice\",\"context\":\"c\",\"choices\":\"not-an-array\"}",
            "{\"op\":\"choice\",\"context\":\"c\",\"choices\":[1,2,\"x\"]}",
            "{\"op\":\"choice\",\"context\":7,\"choices\":[\"a\",\"b\"]}",
            "{\"op\":\"generate\",\"prompt\":[\"x\"]}",
            "{\"op\":\"generate\",\"prompt\":\"x\",\"max_tokens\":-4}",
            "{\"op\":\"generate\",\"prompt\":\"x\",\"temperature\":\"warm\"}",
            "{\"op\":\"generate\",\"prompt\":\"x\",\"seed\":1e300}",
            // structurally broken json
            "{\"op\":\"nll\",\"text\":\"x\"",
            "{\"op\": }",
        ];
        for bad in garbage {
            let r = c.call_raw(bad).unwrap_or_else(|e| panic!("{bad}: hangup ({e})"));
            assert!(matches!(r, Response::Error(_)), "{bad}: {r:?}");
        }
        // every one of them was counted, and the server still works
        assert_eq!(h.stats.errors.load(Ordering::Relaxed), garbage.len() as u64);
        assert!(c.ping().unwrap(), "connection survived all garbage");
        let (mean, _) = {
            let mut c2 = ServeClient::connect(h.addr).unwrap();
            c2.nll("the quick brown fox").unwrap()
        };
        assert!((mean - 1.0).abs() < 1e-9, "scoring path intact after abuse");
        h.shutdown().unwrap();
    }

    #[test]
    fn nan_scores_yield_a_reply_not_a_dead_connection() {
        // regression: `choice` ranked scores with partial_cmp().unwrap(),
        // so one NaN from the scorer panicked the connection's worker
        // thread and the client saw a hangup instead of a reply. The
        // sign-bit-set NaN here is the default x86 arithmetic NaN — it
        // sorts below -inf under total order, so this also pins the
        // rule that a degenerate score can never *win* the ranking.
        let h = serve(
            || {
                Ok(Box::new(|reqs: &[ScoreRequest]| {
                    // odd request ids score -NaN, even ids score 2.0
                    Ok(reqs
                        .iter()
                        .map(|r| (if r.id % 2 == 1 { -f64::NAN } else { 2.0 }, 1usize))
                        .collect())
                }) as Scorer)
            },
            test_tokenizer(),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                max_conns: 4,
                max_batch: 2,
                max_wait: Duration::from_millis(3),
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = ServeClient::connect(h.addr).unwrap();
        let r = c
            .call(&Request::Choice {
                context: "2+2 =".into(),
                choices: vec!["4".into(), "5".into()],
            })
            .expect("NaN scores must still produce a reply line");
        // candidate 0 got the -NaN (first id), candidate 1 the finite
        // score: the finite one must win
        match r {
            Response::Choice { best, ref scores, .. } => {
                assert_eq!(best, 1, "negative NaN must not win: {scores:?}");
                assert_eq!(scores.len(), 2);
            }
            other => panic!("want Choice, got {other:?}"),
        }
        assert!(c.ping().unwrap(), "connection survived NaN scores");
        h.shutdown().unwrap();
    }

    #[test]
    fn concurrent_clients_share_batches() {
        let h = test_server();
        let addr = h.addr;
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut c = ServeClient::connect(addr).unwrap();
                for _ in 0..5 {
                    let (mean, _) = c.nll("the quick brown fox jumps").unwrap();
                    assert!((mean - 1.0).abs() < 1e-9);
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        let bs = h.batcher_stats();
        assert_eq!(bs.rows_scored, 20);
        // dynamic batching actually coalesced concurrent traffic
        assert!(bs.batches < 20, "no batching happened: {bs:?}");
        h.shutdown().unwrap();
    }

    #[test]
    fn client_shutdown_op_stops_server() {
        let h = test_server();
        let mut c = ServeClient::connect(h.addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap();
    }

    /// fake decode engine: parrots token id 5 forever
    struct ParrotEngine;
    impl DecodeEngine for ParrotEngine {
        fn max_seqs(&self) -> usize {
            2
        }
        fn max_positions(&self) -> usize {
            32
        }
        fn start(&mut self, _slot: usize, _prompt: &[i32]) -> crate::Result<Vec<f32>> {
            let mut l = vec![0.0f32; 16];
            l[5] = 10.0;
            Ok(l)
        }
        fn step(&mut self, toks: &[(usize, i32)]) -> crate::Result<Vec<Vec<f32>>> {
            Ok(toks
                .iter()
                .map(|_| {
                    let mut l = vec![0.0f32; 16];
                    l[5] = 10.0;
                    l
                })
                .collect())
        }
        fn finish(&mut self, _slot: usize) {}
    }

    fn gen_test_server() -> ServerHandle {
        serve_generate(
            fake_factory,
            || Ok(Box::new(ParrotEngine) as GenEngine),
            test_tokenizer(),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                max_conns: 8,
                max_batch: 3,
                max_wait: Duration::from_millis(3),
                max_gen_tokens: 8,
            },
        )
        .unwrap()
    }

    #[test]
    fn generate_op_roundtrips_and_caps_tokens() {
        let h = gen_test_server();
        let mut c = ServeClient::connect(h.addr).unwrap();
        let (text, tokens) = c.generate("the quick brown", 100, 0.0).unwrap();
        // server caps 100 → max_gen_tokens = 8; parrot emits id 5 = "."
        assert_eq!(tokens, 8);
        assert!(!text.is_empty());
        let gs = h.gen_stats();
        assert_eq!(gs.completed, 1);
        assert_eq!(gs.tokens_generated, 8);
        assert!(!gs.batch_fill.is_empty());
        h.shutdown().unwrap();
    }

    #[test]
    fn generate_without_engine_is_a_protocol_error() {
        let h = test_server();
        let mut c = ServeClient::connect(h.addr).unwrap();
        let r = c
            .call(&Request::Generate {
                prompt: "x".into(),
                max_tokens: 4,
                temperature: 0.0,
                seed: 0,
            })
            .unwrap();
        assert!(matches!(r, Response::Error(_)), "{r:?}");
        // scoring still works on the same connection
        assert!(c.ping().unwrap());
        h.shutdown().unwrap();
    }

    #[test]
    fn stats_include_generation_counters() {
        let h = gen_test_server();
        let mut c = ServeClient::connect(h.addr).unwrap();
        c.generate("a b", 4, 0.0).unwrap();
        let stats = c.stats().unwrap();
        assert_eq!(stats.at("gen_completed").as_f64(), Some(1.0));
        assert!(stats.at("decode_steps").as_f64().unwrap() >= 1.0);
        assert!(stats.at("batch_fill").as_arr().is_some());
        // perf-telemetry fields threaded from GenScheduler
        assert!(stats.at("decode_nanos").as_f64().unwrap() >= 0.0);
        assert!(stats.at("prefill_nanos").as_f64().unwrap() > 0.0);
        assert!(stats.at("decode_p50_us").as_f64().is_some());
        assert!(stats.at("decode_p99_us").as_f64().is_some());
        h.shutdown().unwrap();
    }

    #[test]
    fn factory_failure_propagates() {
        let r = serve(
            || anyhow::bail!("no checkpoint"),
            test_tokenizer(),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                ..Default::default()
            },
        );
        assert!(r.is_err());
        assert!(format!("{:#}", r.err().unwrap()).contains("no checkpoint"));
    }
}
