//! Typed ops — the one validated request/reply vocabulary every
//! ingress executes.
//!
//! Requests (one JSON object per line on the TCP wire; the same bodies
//! minus `"op"` over HTTP):
//!
//! * `{"op":"ping"}` → `{"ok":true,"pong":true}`
//! * `{"op":"nll","text":"..."}` → mean/sum NLL of the text under the
//!   served model
//! * `{"op":"choice","context":"...","choices":["a","b",...]}` → the
//!   lm-eval-harness zero-shot protocol: rank continuations by summed
//!   log-likelihood, report the argmin-NLL choice
//! * `{"op":"generate","prompt":"...","max_tokens":32,"temperature":0.0,
//!   "seed":0}` → autoregressive continuation of the prompt through the
//!   KV-cached continuous-batching decode engine; `max_tokens`
//!   (default 32, capped server-side), `temperature` (default 0 =
//!   greedy) and `seed` (default 0, temperature sampling only) are
//!   optional. Replies with the generated `text`, token count, decode
//!   `steps` and the mean decode-batch fill the request observed
//! * `{"op":"stats"}` → server + batcher + generation counters:
//!   the per-step `batch_fill` histogram plus the decode-phase wall
//!   clocks (`prefill_nanos`, `decode_nanos` — monotone totals inside
//!   the engine) and the recent-window decode-step latency percentiles
//!   (`decode_p50_us`, `decode_p99_us`)
//! * `{"op":"trace","last":K}` or `{"op":"trace","ids":["<hex>",...]}`
//!   → Chrome trace-event JSON for the last K (default 1) completed
//!   request traces, or for explicit trace IDs, from the in-process
//!   flight recorder (`util::trace`). On a fleet router this merges the
//!   router's spans with every worker's under one page, one process
//!   lane each
//! * `{"op":"shutdown"}` → drain and stop (admin)
//!
//! Replies always carry `"ok"`; failures put a message in `"error"`
//! and never kill the connection.
//!
//! **Trace context on the wire.** A request line may carry one extra
//! transport-metadata field, `"trace":"<trace_hex>/<span_hex>"`, read
//! by [`Request::parse_traced`]. It is *not* part of the typed
//! [`Request`] (so [`Request::to_json`] never emits it and canonical
//! bytes are unchanged); the fleet router injects it when forwarding so
//! worker-side spans parent under the router's dispatch span, the same
//! way `X-Request-Id` rides an HTTP header rather than the body.
//!
//! Serialization is canonical by construction: [`Json`] objects sort
//! keys and print numbers deterministically, so
//! `Reply::from_json(reply.to_json()).to_json()` is **byte-identical**
//! to `reply.to_json()` — the property the fleet router relies on when
//! it re-serializes a worker's reply toward the client, and the one
//! the propcheck test below pins.
//!
//! [`OpExecutor`] is the seam the ingresses program against: the TCP
//! handler, the HTTP dispatcher and the fleet router all hold an
//! `Arc<dyn OpExecutor>`, so a single-process [`Service`] and a
//! multi-process [`FleetRouter`] are interchangeable behind the same
//! sockets.
//!
//! [`Service`]: super::service::Service
//! [`FleetRouter`]: super::fleet::FleetRouter

use super::http::{Gate, HttpStats};
use crate::util::json::Json;
use crate::util::trace;

/// Parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Nll { text: String },
    Choice { context: String, choices: Vec<String> },
    Generate {
        prompt: String,
        max_tokens: usize,
        temperature: f64,
        seed: u64,
    },
    Stats,
    /// Export traces from the flight recorder: explicit `ids` win;
    /// otherwise the most recent `last` completed traces.
    Trace { ids: Vec<u64>, last: usize },
    Shutdown,
}

impl Request {
    /// Parse one wire line (server side).
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        Request::from_json(&v)
    }

    /// Parse one wire line plus its optional `"trace"` transport tag
    /// (`"<trace_hex>/<span_hex>"`). A malformed tag is ignored rather
    /// than rejected — it is cross-process metadata, not client input,
    /// and a mixed-version fleet must keep answering.
    pub fn parse_traced(line: &str) -> Result<(Request, trace::Ctx), String> {
        let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        let ctx = v
            .get("trace")
            .and_then(|t| t.as_str())
            .and_then(parse_wire_tag)
            .unwrap_or(trace::Ctx::NONE);
        Ok((Request::from_json(&v)?, ctx))
    }

    /// Serialize with the `"trace"` transport tag attached (the fleet
    /// router's forwarding side of [`Request::parse_traced`]).
    pub fn to_json_traced(&self, ctx: trace::Ctx) -> Json {
        let j = self.to_json();
        if !ctx.active() {
            return j;
        }
        match j {
            Json::Obj(mut m) => {
                m.insert("trace".to_string(), Json::str(wire_tag(ctx)));
                Json::Obj(m)
            }
            other => other,
        }
    }

    /// Validate a parsed JSON object carrying an `"op"` field.
    pub fn from_json(v: &Json) -> Result<Request, String> {
        let op = v
            .get("op")
            .and_then(|o| o.as_str())
            .ok_or_else(|| "missing \"op\"".to_string())?;
        match op {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            "nll" => Request::nll_from_json(v),
            "choice" => Request::choice_from_json(v),
            "generate" => Request::generate_from_json(v),
            "trace" => Request::trace_from_json(v),
            other => Err(format!("unknown op {other:?}")),
        }
    }

    /// Validate a `trace` body (shared by the TCP op and
    /// `GET /debug/trace`). Present-but-mistyped fields are errors.
    pub fn trace_from_json(v: &Json) -> Result<Request, String> {
        let ids: Vec<u64> = match v.get("ids") {
            None => Vec::new(),
            Some(a) => a
                .as_arr()
                .ok_or_else(|| "ids must be an array".to_string())?
                .iter()
                .map(|x| {
                    x.as_str()
                        .and_then(trace::parse_hex)
                        .ok_or_else(|| "ids must be hex trace IDs".to_string())
                })
                .collect::<Result<_, _>>()?,
        };
        let last = match v.get("last") {
            None => 1,
            Some(l) => {
                let x = l
                    .as_f64()
                    .ok_or_else(|| "last must be a number".to_string())?;
                if x < 1.0 || x.fract() != 0.0 || x > 1024.0 {
                    return Err("last must be an integer in [1, 1024]".into());
                }
                x as usize
            }
        };
        // explicit ids win; normalize so serialization is canonical
        let last = if ids.is_empty() { last } else { 1 };
        Ok(Request::Trace { ids, last })
    }

    /// Validate an `nll` body (no `"op"` required — the HTTP router maps
    /// `POST /score` here, so both ingresses share one validator).
    pub fn nll_from_json(v: &Json) -> Result<Request, String> {
        let text = v
            .get("text")
            .and_then(|t| t.as_str())
            .ok_or_else(|| "nll needs \"text\"".to_string())?;
        if text.is_empty() {
            return Err("empty text".into());
        }
        Ok(Request::Nll { text: text.to_string() })
    }

    /// Validate a `choice` body (shared by the TCP op and `POST /score`
    /// with a `"choices"` field).
    pub fn choice_from_json(v: &Json) -> Result<Request, String> {
        let context = v
            .get("context")
            .and_then(|t| t.as_str())
            .ok_or_else(|| "choice needs \"context\"".to_string())?
            .to_string();
        // a non-string element is an error, not a silent drop —
        // otherwise the reply's indices would not line up with
        // the array the client sent
        let choices: Vec<String> = v
            .get("choices")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| "choice needs \"choices\"".to_string())?
            .iter()
            .map(|c| {
                c.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| "choices must be strings".to_string())
            })
            .collect::<Result<_, _>>()?;
        if choices.len() < 2 {
            return Err("need at least 2 choices".into());
        }
        Ok(Request::Choice { context, choices })
    }

    /// Validate a `generate` body (shared by the TCP op and
    /// `POST /generate`).
    pub fn generate_from_json(v: &Json) -> Result<Request, String> {
        let prompt = v
            .get("prompt")
            .and_then(|p| p.as_str())
            .ok_or_else(|| "generate needs \"prompt\"".to_string())?
            .to_string();
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        // optional fields default when absent, but a present
        // field of the wrong type is an error, not a silent
        // fallback
        let max_tokens = match v.get("max_tokens") {
            None => 32,
            Some(m) => {
                let x = m
                    .as_f64()
                    .ok_or_else(|| "max_tokens must be a number".to_string())?;
                if x < 1.0 || x.fract() != 0.0 {
                    return Err("max_tokens must be a positive integer".into());
                }
                x as usize
            }
        };
        let temperature = match v.get("temperature") {
            None => 0.0,
            Some(t) => t
                .as_f64()
                .ok_or_else(|| "temperature must be a number".to_string())?,
        };
        if !temperature.is_finite() || temperature < 0.0 {
            return Err("temperature must be finite and >= 0".into());
        }
        let seed = match v.get("seed") {
            None => 0,
            Some(s) => {
                let x = s
                    .as_f64()
                    .ok_or_else(|| "seed must be a number".to_string())?;
                // reject rather than silently saturate/round:
                // the seed names an exact sample path, and json
                // f64 transport aliases integers at 2^53
                if x < 0.0 || x.fract() != 0.0 || x >= (1u64 << 53) as f64 {
                    return Err("seed must be a non-negative integer < 2^53".into());
                }
                x as u64
            }
        };
        Ok(Request::Generate {
            prompt,
            max_tokens,
            temperature,
            seed,
        })
    }

    /// Is a retry of this op on another worker observably identical to
    /// the first attempt? The fleet router redispatches only these when
    /// a worker dies under an in-flight request; a `generate` that may
    /// already be decoding is answered with a typed error instead.
    pub fn is_idempotent(&self) -> bool {
        !matches!(self, Request::Generate { .. } | Request::Shutdown)
    }

    /// Wire name of the op — span/log label material.
    pub fn op(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Nll { .. } => "nll",
            Request::Choice { .. } => "choice",
            Request::Generate { .. } => "generate",
            Request::Stats => "stats",
            Request::Trace { .. } => "trace",
            Request::Shutdown => "shutdown",
        }
    }

    /// Serialize (client side).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::obj(vec![("op", Json::str("ping"))]),
            Request::Stats => Json::obj(vec![("op", Json::str("stats"))]),
            Request::Shutdown => Json::obj(vec![("op", Json::str("shutdown"))]),
            Request::Trace { ids, last } => {
                if ids.is_empty() {
                    Json::obj(vec![
                        ("op", Json::str("trace")),
                        ("last", Json::num(*last as f64)),
                    ])
                } else {
                    Json::obj(vec![
                        ("op", Json::str("trace")),
                        (
                            "ids",
                            Json::Arr(
                                ids.iter().map(|i| Json::str(trace::id_hex(*i))).collect(),
                            ),
                        ),
                    ])
                }
            }
            Request::Nll { text } => Json::obj(vec![
                ("op", Json::str("nll")),
                ("text", Json::str(text.clone())),
            ]),
            Request::Choice { context, choices } => Json::obj(vec![
                ("op", Json::str("choice")),
                ("context", Json::str(context.clone())),
                (
                    "choices",
                    Json::Arr(choices.iter().map(|c| Json::str(c.clone())).collect()),
                ),
            ]),
            Request::Generate {
                prompt,
                max_tokens,
                temperature,
                seed,
            } => Json::obj(vec![
                ("op", Json::str("generate")),
                ("prompt", Json::str(prompt.clone())),
                ("max_tokens", Json::num(*max_tokens as f64)),
                ("temperature", Json::num(*temperature)),
                ("seed", Json::num(*seed as f64)),
            ]),
        }
    }
}

/// Render a [`trace::Ctx`] as the wire transport tag
/// (`"<trace_hex>/<span_hex>"`).
pub fn wire_tag(ctx: trace::Ctx) -> String {
    format!("{}/{}", trace::id_hex(ctx.trace), trace::id_hex(ctx.span))
}

/// Parse the wire transport tag back into a [`trace::Ctx`].
pub fn parse_wire_tag(s: &str) -> Option<trace::Ctx> {
    let (t, p) = s.split_once('/')?;
    let trace_id = trace::parse_hex(t)?;
    let span = trace::parse_hex(p)?;
    if trace_id == 0 {
        return None;
    }
    Some(trace::Ctx {
        trace: trace_id,
        span,
    })
}

/// Server replies, serialized with [`Reply::to_json`].
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    Pong,
    Nll {
        mean_nll: f64,
        sum_nll: f64,
        tokens: usize,
        latency_ms: f64,
        batch_fill: usize,
    },
    Choice {
        best: usize,
        scores: Vec<f64>,
        latency_ms: f64,
    },
    Generate {
        text: String,
        tokens: usize,
        steps: usize,
        latency_ms: f64,
        mean_batch_fill: f64,
    },
    Stats(Json),
    /// A Chrome trace-event page from the flight recorder (see
    /// `util::trace::export_chrome` / `validate_chrome`).
    Trace(Json),
    ShuttingDown,
    Error(String),
}

impl Reply {
    pub fn to_json(&self) -> Json {
        match self {
            Reply::Pong => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pong", Json::Bool(true)),
            ]),
            Reply::Nll {
                mean_nll,
                sum_nll,
                tokens,
                latency_ms,
                batch_fill,
            } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("mean_nll", Json::num(*mean_nll)),
                ("sum_nll", Json::num(*sum_nll)),
                ("tokens", Json::num(*tokens as f64)),
                ("latency_ms", Json::num(*latency_ms)),
                ("batch_fill", Json::num(*batch_fill as f64)),
            ]),
            Reply::Choice {
                best,
                scores,
                latency_ms,
            } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("best", Json::num(*best as f64)),
                (
                    "scores",
                    Json::Arr(scores.iter().map(|&s| Json::num(s)).collect()),
                ),
                ("latency_ms", Json::num(*latency_ms)),
            ]),
            Reply::Generate {
                text,
                tokens,
                steps,
                latency_ms,
                mean_batch_fill,
            } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("text", Json::str(text.clone())),
                ("tokens", Json::num(*tokens as f64)),
                ("steps", Json::num(*steps as f64)),
                ("latency_ms", Json::num(*latency_ms)),
                ("mean_batch_fill", Json::num(*mean_batch_fill)),
            ]),
            Reply::Stats(j) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("stats", j.clone()),
            ]),
            Reply::Trace(j) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("trace", j.clone()),
            ]),
            Reply::ShuttingDown => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("shutdown", Json::Bool(true)),
            ]),
            Reply::Error(msg) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(msg.clone())),
            ]),
        }
    }

    /// Parse a server line (client side, and the router's worker side).
    pub fn parse(line: &str) -> Result<Reply, String> {
        let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        Reply::from_json(&v)
    }

    /// Classify a parsed reply object by its fields.
    pub fn from_json(v: &Json) -> Result<Reply, String> {
        let ok = v.get("ok").and_then(|o| o.as_bool()).unwrap_or(false);
        if !ok {
            let msg = v
                .get("error")
                .and_then(|e| e.as_str())
                .unwrap_or("unknown error");
            return Ok(Reply::Error(msg.to_string()));
        }
        if v.get("pong").is_some() {
            return Ok(Reply::Pong);
        }
        if v.get("shutdown").is_some() {
            return Ok(Reply::ShuttingDown);
        }
        if let Some(s) = v.get("stats") {
            return Ok(Reply::Stats(s.clone()));
        }
        if let Some(t) = v.get("trace") {
            return Ok(Reply::Trace(t.clone()));
        }
        if let Some(text) = v.get("text").and_then(|t| t.as_str()) {
            return Ok(Reply::Generate {
                text: text.to_string(),
                tokens: v.get("tokens").and_then(|t| t.as_usize()).unwrap_or(0),
                steps: v.get("steps").and_then(|s| s.as_usize()).unwrap_or(0),
                latency_ms: v.get("latency_ms").and_then(|l| l.as_f64()).unwrap_or(0.0),
                mean_batch_fill: v
                    .get("mean_batch_fill")
                    .and_then(|b| b.as_f64())
                    .unwrap_or(0.0),
            });
        }
        if let Some(best) = v.get("best").and_then(|b| b.as_f64()) {
            let scores = v
                .get("scores")
                .and_then(|s| s.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default();
            let latency_ms = v.get("latency_ms").and_then(|l| l.as_f64()).unwrap_or(0.0);
            return Ok(Reply::Choice {
                best: best as usize,
                scores,
                latency_ms,
            });
        }
        if let Some(mean) = v.get("mean_nll").and_then(|m| m.as_f64()) {
            return Ok(Reply::Nll {
                mean_nll: mean,
                sum_nll: v.get("sum_nll").and_then(|s| s.as_f64()).unwrap_or(0.0),
                tokens: v
                    .get("tokens")
                    .and_then(|t| t.as_usize())
                    .unwrap_or(0),
                latency_ms: v.get("latency_ms").and_then(|l| l.as_f64()).unwrap_or(0.0),
                batch_fill: v
                    .get("batch_fill")
                    .and_then(|b| b.as_usize())
                    .unwrap_or(0),
            });
        }
        Err(format!("unrecognized reply {v}"))
    }
}

/// The op-execution seam both front ends program against.
///
/// A single-process [`Service`](super::service::Service) and the
/// multi-process [`FleetRouter`](super::fleet::FleetRouter) both
/// implement it, so `serve_http` and the TCP acceptor work identically
/// over either — one validated code path from socket to reply,
/// whichever topology is behind it.
pub trait OpExecutor: Send + Sync {
    /// Execute one request synchronously; every path returns a
    /// [`Reply`], never a panic or a hangup.
    fn execute(&self, req: &Request) -> Reply;

    /// Does this executor answer `generate`? (`/health` reports it.)
    fn has_generator(&self) -> bool;

    /// Render the `/metrics` page, folding in the HTTP front end's own
    /// counters. Implementations must return strict Prometheus text
    /// (everything emitted here is fed back through
    /// [`crate::util::prom::parse_text`] by the scrape tests).
    fn metrics_page(&self, http: &HttpStats, gate: &Gate, draining: bool) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn request_roundtrip() {
        for r in [
            Request::Ping,
            Request::Stats,
            Request::Shutdown,
            Request::Nll {
                text: "the quick brown fox".into(),
            },
            Request::Choice {
                context: "2+2 =".into(),
                choices: vec!["4".into(), "5".into()],
            },
            Request::Generate {
                prompt: "the quick".into(),
                max_tokens: 16,
                temperature: 0.7,
                seed: 42,
            },
            Request::Trace {
                ids: vec![],
                last: 5,
            },
            Request::Trace {
                ids: vec![0xabc, 0xdef],
                last: 1,
            },
        ] {
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn trace_request_validation() {
        assert_eq!(
            Request::parse("{\"op\":\"trace\"}").unwrap(),
            Request::Trace {
                ids: vec![],
                last: 1,
            }
        );
        assert_eq!(
            Request::parse("{\"op\":\"trace\",\"last\":8}").unwrap(),
            Request::Trace {
                ids: vec![],
                last: 8,
            }
        );
        // explicit ids win over last, normalized at parse
        assert_eq!(
            Request::parse("{\"op\":\"trace\",\"ids\":[\"ff\"],\"last\":9}").unwrap(),
            Request::Trace {
                ids: vec![0xff],
                last: 1,
            }
        );
        assert!(Request::parse("{\"op\":\"trace\",\"last\":0}").is_err());
        assert!(Request::parse("{\"op\":\"trace\",\"last\":1.5}").is_err());
        assert!(Request::parse("{\"op\":\"trace\",\"last\":\"3\"}").is_err());
        assert!(Request::parse("{\"op\":\"trace\",\"ids\":\"ff\"}").is_err());
        assert!(Request::parse("{\"op\":\"trace\",\"ids\":[12]}").is_err());
        assert!(Request::parse("{\"op\":\"trace\",\"ids\":[\"zz\"]}").is_err());
    }

    #[test]
    fn wire_tag_roundtrip_and_parse_traced() {
        let ctx = trace::Ctx {
            trace: 0xdead_beef,
            span: 0x1234,
        };
        assert_eq!(parse_wire_tag(&wire_tag(ctx)), Some(ctx));
        assert_eq!(parse_wire_tag("nope"), None);
        assert_eq!(parse_wire_tag("zz/11"), None);
        // zero trace id means "not tracing", never a valid tag
        assert_eq!(
            parse_wire_tag(&format!("{}/{}", trace::id_hex(0), trace::id_hex(7))),
            None
        );

        let req = Request::Nll { text: "hi".into() };
        let line = req.to_json_traced(ctx).to_string();
        let (back, got) = Request::parse_traced(&line).unwrap();
        assert_eq!(back, req);
        assert_eq!(got, ctx);
        // the tag is transport metadata: the typed request re-serializes
        // WITHOUT it, byte-identical to the untagged form
        assert_eq!(back.to_json().to_string(), req.to_json().to_string());
        // untagged lines parse with no context; malformed tags are ignored
        let (_, none) = Request::parse_traced(&req.to_json().to_string()).unwrap();
        assert_eq!(none, trace::Ctx::NONE);
        let (_, bad) =
            Request::parse_traced("{\"op\":\"ping\",\"trace\":\"garbage\"}").unwrap();
        assert_eq!(bad, trace::Ctx::NONE);
    }

    #[test]
    fn generate_request_defaults_and_validation() {
        let r = Request::parse("{\"op\":\"generate\",\"prompt\":\"hi\"}").unwrap();
        assert_eq!(
            r,
            Request::Generate {
                prompt: "hi".into(),
                max_tokens: 32,
                temperature: 0.0,
                seed: 0,
            }
        );
        assert!(Request::parse("{\"op\":\"generate\"}").is_err());
        assert!(Request::parse("{\"op\":\"generate\",\"prompt\":\"\"}").is_err());
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"max_tokens\":0}").is_err()
        );
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"temperature\":-1}")
                .is_err()
        );
        // present-but-mistyped fields must error, not silently default
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"max_tokens\":\"64\"}")
                .is_err()
        );
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"temperature\":\"hot\"}")
                .is_err()
        );
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"seed\":\"abc\"}").is_err()
        );
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"seed\":-5}").is_err(),
            "negative seeds must not silently saturate to 0"
        );
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"seed\":1.5}").is_err()
        );
        assert!(
            Request::parse("{\"op\":\"generate\",\"prompt\":\"x\",\"max_tokens\":5.9}")
                .is_err(),
            "fractional max_tokens must not silently truncate"
        );
    }

    #[test]
    fn reply_roundtrip() {
        for r in [
            Reply::Pong,
            Reply::ShuttingDown,
            Reply::Error("boom".into()),
            Reply::Nll {
                mean_nll: 2.5,
                sum_nll: 10.0,
                tokens: 4,
                latency_ms: 1.25,
                batch_fill: 3,
            },
            Reply::Choice {
                best: 1,
                scores: vec![3.0, 2.0, 4.5],
                latency_ms: 0.5,
            },
            Reply::Generate {
                text: "brown fox".into(),
                tokens: 2,
                steps: 1,
                latency_ms: 4.5,
                mean_batch_fill: 2.5,
            },
            Reply::Trace(Json::obj(vec![(
                "traceEvents",
                Json::Arr(vec![]),
            )])),
        ] {
            let line = r.to_json().to_string();
            assert_eq!(Reply::parse(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{}").is_err());
        assert!(Request::parse("{\"op\":\"frobnicate\"}").is_err());
        assert!(Request::parse("{\"op\":\"nll\"}").is_err());
        assert!(Request::parse("{\"op\":\"nll\",\"text\":\"\"}").is_err());
        assert!(
            Request::parse("{\"op\":\"choice\",\"context\":\"c\",\"choices\":[\"x\"]}").is_err()
        );
        // mistyped fields are errors, never silent coercions/drops
        assert!(Request::parse("{\"op\":\"nll\",\"text\":5}").is_err());
        assert!(
            Request::parse("{\"op\":\"choice\",\"context\":\"c\",\"choices\":\"xy\"}").is_err()
        );
        assert!(
            Request::parse("{\"op\":\"choice\",\"context\":\"c\",\"choices\":[1,2,\"a\"]}")
                .is_err(),
            "non-string choice elements must not be dropped"
        );
        assert!(Request::parse("{\"op\":5}").is_err());
    }

    #[test]
    fn error_reply_is_not_fatal_to_parse() {
        let r = Reply::parse("{\"ok\":false,\"error\":\"bad\"}").unwrap();
        assert_eq!(r, Reply::Error("bad".into()));
    }

    #[test]
    fn idempotence_classification() {
        assert!(Request::Ping.is_idempotent());
        assert!(Request::Stats.is_idempotent());
        assert!(Request::Trace {
            ids: vec![],
            last: 1,
        }
        .is_idempotent());
        assert!(Request::Nll { text: "x".into() }.is_idempotent());
        assert!(Request::Choice {
            context: "c".into(),
            choices: vec!["a".into(), "b".into()],
        }
        .is_idempotent());
        assert!(!Request::Generate {
            prompt: "p".into(),
            max_tokens: 1,
            temperature: 0.0,
            seed: 0,
        }
        .is_idempotent());
        assert!(!Request::Shutdown.is_idempotent());
    }

    // ---- propcheck: canonical serialization -------------------------

    /// Strings that exercise the JSON escaper: quotes, backslashes,
    /// control characters, multibyte UTF-8.
    fn arb_text(g: &mut Gen, min_len: usize) -> String {
        const PIECES: [&str; 10] =
            ["a", "bc", " ", "\"", "\\", "\n", "\t", "\u{1}", "é", "日"];
        let n = g.int(min_len.max(1), 12);
        (0..n).map(|_| *g.choose(&PIECES)).collect()
    }

    /// Finite f64 with both integral and fractional cases (the writer
    /// prints integral values as integers — both paths must roundtrip).
    fn arb_f64(g: &mut Gen) -> f64 {
        match g.int(0, 2) {
            0 => g.int(0, 100_000) as f64,
            1 => g.rng.range_f64(-1e3, 1e3),
            _ => g.rng.f64() * 1e-3,
        }
    }

    fn arb_request(g: &mut Gen) -> Request {
        match g.int(0, 6) {
            0 => Request::Ping,
            1 => Request::Stats,
            2 => Request::Shutdown,
            3 => Request::Nll { text: arb_text(g, 1) },
            4 => {
                let n = g.int(2, 5);
                Request::Choice {
                    context: arb_text(g, 1),
                    choices: (0..n).map(|_| arb_text(g, 1)).collect(),
                }
            }
            5 => {
                if g.int(0, 1) == 0 {
                    Request::Trace {
                        ids: vec![],
                        last: g.int(1, 64),
                    }
                } else {
                    let n = g.int(1, 4);
                    Request::Trace {
                        ids: (0..n).map(|_| g.rng.next_u64().max(1)).collect(),
                        last: 1,
                    }
                }
            }
            _ => Request::Generate {
                prompt: arb_text(g, 1),
                max_tokens: g.int(1, 512),
                temperature: g.rng.range_f64(0.0, 2.0),
                seed: g.rng.next_u64() & ((1u64 << 53) - 1),
            },
        }
    }

    fn arb_reply(g: &mut Gen) -> Reply {
        match g.int(0, 7) {
            0 => Reply::Pong,
            1 => Reply::ShuttingDown,
            2 => Reply::Error(arb_text(g, 1)),
            6 => Reply::Trace(Json::obj(vec![
                (
                    "traceEvents",
                    Json::Arr(
                        (0..g.int(0, 3))
                            .map(|_| {
                                Json::obj(vec![
                                    ("name", Json::str(arb_text(g, 1))),
                                    ("ph", Json::str("X")),
                                    ("ts", Json::num(g.int(0, 1_000_000) as f64)),
                                    ("dur", Json::num(g.int(0, 10_000) as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("displayTimeUnit", Json::str("ms")),
            ])),
            3 => Reply::Nll {
                mean_nll: arb_f64(g),
                sum_nll: arb_f64(g),
                tokens: g.int(0, 4096),
                latency_ms: arb_f64(g).abs(),
                batch_fill: g.int(0, 64),
            },
            4 => {
                let n = g.int(2, 6);
                Reply::Choice {
                    best: g.int(0, n - 1),
                    scores: (0..n).map(|_| arb_f64(g)).collect(),
                    latency_ms: arb_f64(g).abs(),
                }
            }
            5 => Reply::Generate {
                text: arb_text(g, 0),
                tokens: g.int(0, 4096),
                steps: g.int(0, 4096),
                latency_ms: arb_f64(g).abs(),
                mean_batch_fill: arb_f64(g).abs(),
            },
            _ => Reply::Stats(Json::obj(vec![
                ("requests", Json::num(g.int(0, 1_000_000) as f64)),
                ("queue_depth", Json::num(g.int(0, 64) as f64)),
                ("mean_batch_fill", Json::num(arb_f64(g).abs())),
            ])),
        }
    }

    /// The fleet-forwarding contract: parse → re-serialize is the
    /// identity on wire bytes, for every op and every reply shape —
    /// error replies included. This is what makes a routed reply
    /// byte-identical to the single-process server's.
    #[test]
    fn wire_bytes_roundtrip_canonically() {
        check("request wire bytes are canonical", 200, |g| {
            let req = arb_request(g);
            let line = req.to_json().to_string();
            let back = Request::parse(&line)
                .map_err(|e| format!("parse failed on {line}: {e}"))?;
            if back != req {
                return Err(format!("value changed: {req:?} -> {back:?}"));
            }
            let line2 = back.to_json().to_string();
            if line2 != line {
                return Err(format!("bytes changed: {line} -> {line2}"));
            }
            Ok(())
        });
        check("reply wire bytes are canonical", 200, |g| {
            let reply = arb_reply(g);
            let line = reply.to_json().to_string();
            let back = Reply::parse(&line)
                .map_err(|e| format!("parse failed on {line}: {e}"))?;
            if back != reply {
                return Err(format!("value changed: {reply:?} -> {back:?}"));
            }
            let line2 = back.to_json().to_string();
            if line2 != line {
                return Err(format!("bytes changed: {line} -> {line2}"));
            }
            Ok(())
        });
    }
}
