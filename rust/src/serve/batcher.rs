//! Dynamic batching core.
//!
//! Requests carry a tokenized sequence; the batcher coalesces up to
//! `max_batch` of them (the model's PJRT batch dimension) and flushes
//! when the batch is full **or** the oldest queued request has waited
//! `max_wait` — the classic latency/throughput knob. Scoring happens in
//! the caller-supplied `score_batch` closure so the queueing logic stays
//! independent of PJRT and can be property-tested directly.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One scoring request: a token sequence (already encoded) plus the
/// index of the first *scored* token (the `pack_windows` convention —
/// context tokens before `scored_from` condition but are not scored).
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub scored_from: usize,
}

/// Per-request result: summed and per-token NLL over the scored span.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreResponse {
    pub id: u64,
    pub sum_nll: f64,
    pub tokens: usize,
    /// wall time spent queued + scored
    pub latency: Duration,
    /// how many requests shared the PJRT call that served this one
    pub batch_fill: usize,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// PJRT batch rows available per call (model config `batch`)
    pub max_batch: usize,
    /// flush deadline counted from the oldest queued request
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(20),
        }
    }
}

struct Pending {
    req: ScoreRequest,
    enqueued: Instant,
    reply: Sender<ScoreResponse>,
}

#[derive(Default)]
struct QueueState {
    q: VecDeque<Pending>,
    closed: bool,
}

/// Aggregate batcher metrics (monotone counters; read with [`Batcher::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub rows_scored: u64,
    /// flushes triggered by the deadline rather than a full batch
    pub timeout_flushes: u64,
}

/// The queue half of the batcher: clone-able submitter + a drain loop.
pub struct Batcher {
    cfg: BatcherConfig,
    state: Arc<(Mutex<QueueState>, Condvar)>,
    stats: Arc<Mutex<BatcherStats>>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch > 0);
        Batcher {
            cfg,
            state: Arc::new((Mutex::new(QueueState::default()), Condvar::new())),
            stats: Arc::new(Mutex::new(BatcherStats::default())),
        }
    }

    pub fn config(&self) -> BatcherConfig {
        self.cfg
    }

    /// Enqueue a request; the returned receiver yields exactly one
    /// response (or disconnects if the batcher shuts down first).
    pub fn submit(&self, req: ScoreRequest) -> Receiver<ScoreResponse> {
        let (tx, rx) = channel();
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        if !st.closed {
            st.q.push_back(Pending {
                req,
                enqueued: Instant::now(),
                reply: tx,
            });
            self.stats.lock().unwrap().requests += 1;
            cv.notify_all();
        } // closed: drop tx → receiver disconnects
        rx
    }

    /// Stop accepting work and wake the drain loop so it exits once the
    /// queue empties.
    pub fn close(&self) {
        let (lock, cv) = &*self.state;
        lock.lock().unwrap().closed = true;
        cv.notify_all();
    }

    pub fn stats(&self) -> BatcherStats {
        *self.stats.lock().unwrap()
    }

    pub fn queue_depth(&self) -> usize {
        self.state.0.lock().unwrap().q.len()
    }

    /// Collect the next batch according to the policy. Blocks until a
    /// batch is ready or `None` once closed **and** drained.
    fn next_batch(&self) -> Option<Vec<Pending>> {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        loop {
            if st.q.len() >= self.cfg.max_batch {
                break;
            }
            if !st.q.is_empty() {
                let oldest = st.q.front().unwrap().enqueued;
                let age = oldest.elapsed();
                if age >= self.cfg.max_wait {
                    self.stats.lock().unwrap().timeout_flushes += 1;
                    break;
                }
                // wait out the remaining deadline (or a new arrival)
                let (s, _t) = cv
                    .wait_timeout(st, self.cfg.max_wait - age)
                    .unwrap();
                st = s;
                continue;
            }
            if st.closed {
                return None;
            }
            st = cv.wait(st).unwrap();
        }
        let take = st.q.len().min(self.cfg.max_batch);
        Some(st.q.drain(..take).collect())
    }

    /// Drain loop: repeatedly collect a batch and score it with
    /// `score_batch(rows) -> per-row (sum_nll, tokens)`. Rows are the
    /// requests' token vectors in arrival order; the callback sees at
    /// most `max_batch` rows. Returns when closed and drained; on a
    /// scorer error the batcher is closed and still-queued requests
    /// are dropped so their clients disconnect instead of hanging.
    pub fn run(
        &self,
        score_batch: impl FnMut(&[ScoreRequest]) -> crate::Result<Vec<(f64, usize)>>,
    ) -> crate::Result<()> {
        let result = self.run_inner(score_batch);
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        st.closed = true;
        st.q.clear();
        cv.notify_all();
        result
    }

    fn run_inner(
        &self,
        mut score_batch: impl FnMut(&[ScoreRequest]) -> crate::Result<Vec<(f64, usize)>>,
    ) -> crate::Result<()> {
        while let Some(batch) = self.next_batch() {
            let reqs: Vec<ScoreRequest> = batch.iter().map(|p| p.req.clone()).collect();
            let fill = reqs.len();
            let scored = score_batch(&reqs)?;
            anyhow::ensure!(
                scored.len() == fill,
                "score_batch returned {} rows for {fill} requests",
                scored.len()
            );
            {
                let mut s = self.stats.lock().unwrap();
                s.batches += 1;
                s.rows_scored += fill as u64;
            }
            for (p, (sum_nll, tokens)) in batch.into_iter().zip(scored) {
                // receiver may have hung up (client timeout) — fine
                let _ = p.reply.send(ScoreResponse {
                    id: p.req.id,
                    sum_nll,
                    tokens,
                    latency: p.enqueued.elapsed(),
                    batch_fill: fill,
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;

    fn req(id: u64, len: usize) -> ScoreRequest {
        ScoreRequest {
            id,
            tokens: vec![1; len + 1],
            scored_from: len,
        }
    }

    /// score every row as (id as f64, token count) for traceability
    fn echo_scorer(reqs: &[ScoreRequest]) -> crate::Result<Vec<(f64, usize)>> {
        Ok(reqs
            .iter()
            .map(|r| (r.id as f64, r.scored_from))
            .collect())
    }

    fn with_running<T>(
        cfg: BatcherConfig,
        body: impl FnOnce(&Batcher) -> T,
    ) -> (T, BatcherStats) {
        let b = Arc::new(Batcher::new(cfg));
        let b2 = Arc::clone(&b);
        let h = thread::spawn(move || b2.run(echo_scorer).unwrap());
        let out = body(&b);
        b.close();
        h.join().unwrap();
        (out, b.stats())
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let ((), stats) = with_running(BatcherConfig::default(), |b| {
            let rxs: Vec<_> = (0..17).map(|i| b.submit(req(i, 8))).collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap();
                assert_eq!(resp.id, i as u64);
                assert_eq!(resp.sum_nll, i as f64);
                // exactly once: second recv must disconnect, not yield
                assert!(rx.recv().is_err());
            }
        });
        assert_eq!(stats.requests, 17);
        assert_eq!(stats.rows_scored, 17);
    }

    #[test]
    fn full_batch_flushes_without_deadline() {
        let cfg = BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(60), // deadline effectively off
        };
        let ((), stats) = with_running(cfg, |b| {
            let rxs: Vec<_> = (0..8).map(|i| b.submit(req(i, 4))).collect();
            for rx in rxs {
                let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
                assert_eq!(r.batch_fill, 4);
            }
        });
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.timeout_flushes, 0);
    }

    #[test]
    fn lone_request_flushed_by_deadline() {
        let cfg = BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(10),
        };
        let ((), stats) = with_running(cfg, |b| {
            let t = Instant::now();
            let rx = b.submit(req(1, 4));
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(r.batch_fill, 1);
            assert!(t.elapsed() >= Duration::from_millis(9), "{:?}", t.elapsed());
        });
        assert_eq!(stats.timeout_flushes, 1);
    }

    #[test]
    fn batch_never_exceeds_max() {
        let cfg = BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(5),
        };
        let max_seen = Arc::new(AtomicUsize::new(0));
        let ms = Arc::clone(&max_seen);
        let b = Arc::new(Batcher::new(cfg));
        let b2 = Arc::clone(&b);
        let h = thread::spawn(move || {
            b2.run(|reqs| {
                ms.fetch_max(reqs.len(), Ordering::SeqCst);
                echo_scorer(reqs)
            })
            .unwrap()
        });
        let rxs: Vec<_> = (0..20).map(|i| b.submit(req(i, 2))).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        b.close();
        h.join().unwrap();
        assert!(max_seen.load(Ordering::SeqCst) <= 3);
        assert!(b.stats().batches >= 7); // ceil(20/3)
    }

    #[test]
    fn fifo_order_within_stream() {
        let ((), _) = with_running(BatcherConfig::default(), |b| {
            let rxs: Vec<_> = (0..9).map(|i| b.submit(req(i, 2))).collect();
            let mut fills = Vec::new();
            for (i, rx) in rxs.into_iter().enumerate() {
                let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
                assert_eq!(r.id, i as u64, "response routed to wrong request");
                fills.push(r.batch_fill);
            }
            assert!(fills.iter().all(|&f| f >= 1 && f <= 4));
        });
    }

    #[test]
    fn submit_after_close_disconnects() {
        let b = Batcher::new(BatcherConfig::default());
        b.close();
        let rx = b.submit(req(1, 2));
        assert!(rx.recv().is_err());
        // run() on a closed empty batcher returns immediately
        b.run(echo_scorer).unwrap();
    }

    #[test]
    fn concurrent_submitters_all_served() {
        let cfg = BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        };
        let b = Arc::new(Batcher::new(cfg));
        let b2 = Arc::clone(&b);
        let worker = thread::spawn(move || b2.run(echo_scorer).unwrap());
        let mut clients = Vec::new();
        for t in 0..6 {
            let b3 = Arc::clone(&b);
            clients.push(thread::spawn(move || {
                for i in 0..10u64 {
                    let id = t * 100 + i;
                    let r = b3
                        .submit(req(id, 3))
                        .recv_timeout(Duration::from_secs(10))
                        .unwrap();
                    assert_eq!(r.id, id);
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        b.close();
        worker.join().unwrap();
        assert_eq!(b.stats().rows_scored, 60);
    }

    #[test]
    fn property_random_traffic_conservation() {
        use crate::util::propcheck::{check, Gen};
        check("batcher conserves requests", 8, |g: &mut Gen| {
            let cfg = BatcherConfig {
                max_batch: g.int(1, 6),
                max_wait: Duration::from_millis(g.int(0, 8) as u64),
            };
            let n = g.int(1, 40) as u64;
            let b = Arc::new(Batcher::new(cfg));
            let b2 = Arc::clone(&b);
            let h = thread::spawn(move || b2.run(echo_scorer).unwrap());
            let rxs: Vec<_> = (0..n).map(|i| b.submit(req(i, 1 + (i as usize % 7)))).collect();
            let mut seen = std::collections::HashSet::new();
            for rx in rxs {
                let r = rx
                    .recv_timeout(Duration::from_secs(10))
                    .map_err(|e| format!("lost response: {e}"))?;
                if !seen.insert(r.id) {
                    return Err(format!("duplicate response id {}", r.id));
                }
            }
            b.close();
            h.join().unwrap();
            let s = b.stats();
            if s.rows_scored != n || s.requests != n {
                return Err(format!("stats {s:?} vs n={n}"));
            }
            Ok(())
        });
    }
}
