//! Continuous-batching generation scheduler.
//!
//! [`super::Batcher`] coalesces *whole requests* and flushes a batch —
//! right for one-shot scoring, wrong for generation, where a 4-token
//! reply would be held hostage by a 256-token batch-mate. This module
//! generalizes the batcher to **per-step membership**: sequences join
//! the decode batch the step after they arrive and leave the step they
//! finish, so the weight-streaming cost of each
//! [`crate::model::SparseLm::decode_step`] is always shared by every
//! in-flight sequence (the packed-operand amortization the decode
//! roofline in [`crate::hwsim`] prices), and short requests never wait
//! on long ones.
//!
//! The scheduler mirrors the [`super::Batcher`] surface — `submit` /
//! `close` / `stats` / `run` — and stays model-agnostic behind
//! [`DecodeEngine`], so the queueing logic is fully unit- and
//! property-testable without a model. [`SpmmEngine`] is the production
//! engine: per-slot [`KvCache`]s over an [`Arc<SparseLm>`], prefill
//! on admission, shared decode steps after.
//!
//! Fairness: admission is strict FIFO and membership is bounded only by
//! [`DecodeEngine::max_seqs`], so no request starves (asserted by the
//! mixed-load property test). Per-step fill levels are recorded in
//! [`GenStats::batch_fill`], the histogram `{"op":"stats"}` exposes.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::eval::Sampler;
use crate::model::{KvCache, SparseLm, SpecDecoder, SpecState};
use crate::util::timer::LatencyRing;
use crate::util::trace;

/// Decode-step latency samples retained for the percentile fields of
/// [`GenStats`] — a sliding window, so `decode_p50_us` reads "p50 now",
/// not "p50 since boot".
const STEP_LATENCY_WINDOW: usize = 4096;

/// Queue-age histogram bucket upper bounds in seconds (time from
/// `submit` to admission), the `sparselm_queue_age_seconds` Prometheus
/// family. [`GenStats::queue_age`] holds one non-cumulative count per
/// bound plus a final overflow slot.
pub const QUEUE_AGE_BOUNDS: [f64; 8] = [0.0001, 0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0];

/// One generation request: a tokenized prompt plus sampling policy.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// tokens to generate (capped so `prompt + generated` fits the
    /// engine's position budget; prompts longer than the budget keep
    /// their tail, like `pack_windows`)
    pub max_tokens: usize,
    /// `0.0` = greedy argmax; `> 0` = seeded softmax sampling
    pub temperature: f32,
    /// per-sequence sampling seed (reproducible regardless of
    /// batch-mates)
    pub seed: u64,
    /// token id that terminates generation without being emitted
    pub stop: Option<i32>,
    /// trace context the scheduler's spans (queue wait, prefill, steps)
    /// parent under; [`trace::Ctx::NONE`] when the request isn't traced
    pub trace: trace::Ctx,
}

/// Per-request result.
#[derive(Clone, Debug, PartialEq)]
pub struct GenResponse {
    pub id: u64,
    /// generated token ids (stop token, if hit, is not included)
    pub tokens: Vec<i32>,
    /// prompt length actually prefilled (after tail-truncation)
    pub prompt_tokens: usize,
    /// decode steps this sequence participated in
    pub steps: u64,
    /// wall time from submit to reply
    pub latency: Duration,
    /// mean decode-batch fill over this sequence's steps (0 when the
    /// first sampled token already finished it)
    pub mean_batch_fill: f64,
}

/// Aggregate scheduler metrics (monotone; read with
/// [`GenScheduler::stats`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GenStats {
    /// requests accepted by `submit`
    pub requests: u64,
    /// sequences admitted to the decode batch (prompt prefilled)
    pub started: u64,
    /// replies delivered
    pub completed: u64,
    /// decode steps executed
    pub decode_steps: u64,
    /// tokens delivered in replies (admission-time first tokens
    /// included, stop tokens excluded)
    pub tokens_generated: u64,
    /// `batch_fill[f]` = decode steps that ran with `f` sequences in
    /// the batch (index 0 unused) — the continuous-batching fill
    /// histogram surfaced by `{"op":"stats"}`
    pub batch_fill: Vec<u64>,
    /// wall nanos spent inside [`DecodeEngine::step`] (monotone)
    pub decode_nanos: u64,
    /// wall nanos spent inside [`DecodeEngine::start`] — admission
    /// prefills (monotone)
    pub prefill_nanos: u64,
    /// decode-step latency p50 in µs over the recent window
    /// (`0.0` before the first step)
    pub decode_p50_us: f64,
    /// decode-step latency p99 in µs over the recent window
    pub decode_p99_us: f64,
    /// submit→admission age histogram: one non-cumulative count per
    /// [`QUEUE_AGE_BOUNDS`] entry plus a final overflow slot (empty
    /// until the first admission)
    pub queue_age: Vec<u64>,
    /// total submit→admission seconds across all admissions (the
    /// histogram family's `_sum`)
    pub queue_age_sum_secs: f64,
}

impl GenStats {
    /// Mean sequences per decode step.
    pub fn mean_fill(&self) -> f64 {
        let steps: u64 = self.batch_fill.iter().sum();
        if steps == 0 {
            return 0.0;
        }
        let rows: u64 = self
            .batch_fill
            .iter()
            .enumerate()
            .map(|(f, &c)| f as u64 * c)
            .sum();
        rows as f64 / steps as f64
    }
}

/// Model-side contract of the scheduler: start sequences in slots,
/// advance all active slots one token per step. Implementations own the
/// per-slot KV state; the scheduler owns queueing, sampling and
/// lifecycle.
pub trait DecodeEngine: Send {
    /// Sequence slots available — the decode batch's maximum fill.
    fn max_seqs(&self) -> usize;

    /// Maximum positions (prompt + generated) a sequence may occupy.
    fn max_positions(&self) -> usize;

    /// Prefill `prompt` into `slot` and return the logits of its last
    /// position. `slot < max_seqs()`, prompt is non-empty and fits
    /// `max_positions()`.
    fn start(&mut self, slot: usize, prompt: &[i32]) -> crate::Result<Vec<f32>>;

    /// Advance every listed slot by one token (`(slot, token)` pairs in
    /// strictly ascending slot order) and return next-token logits per
    /// entry, same order.
    fn step(&mut self, toks: &[(usize, i32)]) -> crate::Result<Vec<Vec<f32>>>;

    /// Sequence in `slot` finished; release its state for reuse.
    fn finish(&mut self, slot: usize);
}

impl DecodeEngine for Box<dyn DecodeEngine> {
    fn max_seqs(&self) -> usize {
        (**self).max_seqs()
    }
    fn max_positions(&self) -> usize {
        (**self).max_positions()
    }
    fn start(&mut self, slot: usize, prompt: &[i32]) -> crate::Result<Vec<f32>> {
        (**self).start(slot, prompt)
    }
    fn step(&mut self, toks: &[(usize, i32)]) -> crate::Result<Vec<Vec<f32>>> {
        (**self).step(toks)
    }
    fn finish(&mut self, slot: usize) {
        (**self).finish(slot)
    }
}

struct PendingGen {
    req: GenRequest,
    enqueued: Instant,
    reply: Sender<GenResponse>,
}

#[derive(Default)]
struct GenQueue {
    q: VecDeque<PendingGen>,
    closed: bool,
}

enum Take {
    Got(Box<PendingGen>),
    Empty,
    Closed,
}

/// An in-flight sequence inside the decode batch.
struct ActiveSeq {
    slot: usize,
    pending: PendingGen,
    sampler: Sampler,
    out: Vec<i32>,
    prompt_tokens: usize,
    /// generation budget after position capping
    allowed: usize,
    next_tok: i32,
    steps: u64,
    fill_sum: u64,
}

/// The queue half of the continuous batcher: clone-able submitter + a
/// drain loop that owns the decode engine.
pub struct GenScheduler {
    state: Arc<(Mutex<GenQueue>, Condvar)>,
    stats: Arc<Mutex<GenStats>>,
    step_lat: Mutex<LatencyRing>,
}

impl Default for GenScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl GenScheduler {
    pub fn new() -> GenScheduler {
        GenScheduler {
            state: Arc::new((Mutex::new(GenQueue::default()), Condvar::new())),
            stats: Arc::new(Mutex::new(GenStats::default())),
            step_lat: Mutex::new(LatencyRing::new(STEP_LATENCY_WINDOW)),
        }
    }

    /// Enqueue a request; the returned receiver yields exactly one
    /// response (or disconnects if the scheduler shuts down first, or
    /// the request is unservable — empty prompt or zero budget).
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        if !st.closed {
            st.q.push_back(PendingGen {
                req,
                enqueued: Instant::now(),
                reply: tx,
            });
            self.stats.lock().unwrap().requests += 1;
            cv.notify_all();
        } // closed: drop tx → receiver disconnects
        rx
    }

    /// Stop accepting work; `run` returns once queued and in-flight
    /// sequences have drained.
    pub fn close(&self) {
        let (lock, cv) = &*self.state;
        lock.lock().unwrap().closed = true;
        cv.notify_all();
    }

    pub fn stats(&self) -> GenStats {
        let mut s = self.stats.lock().unwrap().clone();
        let lat = self.step_lat.lock().unwrap();
        if lat.count() > 0 {
            s.decode_p50_us = lat.percentile(50.0) * 1e6;
            s.decode_p99_us = lat.percentile(99.0) * 1e6;
        }
        s
    }

    pub fn queue_depth(&self) -> usize {
        self.state.0.lock().unwrap().q.len()
    }

    fn take_queued(&self, block: bool) -> Take {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        loop {
            if let Some(p) = st.q.pop_front() {
                return Take::Got(Box::new(p));
            }
            if st.closed {
                return Take::Closed;
            }
            if !block {
                return Take::Empty;
            }
            st = cv.wait(st).unwrap();
        }
    }

    /// Prefill + first-token sampling for a newly admitted request.
    /// Returns `None` when the sequence finished at admission (first
    /// token hit the stop id or the budget was 1) or was unservable
    /// (empty prompt — the dropped reply channel signals the error).
    fn admit(
        &self,
        p: PendingGen,
        slot: usize,
        engine: &mut impl DecodeEngine,
    ) -> crate::Result<Option<ActiveSeq>> {
        // queue age: histogram for the scrape page, span for the trace
        let age = p.enqueued.elapsed();
        let age_secs = age.as_secs_f64();
        {
            let mut s = self.stats.lock().unwrap();
            if s.queue_age.len() != QUEUE_AGE_BOUNDS.len() + 1 {
                s.queue_age = vec![0; QUEUE_AGE_BOUNDS.len() + 1];
            }
            let idx = QUEUE_AGE_BOUNDS
                .iter()
                .position(|&b| age_secs <= b)
                .unwrap_or(QUEUE_AGE_BOUNDS.len());
            s.queue_age[idx] += 1;
            s.queue_age_sum_secs += age_secs;
        }
        let age_us = age.as_micros().min(u64::MAX as u128) as u64;
        trace::record_at(
            "sched.queue_wait",
            p.req.trace,
            trace::now_us().saturating_sub(age_us),
            age_us,
            vec![],
        );
        let max_pos = engine.max_positions().max(2);
        if p.req.prompt.is_empty() {
            return Ok(None); // drop reply: protocol layer validates first
        }
        // keep the prompt tail (pack_windows convention) so at least one
        // token can always be generated
        let cut = p.req.prompt.len().saturating_sub(max_pos - 1);
        let prompt = p.req.prompt[cut..].to_vec();
        let allowed = p.req.max_tokens.min(max_pos - prompt.len());
        if allowed == 0 {
            return Ok(None);
        }
        let t0 = Instant::now();
        let logits = {
            // prefill span parents under the request; spmm spans inside
            // the engine's forward nest under it via the ambient scope
            let _as_req = trace::scope(p.req.trace);
            let mut sp = trace::span("sched.prefill");
            sp.arg("prompt_tokens", prompt.len());
            engine.start(slot, &prompt)?
        };
        let prefill_ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        let mut sampler = Sampler::new(p.req.temperature, p.req.seed);
        let tok = sampler.next(&logits) as i32;
        let mut a = ActiveSeq {
            slot,
            pending: p,
            sampler,
            out: Vec::with_capacity(allowed),
            prompt_tokens: prompt.len(),
            allowed,
            next_tok: tok,
            steps: 0,
            fill_sum: 0,
        };
        let stopped = a.pending.req.stop == Some(tok);
        {
            let mut s = self.stats.lock().unwrap();
            s.started += 1;
            s.prefill_nanos += prefill_ns;
            if !stopped {
                s.tokens_generated += 1;
            }
        }
        if !stopped {
            a.out.push(tok);
        }
        if stopped || a.out.len() >= a.allowed {
            self.retire(a, engine);
            return Ok(None);
        }
        Ok(Some(a))
    }

    /// Release the slot and deliver the reply.
    fn retire(&self, a: ActiveSeq, engine: &mut impl DecodeEngine) {
        engine.finish(a.slot);
        let mean_fill = if a.steps > 0 {
            a.fill_sum as f64 / a.steps as f64
        } else {
            0.0
        };
        self.stats.lock().unwrap().completed += 1;
        // receiver may have hung up (client timeout) — fine
        let _ = a.pending.reply.send(GenResponse {
            id: a.pending.req.id,
            tokens: a.out,
            prompt_tokens: a.prompt_tokens,
            steps: a.steps,
            latency: a.pending.enqueued.elapsed(),
            mean_batch_fill: mean_fill,
        });
    }

    /// Drain loop: admit queued requests into free slots every step,
    /// decode all in-flight sequences together, retire finished ones.
    /// Returns once closed **and** drained. Engine errors are fatal to
    /// the loop (the scheduler pre-validates requests, so an engine
    /// error means the model itself is broken) — on *any* exit the
    /// scheduler is closed and still-queued requests are dropped, so
    /// their clients see a disconnect instead of hanging on a queue
    /// nobody drains.
    pub fn run(&self, engine: impl DecodeEngine) -> crate::Result<()> {
        let result = self.run_inner(engine);
        // seal the queue whether we drained cleanly or died on an
        // engine error: dropping the pending senders disconnects their
        // receivers (in-flight sequences were dropped by run_inner)
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        st.closed = true;
        st.q.clear();
        cv.notify_all();
        result
    }

    fn run_inner(&self, mut engine: impl DecodeEngine) -> crate::Result<()> {
        let max_seqs = engine.max_seqs().max(1);
        let mut active: Vec<ActiveSeq> = Vec::new();
        // free slots, descending so pop() hands out the lowest first
        let mut free: Vec<usize> = (0..max_seqs).rev().collect();
        loop {
            // ---- admission: fill free slots from the FIFO queue ------
            while active.len() < max_seqs {
                match self.take_queued(active.is_empty()) {
                    Take::Got(p) => {
                        let slot = free.pop().expect("free slot when active < max");
                        match self.admit(*p, slot, &mut engine)? {
                            Some(a) => active.push(a),
                            None => free.push(slot),
                        }
                    }
                    Take::Closed => {
                        if active.is_empty() {
                            return Ok(());
                        }
                        break;
                    }
                    Take::Empty => break,
                }
            }
            if active.is_empty() {
                continue;
            }
            // ---- one shared decode step over the current membership --
            active.sort_by_key(|a| a.slot);
            let toks: Vec<(usize, i32)> =
                active.iter().map(|a| (a.slot, a.next_tok)).collect();
            // batch-leader attribution: the first traced member's trace
            // gets a real step span (the engine's spmm spans nest under
            // it via the ambient scope); every other traced member gets
            // the same interval recorded into its own trace afterwards
            let leader_idx = active.iter().position(|a| a.pending.req.trace.active());
            let step_start_us = trace::now_us();
            let t0 = Instant::now();
            let rows = {
                let leader = leader_idx
                    .map(|i| active[i].pending.req.trace)
                    .unwrap_or(trace::Ctx::NONE);
                let _as_leader = trace::scope(leader);
                let mut sp = trace::span("sched.step");
                sp.arg("fill", active.len());
                engine.step(&toks)?
            };
            let step_dt = t0.elapsed();
            if leader_idx.is_some() {
                let dur_us = step_dt.as_micros().min(u64::MAX as u128) as u64;
                for (i, a) in active.iter().enumerate() {
                    if Some(i) == leader_idx {
                        continue;
                    }
                    trace::record_at(
                        "sched.step",
                        a.pending.req.trace,
                        step_start_us,
                        dur_us,
                        vec![("fill", trace::ArgVal::U(active.len() as u64))],
                    );
                }
            }
            debug_assert_eq!(rows.len(), active.len());
            let fill = active.len();
            let mut done: Vec<usize> = Vec::new();
            let mut emitted = 0u64;
            for (i, a) in active.iter_mut().enumerate() {
                a.steps += 1;
                a.fill_sum += fill as u64;
                let tok = a.sampler.next(&rows[i]) as i32;
                let stopped = a.pending.req.stop == Some(tok);
                if !stopped {
                    a.out.push(tok);
                    a.next_tok = tok;
                    emitted += 1;
                }
                if stopped || a.out.len() >= a.allowed {
                    done.push(i);
                }
            }
            // one stats acquisition per step, not one per token — this
            // mutex is contended by every connection's `stats` op
            {
                let mut s = self.stats.lock().unwrap();
                s.decode_steps += 1;
                if s.batch_fill.len() <= fill {
                    s.batch_fill.resize(fill + 1, 0);
                }
                s.batch_fill[fill] += 1;
                s.tokens_generated += emitted;
                s.decode_nanos += step_dt.as_nanos().min(u64::MAX as u128) as u64;
            }
            self.step_lat.lock().unwrap().record(step_dt);
            for &i in done.iter().rev() {
                let a = active.remove(i);
                free.push(a.slot);
                self.retire(a, &mut engine);
            }
            free.sort_unstable_by(|x, y| y.cmp(x));
        }
    }
}

// ------------------------------------------------------------ SpmmEngine

/// The production [`DecodeEngine`]: per-slot [`KvCache`] rings over a
/// shared packed model. Prefill and decode run the same
/// [`crate::sparse::Kernel`] linears the scorer uses — weights stay
/// packed end-to-end, and a single-sequence step takes the
/// [`crate::sparse::spmm_vec`] GEMV fast path.
pub struct SpmmEngine {
    lm: Arc<SparseLm>,
    slots: Vec<Option<KvCache>>,
}

impl SpmmEngine {
    /// `max_seqs` is the decode batch's capacity — unlike the scorer's
    /// fixed PJRT batch dim, the host forward is shape-generic, so this
    /// is a scheduling knob, not a model constant.
    pub fn new(lm: Arc<SparseLm>, max_seqs: usize) -> SpmmEngine {
        assert!(max_seqs > 0);
        SpmmEngine {
            lm,
            slots: (0..max_seqs).map(|_| None).collect(),
        }
    }
}

impl DecodeEngine for SpmmEngine {
    fn max_seqs(&self) -> usize {
        self.slots.len()
    }

    fn max_positions(&self) -> usize {
        self.lm.config.seq
    }

    fn start(&mut self, slot: usize, prompt: &[i32]) -> crate::Result<Vec<f32>> {
        let mut cache = match self.slots[slot].take() {
            Some(c) => c,
            None => KvCache::new(&self.lm.config)?,
        };
        cache.clear();
        // last-position head only: admission runs on the decode thread
        // between steps, and the tied-head GEMM over every prompt row
        // would stall the whole in-flight batch
        let last = self.lm.prefill_last(prompt, &mut cache)?;
        self.slots[slot] = Some(cache);
        Ok(last)
    }

    fn step(&mut self, toks: &[(usize, i32)]) -> crate::Result<Vec<Vec<f32>>> {
        let ids: Vec<i32> = toks.iter().map(|&(_, t)| t).collect();
        // split the slot vec so each active cache is borrowed mutably
        // exactly once (requires ascending slots — the scheduler's order)
        let mut refs: Vec<&mut KvCache> = Vec::with_capacity(toks.len());
        let mut rest: &mut [Option<KvCache>] = &mut self.slots;
        let mut base = 0usize;
        for &(slot, _) in toks {
            anyhow::ensure!(slot >= base, "step slots must be strictly ascending");
            let (head, tail) = rest.split_at_mut(slot - base + 1);
            refs.push(
                head[slot - base]
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("slot {slot} has no started sequence"))?,
            );
            rest = tail;
            base = slot + 1;
        }
        let logits = self.lm.decode_step(&ids, &mut refs)?;
        Ok((0..ids.len()).map(|i| logits.row(i).to_vec()).collect())
    }

    fn finish(&mut self, slot: usize) {
        if let Some(c) = self.slots[slot].as_mut() {
            c.clear();
        }
    }
}

// ------------------------------------------------------------ SpecEngine

/// Speculative [`DecodeEngine`]: per-slot [`SpecState`]s over a shared
/// [`SpecDecoder`] (int4 draft + bf16 target), so continuous batching
/// composes with self-speculative decoding — each sequence runs its own
/// adaptive draft window and the scheduler stays completely unaware.
///
/// Slots advance independently (one [`SpecDecoder::advance`] per
/// `(slot, token)` pair) rather than sharing a cross-slot GEMM: the
/// per-sequence windows have different lengths and roll back at
/// different times, and the batched weight amortization the plain
/// engine gets from its batch dimension is exactly what the verify
/// window already provides *within* each sequence. Logits returned are
/// bitwise identical to [`SpmmEngine`] over the target model, so the
/// two backends generate identical streams for identical requests
/// (`tests/spec_decode.rs` pins this through a live server).
pub struct SpecEngine {
    spec: Arc<SpecDecoder>,
    slots: Vec<Option<SpecState>>,
}

impl SpecEngine {
    /// `max_seqs` bounds concurrent sequences (two KV caches per slot —
    /// draft and target — so a slot is roughly twice as heavy as a
    /// [`SpmmEngine`] slot).
    pub fn new(spec: Arc<SpecDecoder>, max_seqs: usize) -> SpecEngine {
        SpecEngine {
            spec,
            slots: (0..max_seqs.max(1)).map(|_| None).collect(),
        }
    }
}

impl DecodeEngine for SpecEngine {
    fn max_seqs(&self) -> usize {
        self.slots.len()
    }

    fn max_positions(&self) -> usize {
        self.spec.config().seq
    }

    fn start(&mut self, slot: usize, prompt: &[i32]) -> crate::Result<Vec<f32>> {
        let mut state = match self.slots[slot].take() {
            Some(s) => s,
            None => self.spec.new_state()?,
        };
        let logits = self.spec.start(&mut state, prompt)?;
        self.slots[slot] = Some(state);
        Ok(logits)
    }

    fn step(&mut self, toks: &[(usize, i32)]) -> crate::Result<Vec<Vec<f32>>> {
        let mut rows = Vec::with_capacity(toks.len());
        for &(slot, tok) in toks {
            let state = self.slots[slot]
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("slot {slot} has no started sequence"))?;
            rows.push(self.spec.advance(state, tok)?);
        }
        Ok(rows)
    }

    fn finish(&mut self, slot: usize) {
        if let Some(s) = self.slots[slot].as_mut() {
            s.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Deterministic fake: next token is always `(prev + 1) % VOCAB`.
    struct FakeEngine {
        max_seqs: usize,
        max_pos: usize,
        last: Vec<Option<i32>>,
    }

    const VOCAB: usize = 16;

    impl FakeEngine {
        fn new(max_seqs: usize, max_pos: usize) -> FakeEngine {
            FakeEngine {
                max_seqs,
                max_pos,
                last: vec![None; max_seqs],
            }
        }

        fn logits_for(tok: i32) -> Vec<f32> {
            let mut l = vec![0.0f32; VOCAB];
            l[((tok as usize) + 1) % VOCAB] = 10.0;
            l
        }
    }

    impl DecodeEngine for FakeEngine {
        fn max_seqs(&self) -> usize {
            self.max_seqs
        }
        fn max_positions(&self) -> usize {
            self.max_pos
        }
        fn start(&mut self, slot: usize, prompt: &[i32]) -> crate::Result<Vec<f32>> {
            self.last[slot] = Some(*prompt.last().unwrap());
            Ok(Self::logits_for(*prompt.last().unwrap()))
        }
        fn step(&mut self, toks: &[(usize, i32)]) -> crate::Result<Vec<Vec<f32>>> {
            let mut prev: Option<usize> = None;
            for &(slot, _) in toks {
                if let Some(p) = prev {
                    assert!(slot > p, "slots not ascending: {toks:?}");
                }
                prev = Some(slot);
            }
            Ok(toks
                .iter()
                .map(|&(slot, t)| {
                    self.last[slot] = Some(t);
                    Self::logits_for(t)
                })
                .collect())
        }
        fn finish(&mut self, slot: usize) {
            self.last[slot] = None;
        }
    }

    fn req(id: u64, start_tok: i32, max_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: vec![0, start_tok],
            max_tokens,
            temperature: 0.0,
            seed: id,
            stop: None,
            trace: trace::Ctx::NONE,
        }
    }

    fn with_running<T>(
        max_seqs: usize,
        body: impl FnOnce(&GenScheduler) -> T,
    ) -> (T, GenStats) {
        let s = Arc::new(GenScheduler::new());
        let s2 = Arc::clone(&s);
        let h = thread::spawn(move || s2.run(FakeEngine::new(max_seqs, 64)).unwrap());
        let out = body(&s);
        s.close();
        h.join().unwrap();
        (out, s.stats())
    }

    #[test]
    fn greedy_generation_counts_up() {
        let ((), stats) = with_running(2, |s| {
            let rx = s.submit(req(1, 3, 5));
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(r.id, 1);
            // fake model: next = prev + 1 — prompt ends at 3
            assert_eq!(r.tokens, vec![4, 5, 6, 7, 8]);
            assert_eq!(r.prompt_tokens, 2);
            assert_eq!(r.steps, 4, "first token comes from prefill");
            assert!(rx.recv().is_err(), "exactly one reply");
        });
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.tokens_generated, 5);
        assert_eq!(stats.decode_steps, 4);
    }

    #[test]
    fn stats_record_prefill_and_decode_wall_time() {
        let ((), stats) = with_running(2, |s| {
            let r = s
                .submit(req(1, 3, 5))
                .recv_timeout(Duration::from_secs(5))
                .unwrap();
            assert_eq!(r.steps, 4);
        });
        // 1 admission prefill + 4 decode steps ran through the engine;
        // the wall-time accumulators and the windowed percentiles must
        // have moved
        assert!(stats.prefill_nanos > 0, "{stats:?}");
        assert!(stats.decode_nanos > 0, "{stats:?}");
        assert!(stats.decode_p50_us > 0.0, "{stats:?}");
        assert!(stats.decode_p99_us >= stats.decode_p50_us, "{stats:?}");
    }

    #[test]
    fn stop_token_ends_early_and_is_not_emitted() {
        let ((), stats) = with_running(1, |s| {
            let mut r = req(1, 3, 10);
            r.stop = Some(6);
            let got = s
                .submit(r)
                .recv_timeout(Duration::from_secs(5))
                .unwrap();
            assert_eq!(got.tokens, vec![4, 5]);
        });
        assert_eq!(stats.tokens_generated, 2);
    }

    #[test]
    fn sequences_join_and_leave_mid_flight() {
        // submit everything *before* the drain loop starts so the
        // admission/fill schedule is deterministic: 4 slots, 6 requests
        // with different lengths — membership must change step to step
        let s = Arc::new(GenScheduler::new());
        let rxs: Vec<_> = (0..6u64)
            .map(|i| s.submit(req(i, i as i32, 3 + 4 * (i as usize % 3))))
            .collect();
        let s2 = Arc::clone(&s);
        let h = thread::spawn(move || s2.run(FakeEngine::new(4, 64)).unwrap());
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 3 + 4 * (i % 3));
            // greedy chain continues from the prompt tail
            assert_eq!(r.tokens[0], (i as i32 + 1) % VOCAB as i32);
        }
        s.close();
        h.join().unwrap();
        let stats = s.stats();
        assert_eq!(stats.completed, 6);
        // fill never exceeded the slot count
        assert!(stats.batch_fill.len() <= 5, "{:?}", stats.batch_fill);
        // histogram ↔ replies reconciliation
        let step_rows: u64 = stats
            .batch_fill
            .iter()
            .enumerate()
            .map(|(f, &c)| f as u64 * c)
            .sum();
        // every generated token beyond each request's first came from a
        // decode step row
        assert_eq!(step_rows, stats.tokens_generated - stats.started);
        assert!(stats.mean_fill() > 1.0, "no overlap: {:?}", stats.batch_fill);
    }

    #[test]
    fn budget_caps_at_engine_positions() {
        // max_pos 8, prompt 2 → at most 6 generated
        let s = Arc::new(GenScheduler::new());
        let s2 = Arc::clone(&s);
        let h = thread::spawn(move || s2.run(FakeEngine::new(1, 8)).unwrap());
        let r = s
            .submit(req(1, 2, 100))
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(r.tokens.len(), 6);
        // over-long prompt keeps its tail and still generates one token
        let long = GenRequest {
            id: 2,
            prompt: (0..12).collect(),
            max_tokens: 100,
            temperature: 0.0,
            seed: 0,
            stop: None,
            trace: trace::Ctx::NONE,
        };
        let r2 = s
            .submit(long)
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(r2.prompt_tokens, 7);
        assert_eq!(r2.tokens.len(), 1);
        s.close();
        h.join().unwrap();
    }

    #[test]
    fn unservable_requests_disconnect_without_killing_the_loop() {
        let ((), stats) = with_running(2, |s| {
            let empty = GenRequest {
                id: 1,
                prompt: vec![],
                max_tokens: 4,
                temperature: 0.0,
                seed: 0,
                stop: None,
                trace: trace::Ctx::NONE,
            };
            assert!(s.submit(empty).recv().is_err(), "empty prompt disconnects");
            // the loop survives and serves the next request
            let r = s
                .submit(req(2, 1, 2))
                .recv_timeout(Duration::from_secs(5))
                .unwrap();
            assert_eq!(r.tokens, vec![2, 3]);
        });
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn submit_after_close_disconnects() {
        let s = GenScheduler::new();
        s.close();
        assert!(s.submit(req(1, 1, 2)).recv().is_err());
        // run() on a closed empty scheduler returns immediately
        s.run(FakeEngine::new(1, 8)).unwrap();
    }

    #[test]
    fn property_mixed_nll_and_generate_traffic_reconciles() {
        // the satellite bar: concurrent scoring + generation through both
        // schedulers — no request starves, and the stats counters
        // (including the decode-step batch_fill histogram) reconcile
        // exactly with the replies
        use crate::serve::batcher::{Batcher, BatcherConfig, ScoreRequest};
        use crate::util::propcheck::{check, Gen};
        check("mixed nll+generate load conserves", 6, |g: &mut Gen| {
            let n_nll = g.int(1, 25) as u64;
            let n_gen = g.int(1, 15) as u64;
            let max_seqs = g.int(1, 4);
            let batcher = Arc::new(Batcher::new(BatcherConfig {
                max_batch: g.int(1, 4),
                max_wait: Duration::from_millis(g.int(0, 5) as u64),
            }));
            let sched = Arc::new(GenScheduler::new());
            let b2 = Arc::clone(&batcher);
            let bt = thread::spawn(move || {
                b2.run(|reqs: &[ScoreRequest]| {
                    Ok(reqs.iter().map(|r| (r.id as f64, r.scored_from)).collect())
                })
                .unwrap()
            });
            let s2 = Arc::clone(&sched);
            let st = thread::spawn(move || s2.run(FakeEngine::new(max_seqs, 64)).unwrap());

            let b3 = Arc::clone(&batcher);
            let nll_client = thread::spawn(move || -> Result<u64, String> {
                for i in 0..n_nll {
                    b3.submit(ScoreRequest {
                        id: i,
                        tokens: vec![1; 4],
                        scored_from: 3,
                    })
                    .recv_timeout(Duration::from_secs(10))
                    .map_err(|e| format!("nll {i} starved: {e}"))?;
                }
                Ok(n_nll)
            });
            let s3 = Arc::clone(&sched);
            let gen_client = thread::spawn(move || -> Result<(u64, u64), String> {
                let (mut tokens, mut steps) = (0u64, 0u64);
                for i in 0..n_gen {
                    let len = 1 + (i as usize % 5);
                    let r = s3
                        .submit(GenRequest {
                            id: i,
                            prompt: vec![0, (i % 14) as i32],
                            max_tokens: len,
                            temperature: 0.0,
                            seed: i,
                            stop: None,
                            trace: trace::Ctx::NONE,
                        })
                        .recv_timeout(Duration::from_secs(10))
                        .map_err(|e| format!("generate {i} starved: {e}"))?;
                    if r.tokens.len() != len {
                        return Err(format!(
                            "gen {i}: {} tokens, want {len}",
                            r.tokens.len()
                        ));
                    }
                    tokens += r.tokens.len() as u64;
                    steps += r.steps;
                }
                Ok((tokens, steps))
            });
            let nll_served = nll_client.join().unwrap()?;
            let (gen_tokens, gen_steps) = gen_client.join().unwrap()?;
            batcher.close();
            sched.close();
            bt.join().unwrap();
            st.join().unwrap();

            let bs = batcher.stats();
            if bs.rows_scored != nll_served || bs.requests != nll_served {
                return Err(format!("batcher stats {bs:?} vs {nll_served} replies"));
            }
            let gs = sched.stats();
            if gs.completed != n_gen || gs.started != n_gen {
                return Err(format!("gen stats {gs:?} vs {n_gen} replies"));
            }
            if gs.tokens_generated != gen_tokens {
                return Err(format!(
                    "tokens_generated {} vs {} tokens delivered",
                    gs.tokens_generated, gen_tokens
                ));
            }
            // histogram ↔ replies: every decode step is one histogram
            // entry, every step-row is one reply's step
            let hist_steps: u64 = gs.batch_fill.iter().sum();
            if hist_steps != gs.decode_steps {
                return Err(format!(
                    "batch_fill sums to {hist_steps}, decode_steps {}",
                    gs.decode_steps
                ));
            }
            let hist_rows: u64 = gs
                .batch_fill
                .iter()
                .enumerate()
                .map(|(f, &c)| f as u64 * c)
                .sum();
            if hist_rows != gen_steps {
                return Err(format!(
                    "batch_fill rows {hist_rows} vs {gen_steps} per-reply steps"
                ));
            }
            if gs.tokens_generated != gs.started + hist_rows {
                return Err(format!(
                    "token conservation: {} != {} started + {hist_rows} step rows",
                    gs.tokens_generated, gs.started
                ));
            }
            if gs.batch_fill.len() > max_seqs + 1 {
                return Err(format!(
                    "fill exceeded {max_seqs} slots: {:?}",
                    gs.batch_fill
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn temperature_sampling_is_reproducible_per_seed() {
        let run_once = |seed: u64| -> Vec<i32> {
            let s = Arc::new(GenScheduler::new());
            let s2 = Arc::clone(&s);
            let h = thread::spawn(move || s2.run(FakeEngine::new(1, 64)).unwrap());
            let mut r = req(1, 3, 8);
            r.temperature = 1.5;
            r.seed = seed;
            let got = s
                .submit(r)
                .recv_timeout(Duration::from_secs(5))
                .unwrap()
                .tokens;
            s.close();
            h.join().unwrap();
            got
        };
        assert_eq!(run_once(42), run_once(42), "same seed, same sample path");
    }
}
