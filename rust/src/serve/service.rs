//! Transport-independent op execution — the seam between ingress
//! protocols and the model.
//!
//! Before the HTTP front end existed, the whole request path lived
//! inside the TCP server's per-connection loop. [`Service`] is that op
//! logic extracted behind one `execute` call: tokenize → submit to the
//! [`Batcher`] / [`GenScheduler`] → shape the [`Response`]. The TCP
//! handler ([`super::server`]) and the HTTP router
//! ([`super::http::router`]) both call it, so `/score` and `/generate`
//! answers byte-match the line protocol's **by construction** — there
//! is exactly one implementation to diverge from, and the parity
//! integration test (`tests/http_integration.rs`) pins it.
//!
//! Connection-lifecycle ops stay in the ingress: `shutdown` tears down
//! sockets and worker threads the service has no business owning, so
//! [`Service::execute`] answers it with a typed error and the TCP
//! handler intercepts it first.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::batcher::{Batcher, BatcherStats, ScoreRequest};
use super::generate::{GenRequest, GenScheduler, GenStats};
use super::http::{Gate, HttpStats};
use super::ops::OpExecutor;
use super::protocol::{Request, Response};
use super::server::ServerStats;
use crate::data::tokenizer::{BOS, EOS};
use crate::data::Tokenizer;
use crate::util::json::Json;
use crate::util::timer::LatencyRing;
use crate::util::trace;

/// Ops with dedicated latency rings, index-aligned with
/// [`Service::op_latency`]. The names are the `op` label values on the
/// `sparselm_op_latency_seconds` Prometheus family.
pub const LAT_OPS: [&str; 3] = ["nll", "choice", "generate"];

const OP_NLL: usize = 0;
const OP_CHOICE: usize = 1;
const OP_GENERATE: usize = 2;

/// Per-op latency ring window (recent-percentile read, bounded memory).
const OP_LAT_WINDOW: usize = 512;

/// Shared op-execution state: one per server, `Arc`-shared by every
/// connection of every ingress.
pub struct Service {
    batcher: Arc<Batcher>,
    generator: Option<Arc<GenScheduler>>,
    tokenizer: Arc<Tokenizer>,
    stats: Arc<ServerStats>,
    max_gen_tokens: usize,
    next_id: AtomicU64,
    op_lat: [Mutex<LatencyRing>; LAT_OPS.len()],
}

impl Service {
    pub(crate) fn new(
        batcher: Arc<Batcher>,
        generator: Option<Arc<GenScheduler>>,
        tokenizer: Arc<Tokenizer>,
        stats: Arc<ServerStats>,
        max_gen_tokens: usize,
    ) -> Service {
        Service {
            batcher,
            generator,
            tokenizer,
            stats,
            max_gen_tokens: max_gen_tokens.max(1),
            next_id: AtomicU64::new(1),
            op_lat: std::array::from_fn(|_| Mutex::new(LatencyRing::new(OP_LAT_WINDOW))),
        }
    }

    /// `(p50_secs, p99_secs, samples)` over the recent window for the
    /// op at `idx` in [`LAT_OPS`]. Zeros before the first request.
    pub fn op_latency(&self, idx: usize) -> (f64, f64, usize) {
        match self.op_lat[idx].lock() {
            Ok(r) => {
                let (p50, p99) = r.p50_p99();
                (p50, p99, r.count())
            }
            Err(_) => (0.0, 0.0, 0),
        }
    }

    fn record_op_latency(&self, idx: usize, secs: f64) {
        if let Ok(mut r) = self.op_lat[idx].lock() {
            r.record_secs(secs);
        }
    }

    /// Ingress-shared server counters.
    pub fn server_stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Scoring-queue counters.
    pub fn batcher_stats(&self) -> BatcherStats {
        self.batcher.stats()
    }

    /// Scoring requests currently queued (admission gauge).
    pub fn queue_depth(&self) -> usize {
        self.batcher.queue_depth()
    }

    /// Generation counters (default when serving without an engine).
    pub fn gen_stats(&self) -> GenStats {
        self.generator.as_ref().map(|g| g.stats()).unwrap_or_default()
    }

    /// Generation requests currently queued (admission gauge; 0 when
    /// serving without an engine).
    pub fn gen_queue_depth(&self) -> usize {
        self.generator.as_ref().map(|g| g.queue_depth()).unwrap_or(0)
    }

    /// Does this server answer `generate`?
    pub fn has_generator(&self) -> bool {
        self.generator.is_some()
    }

    /// Close both worker queues (shutdown/drain).
    pub fn close(&self) {
        self.batcher.close();
        if let Some(g) = &self.generator {
            g.close();
        }
    }

    /// Execute one request synchronously. Never panics on malformed
    /// model output; every path returns a [`Response`].
    pub fn execute(&self, req: &Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Stats => Response::Stats(self.stats_json()),
            Request::Shutdown => {
                // lifecycle belongs to the ingress (the TCP handler
                // intercepts this op before calling execute; HTTP does
                // not route it at all)
                Response::Error("shutdown is a connection-level op".into())
            }
            Request::Trace { ids, last } => Response::Trace(trace::export_chrome(
                &trace::Selection {
                    ids: ids.clone(),
                    last: *last,
                },
            )),
            Request::Nll { text } => self.run_nll(text),
            Request::Choice { context, choices } => self.run_choice(context, choices),
            Request::Generate {
                prompt,
                max_tokens,
                temperature,
                seed,
            } => self.run_generate(prompt, *max_tokens, *temperature, *seed),
        }
    }

    fn run_nll(&self, text: &str) -> Response {
        self.stats.nll_ops.fetch_add(1, Ordering::Relaxed);
        let mut sp = trace::span("op.nll");
        sp.arg("chars", text.len());
        let _in_op = trace::scope(trace::Ctx {
            trace: sp.trace(),
            span: sp.id(),
        });
        let t0 = Instant::now();
        let mut ids = vec![BOS];
        ids.extend(self.tokenizer.encode(text));
        let rx = self.batcher.submit(ScoreRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens: ids,
            scored_from: 1,
        });
        let resp = match rx.recv() {
            Ok(r) if r.tokens > 0 => Response::Nll {
                mean_nll: r.sum_nll / r.tokens as f64,
                sum_nll: r.sum_nll,
                tokens: r.tokens,
                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                batch_fill: r.batch_fill,
            },
            Ok(_) => Response::Error("text tokenized to nothing scorable".into()),
            Err(_) => Response::Error("server shutting down".into()),
        };
        self.record_op_latency(OP_NLL, t0.elapsed().as_secs_f64());
        resp
    }

    fn run_choice(&self, context: &str, choices: &[String]) -> Response {
        self.stats.choice_ops.fetch_add(1, Ordering::Relaxed);
        let mut sp = trace::span("op.choice");
        sp.arg("choices", choices.len());
        let _in_op = trace::scope(trace::Ctx {
            trace: sp.trace(),
            span: sp.id(),
        });
        let t0 = Instant::now();
        // submit all candidates, then await — they share batches
        let ctx_len = self.tokenizer.encode(context).len();
        let rxs: Vec<_> = choices
            .iter()
            .map(|c| {
                let full = format!("{context} {c}");
                let mut ids = vec![BOS];
                ids.extend(self.tokenizer.encode(&full));
                self.batcher.submit(ScoreRequest {
                    id: self.next_id.fetch_add(1, Ordering::Relaxed),
                    tokens: ids,
                    scored_from: 1 + ctx_len,
                })
            })
            .collect();
        let mut scores = Vec::with_capacity(rxs.len());
        for rx in rxs {
            match rx.recv() {
                Ok(r) if r.tokens > 0 => scores.push(r.sum_nll / r.tokens as f64),
                Ok(_) => scores.push(f64::INFINITY),
                Err(_) => {
                    self.record_op_latency(OP_CHOICE, t0.elapsed().as_secs_f64());
                    return Response::Error("server shutting down".into());
                }
            }
        }
        // total_cmp, not partial_cmp().unwrap(): a NaN score
        // (a degenerate model is the client's problem, not a
        // reason to kill this connection's worker thread)
        // must still produce a reply. Non-finite scores are
        // excluded from the ranking outright — total order
        // alone would let a sign-bit-set NaN (the default
        // x86 arithmetic NaN) sort *below* every finite
        // score and win. All-degenerate falls back to 0.
        let best = scores
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_finite())
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        // JSON has no inf/NaN: clamp degenerate/unscorable
        // entries to MAX so the reply stays numeric and
        // index-aligned with the client's choices array
        for s in scores.iter_mut() {
            if !s.is_finite() {
                *s = f64::MAX;
            }
        }
        self.record_op_latency(OP_CHOICE, t0.elapsed().as_secs_f64());
        Response::Choice {
            best,
            scores,
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
        }
    }

    fn run_generate(
        &self,
        prompt: &str,
        max_tokens: usize,
        temperature: f64,
        seed: u64,
    ) -> Response {
        let Some(g) = &self.generator else {
            return Response::Error(
                "generation not supported by this backend (scoring-only server)".into(),
            );
        };
        self.stats.generate_ops.fetch_add(1, Ordering::Relaxed);
        let mut sp = trace::span("op.generate");
        sp.arg("max_tokens", max_tokens);
        let t0 = Instant::now();
        let mut ids = vec![BOS];
        ids.extend(self.tokenizer.encode(prompt));
        let rx = g.submit(GenRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            prompt: ids,
            max_tokens: max_tokens.min(self.max_gen_tokens),
            temperature: temperature as f32,
            seed,
            stop: Some(EOS),
            trace: trace::Ctx {
                trace: sp.trace(),
                span: sp.id(),
            },
        });
        let resp = match rx.recv() {
            Ok(r) => Response::Generate {
                text: self.tokenizer.decode(&r.tokens),
                tokens: r.tokens.len(),
                steps: r.steps as usize,
                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                mean_batch_fill: r.mean_batch_fill,
            },
            Err(_) => Response::Error("server shutting down".into()),
        };
        self.record_op_latency(OP_GENERATE, t0.elapsed().as_secs_f64());
        resp
    }

    /// The `{"op":"stats"}` object — also reused by the HTTP `/metrics`
    /// renderer for its gauge values, so the two views cannot drift.
    pub fn stats_json(&self) -> Json {
        let b = self.batcher.stats();
        let mut fields = vec![
            (
                "connections",
                Json::num(self.stats.connections.load(Ordering::Relaxed) as f64),
            ),
            (
                "requests",
                Json::num(self.stats.requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "errors",
                Json::num(self.stats.errors.load(Ordering::Relaxed) as f64),
            ),
            ("batches", Json::num(b.batches as f64)),
            ("rows_scored", Json::num(b.rows_scored as f64)),
            ("timeout_flushes", Json::num(b.timeout_flushes as f64)),
            ("queue_depth", Json::num(self.batcher.queue_depth() as f64)),
        ];
        // per-op latency percentiles over the recent window (satellite
        // view of the `sparselm_op_latency_seconds` Prometheus family)
        let (nll50, nll99, _) = self.op_latency(OP_NLL);
        let (ch50, ch99, _) = self.op_latency(OP_CHOICE);
        let (gen50, gen99, _) = self.op_latency(OP_GENERATE);
        fields.push(("nll_p50_ms", Json::num(nll50 * 1e3)));
        fields.push(("nll_p99_ms", Json::num(nll99 * 1e3)));
        fields.push(("choice_p50_ms", Json::num(ch50 * 1e3)));
        fields.push(("choice_p99_ms", Json::num(ch99 * 1e3)));
        fields.push(("generate_p50_ms", Json::num(gen50 * 1e3)));
        fields.push(("generate_p99_ms", Json::num(gen99 * 1e3)));
        if let Some(g) = &self.generator {
            let gs = g.stats();
            fields.push(("gen_requests", Json::num(gs.requests as f64)));
            fields.push(("gen_completed", Json::num(gs.completed as f64)));
            fields.push(("decode_steps", Json::num(gs.decode_steps as f64)));
            fields.push(("tokens_generated", Json::num(gs.tokens_generated as f64)));
            fields.push(("mean_batch_fill", Json::num(gs.mean_fill())));
            fields.push((
                "batch_fill",
                Json::Arr(gs.batch_fill.iter().map(|&c| Json::num(c as f64)).collect()),
            ));
            fields.push(("prefill_nanos", Json::num(gs.prefill_nanos as f64)));
            fields.push(("decode_nanos", Json::num(gs.decode_nanos as f64)));
            fields.push(("decode_p50_us", Json::num(gs.decode_p50_us)));
            fields.push(("decode_p99_us", Json::num(gs.decode_p99_us)));
            fields.push(("gen_queue_depth", Json::num(g.queue_depth() as f64)));
            // speculative-decoding counters (all zero unless the engine
            // is a SpecEngine; process-wide like the perf phases)
            let p = crate::util::perf::snapshot();
            fields.push(("spec_rounds", Json::num(p.spec_rounds as f64)));
            fields.push(("spec_drafted", Json::num(p.spec_drafted as f64)));
            fields.push(("spec_accepted", Json::num(p.spec_accepted as f64)));
            fields.push(("spec_mispredicts", Json::num(p.spec_mispredicts as f64)));
            fields.push(("spec_accept_rate", Json::num(p.spec_accept_rate())));
        }
        Json::obj(fields)
    }
}

impl OpExecutor for Service {
    fn execute(&self, req: &Request) -> Response {
        Service::execute(self, req)
    }

    fn has_generator(&self) -> bool {
        Service::has_generator(self)
    }

    fn metrics_page(&self, http: &HttpStats, gate: &Gate, draining: bool) -> String {
        super::http::metrics::render(self, http, gate, draining)
    }
}
