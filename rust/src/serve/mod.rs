//! Scoring service — the compressed model behind a socket.
//!
//! The paper motivates 8:16 sparsity with deployment efficiency; this
//! module is the deployment: a Rust-only eval server that serves
//! log-likelihood scoring over TCP with **dynamic batching** — requests
//! are coalesced into the model's fixed batch shape, vLLM-router style,
//! so single-request clients still get full-batch throughput.
//!
//! The request path is socket → [`Batcher`] → scorer, where the default
//! scorer ([`spmm_scorer`]) runs the decode-free packed hot path: every
//! linear layer applies bit-packed N:M weights (+ structured outliers)
//! straight from storage via [`crate::sparse::spmm_parallel()`] — the
//! weights are never expanded to dense, so serving traffic matches the
//! packed footprint the paper's Table 1 accounts for. The PJRT-backed
//! [`pjrt_scorer`] (AOT artifacts, `--features xla`) is the
//! artifact-path alternative. Python is never involved. The full hot
//! path (tokens → batcher → packed spmm → logits) is walked through in
//! `docs/ARCHITECTURE.md`.
//!
//! * [`batcher`] — the queueing/coalescing core (pure, fully unit- and
//!   property-tested without sockets);
//! * [`server`] — TCP front end speaking newline-delimited JSON;
//! * [`client`] — a small blocking client used by tests, examples and
//!   the `serve-bench` CLI.

pub mod batcher;
pub mod client;
pub mod protocol;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, ScoreRequest, ScoreResponse};
pub use client::ServeClient;
pub use protocol::{Request, Response};
pub use server::{
    pjrt_scorer, serve, spmm_scorer, Scorer, ServerConfig, ServerHandle, ServerStats,
};
