//! Scoring **and generation** service — the compressed model behind a
//! socket.
//!
//! The paper motivates 8:16 sparsity with deployment efficiency; this
//! module is the deployment: a Rust-only server that serves
//! log-likelihood scoring *and KV-cached autoregressive generation*
//! over TCP. Scoring requests are coalesced into the model's fixed
//! batch shape by the [`Batcher`] (vLLM-router-style dynamic batching);
//! generation requests flow through the [`GenScheduler`], a
//! **continuous-batching** generalization of the same idea — in-flight
//! sequences join and leave the decode batch every step, so short
//! replies never wait on long batch-mates and every
//! [`crate::model::SparseLm::decode_step`] amortizes its packed-weight
//! streaming across the whole in-flight set.
//!
//! The request paths share one packed model (`Arc`): socket →
//! [`Batcher`] → [`spmm_scorer`] for `nll`/`choice`, socket →
//! [`GenScheduler`] → [`spmm_generator`] (prefill → shared decode loop
//! → detokenize) for `generate`. Every linear applies bit-packed N:M
//! weights (+ structured outliers) straight from storage via
//! [`crate::sparse::spmm_parallel()`] / [`crate::sparse::spmm_vec()`] —
//! the weights are never expanded to dense, so serving traffic matches
//! the packed footprint the paper's Table 1 accounts for, in exactly
//! the bandwidth-bound decode regime §8 argues about. The PJRT-backed
//! [`pjrt_scorer`] (AOT artifacts, `--features xla`) is the
//! artifact-path alternative (scoring only). Python is never involved.
//! Both hot paths are walked through in `docs/ARCHITECTURE.md`.
//!
//! * [`batcher`] — the scoring queue/coalescing core (pure, fully unit-
//!   and property-tested without sockets);
//! * [`generate`] — the continuous-batching decode scheduler and the
//!   [`DecodeEngine`] contract (same purity);
//! * [`ops`] — the typed `Request`/`Reply` vocabulary with canonical
//!   (sorted-key, byte-stable) JSON round-trips, and the
//!   [`OpExecutor`] seam every ingress programs against;
//! * [`service`] — the transport-independent op executor both ingresses
//!   share (`/score` byte-matches `{"op":"nll"}` by construction);
//! * [`engine`] — typed backend construction: [`BackendSpec`] +
//!   [`EngineBuilder`], the one path `serve`, `generate` and fleet
//!   worker boot all build their model through;
//! * [`server`] — TCP front end speaking newline-delimited JSON;
//! * [`http`] — HTTP/1.1 front end over any [`OpExecutor`]: `POST
//!   /score`, `POST /generate`, `GET /health` and a Prometheus-text
//!   `GET /metrics`, with admission control (429 + `Retry-After`),
//!   body/header caps and graceful drain;
//! * [`fleet`] — the sharded topology: a router supervising K worker
//!   processes that mmap one `.spak`, with least-inflight routing,
//!   restart-on-crash, redispatch and fleet-wide drain;
//! * [`client`] — a small blocking client used by tests, examples and
//!   the `serve-bench` CLI.

pub mod batcher;
pub mod client;
pub mod engine;
pub mod fleet;
pub mod generate;
pub mod http;
pub mod ops;
pub mod protocol;
pub mod server;
pub mod service;

pub use batcher::{Batcher, BatcherConfig, ScoreRequest, ScoreResponse};
pub use client::ServeClient;
pub use engine::{BackendSpec, Engine, EngineBuilder};
pub use fleet::{FleetConfig, FleetHandle, FleetRouter};
pub use generate::{
    DecodeEngine, GenRequest, GenResponse, GenScheduler, GenStats, SpecEngine, SpmmEngine,
};
pub use http::{serve_http, HttpClient, HttpConfig, HttpHandle, HttpReply};
pub use ops::{OpExecutor, Reply, Request};
pub use protocol::Response;
pub use server::{
    pjrt_scorer, serve, serve_generate, spec_generator, spmm_generator, spmm_scorer, GenEngine,
    Scorer, ServerConfig, ServerHandle, ServerStats,
};
pub use service::Service;
