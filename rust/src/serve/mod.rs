//! Scoring service — the compressed model behind a socket.
//!
//! The paper motivates 8:16 sparsity with deployment efficiency; this
//! module is the deployment: a Rust-only eval server that loads a
//! (compressed) checkpoint plus the AOT artifacts and serves
//! log-likelihood scoring over TCP with **dynamic batching** — requests
//! are coalesced into the model's fixed PJRT batch shape, vLLM-router
//! style, so single-request clients still get full-batch throughput.
//! Python is never involved: the request path is socket → batcher →
//! PJRT executable.
//!
//! * [`batcher`] — the queueing/coalescing core (pure, fully unit- and
//!   property-tested without sockets);
//! * [`server`] — TCP front end speaking newline-delimited JSON;
//! * [`client`] — a small blocking client used by tests, examples and
//!   the `serve-bench` CLI.

pub mod batcher;
pub mod client;
pub mod protocol;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, ScoreRequest, ScoreResponse};
pub use client::ServeClient;
pub use protocol::{Request, Response};
pub use server::{pjrt_scorer, serve, Scorer, ServerConfig, ServerHandle, ServerStats};
