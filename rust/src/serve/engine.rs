//! Typed backend construction — [`BackendSpec`] + [`EngineBuilder`],
//! the one path `serve`, `generate` and fleet worker boot all build
//! their model through.
//!
//! Before this module, every caller that wanted a servable model
//! re-implemented the same `--backend` string `match`: pick a variant,
//! remember the `--repack` acknowledgment, wire the right
//! scorer/generator factory pair. The CLI's `serve` and `generate`
//! subcommands each had a copy, and a fleet worker would have needed a
//! third. Now the vocabulary is a typed enum (`FromStr`/`Display`, so
//! CLI flags and log lines round-trip through it), construction policy
//! lives in one builder, and the product is an [`Engine`] that knows
//! how to put itself behind a socket.
//!
//! The `--repack` refusal moved here with the construction: packing a
//! *dense* checkpoint through `spmm`/`spmm-q4`/`spmm-t`/`spec` re-selects
//! weights by magnitude alone, silently discarding whatever calibrated
//! pipeline produced the checkpoint, so [`EngineBuilder::build`]
//! returns the typed [`crate::Error::BadFlag`] unless the caller
//! acknowledged the lossy re-pack.

use std::fmt;
use std::path::Path;
use std::str::FromStr;
use std::sync::Arc;

use super::server::{
    pjrt_scorer, serve, serve_generate, spec_generator, spmm_generator, spmm_scorer,
    ServerConfig, ServerHandle,
};
use crate::data::Tokenizer;
use crate::model::{ParamSet, SparseLm, SpecDecoder};
use crate::quant::QuantSpec;
use crate::store::ArtifactInfo;

/// The serving backends, as a closed vocabulary instead of a string.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendSpec {
    /// Packed bf16 host forward (8:16 + outliers) — the offline default.
    Spmm,
    /// Fused sparse + int4-under-mask host forward, dequant in-kernel.
    SpmmQ4,
    /// Fused sparse + ternary-under-mask host forward (5 trits/byte,
    /// dequant in-kernel) — sub-2-bits/param serving.
    SpmmT,
    /// Self-speculative: int4 draft proposes, bf16 target verifies.
    Spec,
    /// Exact dense bf16-as-f32 reference forward.
    Dense,
    /// AOT PJRT artifacts (`--features xla`) — scoring only.
    Pjrt,
}

impl BackendSpec {
    /// The CLI token (`--backend <name>`); [`fmt::Display`] prints it.
    pub fn name(self) -> &'static str {
        match self {
            BackendSpec::Spmm => "spmm",
            BackendSpec::SpmmQ4 => "spmm-q4",
            BackendSpec::SpmmT => "spmm-t",
            BackendSpec::Spec => "spec",
            BackendSpec::Dense => "dense",
            BackendSpec::Pjrt => "pjrt",
        }
    }

    /// Does building this backend from a *dense checkpoint* discard
    /// calibrated pruning artifacts (and therefore require the
    /// `--repack` acknowledgment)?
    pub fn needs_repack(self) -> bool {
        matches!(
            self,
            BackendSpec::Spmm | BackendSpec::SpmmQ4 | BackendSpec::SpmmT | BackendSpec::Spec
        )
    }

    /// Does the backend answer the `generate` op?
    pub fn supports_generate(self) -> bool {
        !matches!(self, BackendSpec::Pjrt)
    }
}

impl fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for BackendSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BackendSpec, anyhow::Error> {
        Ok(match s {
            "spmm" => BackendSpec::Spmm,
            "spmm-q4" => BackendSpec::SpmmQ4,
            "spmm-t" => BackendSpec::SpmmT,
            "spec" => BackendSpec::Spec,
            "dense" => BackendSpec::Dense,
            "pjrt" => BackendSpec::Pjrt,
            other => anyhow::bail!(
                "unknown --backend {other} (expected spmm|spmm-q4|spmm-t|spec|dense|pjrt)"
            ),
        })
    }
}

/// Shared construction policy: pattern, outlier budget, quantization,
/// thread count, the `--repack` acknowledgment, PJRT artifact dir.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    pattern: (usize, usize),
    outliers: usize,
    quant: QuantSpec,
    /// ternary scale group (`spmm-t`), gcd-fitted per layer width
    tgroup: usize,
    threads: usize,
    repack_acknowledged: bool,
    artifacts: String,
}

impl Default for EngineBuilder {
    fn default() -> EngineBuilder {
        EngineBuilder {
            pattern: (8, 16),
            outliers: 16,
            quant: QuantSpec::new(4, 128),
            tgroup: 128,
            threads: crate::util::pool::default_parallelism(),
            repack_acknowledged: false,
            artifacts: "artifacts".into(),
        }
    }
}

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// N:M sparsity pattern used when re-packing a dense checkpoint.
    pub fn pattern(mut self, n: usize, m: usize) -> EngineBuilder {
        self.pattern = (n, m);
        self
    }

    /// Structured outliers kept per 256 columns.
    pub fn outliers(mut self, k: usize) -> EngineBuilder {
        self.outliers = k;
        self
    }

    /// Group-quantization of kept values (`spmm-q4` / `spec` draft).
    pub fn quant(mut self, spec: QuantSpec) -> EngineBuilder {
        self.quant = spec;
        self
    }

    /// Ternary scale group (`spmm-t`): kept values per bf16 scale.
    pub fn ternary_group(mut self, group: usize) -> EngineBuilder {
        self.tgroup = group;
        self
    }

    /// Host-forward thread count.
    pub fn threads(mut self, threads: usize) -> EngineBuilder {
        self.threads = threads;
        self
    }

    /// Acknowledge the lossy magnitude-only re-pack of a dense
    /// checkpoint (the `--repack` flag; in-process `generate` passes
    /// `true` because the one-shot tool owns its own approximation).
    pub fn acknowledge_repack(mut self, yes: bool) -> EngineBuilder {
        self.repack_acknowledged = yes;
        self
    }

    /// PJRT artifact directory (`pjrt` backend only).
    pub fn artifacts(mut self, dir: impl Into<String>) -> EngineBuilder {
        self.artifacts = dir.into();
        self
    }

    /// Typed refusal for the silent-approximation trap: re-packing a
    /// dense checkpoint by magnitude discards calibrated artifacts, so
    /// it must be explicitly acknowledged.
    fn require_repack(&self, spec: BackendSpec) -> crate::Result<()> {
        if self.repack_acknowledged {
            return Ok(());
        }
        Err(anyhow::Error::new(crate::Error::BadFlag {
            key: "repack".into(),
            value: "absent".into(),
            want: "to be set: --backend spmm re-packs the checkpoint with magnitude-only \
                   selection, which silently discards calibrated pruning artifacts; pass \
                   --repack to acknowledge the lossy re-pack, or serve a pipeline-packed \
                   artifact with --model <x.spak>",
        })
        .context(format!("--backend {spec} on a dense checkpoint")))
    }

    /// Build an engine for `spec` from a dense checkpoint's parameters.
    /// `model` names the configuration (the PJRT artifact key).
    pub fn build(
        &self,
        spec: BackendSpec,
        params: ParamSet,
        model: &str,
    ) -> crate::Result<Engine> {
        let (n, m) = self.pattern;
        let k = self.outliers;
        match spec {
            BackendSpec::Dense => Ok(Engine::Spmm {
                lm: Arc::new(SparseLm::from_params(&params).with_threads(self.threads)),
                desc: String::new(),
            }),
            BackendSpec::Spmm => {
                self.require_repack(spec)?;
                let lm = SparseLm::compress(&params, n, m, k).with_threads(self.threads);
                let desc = format!(
                    "packing checkpoint to {n}:{m} + {k}:256 (magnitude selection, \
                     --repack acknowledged) — use --model <x.spak> for calibrated artifacts\n\
                     packed linear traffic {} KiB (dense {} KiB)",
                    lm.linear_operand_bytes() / 1024,
                    lm.dense_linear_bytes() / 1024
                );
                Ok(Engine::Spmm { lm: Arc::new(lm), desc })
            }
            BackendSpec::SpmmQ4 => {
                self.require_repack(spec)?;
                let q = self.quant;
                let lm =
                    SparseLm::compress_quant(&params, n, m, k, q).with_threads(self.threads);
                let desc = format!(
                    "packing checkpoint to {n}:{m} + {k}:256 with int{} g{} kept values \
                     (magnitude selection, dequant in-kernel, --repack acknowledged)\n\
                     packed-quant linear traffic {} KiB (dense {} KiB)",
                    q.bits,
                    q.group,
                    lm.linear_operand_bytes() / 1024,
                    lm.dense_linear_bytes() / 1024
                );
                Ok(Engine::Spmm { lm: Arc::new(lm), desc })
            }
            BackendSpec::SpmmT => {
                self.require_repack(spec)?;
                let lm = SparseLm::compress_ternary(&params, n, m, k, self.tgroup)
                    .with_threads(self.threads);
                let desc = format!(
                    "packing checkpoint to {n}:{m} + {k}:256 with ternary g{} kept values \
                     (magnitude selection, 5 trits/byte, dequant in-kernel, --repack \
                     acknowledged)\n\
                     packed-ternary linear traffic {} KiB (dense {} KiB)",
                    self.tgroup,
                    lm.linear_operand_bytes() / 1024,
                    lm.dense_linear_bytes() / 1024
                );
                Ok(Engine::Spmm { lm: Arc::new(lm), desc })
            }
            BackendSpec::Spec => {
                self.require_repack(spec)?;
                let q = self.quant;
                let dec = Arc::new(SpecDecoder::from_dense(&params, n, m, k, q, self.threads)?);
                let desc = format!(
                    "packing checkpoint to {n}:{m} + {k}:256 twice: int{} g{} draft \
                     ({} KiB/step) + bf16 verify target ({} KiB/step), magnitude \
                     selection, --repack acknowledged — speculative decode, output \
                     identical to --backend spmm",
                    q.bits,
                    q.group,
                    dec.draft().linear_operand_bytes() / 1024,
                    dec.target().linear_operand_bytes() / 1024
                );
                Ok(Engine::Spec { dec, desc })
            }
            BackendSpec::Pjrt => Ok(Engine::Pjrt {
                artifacts: self.artifacts.clone(),
                model: model.to_string(),
                params: Box::new(params),
                desc: String::new(),
            }),
        }
    }

    /// mmap a packed `.spak` artifact and serve it zero-copy — no
    /// re-pack, no backend choice (the artifact *is* the format). This
    /// is the path every fleet worker boots through.
    pub fn open_artifact(&self, path: &Path) -> crate::Result<(Engine, ArtifactInfo)> {
        let (packed, info) = crate::store::read_artifact(path)?;
        let lm = packed.into_sparse_lm()?.with_threads(self.threads);
        Ok((
            Engine::Spmm {
                lm: Arc::new(lm),
                desc: String::new(),
            },
            info,
        ))
    }
}

/// A constructed backend, ready to serve or to run in-process.
pub enum Engine {
    /// Packed (or dense-reference) host-forward model — `spmm`,
    /// `spmm-q4`, `dense`, and every `.spak` artifact.
    Spmm { lm: Arc<SparseLm>, desc: String },
    /// Draft + target pair for self-speculative decode.
    Spec { dec: Arc<SpecDecoder>, desc: String },
    /// Deferred PJRT artifact compile/load (scoring only).
    Pjrt {
        artifacts: String,
        model: String,
        params: Box<ParamSet>,
        desc: String,
    },
}

impl Engine {
    /// Human construction summary (empty when there is nothing to say —
    /// `dense`, `pjrt`, artifacts).
    pub fn describe(&self) -> &str {
        match self {
            Engine::Spmm { desc, .. }
            | Engine::Spec { desc, .. }
            | Engine::Pjrt { desc, .. } => desc,
        }
    }

    /// Does this engine answer the `generate` op once served?
    pub fn supports_generate(&self) -> bool {
        !matches!(self, Engine::Pjrt { .. })
    }

    /// The servable model's batch size, when the engine knows it before
    /// boot (host-forward engines do; PJRT reads it from the params).
    pub fn batch(&self) -> usize {
        match self {
            Engine::Spmm { lm, .. } => lm.config.batch,
            Engine::Spec { dec, .. } => dec.target().config.batch,
            Engine::Pjrt { params, .. } => params.config.batch,
        }
    }

    /// Put the engine behind a TCP socket: wire the scorer/generator
    /// factory pair every backend previously wired by hand.
    pub fn serve(
        self,
        tokenizer: Arc<Tokenizer>,
        cfg: ServerConfig,
        gen_batch: usize,
    ) -> crate::Result<ServerHandle> {
        match self {
            Engine::Spmm { lm, .. } => serve_generate(
                spmm_scorer(Arc::clone(&lm)),
                spmm_generator(lm, gen_batch),
                tokenizer,
                cfg,
            ),
            Engine::Spec { dec, .. } => serve_generate(
                spmm_scorer(Arc::clone(dec.target())),
                spec_generator(dec, gen_batch),
                tokenizer,
                cfg,
            ),
            Engine::Pjrt {
                artifacts,
                model,
                params,
                ..
            } => serve(pjrt_scorer(artifacts, model, *params), tokenizer, cfg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::Rng;

    fn tiny_params() -> ParamSet {
        let mut cfg = ModelConfig::preset("tiny").unwrap();
        cfg.n_layers = 2;
        cfg.seq = 32;
        cfg.batch = 2;
        ParamSet::init_outliers(&cfg, &mut Rng::new(7))
    }

    #[test]
    fn backend_spec_roundtrips_through_strings() {
        for b in [
            BackendSpec::Spmm,
            BackendSpec::SpmmQ4,
            BackendSpec::SpmmT,
            BackendSpec::Spec,
            BackendSpec::Dense,
            BackendSpec::Pjrt,
        ] {
            assert_eq!(b.to_string().parse::<BackendSpec>().unwrap(), b);
        }
    }

    #[test]
    fn unknown_backend_keeps_the_error_text() {
        let err = "frob".parse::<BackendSpec>().unwrap_err().to_string();
        assert_eq!(
            err,
            "unknown --backend frob (expected spmm|spmm-q4|spmm-t|spec|dense|pjrt)"
        );
    }

    #[test]
    fn repack_gate_refuses_then_accepts() {
        let params = tiny_params();
        let err = EngineBuilder::new()
            .build(BackendSpec::Spmm, params.clone(), "tiny")
            .unwrap_err();
        // the typed condition survives the context chain
        assert!(
            err.chain()
                .any(|c| c.to_string().contains("--repack")),
            "{err:?}"
        );
        assert!(err.to_string().contains("--backend spmm on a dense checkpoint"));
        let engine = EngineBuilder::new()
            .acknowledge_repack(true)
            .build(BackendSpec::Spmm, params, "tiny")
            .unwrap();
        assert!(engine.supports_generate());
        assert!(engine.describe().contains("--repack acknowledged"));
    }

    #[test]
    fn dense_needs_no_acknowledgment() {
        let engine = EngineBuilder::new()
            .build(BackendSpec::Dense, tiny_params(), "tiny")
            .unwrap();
        assert!(matches!(engine, Engine::Spmm { .. }));
        assert_eq!(engine.batch(), 2);
        assert!(engine.describe().is_empty());
    }

    #[test]
    fn pjrt_is_scoring_only() {
        let engine = EngineBuilder::new()
            .build(BackendSpec::Pjrt, tiny_params(), "tiny")
            .unwrap();
        assert!(!engine.supports_generate());
        assert!(!BackendSpec::Pjrt.supports_generate());
        assert!(!BackendSpec::Pjrt.needs_repack());
        assert!(BackendSpec::SpmmQ4.needs_repack());
        assert!(BackendSpec::SpmmT.needs_repack());
    }

    #[test]
    fn ternary_backend_builds_and_reports_traffic() {
        let engine = EngineBuilder::new()
            .acknowledge_repack(true)
            .build(BackendSpec::SpmmT, tiny_params(), "tiny")
            .unwrap();
        assert!(engine.supports_generate());
        assert!(engine.describe().contains("ternary g128"));
    }
}
