//! Bench harness (criterion is unavailable offline) + the shared
//! experiment context every table bench and example builds on.
//!
//! Each `rust/benches/*.rs` binary (harness = false) regenerates one paper
//! table/figure: it trains (or loads from `runs/`) the stand-in model,
//! sweeps the experiment grid, and prints rows in the paper's layout.
//! `SPARSELM_FAST=1` shrinks grids/items for smoke runs.

pub mod grids;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{ModelExec, TrainConfig, Trainer};
use crate::data::{CorpusKind, CorpusSpec, TokenStream, Tokenizer, World};
use crate::model::{load_checkpoint, save_checkpoint, ParamSet};
use crate::runtime::Engine;
use crate::util::Rng;

// ---------------------------------------------------------------- timing

/// Measure a closure: warmup runs then timed iterations; returns seconds
/// per iteration (mean).
pub fn time_it<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let t = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    t.elapsed().as_secs_f64() / iters.max(1) as f64
}

/// Pretty throughput formatter.
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec > 1e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else {
        format!("{:.1} MB/s", bytes_per_sec / 1e6)
    }
}

/// `SPARSELM_FAST=1` → smoke-test sizing for benches.
pub fn fast_mode() -> bool {
    matches!(std::env::var("SPARSELM_FAST").as_deref(), Ok("1") | Ok("true"))
}

// ------------------------------------------------- trajectory reports

use crate::util::json::Json;
use crate::util::perf;
use std::collections::BTreeMap;

/// One metric inside a [`BenchReport`]: a value, its unit, and which
/// direction is an improvement (the gate script applies tolerance in
/// that direction).
#[derive(Clone, Debug)]
pub struct BenchMetric {
    pub value: f64,
    pub unit: String,
    /// `"higher"` or `"lower"`
    pub better: &'static str,
}

/// Machine-readable perf-trajectory record: every figure bench
/// (`f1`/`f2`/`f3`/`perf_hotpath`) builds one of these alongside its
/// printed table and [emits](Self::emit) it as `BENCH_<name>.json`
/// (schema in `docs/BENCHMARKS.md`). CI's `bench-gate` job compares the
/// emitted files against the committed `bench/baseline.json` and fails
/// on regressions, so the numbers the paper argues about are *recorded*
/// per commit instead of scrolling away in a log.
pub struct BenchReport {
    name: String,
    metrics: BTreeMap<String, BenchMetric>,
    extra: Vec<(String, Json)>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            metrics: BTreeMap::new(),
            extra: Vec::new(),
        }
    }

    /// Record a metric where **higher** is better (throughput, speedup).
    pub fn higher(&mut self, key: &str, value: f64, unit: &str) {
        self.metrics.insert(
            key.to_string(),
            BenchMetric {
                value,
                unit: unit.to_string(),
                better: "higher",
            },
        );
    }

    /// Record a metric where **lower** is better (latency, byte ratios).
    pub fn lower(&mut self, key: &str, value: f64, unit: &str) {
        self.metrics.insert(
            key.to_string(),
            BenchMetric {
                value,
                unit: unit.to_string(),
                better: "lower",
            },
        );
    }

    /// Attach free-form context (e.g. the hwsim device description) —
    /// recorded but never gated.
    pub fn extra(&mut self, key: &str, value: Json) {
        self.extra.push((key.to_string(), value));
    }

    pub fn to_json(&self) -> Json {
        let metrics: Vec<(&str, Json)> = self
            .metrics
            .iter()
            .map(|(k, m)| {
                (
                    k.as_str(),
                    Json::obj(vec![
                        ("value", Json::num(m.value)),
                        ("unit", Json::str(m.unit.clone())),
                        ("better", Json::str(m.better)),
                    ]),
                )
            })
            .collect();
        let mut fields = vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str(self.name.clone())),
            ("fast", Json::Bool(fast_mode())),
            ("metrics", Json::obj(metrics)),
            ("perf", perf::snapshot().to_json()),
        ];
        for (k, v) in &self.extra {
            fields.push((k.as_str(), v.clone()));
        }
        Json::obj(fields)
    }

    /// Directory `BENCH_*.json` files land in: `$SPARSELM_BENCH_DIR`,
    /// or the working directory when unset.
    pub fn out_dir() -> PathBuf {
        std::env::var("SPARSELM_BENCH_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("."))
    }

    /// Write `BENCH_<name>.json` into `dir`.
    pub fn emit_to(&self, dir: &std::path::Path) -> crate::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    /// Write `BENCH_<name>.json` into [`Self::out_dir`] and say so.
    pub fn emit(&self) -> crate::Result<PathBuf> {
        let path = self.emit_to(&Self::out_dir())?;
        println!("\nwrote {}", path.display());
        Ok(path)
    }
}

/// Markdown-ish table printer shared by the table benches.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        let widths = widths.to_vec();
        let mut line = String::from("|");
        for (h, w) in headers.iter().zip(&widths) {
            line.push_str(&format!(" {h:<w$} |"));
        }
        println!("{line}");
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        println!("{sep}");
        TablePrinter { widths }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!(" {c:<w$} |"));
        }
        println!("{line}");
    }
}

// ------------------------------------------------------- experiment ctx

/// Everything a table bench needs: engine, world, tokenizer, corpora, and
/// train-once-cached stand-in models.
pub struct ExperimentCtx {
    pub engine: Arc<Engine>,
    pub world: World,
    pub tokenizer: Tokenizer,
    /// training/calibration streams per corpus kind
    pub wiki_train: TokenStream,
    pub c4_train: TokenStream,
    /// held-out eval streams
    pub wiki_eval: TokenStream,
    pub c4_eval: TokenStream,
    pub runs_dir: PathBuf,
}

pub const WORLD_SEED: u64 = 20250711;

impl ExperimentCtx {
    /// Build the standard context (vocab sized for the given config
    /// family; all current configs use vocab >= 2048 so one tokenizer
    /// serves them all).
    pub fn new(artifacts: &str) -> crate::Result<ExperimentCtx> {
        crate::util::logging::init();
        let engine = Arc::new(Engine::new(artifacts)?);
        let world = World::new(WORLD_SEED);
        let sentences = if fast_mode() { 20_000 } else { 120_000 };
        let wiki_text = CorpusSpec::new(CorpusKind::Wiki, sentences, 11).generate(&world);
        let c4_text = CorpusSpec::new(CorpusKind::C4, sentences, 12).generate(&world);
        let wiki_eval_text =
            CorpusSpec::new(CorpusKind::Wiki, sentences / 10, 13).generate(&world);
        let c4_eval_text =
            CorpusSpec::new(CorpusKind::C4, sentences / 10, 14).generate(&world);
        let tokenizer = Tokenizer::fit(&wiki_text, 2048);
        let enc = |t: &str| TokenStream::new(tokenizer.encode(t));
        Ok(ExperimentCtx {
            engine,
            world,
            tokenizer: tokenizer.clone(),
            wiki_train: enc(&wiki_text),
            c4_train: enc(&c4_text),
            wiki_eval: enc(&wiki_eval_text),
            c4_eval: enc(&c4_eval_text),
            runs_dir: PathBuf::from("runs"),
        })
    }

    pub fn stream(&self, kind: CorpusKind) -> &TokenStream {
        match kind {
            CorpusKind::Wiki => &self.wiki_train,
            CorpusKind::C4 => &self.c4_train,
        }
    }

    pub fn eval_stream(&self, kind: CorpusKind) -> &TokenStream {
        match kind {
            CorpusKind::Wiki => &self.wiki_eval,
            CorpusKind::C4 => &self.c4_eval,
        }
    }

    /// Load a cached trained model or train one now (train-once-per-repo
    /// semantics: benches share checkpoints under `runs/`).
    pub fn ensure_trained(
        &self,
        config_name: &str,
        steps: usize,
    ) -> crate::Result<(ModelExec, ParamSet)> {
        let exec = ModelExec::new(Arc::clone(&self.engine), config_name)?;
        let steps = if fast_mode() { steps.min(40) } else { steps };
        let path = self
            .runs_dir
            .join(format!("{config_name}-s{steps}.ckpt"));
        if path.exists() {
            match load_checkpoint(&path) {
                Ok(ps) => {
                    log::info!("loaded cached checkpoint {}", path.display());
                    return Ok((exec, ps));
                }
                Err(e) => log::warn!("cached checkpoint unreadable ({e}); retraining"),
            }
        }
        let mut rng = Rng::new(0xBEEF ^ steps as u64);
        let mut params = ParamSet::init(&exec.config, &mut rng);
        let trainer = Trainer {
            exec: &exec,
            config: TrainConfig {
                steps,
                lr: 3e-3,
                warmup: (steps / 10).max(1),
                log_every: (steps / 10).max(1),
                seed: 0xABCD,
            },
        };
        log::info!("training {config_name} for {steps} steps...");
        let losses = trainer.run(&mut params, &self.wiki_train)?;
        log::info!(
            "trained {config_name}: loss {:.3} -> {:.3}",
            losses.first().copied().unwrap_or(f32::NAN),
            losses.last().copied().unwrap_or(f32::NAN)
        );
        save_checkpoint(&path, &params)?;
        Ok((exec, params))
    }

    /// Default training budget per config family.
    ///
    /// Sized so the stand-ins actually *memorize* the synthetic fact
    /// corpus (loss well past the bigram plateau): underfit models are
    /// nearly free to prune — every criterion ties and the paper's
    /// orderings vanish into noise. The post-leak-fix runtime trains
    /// ~6× faster, which is what makes these budgets affordable.
    pub fn default_steps(config_name: &str) -> usize {
        match config_name {
            "tiny" => 2000,
            "small" => 350,
            "gqa" | "wide" => 300,
            "e2e" => 300,
            _ => 200,
        }
    }

    /// Items per zero-shot task for accuracy tables.
    pub fn zs_items() -> usize {
        if fast_mode() {
            25
        } else {
            120
        }
    }

    /// PPL eval batches.
    pub fn ppl_batches() -> usize {
        if fast_mode() {
            4
        } else {
            16
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_positive() {
        let secs = time_it(1, 3, || (0..1000).sum::<u64>());
        assert!(secs >= 0.0);
    }

    #[test]
    fn fmt_rate_units() {
        assert!(fmt_rate(2.5e9).contains("GB/s"));
        assert!(fmt_rate(3.0e6).contains("MB/s"));
    }

    #[test]
    fn bench_report_schema_roundtrips() {
        let mut r = BenchReport::new("unit_test");
        r.higher("tok_s", 1234.5, "tok/s");
        r.lower("bytes_ratio", 0.555, "x");
        r.extra("hw", Json::str("test-device"));
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.at("schema").as_usize(), Some(1));
        assert_eq!(j.at("bench").as_str(), Some("unit_test"));
        let m = j.at("metrics");
        assert_eq!(m.at("tok_s").at("value").as_f64(), Some(1234.5));
        assert_eq!(m.at("tok_s").at("better").as_str(), Some("higher"));
        assert_eq!(m.at("bytes_ratio").at("better").as_str(), Some("lower"));
        assert_eq!(m.at("bytes_ratio").at("unit").as_str(), Some("x"));
        assert!(j.at("perf").get("operand_bytes").is_some());
        assert_eq!(j.at("hw").as_str(), Some("test-device"));
    }

    #[test]
    fn bench_report_emits_file() {
        let dir = std::env::temp_dir().join("sparselm-bench-report-test");
        let mut r = BenchReport::new("emit_test");
        r.higher("x", 1.0, "");
        let path = r.emit_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_emit_test.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(text.trim()).unwrap();
        assert_eq!(j.at("bench").as_str(), Some("emit_test"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
