//! Shared grid runners for the accuracy/PPL tables (T2/T3/T6/T7/T8):
//! sweep (calibration corpus × method × outlier pattern × sparsity
//! pattern), compress, evaluate, and hand rows to the caller.

use std::sync::Arc;

use crate::coordinator::{CompressionPipeline, ModelExec, PipelineSpec};
use crate::data::CorpusKind;
use crate::eval::{perplexity, zero_shot_accuracy};
use crate::model::ParamSet;

use super::ExperimentCtx;

/// One evaluated grid cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub ppl_wiki: f64,
    pub mean_acc: f64,
    pub compression_ratio: f64,
}

/// Evaluate one compressed (or dense) model: wiki PPL + mean zero-shot.
pub fn evaluate(
    ctx: &ExperimentCtx,
    exec: &ModelExec,
    params: &ParamSet,
    with_acc: bool,
) -> crate::Result<CellResult> {
    let lits = exec.upload(params)?;
    let ppl = perplexity(exec, &lits, &ctx.wiki_eval, ExperimentCtx::ppl_batches())?.ppl;
    let mean_acc = if with_acc {
        zero_shot_accuracy(
            exec,
            &lits,
            &ctx.tokenizer,
            &ctx.world,
            ExperimentCtx::zs_items(),
            7,
        )?
        .mean_accuracy()
    } else {
        f64::NAN
    };
    Ok(CellResult {
        ppl_wiki: ppl,
        mean_acc,
        compression_ratio: f64::NAN,
    })
}

/// Compress `dense` under `spec` calibrated on `calib`, then evaluate.
pub fn run_cell(
    ctx: &ExperimentCtx,
    exec: &ModelExec,
    pipeline: &CompressionPipeline,
    dense: &ParamSet,
    calib: CorpusKind,
    spec: &PipelineSpec,
    with_acc: bool,
) -> crate::Result<CellResult> {
    let (sparse, report) = pipeline.run(dense, ctx.stream(calib), spec)?;
    let mut cell = evaluate(ctx, exec, &sparse, with_acc)?;
    cell.compression_ratio = report.compression_ratio();
    log::info!(
        "cell [{} calib={} o{}:{} {}:{}] ppl {:.3} acc {:.3}",
        spec.label(),
        calib.label(),
        spec.prune.k_outlier,
        spec.prune.m_outlier,
        spec.prune.n,
        spec.prune.m,
        cell.ppl_wiki,
        cell.mean_acc
    );
    Ok(cell)
}

/// Build (model exec, dense params, pipeline) for a config.
pub fn prepare(
    ctx: &ExperimentCtx,
    model: &str,
) -> crate::Result<(ModelExec, ParamSet, CompressionPipeline)> {
    let (exec, dense) = ctx.ensure_trained(model, ExperimentCtx::default_steps(model))?;
    let pipeline = CompressionPipeline::new(Arc::clone(&ctx.engine), model)?;
    Ok((exec, dense, pipeline))
}
