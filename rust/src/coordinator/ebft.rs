//! EBFT — blockwise reconstruction fine-tuning (Guo et al., 2024; §4
//! stage 4 of the paper).
//!
//! Each transformer block is fine-tuned *independently* to reproduce the
//! dense block's output on the calibration set, under the fixed sparsity
//! masks: only non-salient linear values (through their masks) and the
//! RMSNorm gains receive updates; salient weights stay frozen and are
//! added back inside the L2 graph (`ebft_step` artifact).

use crate::model::{ParamSet, BLOCK_LINEAR, BLOCK_PARAMS};
use crate::runtime::literal_f32;
use crate::tensor::Tensor;

use super::calib::CalibRecord;
use super::exec::{run_refs, ModelExec};

#[derive(Clone, Copy, Debug)]
pub struct EbftConfig {
    pub steps: usize,
    pub lr: f32,
}

pub struct EbftTrainer<'a> {
    pub exec: &'a ModelExec,
    pub config: EbftConfig,
}

impl<'a> EbftTrainer<'a> {
    /// Fine-tune every block of `params` in place. `block_masks` /
    /// `block_salient` are per block in BLOCK_LINEAR order. Returns the
    /// final reconstruction loss per block.
    pub fn run(
        &self,
        params: &mut ParamSet,
        calib: &CalibRecord,
        block_masks: &[Vec<Tensor>],
        block_salient: &[Vec<Tensor>],
    ) -> crate::Result<Vec<f32>> {
        let cfg = &self.exec.config;
        anyhow::ensure!(!calib.hiddens.is_empty(), "EBFT requires calibration IO");
        let mut final_losses = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            let loss = self.tune_block(params, calib, b, &block_masks[b], &block_salient[b])?;
            log::info!("ebft block {b}: final reconstruction loss {loss:.3e}");
            final_losses.push(loss);
        }
        Ok(final_losses)
    }

    /// One block: `steps` masked-AdamW steps cycling over calibration
    /// batches; trainable = non-salient linears + norm gains.
    fn tune_block(
        &self,
        params: &mut ParamSet,
        calib: &CalibRecord,
        block: usize,
        masks: &[Tensor],
        salient: &[Tensor],
    ) -> crate::Result<f32> {
        anyhow::ensure!(masks.len() == BLOCK_LINEAR.len());
        let sig = self.exec.manifest.artifact("ebft_step")?;

        // Trainable tensors: linears hold w_ns only (effective - salient).
        let mut train: Vec<xla::Literal> = Vec::with_capacity(BLOCK_PARAMS.len());
        let mut li = 0;
        for p in BLOCK_PARAMS {
            let name = format!("blk{block}.{p}");
            let t = params.get(&name);
            if BLOCK_LINEAR.contains(&p) {
                let wns = t.zip(&salient[li], |a, s| a - s);
                train.push(literal_f32(&wns)?);
                li += 1;
            } else {
                train.push(literal_f32(t)?);
            }
        }
        let mask_lits: Vec<xla::Literal> = masks
            .iter()
            .map(literal_f32)
            .collect::<crate::Result<_>>()?;
        let sal_lits: Vec<xla::Literal> = salient
            .iter()
            .map(literal_f32)
            .collect::<crate::Result<_>>()?;
        let mut m_state: Vec<xla::Literal> = Vec::with_capacity(BLOCK_PARAMS.len());
        let mut v_state: Vec<xla::Literal> = Vec::with_capacity(BLOCK_PARAMS.len());
        for p in BLOCK_PARAMS {
            let name = format!("blk{block}.{p}");
            let z = Tensor::zeros(params.get(&name).shape().to_vec());
            m_state.push(literal_f32(&z)?);
            v_state.push(literal_f32(&z)?);
        }

        let mut last_loss = f32::NAN;
        for step in 1..=self.config.steps {
            let bi = (step - 1) % calib.hiddens.len();
            let x = &calib.hiddens[bi][block];
            let y = &calib.hiddens[bi][block + 1];
            let stepl = crate::runtime::literal_scalar(step as f32);
            let lrl = crate::runtime::literal_scalar(self.config.lr);

            let mut inputs: Vec<&xla::Literal> = Vec::new();
            inputs.extend(train.iter());
            inputs.extend(mask_lits.iter());
            inputs.extend(sal_lits.iter());
            inputs.push(x);
            inputs.push(y);
            inputs.extend(m_state.iter());
            inputs.extend(v_state.iter());
            inputs.push(&stepl);
            inputs.push(&lrl);

            let mut outs = run_refs(&self.exec.engine, &sig.file, &inputs)?;
            let nb = BLOCK_PARAMS.len();
            anyhow::ensure!(outs.len() == 3 * nb + 1, "ebft_step output arity");
            last_loss = outs.pop().unwrap().to_vec::<f32>()?[0];
            let vs = outs.split_off(2 * nb);
            let ms = outs.split_off(nb);
            train = outs;
            m_state = ms;
            v_state = vs;
        }

        // Write back: effective linear = trained w_ns (mask re-applied in
        // graph, but values outside the mask never moved) + salient.
        let mut li = 0;
        for (i, p) in BLOCK_PARAMS.iter().enumerate() {
            let name = format!("blk{block}.{p}");
            let t = crate::runtime::tensor_from_literal(&train[i])?;
            if BLOCK_LINEAR.contains(p) {
                // re-mask defensively (AdamW update is mask-gated in-graph)
                let masked = t.mul(&masks[li]);
                *params.get_mut(&name) = masked.add(&salient[li]);
                li += 1;
            } else {
                *params.get_mut(&name) = t;
            }
        }
        Ok(last_loss)
    }
}

