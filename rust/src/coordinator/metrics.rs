//! Coordinator metrics registry: named counters + per-stage latency
//! statistics, rendered as a report block at the end of a run.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::timer::LatencyStats;

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    stages: Mutex<BTreeMap<String, LatencyStats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Time a stage closure, recording its latency under `stage`.
    pub fn time<T>(&self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.stages
            .lock()
            .unwrap()
            .entry(stage.to_string())
            .or_default()
            .record(t.elapsed());
        out
    }

    pub fn record_secs(&self, stage: &str, secs: f64) {
        self.stages
            .lock()
            .unwrap()
            .entry(stage.to_string())
            .or_default()
            .record_secs(secs);
    }

    pub fn stage_total(&self, stage: &str) -> f64 {
        self.stages
            .lock()
            .unwrap()
            .get(stage)
            .map(|s| s.total())
            .unwrap_or(0.0)
    }

    pub fn report(&self) -> String {
        let mut out = String::from("metrics:\n");
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<32} {v}\n"));
        }
        for (k, s) in self.stages.lock().unwrap().iter() {
            out.push_str(&format!(
                "  {k:<32} total={:.2}s {}\n",
                s.total(),
                s.summary(1e3, "ms")
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("layers", 3);
        m.incr("layers", 4);
        assert_eq!(m.counter("layers"), 7);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn stage_timing_recorded() {
        let m = Metrics::new();
        let out = m.time("stage_a", || 5);
        assert_eq!(out, 5);
        assert!(m.stage_total("stage_a") >= 0.0);
        let r = m.report();
        assert!(r.contains("stage_a"));
    }
}
