//! The Layer-3 coordinator: the paper's §4 pipeline as a production
//! service.
//!
//! ```text
//!             ┌──────────────┐   per-layer jobs    ┌──────────────┐
//!  corpus ──► │ calibration  │ ──────────────────► │ prune+pack   │
//!             │ (stats + IO) │                     │ (L1 kernels) │
//!             └──────┬───────┘                     └──────┬───────┘
//!                    │ block io pairs                     │ effective W
//!                    ▼                                    ▼
//!             ┌──────────────┐                     ┌──────────────┐
//!             │ EBFT sched   │ ◄────────────────── │ sparse store │
//!             │ (L2 bwd)     │                     │ nm + k:256   │
//!             └──────┬───────┘                     └──────────────┘
//!                    ▼
//!               eval (ppl + zero-shot) ► reports
//! ```
//!
//! [`ModelExec`] owns PJRT execution of the model graphs; [`Calibrator`]
//! streams calibration batches layer-by-layer collecting activation
//! statistics and block IO pairs; [`CompressionPipeline`] runs scoring →
//! outlier extraction → N:M masking → variance correction (through the L1
//! kernel artifacts) and packs results into the sparse stores;
//! [`EbftTrainer`] runs blockwise reconstruction fine-tuning; [`Trainer`]
//! drives pre-training through the exported train-step artifact.

mod calib;
mod ebft;
mod exec;
mod metrics;
mod pipeline;
mod train;

pub use calib::{BlockStats, CalibRecord, Calibrator};
pub use ebft::{EbftConfig, EbftTrainer};
pub use exec::{ModelExec, ParamLiterals};
pub use metrics::Metrics;
pub use pipeline::{CompressionPipeline, CompressionReport, LayerReport, PipelineSpec};
pub use train::{TrainConfig, Trainer};
