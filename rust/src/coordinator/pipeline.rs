//! The compression pipeline: §4 of the paper as an orchestrated service.
//!
//! Per prunable linear layer the coordinator chains the L1 kernel
//! artifacts — score (RIA, optionally SQ-equalized) → structured outlier
//! mask → N:M keep mask (salient positions excluded) → finalize (+VC) —
//! then packs the results into the sparse stores ([`PackedNm`] +
//! [`StructuredOutliers`]) and swaps the *effective* dense weight
//! (`w_ns + w_salient`) into the compressed model.  Matrices whose shape
//! has no exported kernel artifact fall back to the host mirrors in
//! [`crate::pruning`] (numerically identical; cross-checked by the
//! `runtime_kernels` integration suite).
//!
//! When [`PipelineSpec::quant`] is set the pipeline appends a pack-time
//! **quantization stage**: after pruning, variance correction and
//! (optional) EBFT have produced the final non-salient weights, the kept
//! values of every linear are group-quantized and stored as
//! [`PackedQnm`] (mask meta + int codes + bf16 scales) — the §4.2
//! correction composes with quantization because VC rescales the values
//! *before* the quantizer fits its per-group scales to them. Salient
//! weights stay bf16 (the SPQR discipline), and the effective dense
//! weight swapped into the compressed model is the dequantized base +
//! outliers, so downstream eval measures exactly what a
//! `--backend spmm-q4` deployment serves. [`PipelineSpec::ternary`]
//! swaps the int quantizer for the 1.58-bit ternary one
//! ([`PackedTnm`], label `+T158`) with the same placement and the same
//! dequantize-for-eval discipline — the `--backend spmm-t` deployment.
//!
//! [`CompressionPipeline::run_packed`] adds the **pack-artifact output
//! stage**: instead of discarding the packed layers after accounting,
//! it assembles them (plus the dense non-linear params) into a
//! [`crate::store::PackedModel`] for [`crate::store::write_artifact`] —
//! the `.spak` container a server then mmaps directly, skipping the
//! lossy magnitude re-pack a dense checkpoint cold start performs.

use std::sync::Arc;

use crate::data::TokenStream;
use crate::model::ParamSet;
use crate::pruning::{
    self, ActStats, PruneMethod, PruneSpec,
};
use crate::quant::QuantSpec;
use crate::runtime::{literal_f32, tensor_from_literal, Engine, KernelSet};
use crate::sparse::{Csr, PackedNm, PackedQnm, PackedTnm, StructuredOutliers};
use crate::store::{PackedLayer, PackedModel, PackedWeights};
use crate::tensor::Tensor;
use crate::util::Rng;

use super::calib::Calibrator;
use super::ebft::{EbftConfig, EbftTrainer};
use super::exec::{run_refs, ModelExec};
use super::metrics::Metrics;

/// Full experiment-cell configuration.
#[derive(Clone, Debug)]
pub struct PipelineSpec {
    pub prune: PruneSpec,
    pub calib_batches: usize,
    /// EBFT steps per block (0 disables)
    pub ebft_steps: usize,
    pub ebft_lr: f32,
    /// route scoring/masking through the PJRT kernel artifacts (true) or
    /// the host mirrors (false)
    pub use_kernels: bool,
    pub seed: u64,
    /// store salient weights unstructured (CSR at matched budget) instead
    /// of structured k:256 — the Table 7 baseline
    pub unstructured_outliers: bool,
    /// group-quantize the kept base values at pack time
    /// (prune → VC → [EBFT] → quantize → pack into [`PackedQnm`]);
    /// `None` stores them bf16 ([`PackedNm`])
    pub quant: Option<QuantSpec>,
    /// ternarize the kept base values at pack time instead (the value
    /// is the scale group, gcd-fitted per layer width; packs into
    /// [`PackedTnm`]). Mutually exclusive with `quant`.
    pub ternary: Option<usize>,
}

impl PipelineSpec {
    pub fn new(prune: PruneSpec) -> Self {
        PipelineSpec {
            prune,
            calib_batches: 8,
            ebft_steps: 0,
            ebft_lr: 1e-3,
            use_kernels: true,
            seed: 0x5EED,
            unstructured_outliers: false,
            quant: None,
            ternary: None,
        }
    }

    pub fn ebft(mut self, steps: usize) -> Self {
        self.ebft_steps = steps;
        self
    }

    /// Quantize the kept base values at pack time.
    pub fn quantize(mut self, spec: QuantSpec) -> Self {
        self.quant = Some(spec);
        self
    }

    /// Ternarize the kept base values at pack time (`group` kept values
    /// per bf16 scale).
    pub fn ternarize(mut self, group: usize) -> Self {
        self.ternary = Some(group);
        self
    }

    pub fn label(&self) -> String {
        let mut s = String::new();
        match self.prune.method {
            PruneMethod::Ria => s.push_str("RIA"),
            PruneMethod::Magnitude => s.push_str("Magnitude"),
            PruneMethod::Wanda => s.push_str("Wanda"),
        }
        if self.prune.use_sq {
            s.push_str("+SQ");
        }
        if self.prune.use_vc {
            s.push_str("+VC");
        }
        if self.ebft_steps > 0 {
            s.push_str("+EBFT");
        }
        if let Some(q) = &self.quant {
            s.push_str(&format!("+INT{}", q.bits));
        }
        if self.ternary.is_some() {
            // 1.58 bits/value: log2(3) trits, the BitNet-style tag
            s.push_str("+T158");
        }
        s
    }
}

/// Storage accounting for one pruned linear layer.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub sparsity: f64,
    /// packed N:M base bytes (values + mask metadata); when the spec
    /// quantizes, this is the [`PackedQnm`] footprint (codes + scales +
    /// mask metadata)
    pub nm_bytes: usize,
    /// structured outlier bytes (0 when no outliers kept)
    pub outlier_bytes: usize,
    /// CSR bytes for the same salient set (the unstructured alternative)
    pub outlier_csr_bytes: usize,
    pub dense_bytes: usize,
}

/// Whole-model compression result.
pub struct CompressionReport {
    pub layers: Vec<LayerReport>,
    pub label: String,
    pub ebft_losses: Vec<f32>,
}

impl CompressionReport {
    pub fn total_nm_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.nm_bytes).sum()
    }

    pub fn total_outlier_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.outlier_bytes).sum()
    }

    pub fn total_dense_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.dense_bytes).sum()
    }

    pub fn compression_ratio(&self) -> f64 {
        self.total_dense_bytes() as f64
            / (self.total_nm_bytes() + self.total_outlier_bytes()).max(1) as f64
    }
}

/// The orchestrator.
pub struct CompressionPipeline {
    pub exec: ModelExec,
    pub metrics: Arc<Metrics>,
}

impl CompressionPipeline {
    pub fn new(engine: Arc<Engine>, config_name: &str) -> crate::Result<Self> {
        Ok(CompressionPipeline {
            exec: ModelExec::new(engine, config_name)?,
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Compress `dense` according to `spec` using `stream` for
    /// calibration. Returns the compressed parameters (effective dense
    /// weights) and the storage report.
    pub fn run(
        &self,
        dense: &ParamSet,
        stream: &TokenStream,
        spec: &PipelineSpec,
    ) -> crate::Result<(ParamSet, CompressionReport)> {
        let (params, report, _) = self.run_inner(dense, stream, spec, false)?;
        Ok((params, report))
    }

    /// [`Self::run`] plus the **pack-artifact output stage**: the exact
    /// per-layer artifacts the pipeline computed — calibrated keep
    /// masks, variance-corrected (and optionally EBFT-tuned) kept
    /// values, quant codes/scales, structured outlier sets — are kept
    /// in packed form and returned as a [`PackedModel`], ready for
    /// [`crate::store::write_artifact`]. Serving that artifact skips
    /// the lossy magnitude re-pack a dense checkpoint cold start would
    /// do. Unstructured (CSR) outliers have no serving composite, so
    /// `spec.unstructured_outliers` is rejected here.
    pub fn run_packed(
        &self,
        dense: &ParamSet,
        stream: &TokenStream,
        spec: &PipelineSpec,
    ) -> crate::Result<(ParamSet, CompressionReport, PackedModel)> {
        let (params, report, packed) = self.run_inner(dense, stream, spec, true)?;
        Ok((params, report, packed.expect("run_inner packs when asked")))
    }

    fn run_inner(
        &self,
        dense: &ParamSet,
        stream: &TokenStream,
        spec: &PipelineSpec,
        want_pack: bool,
    ) -> crate::Result<(ParamSet, CompressionReport, Option<PackedModel>)> {
        anyhow::ensure!(
            !(want_pack && spec.unstructured_outliers),
            "pack-artifact stage supports structured outliers only (drop --unstructured)"
        );
        anyhow::ensure!(
            !(spec.quant.is_some() && spec.ternary.is_some()),
            "pick one pack-time value format: --quant intN or --quant ternary, not both"
        );
        let mut rng = Rng::new(spec.seed);
        let lits = self.exec.upload(dense)?;

        // 1. calibration (stats + block IO for EBFT)
        let calib = self.metrics.time("calibrate", || {
            Calibrator::new(&self.exec, spec.calib_batches)
                .run(dense, &lits, stream, &mut rng)
        })?;

        // 2. per-layer pruning
        let mut compressed = dense.clone();
        let mut layers = Vec::new();
        // per block: (masks, outlier masks, salient tensors) for EBFT
        // and the pack stage, BLOCK_LINEAR order
        let mut block_masks: Vec<Vec<Tensor>> = Vec::new();
        let mut block_omasks: Vec<Vec<Tensor>> = Vec::new();
        let mut block_salient: Vec<Vec<Tensor>> = Vec::new();

        for b in 0..self.exec.config.n_layers {
            let mut masks = Vec::new();
            let mut omasks = Vec::new();
            let mut salients = Vec::new();
            for lin in crate::model::BLOCK_LINEAR {
                let name = format!("blk{b}.{lin}");
                let w = dense.get(&name).clone();
                let stats = calib.stats[b].for_linear(lin)?.clone();
                let (w_eff, keep, omask, sal, report) = self.metrics.time("prune_layer", || {
                    self.prune_one(&name, &w, &stats, spec)
                })?;
                *compressed.get_mut(&name) = w_eff;
                masks.push(keep);
                omasks.push(omask);
                salients.push(sal);
                layers.push(report);
                self.metrics.incr("layers_pruned", 1);
            }
            block_masks.push(masks);
            block_omasks.push(omasks);
            block_salient.push(salients);
        }

        // 3. EBFT blockwise fine-tuning
        let mut ebft_losses = Vec::new();
        if spec.ebft_steps > 0 {
            let trainer = EbftTrainer {
                exec: &self.exec,
                config: EbftConfig {
                    steps: spec.ebft_steps,
                    lr: spec.ebft_lr,
                },
            };
            ebft_losses = self.metrics.time("ebft", || {
                trainer.run(&mut compressed, &calib, &block_masks, &block_salient)
            })?;
        }

        // 4. pack-time quantization: group-quantize the final kept base
        // values (post-VC, post-EBFT) into PackedQnm and swap the
        // dequantized effective weight back in, so eval sees exactly the
        // serving format's values. Runs last because EBFT nudges dense
        // values the quantizer must then fit. When the artifact stage is
        // on, the freshly packed layers are kept instead of discarded.
        let mut packed_layers: Vec<PackedLayer> = Vec::new();
        if let Some(qspec) = spec.quant {
            self.metrics.time("quantize", || -> crate::Result<()> {
                for b in 0..self.exec.config.n_layers {
                    for (i, lin) in crate::model::BLOCK_LINEAR.iter().enumerate() {
                        let name = format!("blk{b}.{lin}");
                        let salient = &block_salient[b][i];
                        let keep = &block_masks[b][i];
                        let w_eff = compressed.get(&name);
                        let w_ns = w_eff.zip(salient, |w, s| w - s);
                        let (_, cols) = w_ns.dims2();
                        let fitted =
                            PackedQnm::fit_spec(qspec, spec.prune.n, spec.prune.m, cols);
                        let qnm = PackedQnm::from_dense_mask(
                            &w_ns,
                            keep,
                            spec.prune.n,
                            spec.prune.m,
                            fitted,
                        );
                        let li = b * crate::model::BLOCK_LINEAR.len() + i;
                        layers[li].nm_bytes = qnm.bytes();
                        *compressed.get_mut(&name) = qnm.to_dense().add(salient);
                        if want_pack {
                            packed_layers.push(PackedLayer {
                                name,
                                weights: PackedWeights::Qnm(qnm),
                                outliers: pack_outliers(
                                    salient,
                                    &block_omasks[b][i],
                                    &spec.prune,
                                ),
                            });
                        }
                        self.metrics.incr("layers_quantized", 1);
                    }
                }
                Ok(())
            })?;
        } else if let Some(group) = spec.ternary {
            // 4''. pack-time ternarization: same placement as the int
            // quantizer (post-VC, post-EBFT — the corrected values are
            // what the per-group absmax scales fit), but the kept base
            // collapses to {-s, 0, +s} stored 5 trits per byte.
            self.metrics.time("ternarize", || -> crate::Result<()> {
                for b in 0..self.exec.config.n_layers {
                    for (i, lin) in crate::model::BLOCK_LINEAR.iter().enumerate() {
                        let name = format!("blk{b}.{lin}");
                        let salient = &block_salient[b][i];
                        let keep = &block_masks[b][i];
                        let w_eff = compressed.get(&name);
                        let w_ns = w_eff.zip(salient, |w, s| w - s);
                        let (_, cols) = w_ns.dims2();
                        let fitted =
                            PackedTnm::fit_group(group, spec.prune.n, spec.prune.m, cols);
                        let tnm = PackedTnm::from_dense_mask(
                            &w_ns,
                            keep,
                            spec.prune.n,
                            spec.prune.m,
                            fitted,
                        );
                        let li = b * crate::model::BLOCK_LINEAR.len() + i;
                        layers[li].nm_bytes = tnm.bytes();
                        *compressed.get_mut(&name) = tnm.to_dense().add(salient);
                        if want_pack {
                            packed_layers.push(PackedLayer {
                                name,
                                weights: PackedWeights::Tnm(tnm),
                                outliers: pack_outliers(
                                    salient,
                                    &block_omasks[b][i],
                                    &spec.prune,
                                ),
                            });
                        }
                        self.metrics.incr("layers_ternarized", 1);
                    }
                }
                Ok(())
            })?;
        } else if want_pack {
            // 4'. bf16 pack stage: the same per-layer assembly without
            // the quantizer — PackedNm base over the calibrated keep
            // mask, structured outliers from the salient side.
            self.metrics.time("pack_artifact", || {
                for b in 0..self.exec.config.n_layers {
                    for (i, lin) in crate::model::BLOCK_LINEAR.iter().enumerate() {
                        let name = format!("blk{b}.{lin}");
                        let salient = &block_salient[b][i];
                        let keep = &block_masks[b][i];
                        let w_eff = compressed.get(&name);
                        let w_ns = w_eff.zip(salient, |w, s| w - s);
                        let nm =
                            PackedNm::from_dense_mask(&w_ns, keep, spec.prune.n, spec.prune.m);
                        packed_layers.push(PackedLayer {
                            name,
                            weights: PackedWeights::Nm(nm),
                            outliers: pack_outliers(salient, &block_omasks[b][i], &spec.prune),
                        });
                    }
                }
            });
        }

        // 5. assemble the artifact model: packed linears + the dense
        // non-linear params (embeddings, norms) of the compressed set
        let packed = if want_pack {
            let linear_names: std::collections::BTreeSet<String> = compressed
                .linear_indices()
                .into_iter()
                .map(|(name, _)| name)
                .collect();
            let dense_params: Vec<(String, Tensor)> = compressed
                .names
                .iter()
                .zip(&compressed.tensors)
                .filter(|(name, _)| !linear_names.contains(*name))
                .map(|(name, t)| (name.clone(), t.clone()))
                .collect();
            Some(PackedModel {
                config: compressed.config.clone(),
                label: spec.label(),
                dense: dense_params,
                layers: packed_layers,
            })
        } else {
            None
        };

        Ok((
            compressed,
            CompressionReport {
                layers,
                label: spec.label(),
                ebft_losses,
            },
            packed,
        ))
    }

    /// Prune a single weight matrix; returns (effective weight, keep
    /// mask, outlier mask, salient tensor, storage report).
    fn prune_one(
        &self,
        name: &str,
        w: &Tensor,
        stats: &ActStats,
        spec: &PipelineSpec,
    ) -> crate::Result<(Tensor, Tensor, Tensor, Tensor, LayerReport)> {
        let (rows, cols) = w.dims2();
        let p = &spec.prune;

        let result = if spec.use_kernels {
            match self.prune_via_kernels(w, stats, p) {
                Ok(r) => r,
                Err(e) => {
                    log::warn!("kernel path failed for {name} ({e}); host fallback");
                    pruning::prune_layer(w, stats, p)
                }
            }
        } else {
            pruning::prune_layer(w, stats, p)
        };

        // storage accounting: pack the non-salient weights + the salient set
        let nm = PackedNm::from_dense_mask(&result.w_ns, &result.keep, p.n, p.m);
        let (outlier_bytes, outlier_csr_bytes, salient) = if p.k_outlier > 0 {
            let sal = w.mul(&result.omask);
            let csr = Csr::from_dense_mask(w, &result.omask);
            if spec.unstructured_outliers {
                (csr.bytes(), csr.bytes(), sal)
            } else {
                let so = StructuredOutliers::from_dense_mask(
                    w,
                    &result.omask,
                    p.k_outlier,
                    p.m_outlier,
                );
                (so.bytes(), csr.bytes(), sal)
            }
        } else {
            (0, 0, Tensor::zeros(vec![rows, cols]))
        };

        let mut w_eff = result.w_ns.clone();
        w_eff = w_eff.add(&salient);
        let report = LayerReport {
            name: name.to_string(),
            rows,
            cols,
            sparsity: w_eff.sparsity(),
            nm_bytes: nm.bytes(),
            outlier_bytes,
            outlier_csr_bytes,
            dense_bytes: rows * cols * 2,
        };
        Ok((w_eff, result.keep, result.omask, salient, report))
    }

    /// The L1-kernel route: score → outlier mask → keep mask → finalize,
    /// all through PJRT artifacts for this layer's shape.
    fn prune_via_kernels(
        &self,
        w: &Tensor,
        stats: &ActStats,
        p: &PruneSpec,
    ) -> crate::Result<pruning::PruneResult> {
        let (rows, cols) = w.dims2();
        let engine = &self.exec.engine;
        let km = engine.kernel_manifest(rows, cols)?;
        let wl = literal_f32(w)?;

        // scoring
        let score = match p.method {
            PruneMethod::Ria => {
                let cm = crate::runtime::literal_f32_slice(&stats.colmax, &[cols])?;
                let l2 = crate::runtime::literal_f32_slice(&stats.l2, &[cols])?;
                let sig = km.artifact(KernelSet::score_name(p.use_sq))?;
                run_refs(engine, &sig.file, &[&wl, &cm, &l2])?.remove(0)
            }
            PruneMethod::Magnitude => {
                let sig = km.artifact("magnitude")?;
                run_refs(engine, &sig.file, &[&wl])?.remove(0)
            }
            PruneMethod::Wanda => {
                let l2 = crate::runtime::literal_f32_slice(&stats.l2, &[cols])?;
                let sig = km.artifact("wanda")?;
                run_refs(engine, &sig.file, &[&wl, &l2])?.remove(0)
            }
        };

        // structured outlier mask
        let zeros = literal_f32(&Tensor::zeros(vec![rows, cols]))?;
        let omask_lit = if p.k_outlier > 0 {
            let sig = km.artifact(&KernelSet::mask_name(p.k_outlier, p.m_outlier))?;
            run_refs(engine, &sig.file, &[&score, &zeros])?.remove(0)
        } else {
            zeros
        };

        // N:M keep mask with salient exclusion
        let sig = km.artifact(&KernelSet::mask_name(p.n, p.m))?;
        let keep_lit = run_refs(engine, &sig.file, &[&score, &omask_lit])?.remove(0);

        // finalize (+VC)
        let sig = km.artifact(KernelSet::finalize_name(p.use_vc))?;
        let wns_lit = run_refs(engine, &sig.file, &[&wl, &keep_lit, &omask_lit])?.remove(0);

        Ok(pruning::PruneResult {
            w_ns: tensor_from_literal(&wns_lit)?,
            keep: tensor_from_literal(&keep_lit)?,
            omask: tensor_from_literal(&omask_lit)?,
        })
    }

    /// Convenience: generate a calibration stream-compatible RNG seed per
    /// experiment cell (deterministic across runs).
    pub fn cell_seed(base: u64, cell: &str) -> u64 {
        let mut h = 0xcbf29ce484222325u64 ^ base;
        for b in cell.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Pack the salient side stream for the artifact stage: the calibrated
/// outlier mask selects exactly `k_outlier` entries per `m_outlier`
/// block, and the values come from the *salient tensor* — the very
/// component the pipeline adds into the effective weight, so
/// base + outliers reproduces the evaluated model (up to bf16 storage).
fn pack_outliers(
    salient: &Tensor,
    omask: &Tensor,
    p: &PruneSpec,
) -> Option<StructuredOutliers> {
    if p.k_outlier == 0 {
        return None;
    }
    Some(StructuredOutliers::from_dense_mask(
        salient,
        omask,
        p.k_outlier,
        p.m_outlier,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_labels_match_paper_rows() {
        let spec = PipelineSpec::new(PruneSpec::new(2, 4));
        assert_eq!(spec.label(), "RIA+SQ+VC");
        let spec = PipelineSpec::new(PruneSpec::new(2, 4).sq(false).vc(false)).ebft(5);
        assert_eq!(spec.label(), "RIA+EBFT");
        let spec = PipelineSpec::new(
            PruneSpec::new(8, 16)
                .method(PruneMethod::Magnitude)
                .sq(false)
                .vc(false),
        );
        assert_eq!(spec.label(), "Magnitude");
        let spec = PipelineSpec::new(PruneSpec::new(8, 16)).quantize(QuantSpec::int4_g128());
        assert_eq!(spec.label(), "RIA+SQ+VC+INT4");
        let spec = PipelineSpec::new(PruneSpec::new(8, 16)).ternarize(128);
        assert_eq!(spec.label(), "RIA+SQ+VC+T158");
    }

    #[test]
    fn cell_seed_deterministic_distinct() {
        let a = CompressionPipeline::cell_seed(1, "t2/c4/2:4");
        let b = CompressionPipeline::cell_seed(1, "t2/c4/2:4");
        let c = CompressionPipeline::cell_seed(1, "t2/wiki/2:4");
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
