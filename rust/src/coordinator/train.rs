//! Pre-training driver: loops the exported `train_step` (fwd + bwd +
//! AdamW, all inside one HLO module) with a warmup+cosine LR schedule.
//!
//! This is how the stand-in models for LLaMA-2/3 and Mistral are produced
//! (DESIGN.md §Substitutions) — the e2e example trains one and logs its
//! loss curve to EXPERIMENTS.md.

use crate::data::TokenStream;
use crate::model::ParamSet;
use crate::util::timer::Stopwatch;
use crate::util::Rng;

use super::exec::ModelExec;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            lr: 3e-3,
            warmup: 20,
            log_every: 20,
            seed: 1234,
        }
    }
}

impl TrainConfig {
    /// Warmup then cosine decay to 10% of peak.
    pub fn lr_at(&self, step: usize) -> f32 {
        if step <= self.warmup {
            return self.lr * step as f32 / self.warmup.max(1) as f32;
        }
        let t = (step - self.warmup) as f32 / (self.steps - self.warmup).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos());
        self.lr * (0.1 + 0.9 * cos)
    }
}

pub struct Trainer<'a> {
    pub exec: &'a ModelExec,
    pub config: TrainConfig,
}

impl<'a> Trainer<'a> {
    /// Train `params` in place; returns the per-step loss curve.
    pub fn run(&self, params: &mut ParamSet, stream: &TokenStream) -> crate::Result<Vec<f32>> {
        let cfg = &self.exec.config;
        let mut rng = Rng::new(self.config.seed);
        let mut plits = self.exec.upload(params)?;
        let zeros = params.zeros_like();
        let mut m = self.exec.upload(&zeros)?;
        let mut v = self.exec.upload(&zeros)?;

        let mut losses = Vec::with_capacity(self.config.steps);
        let sw = Stopwatch::start();
        for step in 1..=self.config.steps {
            let tokens = stream.sample_batch(cfg.batch, cfg.seq, &mut rng);
            let lr = self.config.lr_at(step);
            let loss = self
                .exec
                .train_step(&mut plits, &mut m, &mut v, step as f32, lr, &tokens)?;
            losses.push(loss);
            if step % self.config.log_every == 0 || step == 1 {
                log::info!(
                    "train step {step}/{}: loss {loss:.4} lr {lr:.2e} ({:.2}s/step)",
                    self.config.steps,
                    sw.secs() / step as f64
                );
            }
            anyhow::ensure!(loss.is_finite(), "training diverged at step {step}");
        }
        *params = self.exec.download(&plits, params)?;
        Ok(losses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let c = TrainConfig {
            steps: 100,
            lr: 1.0,
            warmup: 10,
            log_every: 10,
            seed: 0,
        };
        assert!(c.lr_at(1) < c.lr_at(10));
        assert!((c.lr_at(10) - 1.0).abs() < 1e-6);
        assert!(c.lr_at(50) < 1.0);
        assert!(c.lr_at(100) >= 0.1 * 0.99);
        assert!(c.lr_at(100) < c.lr_at(50));
    }
}
