//! Calibration: stream batches through the dense model layer-by-layer,
//! accumulating per-linear activation statistics (SmoothQuant max-abs,
//! RIA/Wanda L2 norms) and caching the block input/output hidden states
//! that EBFT later reconstructs against.

use crate::data::TokenStream;
use crate::model::{ParamSet, BLOCK_PARAMS};
use crate::pruning::ActStats;
use crate::util::Rng;

use super::exec::{ModelExec, ParamLiterals};

/// Activation statistics for the four distinct linear inputs of a block.
#[derive(Clone, Debug)]
pub struct BlockStats {
    /// q/k/v projections input (post-ln1 hidden), dim D
    pub attn_in: ActStats,
    /// o projection input (attention output), dim D
    pub o_in: ActStats,
    /// gate/up projections input (post-ln2 hidden), dim D
    pub mlp_in: ActStats,
    /// down projection input (SwiGLU output), dim H
    pub down_in: ActStats,
}

impl BlockStats {
    fn new(d: usize, h: usize) -> Self {
        BlockStats {
            attn_in: ActStats::new(d),
            o_in: ActStats::new(d),
            mlp_in: ActStats::new(d),
            down_in: ActStats::new(h),
        }
    }

    /// Statistics for a named linear weight of the block. A name outside
    /// the `BLOCK_LINEAR` contract (a malformed checkpoint or pipeline
    /// spec) returns a typed [`crate::Error::NotALinear`] instead of
    /// panicking, so it cannot abort a serving/compression process.
    pub fn for_linear(&self, name: &str) -> crate::Result<&ActStats> {
        match name {
            "wq" | "wk" | "wv" => Ok(&self.attn_in),
            "wo" => Ok(&self.o_in),
            "wg" | "wu" => Ok(&self.mlp_in),
            "wd" => Ok(&self.down_in),
            _ => Err(crate::Error::NotALinear(name.to_string()).into()),
        }
    }
}

/// Calibration output: stats per block + cached block IO for EBFT.
pub struct CalibRecord {
    pub stats: Vec<BlockStats>,
    /// per batch: token ids (B*S) fed to the model
    pub batch_ids: Vec<Vec<i32>>,
    /// per batch, per block boundary (L+1 entries): hidden literals of the
    /// *dense* model — `hiddens[bi][l]` is the input to block `l`,
    /// `hiddens[bi][L]` the final hidden
    pub hiddens: Vec<Vec<xla::Literal>>,
}

/// Runs calibration passes.
pub struct Calibrator<'a> {
    pub exec: &'a ModelExec,
    pub n_batches: usize,
}

impl<'a> Calibrator<'a> {
    pub fn new(exec: &'a ModelExec, n_batches: usize) -> Self {
        Calibrator { exec, n_batches }
    }

    /// Run the dense model over `n_batches` sampled windows, collecting
    /// stats and block IO.
    pub fn run(
        &self,
        params: &ParamSet,
        lits: &ParamLiterals,
        stream: &TokenStream,
        rng: &mut Rng,
    ) -> crate::Result<CalibRecord> {
        let cfg = &self.exec.config;
        let (b, s) = (cfg.batch, cfg.seq);
        let nb = BLOCK_PARAMS.len();
        let mut stats: Vec<BlockStats> = (0..cfg.n_layers)
            .map(|_| BlockStats::new(cfg.dim, cfg.hidden))
            .collect();
        let mut batch_ids = Vec::with_capacity(self.n_batches);
        let mut hiddens = Vec::with_capacity(self.n_batches);

        for _ in 0..self.n_batches {
            let window = stream.sample_batch(b, s, rng); // (B, S+1)
            // inputs only (drop the shifted target column)
            let mut ids = Vec::with_capacity(b * s);
            for r in 0..b {
                ids.extend_from_slice(&window[r * (s + 1)..r * (s + 1) + s]);
            }
            let tok_emb = &lits.lits[0];
            let mut h = self.exec.embed(tok_emb, &ids)?;
            let mut layer_hiddens = Vec::with_capacity(cfg.n_layers + 1);
            for l in 0..cfg.n_layers {
                let base = 1 + l * nb;
                let blk: Vec<&xla::PjRtBuffer> =
                    lits.lits[base..base + nb].iter().map(|d| &**d).collect();
                let (h_out, st) = self.exec.block_fwd(&blk, &h)?;
                // aot order: (colmax, l2) × (attn_in, o_in, mlp_in, down_in)
                stats[l].attn_in.merge(&st[0], &st[1]);
                stats[l].o_in.merge(&st[2], &st[3]);
                stats[l].mlp_in.merge(&st[4], &st[5]);
                stats[l].down_in.merge(&st[6], &st[7]);
                layer_hiddens.push(h);
                h = h_out;
            }
            layer_hiddens.push(h);
            batch_ids.push(ids);
            hiddens.push(layer_hiddens);
        }
        let _ = params;
        Ok(CalibRecord {
            stats,
            batch_ids,
            hiddens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_linear_rejects_non_linears_with_typed_error() {
        let bs = BlockStats::new(4, 8);
        assert!(bs.for_linear("wq").is_ok());
        assert!(bs.for_linear("wd").is_ok());
        let err = bs.for_linear("ln1").unwrap_err();
        match err.downcast_ref::<crate::Error>() {
            Some(crate::Error::NotALinear(n)) => assert_eq!(n, "ln1"),
            other => panic!("want NotALinear, got {other:?}"),
        }
    }
}
