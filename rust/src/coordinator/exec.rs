//! Model-graph execution: typed wrappers over the model-level HLO
//! artifacts (embed_fwd / block_fwd / head_nll / lm_nll / train_step /
//! ebft_step).
//!
//! Parameters are kept as PJRT literals (`upload`) so repeated executions
//! (eval batches, train steps) don't re-serialize host tensors.

use std::sync::Arc;

use crate::model::{ModelConfig, ParamSet, BLOCK_PARAMS};
use crate::runtime::{literal_f32, literal_i32, literal_scalar, DeviceBuffer, Engine, Manifest};
use crate::tensor::Tensor;

/// Executes the model-level artifacts of one config.
pub struct ModelExec {
    pub engine: Arc<Engine>,
    pub manifest: Manifest,
    pub config: ModelConfig,
}

/// Parameters resident **on device** (PJRT buffers), in flat artifact
/// order. Uploaded once; every eval/train call borrows them, so the
/// per-call host→device traffic is just the token batch.
pub struct ParamLiterals {
    pub lits: Vec<DeviceBuffer>,
}

impl ModelExec {
    pub fn new(engine: Arc<Engine>, config_name: &str) -> crate::Result<ModelExec> {
        let manifest = engine.model_manifest(config_name)?;
        let config = ModelConfig::from_manifest(&manifest.raw);
        Ok(ModelExec {
            engine,
            manifest,
            config,
        })
    }

    /// Upload a parameter set (flat order) to device buffers.
    pub fn upload(&self, params: &ParamSet) -> crate::Result<ParamLiterals> {
        let lits = params
            .tensors
            .iter()
            .map(|t| self.engine.upload(literal_f32(t)?))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(ParamLiterals { lits })
    }

    /// Per-token negative log-likelihood over a (B, S+1) token batch.
    pub fn lm_nll(&self, params: &ParamLiterals, tokens: &[i32]) -> crate::Result<Tensor> {
        let (b, s) = (self.config.batch, self.config.seq);
        anyhow::ensure!(tokens.len() == b * (s + 1), "lm_nll batch shape");
        let mut inputs: Vec<&xla::PjRtBuffer> = params.lits.iter().map(|d| &**d).collect();
        let tok = self.engine.upload(literal_i32(tokens, &[b, s + 1])?)?;
        inputs.push(&tok);
        let sig = self.manifest.artifact("lm_nll")?;
        let outs = self.engine.run_buffers(&sig.file, &inputs)?;
        crate::runtime::tensor_from_literal(&outs[0])
    }

    /// Token embedding: (B, S) ids -> (B, S, D) hidden.
    pub fn embed(&self, tok_emb: &xla::PjRtBuffer, ids: &[i32]) -> crate::Result<xla::Literal> {
        let (b, s) = (self.config.batch, self.config.seq);
        anyhow::ensure!(ids.len() == b * s, "embed batch shape");
        let idl = self.engine.upload(literal_i32(ids, &[b, s])?)?;
        let sig = self.manifest.artifact("embed_fwd")?;
        let mut outs = self.engine.run_buffers(&sig.file, &[tok_emb, &idl])?;
        Ok(outs.remove(0))
    }

    /// One block forward with activation statistics.
    ///
    /// Returns `(hidden_out, stats)` where stats is the 8 aot-ordered
    /// vectors: (colmax, l2) × (attn_in, o_in, mlp_in, down_in).
    pub fn block_fwd(
        &self,
        block_params: &[&xla::PjRtBuffer],
        hidden: &xla::Literal,
    ) -> crate::Result<(xla::Literal, Vec<Vec<f32>>)> {
        anyhow::ensure!(block_params.len() == BLOCK_PARAMS.len());
        let mut inputs: Vec<&xla::PjRtBuffer> = block_params.to_vec();
        let hb = self.engine.upload(hidden.clone())?;
        inputs.push(&hb);
        let sig = self.manifest.artifact("block_fwd")?;
        let mut outs = self.engine.run_buffers(&sig.file, &inputs)?;
        let hidden_out = outs.remove(0);
        let stats = outs
            .iter()
            .map(|l| crate::runtime::vec_from_literal(l))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok((hidden_out, stats))
    }

    /// Final norm + tied head: per-token nll of `targets` given hidden.
    pub fn head_nll(
        &self,
        ln_f: &xla::PjRtBuffer,
        tok_emb: &xla::PjRtBuffer,
        hidden: &xla::Literal,
        targets: &[i32],
    ) -> crate::Result<Tensor> {
        let (b, s) = (self.config.batch, self.config.seq);
        let tgt = self.engine.upload(literal_i32(targets, &[b, s])?)?;
        let hb = self.engine.upload(hidden.clone())?;
        let sig = self.manifest.artifact("head_nll")?;
        let outs = self
            .engine
            .run_buffers(&sig.file, &[ln_f, tok_emb, &hb, &tgt])?;
        crate::runtime::tensor_from_literal(&outs[0])
    }

    /// One AdamW pre-training step; updates `params`, `m`, `v` in place
    /// (literal swap) and returns the loss.
    pub fn train_step(
        &self,
        params: &mut ParamLiterals,
        m: &mut ParamLiterals,
        v: &mut ParamLiterals,
        step: f32,
        lr: f32,
        tokens: &[i32],
    ) -> crate::Result<f32> {
        let (b, s) = (self.config.batch, self.config.seq);
        anyhow::ensure!(tokens.len() == b * (s + 1), "train batch shape");
        let np = params.lits.len();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(3 * np + 3);
        inputs.extend(params.lits.iter().map(|d| &**d));
        inputs.extend(m.lits.iter().map(|d| &**d));
        inputs.extend(v.lits.iter().map(|d| &**d));
        let stepl = self.engine.upload(literal_scalar(step))?;
        let lrl = self.engine.upload(literal_scalar(lr))?;
        let tok = self.engine.upload(literal_i32(tokens, &[b, s + 1])?)?;
        inputs.push(&stepl);
        inputs.push(&lrl);
        inputs.push(&tok);
        let sig = self.manifest.artifact("train_step")?;
        let mut outs = self.engine.run_buffers(&sig.file, &inputs)?;
        anyhow::ensure!(outs.len() == 3 * np + 1, "train_step output arity");
        let loss = outs.pop().unwrap().to_vec::<f32>()?[0];
        // re-upload the updated state as device buffers for the next step
        let mut bufs = outs
            .into_iter()
            .map(|l| self.engine.upload(l))
            .collect::<crate::Result<Vec<_>>>()?;
        let vs = bufs.split_off(2 * np);
        let ms = bufs.split_off(np);
        params.lits = bufs;
        m.lits = ms;
        v.lits = vs;
        Ok(loss)
    }

    /// Download literal parameters back into a host [`ParamSet`].
    pub fn download(&self, lits: &ParamLiterals, like: &ParamSet) -> crate::Result<ParamSet> {
        anyhow::ensure!(lits.lits.len() == like.tensors.len());
        let tensors = lits
            .lits
            .iter()
            .map(|b| crate::runtime::tensor_from_literal(&b.to_literal_sync()?))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(ParamSet {
            config: like.config.clone(),
            names: like.names.clone(),
            tensors,
        })
    }
}

/// Execute with borrowed host literals: uploads to device buffers for
/// this call only (they drop on return). Use [`Engine::run_buffers`]
/// directly when inputs are reused across calls.
pub(crate) fn run_refs(
    engine: &Engine,
    file: &std::path::Path,
    inputs: &[&xla::Literal],
) -> crate::Result<Vec<xla::Literal>> {
    // borrowed uploads: the caller's literals outlive this synchronous
    // call, which awaits the output chain (see Engine::upload_borrowed)
    let bufs = inputs
        .iter()
        .map(|l| engine.upload_borrowed(l))
        .collect::<crate::Result<Vec<_>>>()?;
    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    engine.run_buffers(file, &refs)
}
