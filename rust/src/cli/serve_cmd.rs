//! `sparselm serve` / `serve-bench` — the deployment front end.
//!
//! `serve` loads a (compressed) checkpoint and exposes the scoring
//! protocol on a TCP port; `serve-bench` is the matching closed-loop
//! load generator reporting latency percentiles and batch fill — the
//! numbers a deployment of the paper's sparse models would be judged on.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::{CorpusKind, CorpusSpec, Tokenizer, World};
use crate::model::{load_checkpoint, SparseLm};
use crate::serve::{pjrt_scorer, serve, spmm_scorer, ServeClient, ServerConfig};
use crate::util::args::Args;

/// Rebuild the deterministic tokenizer every component shares (the same
/// construction as `ExperimentCtx::new`, without touching PJRT).
pub fn standard_tokenizer(fast: bool) -> Tokenizer {
    let world = World::new(crate::bench::WORLD_SEED);
    let sentences = if fast { 20_000 } else { 120_000 };
    let text = CorpusSpec::new(CorpusKind::Wiki, sentences, 11).generate(&world);
    Tokenizer::fit(&text, 2048)
}

pub fn cmd_serve(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "tiny");
    let ckpt = args.get_str("ckpt", &format!("runs/{model}.ckpt"));
    let artifacts = args.get_str("artifacts", "artifacts");
    let addr = args.get_str("addr", "127.0.0.1:7433");
    let params = load_checkpoint(std::path::Path::new(&ckpt))?;
    let batch = params.config.batch;
    let tokenizer = Arc::new(standard_tokenizer(crate::bench::fast_mode()));
    let server_cfg = ServerConfig {
        addr,
        max_conns: args.get_usize("max-conns", 32),
        max_batch: batch,
        max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 15)),
    };
    // default: serve the checkpoint decode-free (packed spmm host
    // forward); `--backend dense` serves the exact weights through the
    // host forward; `--backend pjrt` keeps the artifact path (needs
    // `--features xla`)
    let default_backend = if crate::runtime::pjrt_available() {
        "pjrt"
    } else {
        "spmm"
    };
    let backend = args.get_str("backend", default_backend);
    let threads = args.get_usize("threads", crate::util::pool::default_parallelism());
    let handle = match backend.as_str() {
        "pjrt" => serve(
            pjrt_scorer(artifacts, model.clone(), params),
            tokenizer,
            server_cfg,
        )?,
        "dense" => {
            let lm = SparseLm::from_params(&params).with_threads(threads);
            serve(spmm_scorer(lm), tokenizer, server_cfg)?
        }
        "spmm" => {
            let (n, m) = super::parse_pattern(&args.get_str("pack", "8:16"))?;
            let k = args.get_usize("outliers", 16);
            let lm = SparseLm::compress(&params, n, m, k).with_threads(threads);
            println!(
                "packing checkpoint to {n}:{m} + {k}:256 (magnitude selection) — \
                 lossy for dense checkpoints; use --backend dense to serve exact weights"
            );
            println!(
                "packed linear traffic {} KiB (dense {} KiB)",
                lm.linear_operand_bytes() / 1024,
                lm.dense_linear_bytes() / 1024
            );
            serve(spmm_scorer(lm), tokenizer, server_cfg)?
        }
        other => anyhow::bail!("unknown --backend {other} (expected spmm|dense|pjrt)"),
    };
    println!(
        "serving {model} ({ckpt}, {backend}) on {} — newline-JSON ops: ping/nll/choice/stats/shutdown",
        handle.addr
    );
    handle.join()?;
    println!("server stopped");
    Ok(())
}

pub fn cmd_serve_bench(args: Args) -> crate::Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7433");
    let clients = args.get_usize("clients", 4);
    let reqs = args.get_usize("requests", 50);
    let world = World::new(99);
    let text = CorpusSpec::new(CorpusKind::Wiki, 2_000, 17).generate(&world);
    let sentences: Vec<&str> = text
        .split('.')
        .filter(|s| s.split_whitespace().count() > 4)
        .collect();
    anyhow::ensure!(!sentences.is_empty(), "no bench sentences");

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let sents: Vec<String> = sentences
            .iter()
            .skip(c)
            .step_by(clients)
            .take(reqs)
            .map(|s| s.to_string())
            .collect();
        handles.push(std::thread::spawn(move || -> crate::Result<Vec<f64>> {
            let mut cl = ServeClient::connect(&addr)?;
            cl.set_timeout(Duration::from_secs(60))?;
            let mut lats = Vec::with_capacity(sents.len());
            for s in &sents {
                let t = Instant::now();
                cl.nll(s)?;
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lats)
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for h in handles {
        lats.extend(h.join().map_err(|_| anyhow::anyhow!("client panicked"))??);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
    println!(
        "{} requests from {clients} clients in {wall:.2}s ({:.1} req/s)",
        lats.len(),
        lats.len() as f64 / wall
    );
    println!(
        "latency ms: p50 {:.1} / p90 {:.1} / p99 {:.1} / max {:.1}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        lats.last().unwrap()
    );
    // pull server-side stats for batch fill
    let mut cl = ServeClient::connect(&addr)?;
    let stats = cl.stats()?;
    let batches = stats.at("batches").as_f64().unwrap_or(1.0).max(1.0);
    let rows = stats.at("rows_scored").as_f64().unwrap_or(0.0);
    println!(
        "server: {} batches, mean fill {:.2} rows/batch, {} timeout flushes",
        batches,
        rows / batches,
        stats.at("timeout_flushes").as_f64().unwrap_or(0.0)
    );
    Ok(())
}
