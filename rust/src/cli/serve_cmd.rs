//! `sparselm serve` / `serve-bench` / `generate` — the deployment
//! front end.
//!
//! `serve` loads a (compressed) checkpoint and exposes the scoring +
//! generation protocol on a TCP port — and, with `--http ADDR`, the
//! same model over the production HTTP front end (`POST /score`,
//! `POST /generate`, `GET /health`, Prometheus `GET /metrics`) with a
//! SIGTERM-driven graceful drain; `--fleet K` swaps the single process
//! for a router + K supervised worker processes sharing one mmap'd
//! artifact ([`super::fleet_cmd`]); `generate` runs the same KV-cached
//! decode engine in-process for one prompt; `serve-bench` is the
//! matching closed-loop load generator reporting latency percentiles
//! and batch fill — the numbers a deployment of the paper's sparse
//! models would be judged on.
//!
//! Backend construction is typed end to end: the `--backend` string
//! parses into a [`BackendSpec`], and [`EngineBuilder`] (shared with
//! `generate` and fleet worker boot) owns pattern/outlier/quant policy
//! and the `--repack` acknowledgment.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::tokenizer::{BOS, EOS};
use crate::data::{CorpusKind, CorpusSpec, Tokenizer, World};
use crate::eval::Sampler;
use crate::model::{load_checkpoint, ModelConfig, ParamSet, SparseLm};
use crate::serve::{
    BackendSpec, Engine, EngineBuilder, HttpConfig, ServeClient, ServerConfig, ServerHandle,
};
use crate::util::args::Args;
use crate::util::{trace, Rng};

/// Rebuild the deterministic tokenizer every component shares (the same
/// construction as `ExperimentCtx::new`, without touching PJRT).
pub fn standard_tokenizer(fast: bool) -> Tokenizer {
    let world = World::new(crate::bench::WORLD_SEED);
    let sentences = if fast { 20_000 } else { 120_000 };
    let text = CorpusSpec::new(CorpusKind::Wiki, sentences, 11).generate(&world);
    Tokenizer::fit(&text, 2048)
}

/// The one `--pack`/`--outliers`/`--qbits`/`--tgroup`/`--threads`/
/// `--repack` → [`EngineBuilder`] mapping, shared by `serve`,
/// `generate` and fleet worker boot so the three cannot drift.
pub(crate) fn engine_builder(args: &Args) -> crate::Result<EngineBuilder> {
    let (n, m) = super::parse_pattern(&args.get_str("pack", "8:16"))?;
    Ok(EngineBuilder::new()
        .pattern(n, m)
        .outliers(args.get_usize("outliers", 16)?)
        .quant(super::parse_quant_spec(args)?)
        .ternary_group(args.get_usize("tgroup", 128)?)
        .threads(args.get_usize("threads", crate::util::pool::default_parallelism())?)
        .acknowledge_repack(args.get_bool("repack"))
        .artifacts(args.get_str("artifacts", "artifacts")))
}

/// Apply `--trace-slow-ms` (slow-request structured log threshold; the
/// span recorder itself is always on). Shared by `serve`, the fleet
/// router and fleet workers so the flag means the same thing per role.
pub(crate) fn apply_trace_flags(args: &Args) -> crate::Result<()> {
    if args.get("trace-slow-ms").is_some() {
        trace::set_slow_ms(args.get_u64("trace-slow-ms", u64::MAX)?);
    }
    Ok(())
}

/// `--http*` flags → front-end config; `None` when `--http` is absent.
pub(crate) fn http_cfg(args: &Args) -> crate::Result<Option<HttpConfig>> {
    let Some(addr) = args.get("http") else {
        return Ok(None);
    };
    let mut cfg = HttpConfig::default();
    // bare `--http` (no value) parses as "true": keep the default addr
    if addr != "true" {
        cfg.addr = addr.to_string();
    }
    cfg.max_conns = args.get_usize("http-max-conns", cfg.max_conns)?;
    cfg.max_body = args.get_usize("http-max-body", cfg.max_body)?;
    cfg.max_head = args.get_usize("http-max-head", cfg.max_head)?;
    cfg.max_inflight = args.get_usize("http-max-inflight", cfg.max_inflight)?;
    cfg.read_timeout = Duration::from_millis(args.get_u64("http-read-timeout-ms", 5_000)?);
    cfg.write_timeout =
        Duration::from_millis(args.get_u64("http-write-timeout-ms", 5_000)?);
    cfg.retry_after_secs = args.get_u64("http-retry-after", cfg.retry_after_secs)?;
    cfg.drain_grace = Duration::from_millis(args.get_u64("http-drain-grace-ms", 5_000)?);
    Ok(Some(cfg))
}

/// Block on the TCP handle; with `--http`, run the HTTP front end
/// alongside it and install the SIGTERM/SIGINT graceful-drain sequence
/// (refuse new HTTP work → finish in-flight → stop HTTP → stop TCP).
fn run_front_ends(handle: ServerHandle, http: Option<HttpConfig>) -> crate::Result<()> {
    let Some(cfg) = http else {
        handle.join()?;
        println!("server stopped");
        return Ok(());
    };
    let http_handle = Arc::new(handle.attach_http(cfg)?);
    println!(
        "http front end on {} — POST /score, POST /generate, GET /health, GET /metrics",
        http_handle.addr
    );
    crate::util::signal::install();
    let tcp_addr = handle.addr;
    let watcher_http = Arc::clone(&http_handle);
    std::thread::spawn(move || {
        while !crate::util::signal::termination_requested() {
            std::thread::sleep(Duration::from_millis(100));
        }
        // drain first (in-flight HTTP requests still need the workers),
        // then stop the TCP server, which unblocks the join below
        let _ = watcher_http.shutdown();
        if let Ok(mut c) = ServeClient::connect(tcp_addr) {
            let _ = c.shutdown();
        }
    });
    handle.join()?;
    // TCP stopped via a client's shutdown op rather than a signal:
    // bring the HTTP side down too (no-op after the watcher's call)
    http_handle.shutdown()?;
    println!("server stopped");
    Ok(())
}

pub fn cmd_serve(args: Args) -> crate::Result<()> {
    // --fleet K: router + K supervised worker processes over one .spak
    if args.get("fleet").is_some() {
        return super::fleet_cmd::cmd_serve_fleet(args);
    }
    trace::set_process_name("server");
    apply_trace_flags(&args)?;
    let model = args.get_str("model", "tiny");
    let addr = args.get_str("addr", "127.0.0.1:7433");
    let gen_batch = args.get_usize("gen-batch", 8)?.max(1);
    let mk_cfg = |batch: usize| -> crate::Result<ServerConfig> {
        Ok(ServerConfig {
            addr: addr.clone(),
            max_conns: args.get_usize("max-conns", 32)?,
            max_batch: batch,
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 15)?),
            max_gen_tokens: args.get_usize("max-gen-tokens", 512)?,
        })
    };
    let tokenizer = Arc::new(standard_tokenizer(crate::bench::fast_mode()));
    let builder = engine_builder(&args)?;

    // --model x.spak: mmap the packed artifact and serve it zero-copy —
    // no re-pack, no backend choice (the artifact *is* the format)
    if model.ends_with(".spak") {
        if let Some(b) = args.get("backend") {
            anyhow::bail!(
                "--model {model} serves the artifact's own packed format; \
                 --backend {b} does not apply"
            );
        }
        let t0 = Instant::now();
        let (engine, info) = builder.open_artifact(std::path::Path::new(&model))?;
        println!(
            "mmap'd {model} in {:.0} ms ({}; zero-copy: {}): packed linears {} KiB \
             at {:.4} bits/param base, dense params {} KiB",
            t0.elapsed().as_secs_f64() * 1e3,
            if info.label.is_empty() { "unlabeled" } else { info.label.as_str() },
            info.mapped,
            (info.linear_stream_bytes + info.outlier_stream_bytes) / 1024,
            info.base_bits_per_param(),
            info.dense_stream_bytes / 1024
        );
        let cfg = mk_cfg(engine.batch())?;
        let handle = engine.serve(Arc::clone(&tokenizer), cfg, gen_batch)?;
        println!(
            "serving {model} (spak, spmm) on {} — newline-JSON ops: \
             ping/nll/choice/generate/stats/shutdown",
            handle.addr
        );
        return run_front_ends(handle, http_cfg(&args)?);
    }

    let ckpt = args.get_str("ckpt", &format!("runs/{model}.ckpt"));
    let params = load_checkpoint(std::path::Path::new(&ckpt))?;
    let server_cfg = mk_cfg(params.config.batch)?;
    // default: serve the checkpoint decode-free (packed spmm host
    // forward); `--backend dense` serves the exact weights through the
    // host forward; `--backend pjrt` keeps the artifact path (needs
    // `--features xla`). The host-forward backends also serve the
    // `generate` op through the continuous-batching decode engine —
    // `--gen-batch` bounds the decode batch.
    let default_backend = if crate::runtime::pjrt_available() {
        "pjrt"
    } else {
        "spmm"
    };
    let backend: BackendSpec = args.get_str("backend", default_backend).parse()?;
    let engine = builder.build(backend, params, &model)?;
    if !engine.describe().is_empty() {
        println!("{}", engine.describe());
    }
    let supports_generate = engine.supports_generate();
    let handle = engine.serve(Arc::clone(&tokenizer), server_cfg, gen_batch)?;
    println!(
        "serving {model} ({ckpt}, {backend}) on {} — newline-JSON ops: \
         ping/nll/choice/generate/stats/shutdown{}",
        handle.addr,
        if supports_generate {
            ""
        } else {
            " (generate unavailable on pjrt)"
        }
    );
    run_front_ends(handle, http_cfg(&args)?)
}

/// `sparselm generate` — one-shot KV-cached generation, in-process (the
/// same prefill → decode loop the server's `generate` op runs, without
/// the socket). `--random` initializes a stand-in model instead of
/// loading a checkpoint, so the subcommand works fully offline.
pub fn cmd_generate(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "tiny");
    let prompt = args.get_str("prompt", "the quick brown fox");
    let max_tokens = args.get_usize("max-tokens", 32)?.max(1);
    let temperature = args.get_f64("temperature", 0.0)? as f32;
    let seed = args.get_u64("seed", 0)?;
    // the one-shot tool owns its approximation: no --repack ceremony
    let builder = engine_builder(&args)?.acknowledge_repack(true);
    let load_params = || -> crate::Result<ParamSet> {
        if args.get_bool("random") {
            let cfg = ModelConfig::preset(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown model preset {model:?}"))?;
            Ok(ParamSet::init_outliers(&cfg, &mut Rng::new(seed ^ 0xFACE)))
        } else {
            let ckpt = args.get_str("ckpt", &format!("runs/{model}.ckpt"));
            load_checkpoint(std::path::Path::new(&ckpt))
        }
    };

    // --spec: self-speculative decode in-process — int4 draft proposes,
    // bf16 target verifies in one windowed forward; the emitted tokens
    // are identical to the plain packed path by construction
    if args.get_bool("spec") {
        anyhow::ensure!(
            !model.ends_with(".spak"),
            "--spec needs a dense checkpoint or --random: a .spak artifact holds one \
             packed value stream, not the draft/target pair"
        );
        let Engine::Spec { dec, .. } =
            builder.build(BackendSpec::Spec, load_params()?, &model)?
        else {
            unreachable!("BackendSpec::Spec builds Engine::Spec");
        };
        let tokenizer = standard_tokenizer(crate::bench::fast_mode());
        let mut ids = vec![BOS];
        ids.extend(tokenizer.encode(&prompt));
        let mut sampler = Sampler::new(temperature, seed);
        let before = crate::util::perf::snapshot();
        let t0 = Instant::now();
        let emitted = dec.generate(&ids, max_tokens, Some(EOS), |logits| sampler.next(logits))?;
        let dt = t0.elapsed().as_secs_f64();
        let p = crate::util::perf::snapshot().delta(&before);
        println!("{prompt} {}", tokenizer.decode(&emitted));
        println!(
            "-- {} tokens in {dt:.2}s ({:.1} tok/s); {} spec rounds, accept rate \
             {:.2}, mean accepted {:.2}/round, {} mispredicts; draft streams {} KiB \
             packed weights/step (target {} KiB)",
            emitted.len(),
            emitted.len() as f64 / dt.max(1e-9),
            p.spec_rounds,
            p.spec_accept_rate(),
            p.spec_mean_accepted(),
            p.spec_mispredicts,
            dec.draft().linear_operand_bytes() / 1024,
            dec.target().linear_operand_bytes() / 1024
        );
        return Ok(());
    }

    // --model x.spak: decode straight from the mmap'd artifact (no
    // re-pack; the stored selection — calibrated when the pipeline
    // wrote it — is what serves)
    let lm: Arc<SparseLm> = if model.ends_with(".spak") {
        let (engine, info) = builder.open_artifact(std::path::Path::new(&model))?;
        println!(
            "mmap'd {model} ({}; zero-copy: {}): {:.4} bits/param base",
            if info.label.is_empty() { "unlabeled" } else { info.label.as_str() },
            info.mapped,
            info.base_bits_per_param()
        );
        let Engine::Spmm { lm, .. } = engine else {
            unreachable!("artifacts open as Engine::Spmm");
        };
        lm
    } else {
        let backend = if args.get_bool("dense") {
            BackendSpec::Dense
        } else {
            match super::parse_quant_mode(&args)? {
                super::QuantMode::None => BackendSpec::Spmm,
                super::QuantMode::Int(_) => BackendSpec::SpmmQ4,
                super::QuantMode::Ternary(_) => BackendSpec::SpmmT,
            }
        };
        let Engine::Spmm { lm, .. } = builder.build(backend, load_params()?, &model)? else {
            unreachable!("host-forward backends build Engine::Spmm");
        };
        lm
    };
    let tokenizer = standard_tokenizer(crate::bench::fast_mode());

    let mut ids = vec![BOS];
    ids.extend(tokenizer.encode(&prompt));
    let mut sampler = Sampler::new(temperature, seed);
    let t0 = Instant::now();
    // one shared decode loop: SparseLm::generate stops at EOS without
    // burning budget and caps prompt + generated at the context window
    let emitted = lm.generate(&ids, max_tokens, Some(EOS), |logits| sampler.next(logits))?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{prompt} {}", tokenizer.decode(&emitted));
    println!(
        "-- {} tokens in {:.2}s ({:.1} tok/s); decode streams {} KiB packed weights/step \
         (dense {} KiB)",
        emitted.len(),
        dt,
        emitted.len() as f64 / dt.max(1e-9),
        lm.linear_operand_bytes() / 1024,
        lm.dense_linear_bytes() / 1024
    );
    Ok(())
}

/// `sparselm trace` — pull Chrome trace-event JSON out of a running
/// server's (or fleet router's) flight recorder over the line protocol.
/// Explicit `--id` hex ids win over `--last K`; the page loads directly
/// in Perfetto / `chrome://tracing`.
pub fn cmd_trace(args: Args) -> crate::Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7433");
    let mut ids: Vec<u64> = Vec::new();
    if let Some(v) = args.get("id") {
        for part in v.split(',').filter(|p| !p.is_empty()) {
            ids.push(
                trace::parse_hex(part).ok_or_else(|| anyhow::anyhow!("bad trace id {part:?}"))?,
            );
        }
    }
    let last = args.get_usize("last", 1)?;
    anyhow::ensure!(
        (1..=1024).contains(&last),
        "--last must be in 1..=1024, got {last}"
    );
    let mut cl = ServeClient::connect(&addr)?;
    cl.set_timeout(Duration::from_secs(10))?;
    let page = cl.trace_export(&ids, last)?;
    let events = page
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .map(|a| a.len())
        .unwrap_or(0);
    let out = args.get_str("out", "");
    if out.is_empty() {
        println!("{page}");
    } else {
        std::fs::write(&out, page.to_string())?;
        eprintln!("wrote {out}: {events} events — load in Perfetto or chrome://tracing");
    }
    Ok(())
}

pub fn cmd_serve_bench(args: Args) -> crate::Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7433");
    let clients = args.get_usize("clients", 4)?;
    let reqs = args.get_usize("requests", 50)?;
    let world = World::new(99);
    let text = CorpusSpec::new(CorpusKind::Wiki, 2_000, 17).generate(&world);
    let sentences: Vec<&str> = text
        .split('.')
        .filter(|s| s.split_whitespace().count() > 4)
        .collect();
    anyhow::ensure!(!sentences.is_empty(), "no bench sentences");

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let sents: Vec<String> = sentences
            .iter()
            .skip(c)
            .step_by(clients)
            .take(reqs)
            .map(|s| s.to_string())
            .collect();
        handles.push(std::thread::spawn(move || -> crate::Result<Vec<f64>> {
            let mut cl = ServeClient::connect(&addr)?;
            cl.set_timeout(Duration::from_secs(60))?;
            let mut lats = Vec::with_capacity(sents.len());
            for s in &sents {
                let t = Instant::now();
                cl.nll(s)?;
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lats)
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for h in handles {
        lats.extend(h.join().map_err(|_| anyhow::anyhow!("client panicked"))??);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
    println!(
        "{} requests from {clients} clients in {wall:.2}s ({:.1} req/s)",
        lats.len(),
        lats.len() as f64 / wall
    );
    println!(
        "latency ms: p50 {:.1} / p90 {:.1} / p99 {:.1} / max {:.1}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        lats.last().unwrap()
    );
    // pull server-side stats for batch fill — `get`, not the panicking
    // `at`: the server's reply is not a manifest we control, and a
    // missing counter (older server, pjrt backend) should degrade to a
    // zero, not abort the bench
    let mut cl = ServeClient::connect(&addr)?;
    let stats = cl.stats()?;
    let field = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let batches = field("batches").max(1.0);
    println!(
        "server: {} batches, mean fill {:.2} rows/batch, {} timeout flushes",
        batches,
        field("rows_scored") / batches,
        field("timeout_flushes")
    );
    Ok(())
}
