//! `sparselm serve --fleet K` and the internal `fleet-worker`
//! subcommand it re-execs.
//!
//! The router side (`cmd_serve_fleet`) boots K `fleet-worker` child
//! processes over one `.spak`, each a complete single-process server on
//! an OS-assigned loopback port, and exposes the same TCP + HTTP
//! ingress a plain `sparselm serve` would — so clients and dashboards
//! cannot tell a fleet from a single process except by throughput and
//! the extra `sparselm_fleet_*` metric families.
//!
//! The worker side (`cmd_fleet_worker`) is deliberately thin: the same
//! [`EngineBuilder`] path as `serve --model x.spak`, plus the one-line
//! stdout readiness handshake the router blocks on.
//!
//! [`EngineBuilder`]: crate::serve::EngineBuilder

use std::io::Write;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::serve::fleet::{process_spawner, start_fleet, FleetConfig, READY_PREFIX};
use crate::serve::http::serve_http;
use crate::serve::ServerConfig;
use crate::util::args::Args;

/// Router process: spawn and supervise K workers, fan ops out.
pub(crate) fn cmd_serve_fleet(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "");
    anyhow::ensure!(
        model.ends_with(".spak"),
        "--fleet serves a packed artifact: pass --model <x.spak> (every worker \
         mmaps the same read-only copy, so K workers cost ~one copy of the weights)"
    );
    super::serve_cmd::apply_trace_flags(&args)?;
    let defaults = FleetConfig::default();
    let cfg = FleetConfig {
        addr: args.get_str("addr", &defaults.addr),
        workers: args.get_usize("fleet", defaults.workers)?.max(1),
        max_conns: args.get_usize("max-conns", defaults.max_conns)?,
        worker_inflight: args.get_usize("worker-inflight", defaults.worker_inflight)?,
        drain_grace: Duration::from_millis(args.get_u64("drain-grace-ms", 5_000)?),
        reap_grace: Duration::from_millis(args.get_u64("reap-grace-ms", 5_000)?),
        ..defaults
    };
    // the HTTP gate is the fleet's 429 admission valve: unless the user
    // pinned it, saturate exactly when every worker is at its cap
    let mut http = super::serve_cmd::http_cfg(&args)?;
    if let Some(h) = &mut http {
        if args.get("http-max-inflight").is_none() {
            h.max_inflight = cfg.workers * cfg.worker_inflight;
        }
    }

    // workers re-exec this binary; flags the worker understands pass
    // through verbatim (never --addr: workers bind OS-assigned ports)
    let mut wargs: Vec<String> = vec!["--model".into(), model.clone()];
    for flag in [
        "gen-batch",
        "max-wait-ms",
        "max-gen-tokens",
        "threads",
        "artifacts",
        "trace-slow-ms",
    ] {
        if let Some(v) = args.get(flag) {
            wargs.push(format!("--{flag}"));
            wargs.push(v.to_string());
        }
    }
    let bin = std::env::current_exe()?;
    let spawner = process_spawner(bin, wargs, Vec::new(), cfg.boot_timeout);

    let t0 = Instant::now();
    let handle = Arc::new(start_fleet(cfg, spawner)?);
    println!(
        "fleet of {} workers over {model} on {} in {:.1}s — least-inflight routing, \
         sticky generate placement, restart-on-crash",
        handle.workers(),
        handle.addr,
        t0.elapsed().as_secs_f64()
    );

    // SIGTERM/SIGINT must walk the full drain (stop admitting → finish
    // in-flight → reap children) — a dying router never orphans workers
    crate::util::signal::install();
    let http_handle = match http {
        Some(hcfg) => {
            let h = Arc::new(serve_http(handle.router(), hcfg)?);
            println!(
                "http front end on {} — POST /score, POST /generate, GET /health, \
                 GET /metrics (per-worker labels + fleet rollups)",
                h.addr
            );
            Some(h)
        }
        None => None,
    };
    let watcher_fleet = Arc::clone(&handle);
    let watcher_http = http_handle.clone();
    std::thread::spawn(move || {
        while !crate::util::signal::termination_requested() {
            std::thread::sleep(Duration::from_millis(100));
        }
        if let Some(h) = &watcher_http {
            let _ = h.shutdown();
        }
        let _ = watcher_fleet.shutdown();
    });
    handle.join()?;
    if let Some(h) = &http_handle {
        h.shutdown()?;
    }
    println!("fleet stopped");
    Ok(())
}

/// Worker process: one full server over the shared artifact, announced
/// to the parent router via the stdout handshake.
pub(crate) fn cmd_fleet_worker(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "");
    anyhow::ensure!(
        model.ends_with(".spak"),
        "fleet-worker serves a packed artifact: pass --model <x.spak>"
    );
    // one trace lane per worker process in merged fleet exports — the
    // pid keeps the label unique without threading the slot index in
    crate::util::trace::set_process_name(&format!("worker-{}", std::process::id()));
    super::serve_cmd::apply_trace_flags(&args)?;
    let gen_batch = args.get_usize("gen-batch", 8)?.max(1);
    let builder = super::serve_cmd::engine_builder(&args)?;
    let (engine, info) = builder.open_artifact(std::path::Path::new(&model))?;
    let cfg = ServerConfig {
        // OS-assigned port: K workers on one host never collide
        addr: args.get_str("addr", "127.0.0.1:0"),
        max_conns: args.get_usize("max-conns", 64)?,
        max_batch: engine.batch(),
        max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 15)?),
        max_gen_tokens: args.get_usize("max-gen-tokens", 512)?,
    };
    let tokenizer = Arc::new(super::serve_cmd::standard_tokenizer(crate::bench::fast_mode()));
    let handle = engine.serve(tokenizer, cfg, gen_batch)?;
    println!(
        "worker pid {} serving {model} ({}; zero-copy: {})",
        std::process::id(),
        if info.label.is_empty() { "unlabeled" } else { info.label.as_str() },
        info.mapped
    );
    // the line the router's spawner blocks on; flush so it crosses the
    // pipe immediately even if stdout buffering ever changes
    println!("{READY_PREFIX}{}", handle.addr);
    std::io::stdout().flush()?;
    handle.join()?;
    Ok(())
}
