//! `sparselm quant` — group-quantize a checkpoint's linear layers
//! (optionally SPQR-style with structured outliers) and report
//! reconstruction error + bits/param; with `--pack N:M` the report is
//! for the **fused sparse+quant** serving format
//! ([`crate::sparse::PackedQnm`]: mask metadata + int codes + scales —
//! what `--backend spmm-q4` streams); `sparselm owl` — report the OWL
//! per-layer pattern allocation for a checkpoint.

use std::path::Path;

use crate::model::load_checkpoint;
use crate::pruning::{
    layer_outlier_distribution, mask_topn_per_block, owl_allocate, ActStats, LayerOutlierStats,
};
use crate::quant::{nm_quant_bits_per_param, OutlierStore, QuantSpec, SpqrLayer, SpqrSpec};
use crate::sparse::PackedQnm;
use crate::tensor::rel_error;
use crate::util::args::Args;

/// The `--pack N:M` report: pack every divisible linear into
/// [`PackedQnm`] (magnitude top-n selection, the same packing
/// `--backend spmm-q4` serves) and report measured vs analytic
/// bits/param. Returns `(layers, measured_bits_per_param)` so the
/// storage cross-check test can hold the report to
/// [`nm_quant_bits_per_param`].
pub fn packed_quant_report(
    params: &crate::model::ParamSet,
    n: usize,
    m: usize,
    spec: QuantSpec,
    verbose: bool,
) -> crate::Result<(usize, f64)> {
    let mut total_bytes = 0usize;
    let mut total_elems = 0usize;
    let mut layers = 0usize;
    for (name, idx) in params.linear_indices() {
        let w = &params.tensors[idx];
        let (_r, c) = w.dims2();
        if c % m != 0 {
            continue;
        }
        let mask = mask_topn_per_block(&w.map(f32::abs), n, m);
        let fitted = PackedQnm::fit_spec(spec, n, m, c);
        let p = PackedQnm::from_dense_mask(w, &mask, n, m, fitted);
        let err = rel_error(&p.to_dense(), &w.mul(&mask));
        total_bytes += p.bytes();
        total_elems += w.len();
        layers += 1;
        if verbose {
            println!(
                "  {name:<28} err {err:.4}  {:.4} bits/param (g{})",
                p.bits_per_param(),
                fitted.group
            );
        }
    }
    anyhow::ensure!(layers > 0, "no packable linear layers found");
    Ok((layers, 8.0 * total_bytes as f64 / total_elems as f64))
}

pub fn cmd_quant(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "tiny");
    let ckpt = args.get_str("ckpt", &format!("runs/{model}.ckpt"));
    let bits = args.get_usize("bits", 4)? as u32;
    let group = args.get_usize("group", 128)?;
    let k = args.get_usize("outliers", 0)?;
    let params = load_checkpoint(Path::new(&ckpt))?;
    anyhow::ensure!((2..=8).contains(&bits), "--bits must be 2..=8, got {bits}");
    anyhow::ensure!(group > 0, "--group must be > 0");
    if let Some(pat) = args.get("pack") {
        let (n, m) = super::parse_pattern(pat)?;
        let spec = QuantSpec::new(bits, group);
        println!("packing {ckpt}: {n}:{m} mask + int{bits} g{group} kept values");
        let (layers, measured) =
            packed_quant_report(&params, n, m, spec, args.get_bool("verbose"))?;
        let analytic = nm_quant_bits_per_param(n, m, bits, group);
        println!(
            "{layers} layers: {measured:.4} bits/param measured \
             (analytic {analytic:.4} = {:.3} mask + {:.3} codes+scales; \
             {:.2}x vs bf16)",
            crate::sparse::PatternInfo::new(n, m).bits_per_element_codebook(),
            analytic - crate::sparse::PatternInfo::new(n, m).bits_per_element_codebook(),
            16.0 / measured
        );
        return Ok(());
    }
    let store = if k > 0 {
        OutlierStore::Structured { k, m: 256 }
    } else {
        OutlierStore::None
    };
    let spec = SpqrSpec::new(QuantSpec::new(bits, group), store);

    println!(
        "quantizing {ckpt}: int{bits} g{group}{}",
        if k > 0 {
            format!(" + {k}:256 outliers")
        } else {
            String::new()
        }
    );
    let mut total_bytes = 0usize;
    let mut total_dense = 0usize;
    let mut worst: (f64, String) = (0.0, String::new());
    let mut layers = 0usize;
    for (name, idx) in params.linear_indices() {
        let w = &params.tensors[idx];
        let (_r, c) = w.dims2();
        if c % group != 0 || c % 256 != 0 {
            continue; // skip layers the group layout doesn't divide
        }
        let stats = ActStats::uniform(c);
        let layer = SpqrLayer::compress(w, &stats, &spec);
        let err = rel_error(&layer.to_dense(), w);
        total_bytes += layer.bytes();
        total_dense += w.len() * 2;
        layers += 1;
        if err > worst.0 {
            worst = (err, name.clone());
        }
        if args.get_bool("verbose") {
            println!("  {name:<28} err {err:.4}  {:.3} bits/param", layer.bits_per_param());
        }
    }
    anyhow::ensure!(layers > 0, "no quantizable linear layers found");
    println!(
        "{layers} layers: {:.3} bits/param overall ({:.2}x vs bf16), worst layer {} (err {:.4})",
        8.0 * total_bytes as f64 / (total_dense as f64 / 2.0),
        total_dense as f64 / total_bytes as f64,
        worst.1,
        worst.0
    );
    Ok(())
}

pub fn cmd_owl(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "tiny");
    let ckpt = args.get_str("ckpt", &format!("runs/{model}.ckpt"));
    let m = args.get_usize("m", 16)?;
    let theta = args.get_f64("theta", 5.0)? as f32;
    let lambda = args.get_f64("lambda", 2.0)?;
    let keep = args.get_f64("keep", 0.5)?;
    let params = load_checkpoint(Path::new(&ckpt))?;

    let stats: Vec<LayerOutlierStats> = params
        .linear_indices()
        .into_iter()
        .map(|(name, idx)| {
            let w = &params.tensors[idx];
            LayerOutlierStats {
                name,
                size: w.len(),
                lod: layer_outlier_distribution(w, theta),
            }
        })
        .collect();
    anyhow::ensure!(!stats.is_empty(), "no linear layers in checkpoint");
    let allocs = owl_allocate(&stats, m, keep, lambda, 1);
    println!("OWL allocation (theta={theta}, lambda={lambda}, target keep {keep}):");
    for (s, a) in stats.iter().zip(&allocs) {
        println!(
            "  {:<28} lod {:.4}  ->  {:>2}:{m}",
            s.name, s.lod, a.n
        );
    }
    let realized = crate::pruning::owl::realized_keep(&allocs, &stats);
    println!("realized keep fraction: {realized:.4}");
    Ok(())
}
