//! CLI subcommand dispatch for the `sparselm` binary.
//!
//! ```text
//! sparselm train    --model tiny --steps 300 --out runs/tiny.ckpt
//! sparselm compress --model tiny --ckpt runs/tiny.ckpt --sparsity 8:16 \
//!                   --outliers 16 --method ria --sq --vc --ebft 40
//! sparselm eval     --model tiny --ckpt runs/tiny-8x16.ckpt [--zeroshot]
//! sparselm pack     --ckpt runs/tiny.ckpt --out runs/tiny.spak --sparsity 8:16 \
//!                   --outliers 16 [--quant --qbits 4 --qgroup 128 | --quant ternary --tgroup 128]
//! sparselm inspect  runs/tiny.spak
//! sparselm hwsim    --batch 8
//! sparselm info     --model tiny
//! sparselm quant    --ckpt runs/tiny.ckpt --bits 4 --group 128 --outliers 16
//! sparselm owl      --ckpt runs/tiny.ckpt --m 16 --keep 0.5
//! sparselm serve    --model tiny --ckpt runs/tiny-8x16.ckpt --addr 127.0.0.1:7433 \
//!                   --http 127.0.0.1:7080
//! sparselm serve    --model runs/tiny.spak --fleet 4 --http 127.0.0.1:7080
//! sparselm generate --model tiny --random --prompt "the quick brown" --max-tokens 32
//! sparselm serve-bench --addr 127.0.0.1:7433 --clients 4 --requests 50
//! sparselm trace    --addr 127.0.0.1:7433 --last 5 --out trace.json
//! ```

mod fleet_cmd;
mod quant_cmd;
mod serve_cmd;

pub use quant_cmd::packed_quant_report;
pub use serve_cmd::standard_tokenizer;

use std::path::PathBuf;
use std::sync::Arc;

use crate::bench::ExperimentCtx;
use crate::coordinator::{CompressionPipeline, ModelExec, PipelineSpec, TrainConfig, Trainer};
use crate::data::CorpusKind;
use crate::eval::{perplexity, zero_shot_accuracy};
use crate::hwsim::{speedup_curve, HwModel};
use crate::model::{load_checkpoint, save_checkpoint, ParamSet};
use crate::pruning::{PruneMethod, PruneSpec};
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::util::Rng;

pub fn main_entry() -> crate::Result<()> {
    crate::util::logging::init();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(args),
        "compress" => cmd_compress(args),
        "eval" => cmd_eval(args),
        "pack" => cmd_pack(args),
        "inspect" => cmd_inspect(args),
        "hwsim" => cmd_hwsim(args),
        "info" => cmd_info(args),
        "quant" => quant_cmd::cmd_quant(args),
        "owl" => quant_cmd::cmd_owl(args),
        "serve" => serve_cmd::cmd_serve(args),
        "fleet-worker" => fleet_cmd::cmd_fleet_worker(args),
        "generate" => serve_cmd::cmd_generate(args),
        "serve-bench" => serve_cmd::cmd_serve_bench(args),
        "trace" => serve_cmd::cmd_trace(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "sparselm — 8:16 sparsity with structured outliers and variance correction

subcommands:
  train     train a stand-in model via the AOT train-step artifact
  compress  run the §4 pipeline (SQ -> RIA -> N:M + k:256 outliers -> VC ->
            EBFT; --quant adds the pack-time int4 stage, --quant ternary
            the 1.58-bit PackedTnm stage; --pack-out x.spak additionally
            writes the calibrated packed-model artifact)
  eval      perplexity (and --zeroshot accuracy) of a checkpoint
  pack      pack a dense checkpoint into a .spak artifact (magnitude
            selection; the calibrated route is compress --pack-out)
  inspect   validate a .spak artifact and print its per-tensor layout,
            per-kind stream breakdown (mask/values/scales/outliers),
            exact byte accounting and bits/param vs the Table-1 model
  hwsim     projected sparse-GEMM speedups (the paper's §2 analysis)
  info      model/artifact inventory
  quant     group-quantize a checkpoint (SPQR-style outliers optional;
            --pack N:M reports the fused sparse+quant PackedQnm footprint)
  owl       OWL per-layer N:M allocation report
  serve     scoring + generation server (dynamic batching for nll/choice,
            continuous batching for generate; --model x.spak mmaps a packed
            artifact and serves it zero-copy; --backend spmm re-packs a dense
            checkpoint — requires --repack to acknowledge the lossy magnitude
            selection — spmm-q4 additionally int4-quantizes the kept values
            (--qbits/--qgroup), spmm-t packs them as 1.58-bit ternary
            (--tgroup) for sub-2-bits/param serving, spec serves
            self-speculative decode — int4
            draft + bf16 windowed verify, same tokens as spmm, fewer bf16
            steps per token — dense serves exact weights via the host
            forward, pjrt uses the AOT artifacts, scoring only; --http ADDR
            adds the HTTP front end: POST /score, POST /generate, GET /health,
            Prometheus GET /metrics, 429 backpressure via --http-max-inflight,
            graceful SIGTERM drain; --fleet K swaps the single process for a
            router + K supervised worker processes mmap-ing one .spak —
            least-inflight routing, sticky generate placement, redispatch of
            idempotent ops on worker crash, restart-on-crash, fleet-wide
            /metrics rollups with per-worker labels)
  generate  one-shot KV-cached generation from a checkpoint or a .spak
            artifact (--model x.spak mmaps the packed model; --random for
            an offline stand-in; --quant for the int4 packed format,
            --quant ternary for 1.58-bit PackedTnm; --spec for
            self-speculative decode; --temperature 0 = greedy)
  serve-bench  closed-loop load generator against a running server
  trace     export Chrome trace-event JSON from a running server or fleet
            router (--addr, --id <hex>[,<hex>..] | --last K, --out x.json);
            load the page in Perfetto or chrome://tracing. serve-side knobs:
            --trace-slow-ms N logs any request slower than N ms with its
            trace id; GET /debug/trace serves the same export over HTTP

common flags: --model <tiny|small|gqa|wide|e2e> --artifacts <dir>
run a subcommand with --help for its flags"
    );
}

/// Parse "N:M" pattern strings.
pub fn parse_pattern(s: &str) -> crate::Result<(usize, usize)> {
    let (n, m) = s
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("pattern must be N:M, got {s:?}"))?;
    Ok((n.parse()?, m.parse()?))
}

/// Parse `--qbits` / `--qgroup` into a validated
/// [`crate::quant::QuantSpec`] — typed errors instead of the
/// constructor's assert, since CLI flags are untrusted input.
pub fn parse_quant_spec(args: &Args) -> crate::Result<crate::quant::QuantSpec> {
    let bits = args.get_usize("qbits", 4)?;
    let group = args.get_usize("qgroup", 128)?;
    anyhow::ensure!((2..=8).contains(&bits), "--qbits must be 2..=8, got {bits}");
    anyhow::ensure!(group > 0, "--qgroup must be > 0, got {group}");
    Ok(crate::quant::QuantSpec::new(bits as u32, group))
}

/// What `--quant` selects for the kept values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuantMode {
    /// bf16 kept values (no `--quant`)
    None,
    /// bare `--quant` (or `--quant int`): group-quantized intN per
    /// `--qbits` / `--qgroup`
    Int(crate::quant::QuantSpec),
    /// `--quant ternary`: 1.58-bit [`crate::sparse::PackedTnm`] with
    /// the given `--tgroup` scale group
    Ternary(usize),
}

/// Interpret the `--quant` flag value. The bare-flag spelling stays an
/// int quantizer for backward compatibility; `--quant ternary` routes
/// to the PackedTnm format, whose only knob is `--tgroup`.
pub fn parse_quant_mode(args: &Args) -> crate::Result<QuantMode> {
    match args.get("quant") {
        None => Ok(QuantMode::None),
        Some("true") | Some("1") | Some("yes") | Some("int") => {
            Ok(QuantMode::Int(parse_quant_spec(args)?))
        }
        Some("ternary") | Some("t158") => {
            let group = args.get_usize("tgroup", 128)?;
            anyhow::ensure!(group > 0, "--tgroup must be > 0, got {group}");
            Ok(QuantMode::Ternary(group))
        }
        Some(other) => anyhow::bail!(
            "unknown --quant {other:?} (expected bare --quant for intN, or --quant ternary)"
        ),
    }
}

fn cmd_train(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "tiny");
    let steps = args.get_usize("steps", 300)?;
    let out = args.get_str("out", &format!("runs/{model}.ckpt"));
    let ctx = ExperimentCtx::new(&args.get_str("artifacts", "artifacts"))?;
    let exec = ModelExec::new(Arc::clone(&ctx.engine), &model)?;
    let mut rng = Rng::new(args.get_u64("seed", 0xBEEF)?);
    let mut params = ParamSet::init(&exec.config, &mut rng);
    let trainer = Trainer {
        exec: &exec,
        config: TrainConfig {
            steps,
            lr: args.get_f64("lr", 3e-3)? as f32,
            warmup: steps / 10,
            log_every: (steps / 20).max(1),
            seed: args.get_u64("seed", 0xBEEF)?,
        },
    };
    let kind = CorpusKind::parse(&args.get_str("corpus", "wiki")).unwrap_or(CorpusKind::Wiki);
    let losses = trainer.run(&mut params, ctx.stream(kind))?;
    save_checkpoint(&PathBuf::from(&out), &params)?;
    println!(
        "trained {model} {steps} steps: loss {:.3} -> {:.3}; saved {out}",
        losses[0],
        losses.last().unwrap()
    );
    Ok(())
}

fn build_spec(args: &Args) -> crate::Result<PipelineSpec> {
    let (n, m) = parse_pattern(&args.get_str("sparsity", "8:16"))?;
    let k = args.get_usize("outliers", 0)?;
    let method = PruneMethod::parse(&args.get_str("method", "ria"))
        .ok_or_else(|| anyhow::anyhow!("bad --method"))?;
    let mut prune = PruneSpec::new(n, m)
        .method(method)
        .sq(args.get_bool("sq"))
        .vc(args.get_bool("vc"));
    if k > 0 {
        prune = prune.outliers(k);
    }
    let mut spec = PipelineSpec::new(prune);
    spec.ebft_steps = args.get_usize("ebft", 0)?;
    spec.ebft_lr = args.get_f64("ebft-lr", 1e-3)? as f32;
    spec.calib_batches = args.get_usize("calib-batches", 8)?;
    spec.unstructured_outliers = args.get_bool("unstructured");
    spec.use_kernels = !args.get_bool("host-prune");
    match parse_quant_mode(args)? {
        QuantMode::None => {}
        QuantMode::Int(q) => spec.quant = Some(q),
        QuantMode::Ternary(group) => spec = spec.ternarize(group),
    }
    Ok(spec)
}

fn cmd_compress(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "tiny");
    let ckpt = args.get_str("ckpt", &format!("runs/{model}.ckpt"));
    let out = args.get_str("out", &format!("runs/{model}-compressed.ckpt"));
    let pack_out = args.get_str("pack-out", "");
    let ctx = ExperimentCtx::new(&args.get_str("artifacts", "artifacts"))?;
    let dense = load_checkpoint(&PathBuf::from(&ckpt))?;
    let spec = build_spec(&args)?;
    let kind = CorpusKind::parse(&args.get_str("corpus", "wiki")).unwrap_or(CorpusKind::Wiki);

    let pipeline = CompressionPipeline::new(Arc::clone(&ctx.engine), &model)?;
    let (compressed, report) = if pack_out.is_empty() {
        let (compressed, report) = pipeline.run(&dense, ctx.stream(kind), &spec)?;
        (compressed, report)
    } else {
        // pack-artifact output stage: persist the calibrated packed
        // layers themselves, not just their dense expansion
        let (compressed, report, packed) =
            pipeline.run_packed(&dense, ctx.stream(kind), &spec)?;
        let info = crate::store::write_artifact(&PathBuf::from(&pack_out), &packed)?;
        println!(
            "packed artifact {pack_out}: {} bytes on disk, base {:.4} bits/param \
             (+outliers {:.4})",
            info.file_bytes,
            info.base_bits_per_param(),
            info.total_bits_per_param()
        );
        (compressed, report)
    };
    save_checkpoint(&PathBuf::from(&out), &compressed)?;

    println!("pipeline: {} on {}", report.label, model);
    println!(
        "storage: nm {} KiB + outliers {} KiB vs dense {} KiB ({:.2}x)",
        report.total_nm_bytes() / 1024,
        report.total_outlier_bytes() / 1024,
        report.total_dense_bytes() / 1024,
        report.compression_ratio()
    );
    println!("{}", pipeline.metrics.report());
    println!("saved {out}");
    Ok(())
}

/// `sparselm pack` — pack a dense checkpoint into a `.spak` artifact
/// with **magnitude selection** (no calibration data involved; the
/// calibrated route is `compress --pack-out`). The written file is the
/// exact operand set `serve --model x.spak` later mmaps.
fn cmd_pack(args: Args) -> crate::Result<()> {
    let ckpt = args.get_str("ckpt", "");
    anyhow::ensure!(!ckpt.is_empty(), "pack needs --ckpt <checkpoint>");
    let (n, m) = parse_pattern(&args.get_str("sparsity", "8:16"))?;
    let k = args.get_usize("outliers", 16)?;
    let mode = parse_quant_mode(&args)?;
    let default_out = format!("{}.spak", ckpt.trim_end_matches(".ckpt"));
    let out = args.get_str("out", &default_out);

    let params = load_checkpoint(&PathBuf::from(&ckpt))?;
    let packed = match mode {
        QuantMode::None => crate::store::PackedModel::compress(&params, n, m, k, None),
        QuantMode::Int(q) => crate::store::PackedModel::compress(&params, n, m, k, Some(q)),
        QuantMode::Ternary(group) => {
            crate::store::PackedModel::compress_ternary(&params, n, m, k, group)
        }
    };
    let info = crate::store::write_artifact(&PathBuf::from(&out), &packed)?;
    println!(
        "packed {ckpt} -> {out} ({}, {n}:{m} + {k}:256, magnitude selection)",
        packed.label
    );
    println!(
        "on disk: {} bytes = header {} + streams {} + padding {} + trailer 8",
        info.file_bytes,
        info.header_bytes(),
        info.payload_bytes,
        info.padding_bytes
    );
    println!(
        "packed linears: {} KiB base ({:.4} bits/param) + {} KiB outliers \
         ({:.4} bits/param total); dense params {} KiB",
        info.linear_stream_bytes / 1024,
        info.base_bits_per_param(),
        info.outlier_stream_bytes / 1024,
        info.total_bits_per_param(),
        info.dense_stream_bytes / 1024
    );
    let modeled = match mode {
        QuantMode::None => {
            crate::hwsim::artifact::model_linear_stream_bytes(&params.config, n, m, None)
        }
        QuantMode::Int(q) => {
            crate::hwsim::artifact::model_linear_stream_bytes(&params.config, n, m, Some(q))
        }
        QuantMode::Ternary(group) => crate::hwsim::artifact::model_linear_stream_bytes_ternary(
            &params.config,
            n,
            m,
            group,
        ),
    };
    println!(
        "hwsim cross-check: modeled base streams {} bytes — {}",
        modeled,
        if modeled == info.linear_stream_bytes { "exact match" } else { "MISMATCH" }
    );
    anyhow::ensure!(
        modeled == info.linear_stream_bytes,
        "artifact base streams ({} bytes) diverge from the hwsim accounting ({modeled})",
        info.linear_stream_bytes
    );
    Ok(())
}

/// `sparselm inspect` — validate (magic/version/checksum/layout) and
/// print the byte-exact contents of a `.spak` artifact.
fn cmd_inspect(args: Args) -> crate::Result<()> {
    let path = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| args.get_str("model", ""));
    anyhow::ensure!(!path.is_empty(), "inspect needs a path: sparselm inspect x.spak");
    let (packed, info) = crate::store::read_artifact(&PathBuf::from(&path))?;
    let cfg = &packed.config;
    println!(
        "{path}: SPAK v{} ({}), checksum OK, {} bytes",
        crate::store::VERSION,
        if info.label.is_empty() { "unlabeled" } else { info.label.as_str() },
        info.file_bytes
    );
    println!(
        "model {}: dim={} layers={} heads={} (kv {}) hidden={} vocab={} seq={} batch={}",
        cfg.name,
        cfg.dim,
        cfg.n_layers,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.hidden,
        cfg.vocab,
        cfg.seq,
        cfg.batch
    );
    // per-kind stream breakdown — classify every index-declared stream
    // into mask (combinadic meta), values (bf16/int/trit payload, or
    // dense f32), scales, outliers. [mask, values, scales, outliers,
    // total, count, elems] per kind.
    let class_of = |key: &str| -> usize {
        if key.starts_with("outlier.") {
            3
        } else if key == "meta" {
            0
        } else if key == "scales" {
            2
        } else {
            1 // values / codes / trits / dense f32
        }
    };
    println!(
        "{:<8} {:>7} {:>14} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "kind", "tensors", "shape-elems", "mask", "values", "scales", "outliers", "bytes"
    );
    let mut by_kind: std::collections::BTreeMap<String, [usize; 7]> =
        std::collections::BTreeMap::new();
    for t in &info.tensors {
        let e = by_kind.entry(t.kind.clone()).or_default();
        e[5] += 1;
        e[6] += t.shape.iter().product::<usize>();
        for (key, bytes) in &t.streams {
            e[class_of(key)] += bytes;
            e[4] += bytes;
        }
    }
    for (kind, r) in &by_kind {
        println!(
            "{kind:<8} {:>7} {:>14} {:>12} {:>12} {:>10} {:>12} {:>12}",
            r[5], r[6], r[0], r[1], r[2], r[3], r[4]
        );
    }
    // byte-exact cross-check: the breakdown must re-derive the headline
    // bits/param with no residue anywhere
    let (mut base_sum, mut outlier_sum) = (0usize, 0usize);
    for (kind, r) in &by_kind {
        if kind != "dense" {
            base_sum += r[0] + r[1] + r[2];
            outlier_sum += r[3];
        }
    }
    anyhow::ensure!(
        base_sum == info.linear_stream_bytes && outlier_sum == info.outlier_stream_bytes,
        "stream breakdown ({base_sum} base + {outlier_sum} outlier bytes) does not \
         re-add to the artifact accounting ({} + {})",
        info.linear_stream_bytes,
        info.outlier_stream_bytes
    );
    let rebuilt = 8.0 * (base_sum + outlier_sum) as f64 / info.linear_elems.max(1) as f64;
    anyhow::ensure!(
        rebuilt == info.total_bits_per_param(),
        "breakdown-derived bits/param {rebuilt} != total_bits_per_param {}",
        info.total_bits_per_param()
    );
    println!(
        "breakdown cross-check: {} packed bytes -> {:.4} bits/param (re-adds to \
         total_bits_per_param exactly)",
        base_sum + outlier_sum,
        rebuilt
    );
    println!(
        "layout: header {} + streams {} + padding {} + trailer 8 = {} bytes",
        info.header_bytes(),
        info.payload_bytes,
        info.padding_bytes,
        info.file_bytes
    );
    if let Some((n, m, quant)) = packed.pack_summary() {
        let modeled = crate::hwsim::artifact::model_linear_stream_bytes(cfg, n, m, quant);
        let analytic = match quant {
            Some(q) => crate::quant::nm_quant_bits_per_param(n, m, q.bits, q.group),
            None => crate::quant::nm_bits_per_param(n, m),
        };
        println!(
            "packed base: {n}:{m}{} — {:.4} bits/param measured vs {analytic:.4} analytic, \
             modeled streams {} bytes ({})",
            match quant {
                Some(q) => format!(" int{} g{}", q.bits, q.group),
                None => String::new(),
            },
            info.base_bits_per_param(),
            modeled,
            if modeled == info.linear_stream_bytes { "exact match" } else { "MISMATCH" }
        );
    }
    // PackedTnm carries no QuantSpec, so it bypasses pack_summary —
    // cross-check it against the ternary hwsim model per layer instead.
    // Each stored group is already fitted and fit_group is idempotent,
    // so re-deriving from (rows, cols, group) is exact.
    let mut tnm_modeled = 0usize;
    let mut tnm_head = None;
    for l in &packed.layers {
        if let crate::store::PackedWeights::Tnm(p) = &l.weights {
            tnm_modeled += crate::hwsim::artifact::tnm_stream_bytes(
                p.rows,
                p.cols,
                p.pattern.n,
                p.pattern.m,
                p.group,
            );
            tnm_head.get_or_insert((p.pattern.n, p.pattern.m, p.group));
        }
    }
    if let Some((n, m, group)) = tnm_head {
        let measured = by_kind.get("tnm").map(|r| r[0] + r[1] + r[2]).unwrap_or(0);
        let analytic = crate::quant::nm_ternary_bits_per_param(n, m, group);
        println!(
            "packed base: {n}:{m} ternary g{group} — {:.4} bits/param measured vs \
             {analytic:.4} analytic, modeled streams {tnm_modeled} bytes ({})",
            info.base_bits_per_param(),
            if tnm_modeled == measured { "exact match" } else { "MISMATCH" }
        );
        anyhow::ensure!(
            tnm_modeled == measured,
            "tnm streams ({measured} bytes) diverge from the hwsim accounting ({tnm_modeled})"
        );
    }
    Ok(())
}

fn cmd_eval(args: Args) -> crate::Result<()> {
    let model = args.get_str("model", "tiny");
    let ckpt = args.get_str("ckpt", &format!("runs/{model}.ckpt"));
    let ctx = ExperimentCtx::new(&args.get_str("artifacts", "artifacts"))?;
    let exec = ModelExec::new(Arc::clone(&ctx.engine), &model)?;
    let params = load_checkpoint(&PathBuf::from(&ckpt))?;
    let lits = exec.upload(&params)?;
    for kind in [CorpusKind::Wiki, CorpusKind::C4] {
        let rep = perplexity(&exec, &lits, ctx.eval_stream(kind), ExperimentCtx::ppl_batches())?;
        println!(
            "{}: ppl {:.3} (nll {:.4}, {} tokens)",
            kind.label(),
            rep.ppl,
            rep.mean_nll,
            rep.tokens
        );
    }
    if args.get_bool("zeroshot") {
        let zs = zero_shot_accuracy(
            &exec,
            &lits,
            &ctx.tokenizer,
            &ctx.world,
            args.get_usize("items", ExperimentCtx::zs_items())?,
            7,
        )?;
        for t in &zs.tasks {
            println!(
                "  {:<12} acc {:.1}% (chance {:.0}%)",
                t.task,
                t.accuracy * 100.0,
                t.chance * 100.0
            );
        }
        println!("mean accuracy: {:.2}%", zs.mean_accuracy() * 100.0);
    }
    Ok(())
}

fn cmd_hwsim(args: Args) -> crate::Result<()> {
    let hw = HwModel::default();
    let batch = args.get_usize("batch", 8)?;
    let sizes = [512usize, 1024, 2048, 4096, 8192, 16384];
    let patterns = [(2usize, 4usize), (4, 8), (8, 16), (16, 32)];
    println!("projected sparse-GEMM speedup vs dense (batch={batch}):");
    print!("{:>8}", "size");
    for (n, m) in patterns {
        print!("{:>9}", format!("{n}:{m}"));
    }
    println!();
    for pt in speedup_curve(&hw, batch, &sizes, &patterns).chunks(patterns.len()) {
        print!("{:>8}", pt[0].size);
        for p in pt {
            print!("{:>8.2}x", p.speedup);
        }
        println!();
    }
    Ok(())
}

fn cmd_info(args: Args) -> crate::Result<()> {
    let artifacts = args.get_str("artifacts", "artifacts");
    let engine = Engine::new(&artifacts)?;
    let model = args.get_str("model", "tiny");
    let manifest = engine.model_manifest(&model)?;
    let cfg = crate::model::ModelConfig::from_manifest(&manifest.raw);
    println!(
        "{}: dim={} layers={} heads={} (kv {}) hidden={} vocab={} seq={} batch={}",
        cfg.name,
        cfg.dim,
        cfg.n_layers,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.hidden,
        cfg.vocab,
        cfg.seq,
        cfg.batch
    );
    println!("parameters: {:.2}M", cfg.n_params() as f64 / 1e6);
    println!("artifacts:");
    for (name, sig) in &manifest.artifacts {
        println!(
            "  {name:<12} {} in / {} out  ({})",
            sig.inputs.len(),
            sig.outputs.len(),
            sig.file.file_name().unwrap().to_string_lossy()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_parsing() {
        assert_eq!(parse_pattern("8:16").unwrap(), (8, 16));
        assert_eq!(parse_pattern("2:4").unwrap(), (2, 4));
        assert!(parse_pattern("816").is_err());
    }
}
