//! Token batching: training windows, eval windows, and padded
//! fixed-shape encodings for the zero-shot scorer.

use super::tokenizer::PAD;
use crate::util::Rng;

/// A tokenized corpus with deterministic window sampling.
#[derive(Clone, Debug)]
pub struct TokenStream {
    pub tokens: Vec<i32>,
}

impl TokenStream {
    pub fn new(tokens: Vec<i32>) -> Self {
        TokenStream { tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Random (B, S+1) training batch, flattened row-major.
    pub fn sample_batch(&self, b: usize, s: usize, rng: &mut Rng) -> Vec<i32> {
        let w = s + 1;
        assert!(self.tokens.len() > w, "corpus shorter than one window");
        let mut out = Vec::with_capacity(b * w);
        for _ in 0..b {
            let start = rng.below(self.tokens.len() - w);
            out.extend_from_slice(&self.tokens[start..start + w]);
        }
        out
    }

    /// Deterministic non-overlapping (B, S+1) eval batches covering the
    /// stream prefix — the PPL protocol (stride = window, no overlap).
    pub fn eval_batches(&self, b: usize, s: usize, max_batches: usize) -> Vec<Vec<i32>> {
        let w = s + 1;
        let n_windows = self.tokens.len() / w;
        let n_batches = (n_windows / b).min(max_batches);
        (0..n_batches)
            .map(|bi| {
                let mut flat = Vec::with_capacity(b * w);
                for r in 0..b {
                    let start = (bi * b + r) * w;
                    flat.extend_from_slice(&self.tokens[start..start + w]);
                }
                flat
            })
            .collect()
    }
}

/// Pack a list of variable-length sequences into a fixed (B, S) id matrix
/// plus a 0/1 f32 mask selecting the *scored* positions of each row.
///
/// Each entry is `(ids, scored_from)`: positions `>= scored_from` (i.e.
/// the completion tokens of a multiple-choice candidate) get mask 1 at
/// their *target* offset. Rows are PAD-filled; sequences longer than
/// `s + 1` are left-truncated (keeping the completion).
pub fn pack_windows(
    items: &[(Vec<i32>, usize)],
    b: usize,
    s: usize,
) -> (Vec<i32>, Vec<f32>) {
    assert!(items.len() <= b);
    let w = s + 1;
    let mut ids = vec![PAD; b * w];
    let mut mask = vec![0.0f32; b * s];
    for (r, (seq, scored_from)) in items.iter().enumerate() {
        let (seq, scored_from) = if seq.len() > w {
            let cut = seq.len() - w;
            (&seq[cut..], scored_from.saturating_sub(cut))
        } else {
            (&seq[..], *scored_from)
        };
        ids[r * w..r * w + seq.len()].copy_from_slice(seq);
        // target position t predicts token t+1, so token index j is scored
        // at mask position j-1
        for j in (*(&scored_from)).max(1)..seq.len() {
            mask[r * s + (j - 1)] = 1.0;
        }
    }
    (ids, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_batch_shape() {
        let ts = TokenStream::new((0..1000).collect());
        let mut rng = Rng::new(1);
        let batch = ts.sample_batch(4, 32, &mut rng);
        assert_eq!(batch.len(), 4 * 33);
    }

    #[test]
    fn eval_batches_nonoverlapping() {
        let ts = TokenStream::new((0..330).collect());
        let batches = ts.eval_batches(2, 10, 100);
        // 330 / 11 = 30 windows -> 15 batches
        assert_eq!(batches.len(), 15);
        assert_eq!(batches[0][0], 0);
        assert_eq!(batches[0][11], 11); // row 1 starts at next window
        assert_eq!(batches[1][0], 22);
    }

    #[test]
    fn pack_respects_scored_from() {
        let items = vec![(vec![2, 10, 11, 12], 2usize)];
        let (ids, mask) = pack_windows(&items, 2, 8);
        assert_eq!(&ids[..4], &[2, 10, 11, 12]);
        assert_eq!(ids[4], PAD);
        // tokens 2,3 (values 11,12) are scored -> mask positions 1,2
        assert_eq!(&mask[..4], &[0.0, 1.0, 1.0, 0.0]);
        // second row fully padded / unscored
        assert!(mask[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pack_truncates_long_sequences_keeping_tail() {
        let seq: Vec<i32> = (0..20).collect();
        let (ids, mask) = pack_windows(&[(seq, 18)], 1, 8);
        // keeps the last 9 tokens: 11..=19
        assert_eq!(&ids[..9], &[11, 12, 13, 14, 15, 16, 17, 18, 19]);
        // scored_from 18 shifts to 7: tokens at positions 7, 8 are scored,
        // i.e. mask (target) positions 6 and 7
        assert_eq!(mask[5], 0.0);
        assert_eq!(mask[6], 1.0);
        assert_eq!(mask[7], 1.0);
    }
}
