//! The deterministic "world model" behind the synthetic corpora and
//! evaluation tasks: a small knowledge base of entities with attributes.
//!
//! Corpora verbalize these facts (with Zipfian filler prose); the
//! zero-shot tasks query the *same* facts, so a language model only scores
//! above chance by actually learning the associations during training —
//! giving the monotone quality signal the paper's accuracy tables need.

use crate::util::Rng;

pub const COLORS: &[&str] = &[
    "red", "blue", "green", "yellow", "black", "white", "purple", "orange",
];
pub const MATERIALS: &[&str] = &[
    "wood", "metal", "stone", "glass", "cloth", "clay", "bone", "leather",
];
pub const PLACES: &[&str] = &[
    "forest", "river", "mountain", "desert", "valley", "cave", "meadow",
    "island", "swamp", "canyon",
];
pub const ABILITIES: &[&str] = &["fly", "swim", "run", "climb", "dig", "jump"];
pub const USES: &[&str] = &["cut", "carry", "build", "cook", "hunt", "write"];
pub const SIZES: &[&str] = &["small", "large", "tiny", "huge"];

pub const OBJECTS: &[&str] = &[
    "ruby", "lantern", "hammer", "basket", "dagger", "kettle", "mirror",
    "saddle", "anchor", "bell", "candle", "drum", "flute", "goblet",
    "ladder", "needle",
];
pub const ANIMALS: &[&str] = &[
    "falcon", "otter", "badger", "heron", "lynx", "viper", "marmot",
    "ibex", "crane", "salmon", "beetle", "hare",
];

/// Attributes assigned to one object.
#[derive(Clone, Debug)]
pub struct ObjectFacts {
    pub name: &'static str,
    pub color: &'static str,
    pub material: &'static str,
    pub place: &'static str,
    pub use_verb: &'static str,
}

/// Attributes assigned to one animal.
#[derive(Clone, Debug)]
pub struct AnimalFacts {
    pub name: &'static str,
    pub ability: &'static str,
    pub place: &'static str,
    pub size: &'static str,
}

/// The complete deterministic knowledge base.
#[derive(Clone, Debug)]
pub struct World {
    pub objects: Vec<ObjectFacts>,
    pub animals: Vec<AnimalFacts>,
    pub seed: u64,
}

impl World {
    /// Build a world from a seed. Attribute assignment is a deterministic
    /// function of the seed, so corpora and tasks built from the same seed
    /// agree on every fact.
    pub fn new(seed: u64) -> World {
        let mut rng = Rng::new(seed ^ 0x57_4F_52_4C_44); // "WORLD"
        let objects = OBJECTS
            .iter()
            .map(|&name| ObjectFacts {
                name,
                color: COLORS[rng.below(COLORS.len())],
                material: MATERIALS[rng.below(MATERIALS.len())],
                place: PLACES[rng.below(PLACES.len())],
                use_verb: USES[rng.below(USES.len())],
            })
            .collect();
        let animals = ANIMALS
            .iter()
            .map(|&name| AnimalFacts {
                name,
                ability: ABILITIES[rng.below(ABILITIES.len())],
                place: PLACES[rng.below(PLACES.len())],
                size: SIZES[rng.below(SIZES.len())],
            })
            .collect();
        World {
            objects,
            animals,
            seed,
        }
    }

    pub fn object(&self, i: usize) -> &ObjectFacts {
        &self.objects[i % self.objects.len()]
    }

    pub fn animal(&self, i: usize) -> &AnimalFacts {
        &self.animals[i % self.animals.len()]
    }

    /// All fact sentences, one per (entity, attribute) pair — the fact
    /// vocabulary the corpora sample from.
    pub fn fact_sentences(&self) -> Vec<String> {
        let mut out = Vec::new();
        for o in &self.objects {
            out.push(format!("the {} is {}", o.name, o.color));
            out.push(format!("the {} is made of {}", o.name, o.material));
            out.push(format!("the {} was found in the {}", o.name, o.place));
            out.push(format!("people use the {} to {}", o.name, o.use_verb));
        }
        for a in &self.animals {
            out.push(format!("the {} can {}", a.name, a.ability));
            out.push(format!("the {} lives in the {}", a.name, a.place));
            out.push(format!("the {} is a {} animal", a.name, a.size));
        }
        out
    }

    /// Filler vocabulary (Zipf-weighted prose words).
    pub fn filler_words() -> Vec<&'static str> {
        let mut words = vec![
            "the", "a", "and", "of", "in", "was", "is", "it", "that", "with",
            "for", "as", "on", "by", "at", "from", "old", "long", "deep",
            "bright", "quiet", "early", "people", "traveler", "story",
            "village", "road", "winter", "summer", "morning", "evening",
            "light", "shadow", "water", "wind", "fire", "earth", "walked",
            "found", "carried", "made", "kept", "lost", "gave", "took",
            "saw", "heard", "knew", "came", "went", "stood", "fell",
        ];
        words.extend(COLORS);
        words.extend(PLACES);
        words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = World::new(42);
        let b = World::new(42);
        for (x, y) in a.objects.iter().zip(&b.objects) {
            assert_eq!(x.color, y.color);
            assert_eq!(x.material, y.material);
        }
        let c = World::new(43);
        let diff = a
            .objects
            .iter()
            .zip(&c.objects)
            .filter(|(x, y)| x.color != y.color)
            .count();
        assert!(diff > 0, "different seeds should differ");
    }

    #[test]
    fn fact_count() {
        let w = World::new(1);
        assert_eq!(
            w.fact_sentences().len(),
            OBJECTS.len() * 4 + ANIMALS.len() * 3
        );
    }

    #[test]
    fn entity_names_unique() {
        let mut names: Vec<&str> = OBJECTS.iter().chain(ANIMALS.iter()).copied().collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
    }

    #[test]
    fn facts_reference_valid_attributes() {
        let w = World::new(7);
        for o in &w.objects {
            assert!(COLORS.contains(&o.color));
            assert!(MATERIALS.contains(&o.material));
            assert!(PLACES.contains(&o.place));
        }
        for a in &w.animals {
            assert!(ABILITIES.contains(&a.ability));
            assert!(SIZES.contains(&a.size));
        }
    }
}
