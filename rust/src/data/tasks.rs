//! Five zero-shot multiple-choice task suites over the synthetic world —
//! stand-ins for ARC-e, ARC-c, PIQA, Winogrande and HellaSwag with the
//! same *scoring protocol* (LM log-likelihood of each candidate
//! completion, length-normalized, argmin-nll wins).

use super::world::{World, ABILITIES, COLORS, PLACES, SIZES, USES};
use crate::util::Rng;

/// One multiple-choice item: a context, N candidate completions, the
/// correct index.
#[derive(Clone, Debug)]
pub struct McItem {
    pub context: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// "what color is X" — factual recall, easy distractors (ARC-e)
    ArcEasy,
    /// material question with confusable distractors (ARC-c)
    ArcChallenge,
    /// tool-use affordance, 2 choices (PIQA)
    Piqa,
    /// referent disambiguation, 2 choices (Winogrande)
    Winogrande,
    /// sentence continuation, 4 choices (HellaSwag)
    HellaSwag,
}

pub const ALL_TASKS: [TaskKind; 5] = [
    TaskKind::ArcEasy,
    TaskKind::ArcChallenge,
    TaskKind::Piqa,
    TaskKind::Winogrande,
    TaskKind::HellaSwag,
];

impl TaskKind {
    pub fn label(&self) -> &'static str {
        match self {
            TaskKind::ArcEasy => "arc_e",
            TaskKind::ArcChallenge => "arc_c",
            TaskKind::Piqa => "piqa",
            TaskKind::Winogrande => "winogrande",
            TaskKind::HellaSwag => "hellaswag",
        }
    }

    pub fn n_choices(&self) -> usize {
        match self {
            TaskKind::Piqa | TaskKind::Winogrande => 2,
            _ => 4,
        }
    }

    /// Generate `n` deterministic items for this task over `world`.
    pub fn generate(&self, world: &World, n: usize, seed: u64) -> Vec<McItem> {
        let mut rng = Rng::new(seed ^ (*self as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        (0..n).map(|_| self.gen_one(world, &mut rng)).collect()
    }

    fn gen_one(&self, world: &World, rng: &mut Rng) -> McItem {
        match self {
            TaskKind::ArcEasy => {
                let o = world.object(rng.below(world.objects.len()));
                let mut choices = distractors(COLORS, o.color, 4, rng);
                let answer = rng.below(4);
                choices.insert(answer, o.color.to_string());
                choices.truncate(4);
                McItem {
                    context: format!("the {} is", o.name),
                    choices,
                    answer,
                }
            }
            TaskKind::ArcChallenge => {
                // distractors = materials of *other* objects (confusable)
                let oi = rng.below(world.objects.len());
                let o = world.object(oi);
                let mut pool: Vec<&str> = world
                    .objects
                    .iter()
                    .enumerate()
                    .filter(|(i, x)| *i != oi && x.material != o.material)
                    .map(|(_, x)| x.material)
                    .collect();
                pool.dedup();
                let mut choices = distractors(&pool, o.material, 4, rng);
                let answer = rng.below(4);
                choices.insert(answer, o.material.to_string());
                choices.truncate(4);
                McItem {
                    context: format!("the {} is made of", o.name),
                    choices,
                    answer,
                }
            }
            TaskKind::Piqa => {
                let oi = rng.below(world.objects.len());
                let o = world.object(oi);
                let wrong = loop {
                    let w = USES[rng.below(USES.len())];
                    if w != o.use_verb {
                        break w;
                    }
                };
                let answer = rng.below(2);
                let mut choices = vec![wrong.to_string()];
                choices.insert(answer, o.use_verb.to_string());
                choices.truncate(2);
                McItem {
                    context: format!("people use the {} to", o.name),
                    choices,
                    answer,
                }
            }
            TaskKind::Winogrande => {
                // which animal has the named ability?
                let ai = rng.below(world.animals.len());
                let a = world.animal(ai);
                let other = loop {
                    let b = world.animal(rng.below(world.animals.len()));
                    if b.ability != a.ability {
                        break b;
                    }
                };
                let answer = rng.below(2);
                let mut choices = vec![other.name.to_string()];
                choices.insert(answer, a.name.to_string());
                choices.truncate(2);
                McItem {
                    context: format!("the animal that can {} is the", a.ability),
                    choices,
                    answer,
                }
            }
            TaskKind::HellaSwag => {
                let ai = rng.below(world.animals.len());
                let a = world.animal(ai);
                let truth = format!("lives in the {}", a.place);
                let mut choices = Vec::new();
                while choices.len() < 3 {
                    let p = PLACES[rng.below(PLACES.len())];
                    let cand = format!("lives in the {p}");
                    if p != a.place && !choices.contains(&cand) {
                        choices.push(cand);
                    }
                }
                let answer = rng.below(4);
                choices.insert(answer, truth);
                choices.truncate(4);
                McItem {
                    context: format!("the {} is a {} animal that", a.name, a.size),
                    choices,
                    answer,
                }
            }
        }
    }
}

/// `count-1` distinct distractors ≠ answer, as owned strings.
fn distractors(pool: &[&str], answer: &str, count: usize, rng: &mut Rng) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    let mut guard = 0;
    while out.len() < count - 1 {
        let c = pool[rng.below(pool.len())];
        if c != answer && !out.iter().any(|x| x == c) {
            out.push(c.to_string());
        }
        guard += 1;
        if guard > 1000 {
            // degenerate pool: fill with attribute words from other lists
            for fallback in SIZES.iter().chain(ABILITIES) {
                if out.len() >= count - 1 {
                    break;
                }
                if *fallback != answer && !out.iter().any(|x| x == fallback) {
                    out.push(fallback.to_string());
                }
            }
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::world::MATERIALS;

    #[test]
    fn all_tasks_generate_valid_items() {
        let w = World::new(5);
        for task in ALL_TASKS {
            let items = task.generate(&w, 50, 11);
            assert_eq!(items.len(), 50);
            for it in &items {
                assert_eq!(it.choices.len(), task.n_choices(), "{task:?}");
                assert!(it.answer < it.choices.len());
                // answer string must be unique among choices
                let ans = &it.choices[it.answer];
                assert_eq!(it.choices.iter().filter(|c| c == &ans).count(), 1);
                assert!(!it.context.is_empty());
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let w = World::new(5);
        let a = TaskKind::ArcEasy.generate(&w, 10, 3);
        let b = TaskKind::ArcEasy.generate(&w, 10, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.choices, y.choices);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn answers_are_world_consistent() {
        let w = World::new(5);
        for it in TaskKind::ArcEasy.generate(&w, 30, 7) {
            // context "the X is" — the answer must be X's true color
            let name = it.context.split_whitespace().nth(1).unwrap();
            let obj = w.objects.iter().find(|o| o.name == name).unwrap();
            assert_eq!(it.choices[it.answer], obj.color);
        }
    }

    #[test]
    fn answer_position_unbiased() {
        let w = World::new(5);
        let items = TaskKind::HellaSwag.generate(&w, 400, 13);
        let mut counts = [0usize; 4];
        for it in &items {
            counts[it.answer] += 1;
        }
        for c in counts {
            assert!(c > 50, "positions should be roughly uniform: {counts:?}");
        }
    }

    #[test]
    fn distractor_materials_differ_from_answer() {
        let w = World::new(5);
        for it in TaskKind::ArcChallenge.generate(&w, 50, 17) {
            for (i, c) in it.choices.iter().enumerate() {
                if i != it.answer {
                    assert_ne!(c, &it.choices[it.answer]);
                    assert!(MATERIALS.contains(&c.as_str()));
                }
            }
        }
    }
}
