//! Word-level tokenizer with a frequency-built vocabulary.
//!
//! The synthetic corpora have a closed vocabulary of a few hundred words,
//! so word-level tokenization (ids assigned by frequency rank, OOV → UNK)
//! is faithful to how the paper's models see text while staying exactly
//! reproducible. Special ids: 0 PAD, 1 UNK, 2 BOS, 3 EOS, 4 ".".

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const BOS: i32 = 2;
pub const EOS: i32 = 3;
pub const DOT: i32 = 4;
const N_SPECIAL: usize = 5;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab: HashMap<String, i32>,
    words: Vec<String>,
    pub vocab_size: usize,
}

impl Tokenizer {
    /// Build from text: most frequent words get the smallest ids, capped
    /// at `vocab_size` total entries (including specials).
    pub fn fit(text: &str, vocab_size: usize) -> Tokenizer {
        assert!(vocab_size > N_SPECIAL);
        let mut freq: HashMap<&str, u64> = HashMap::new();
        for w in text.split_whitespace() {
            if w != "." {
                *freq.entry(w).or_insert(0) += 1;
            }
        }
        let mut by_freq: Vec<(&str, u64)> = freq.into_iter().collect();
        // frequency desc, then lexicographic for determinism
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let mut vocab = HashMap::new();
        let mut words = vec![
            "<pad>".to_string(),
            "<unk>".to_string(),
            "<bos>".to_string(),
            "<eos>".to_string(),
            ".".to_string(),
        ];
        for (w, _) in by_freq.into_iter().take(vocab_size - N_SPECIAL) {
            vocab.insert(w.to_string(), words.len() as i32);
            words.push(w.to_string());
        }
        vocab.insert(".".to_string(), DOT);
        Tokenizer {
            vocab,
            words,
            vocab_size,
        }
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| *self.vocab.get(w).unwrap_or(&UNK))
            .collect()
    }

    /// Encode with BOS prefix and EOS suffix.
    pub fn encode_sentence(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        out.extend(self.encode(text));
        out.push(EOS);
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| {
                self.words
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<bad>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn n_words(&self) -> usize {
        self.words.len()
    }

    /// Fraction of tokens in `text` that map to UNK.
    pub fn oov_rate(&self, text: &str) -> f64 {
        let ids = self.encode(text);
        if ids.is_empty() {
            return 0.0;
        }
        ids.iter().filter(|&&i| i == UNK).count() as f64 / ids.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_assigns_frequent_words_small_ids() {
        let t = Tokenizer::fit("cat cat cat dog dog bird", 100);
        let cat = t.encode("cat")[0];
        let dog = t.encode("dog")[0];
        let bird = t.encode("bird")[0];
        assert!(cat < dog && dog < bird);
        assert!(cat as usize >= 5);
    }

    #[test]
    fn roundtrip() {
        let t = Tokenizer::fit("the lynx lives in the cave . the ruby is red", 64);
        let ids = t.encode("the ruby is red");
        assert_eq!(t.decode(&ids), "the ruby is red");
    }

    #[test]
    fn oov_maps_to_unk() {
        let t = Tokenizer::fit("a b c", 32);
        assert_eq!(t.encode("zzz"), vec![UNK]);
        assert!(t.oov_rate("a zzz") == 0.5);
    }

    #[test]
    fn vocab_cap_respected() {
        let text: String = (0..100).map(|i| format!("w{i} ")).collect();
        let t = Tokenizer::fit(&text, 20);
        assert!(t.n_words() <= 20);
    }

    #[test]
    fn sentence_wrapping() {
        let t = Tokenizer::fit("x y", 32);
        let ids = t.encode_sentence("x y");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
    }

    #[test]
    fn deterministic_ties() {
        let a = Tokenizer::fit("b a c b a c", 32);
        let b = Tokenizer::fit("b a c b a c", 32);
        assert_eq!(a.encode("a b c"), b.encode("a b c"));
    }
}
