//! Data substrate: synthetic corpora, tokenizer, batching, and the five
//! zero-shot evaluation task generators.
//!
//! The paper calibrates on WikiText-2 / C4 and evaluates zero-shot on
//! ARC-e/ARC-c/PIQA/Winogrande/HellaSwag. None of those ship with this
//! sandbox, so this module builds the closest synthetic equivalents (see
//! DESIGN.md §Substitutions): a deterministic *world model* of entities
//! and attributes ([`world::World`]), two corpora with different
//! statistics generated from it ([`corpus`]), and five multiple-choice
//! task suites that query the same facts ([`tasks`]) using LM
//! log-likelihood scoring exactly like lm-eval-harness.

pub mod batch;
pub mod corpus;
pub mod tasks;
pub mod tokenizer;
pub mod world;

pub use batch::{pack_windows, TokenStream};
pub use corpus::{CorpusKind, CorpusSpec};
pub use tasks::{McItem, TaskKind, ALL_TASKS};
pub use tokenizer::Tokenizer;
pub use world::World;
