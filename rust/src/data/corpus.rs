//! Synthetic corpora: `wiki` (narrow, fact-dense) and `c4` (broad, noisy).
//!
//! Both verbalize the same [`super::world::World`] knowledge base but with
//! different mixtures, mirroring the calibration-set contrast of the
//! paper's Tables 2/3 (WikiText-2 vs C4): `wiki` is 75% fact sentences +
//! 25% filler prose; `c4` is 35% facts + 65% Zipfian filler with a larger
//! template variety, so its channel statistics are flatter and its
//! calibration signal weaker — the same *qualitative* difference the paper
//! exploits.

use super::world::World;
use crate::util::rng::ZipfSampler;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CorpusKind {
    Wiki,
    C4,
}

impl CorpusKind {
    pub fn parse(s: &str) -> Option<CorpusKind> {
        match s.to_ascii_lowercase().as_str() {
            "wiki" | "wikitext" | "wikitext2" => Some(CorpusKind::Wiki),
            "c4" => Some(CorpusKind::C4),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CorpusKind::Wiki => "wikitext2",
            CorpusKind::C4 => "c4",
        }
    }
}

/// Generation parameters for one corpus draw.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub kind: CorpusKind,
    pub sentences: usize,
    pub seed: u64,
}

impl CorpusSpec {
    pub fn new(kind: CorpusKind, sentences: usize, seed: u64) -> Self {
        CorpusSpec {
            kind,
            sentences,
            seed,
        }
    }

    /// Generate the corpus text (whitespace-tokenized words, "." sentence
    /// separators).
    pub fn generate(&self, world: &World) -> String {
        let mut rng = Rng::new(self.seed ^ 0xC0_52_50_55_53);
        let facts = world.fact_sentences();
        let filler = World::filler_words();
        let zipf = ZipfSampler::new(filler.len(), 1.05);
        let fact_p = match self.kind {
            CorpusKind::Wiki => 0.75,
            CorpusKind::C4 => 0.35,
        };
        let mut out = String::with_capacity(self.sentences * 40);
        for _ in 0..self.sentences {
            if rng.f64() < fact_p {
                out.push_str(&facts[rng.below(facts.len())]);
            } else {
                let len = match self.kind {
                    CorpusKind::Wiki => 4 + rng.below(6),
                    CorpusKind::C4 => 3 + rng.below(12),
                };
                for i in 0..len {
                    if i > 0 {
                        out.push(' ');
                    }
                    out.push_str(filler[zipf.sample(&mut rng)]);
                }
            }
            out.push_str(" . ");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let w = World::new(1);
        let a = CorpusSpec::new(CorpusKind::Wiki, 100, 7).generate(&w);
        let b = CorpusSpec::new(CorpusKind::Wiki, 100, 7).generate(&w);
        assert_eq!(a, b);
        let c = CorpusSpec::new(CorpusKind::Wiki, 100, 8).generate(&w);
        assert_ne!(a, c);
    }

    #[test]
    fn wiki_denser_in_facts_than_c4() {
        let w = World::new(1);
        let wiki = CorpusSpec::new(CorpusKind::Wiki, 2000, 3).generate(&w);
        let c4 = CorpusSpec::new(CorpusKind::C4, 2000, 3).generate(&w);
        // count a marker phrase that only fact templates produce
        let count = |s: &str| s.matches("is made of").count();
        assert!(count(&wiki) > count(&c4), "{} !> {}", count(&wiki), count(&c4));
    }

    #[test]
    fn sentences_terminated() {
        let w = World::new(2);
        let text = CorpusSpec::new(CorpusKind::C4, 50, 1).generate(&w);
        assert_eq!(text.matches(" . ").count(), 50);
    }

    #[test]
    fn kind_parse() {
        assert_eq!(CorpusKind::parse("WikiText2"), Some(CorpusKind::Wiki));
        assert_eq!(CorpusKind::parse("c4"), Some(CorpusKind::C4));
        assert_eq!(CorpusKind::parse("pile"), None);
    }
}
