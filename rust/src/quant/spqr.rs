//! SPQR-style sparse-quantized layer (Dettmers et al. 2023b) — quantized
//! base weights plus salient weights carved into a separate sparse
//! matrix.
//!
//! The paper's §1/§3 cite SPQR as the canonical "isolate the outliers"
//! scheme with an *unstructured* (CSR) side matrix; its own contribution
//! is that the **structured** k:256 format is competitive. This module
//! implements both flavours over the same [`GroupQuant`] base so the
//! `a2_threshold` bench can put quantization and sparsification on one
//! bits-per-parameter axis, and the structured-vs-unstructured contrast
//! of Table 7 can be replayed in the quantized regime.

use super::groupq::{GroupQuant, QuantSpec};
use crate::pruning::{mask_topn_per_block, ActStats};
use crate::sparse::{Csr, StructuredOutliers};
use crate::tensor::Tensor;

/// How the salient side matrix is stored.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OutlierStore {
    /// the paper's structured k:256 pattern
    Structured { k: usize, m: usize },
    /// SPQR's unstructured CSR at a matched element budget
    Unstructured { count: usize },
    /// no outlier carve-out (plain RTN group quant)
    None,
}

/// Configuration for one SPQR-style layer compression.
#[derive(Clone, Copy, Debug)]
pub struct SpqrSpec {
    pub quant: QuantSpec,
    pub store: OutlierStore,
}

impl SpqrSpec {
    pub fn new(quant: QuantSpec, store: OutlierStore) -> Self {
        SpqrSpec { quant, store }
    }
}

/// A compressed layer: quantized non-salient base + optional salient side
/// matrix (exactly one of `structured` / `unstructured` is non-empty).
pub struct SpqrLayer {
    pub base: GroupQuant,
    pub structured: Option<StructuredOutliers>,
    pub unstructured: Option<Csr>,
}

impl SpqrLayer {
    /// Compress `w`. Salience is the same RIA-style activation-aware
    /// magnitude the sparse pipeline uses: `|w| * act_l2^0.5` — so sparse
    /// and quantized runs isolate identical weights.
    pub fn compress(w: &Tensor, stats: &ActStats, spec: &SpqrSpec) -> Self {
        let (_rows, cols) = w.dims2();
        assert_eq!(stats.l2.len(), cols, "act stats width");
        let score = w.zip(
            &Tensor::new(
                w.shape().to_vec(),
                w.data()
                    .iter()
                    .enumerate()
                    .map(|(i, _)| stats.l2[i % cols].sqrt())
                    .collect(),
            ),
            |wi, a| wi.abs() * a,
        );

        let (omask, structured, unstructured) = match spec.store {
            OutlierStore::Structured { k, m } => {
                let mask = mask_topn_per_block(&score, k, m);
                let st = StructuredOutliers::from_dense_mask(w, &mask, k, m);
                (Some(mask), Some(st), None)
            }
            OutlierStore::Unstructured { count } => {
                let csr = Csr::from_topk_global(w, &score, count);
                let mask = csr.to_dense().map(|x| if x != 0.0 { 1.0 } else { 0.0 });
                (Some(mask), None, Some(csr))
            }
            OutlierStore::None => (None, None, None),
        };

        // zero the salient entries out of the base before quantization so
        // they stop stretching the per-group scales — SPQR's key effect
        let base_dense = match &omask {
            Some(m) => w.zip(m, |x, o| x * (1.0 - o)),
            None => w.clone(),
        };
        let base = GroupQuant::quantize(&base_dense, spec.quant);
        SpqrLayer {
            base,
            structured,
            unstructured,
        }
    }

    /// Reconstruct the effective dense weights (dequantized base with the
    /// exact salient values patched back in).
    pub fn to_dense(&self) -> Tensor {
        let mut out = self.base.dequantize();
        if let Some(s) = &self.structured {
            s.add_into(&mut out);
        }
        if let Some(u) = &self.unstructured {
            u.add_into(&mut out);
        }
        out
    }

    /// Total storage in bytes.
    pub fn bytes(&self) -> usize {
        self.base.bytes()
            + self.structured.as_ref().map_or(0, |s| s.bytes())
            + self.unstructured.as_ref().map_or(0, |u| u.bytes())
    }

    /// Effective bits per (dense) parameter.
    pub fn bits_per_param(&self) -> f64 {
        8.0 * self.bytes() as f64 / (self.base.rows * self.base.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_error;
    use crate::util::Rng;

    fn setup(seed: u64) -> (Tensor, ActStats) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn_outliers(vec![32, 512], 0.05, 0.01, 15.0, &mut rng);
        let mut stats = ActStats::new(512);
        let l2: Vec<f32> = (0..512).map(|_| rng.f32() * 4.0 + 0.2).collect();
        let cm = l2.clone();
        stats.merge(&cm, &l2);
        (w, stats)
    }

    #[test]
    fn outlier_carveout_reduces_quant_error() {
        let (w, stats) = setup(51);
        let plain = SpqrLayer::compress(
            &w,
            &stats,
            &SpqrSpec::new(QuantSpec::new(3, 128), OutlierStore::None),
        );
        let spqr = SpqrLayer::compress(
            &w,
            &stats,
            &SpqrSpec::new(
                QuantSpec::new(3, 128),
                OutlierStore::Structured { k: 16, m: 256 },
            ),
        );
        let e_plain = rel_error(&plain.to_dense(), &w);
        let e_spqr = rel_error(&spqr.to_dense(), &w);
        assert!(e_spqr < e_plain, "{e_spqr} !< {e_plain}");
    }

    #[test]
    fn salient_values_exact() {
        let (w, stats) = setup(52);
        let layer = SpqrLayer::compress(
            &w,
            &stats,
            &SpqrSpec::new(
                QuantSpec::int4_g128(),
                OutlierStore::Structured { k: 8, m: 256 },
            ),
        );
        let st = layer.structured.as_ref().unwrap();
        let sd = st.to_dense();
        let rec = layer.to_dense();
        let mut checked = 0;
        for i in 0..w.len() {
            if sd.data()[i] != 0.0 {
                // bf16 storage is the only loss on salient entries
                let want = w.data()[i];
                assert!(
                    (rec.data()[i] - want).abs() <= want.abs() * 0.01,
                    "salient {i}"
                );
                checked += 1;
            }
        }
        assert_eq!(checked, st.n_salient());
    }

    #[test]
    fn structured_vs_unstructured_matched_budget() {
        let (w, stats) = setup(53);
        let k = 16;
        let count = 32 * (512 / 256) * k; // same element budget
        let st = SpqrLayer::compress(
            &w,
            &stats,
            &SpqrSpec::new(
                QuantSpec::new(3, 128),
                OutlierStore::Structured { k, m: 256 },
            ),
        );
        let un = SpqrLayer::compress(
            &w,
            &stats,
            &SpqrSpec::new(QuantSpec::new(3, 128), OutlierStore::Unstructured { count }),
        );
        assert_eq!(
            st.structured.as_ref().unwrap().n_salient(),
            un.unstructured.as_ref().unwrap().nnz()
        );
        // structured metadata is cheaper per element
        assert!(st.bytes() < un.bytes(), "{} !< {}", st.bytes(), un.bytes());
        // both reconstruct substantially better than nothing; quality gap
        // between the two stores is small (Table 7's claim, quant regime)
        let e_st = rel_error(&st.to_dense(), &w);
        let e_un = rel_error(&un.to_dense(), &w);
        assert!((e_st - e_un).abs() < 0.5 * e_un.max(e_st), "{e_st} vs {e_un}");
    }

    #[test]
    fn bits_per_param_accounting() {
        let (w, stats) = setup(54);
        let layer = SpqrLayer::compress(
            &w,
            &stats,
            &SpqrSpec::new(QuantSpec::int4_g128(), OutlierStore::None),
        );
        // int4 g128: 4 + 16/128 = 4.125 bits/param exactly
        assert!((layer.bits_per_param() - 4.125).abs() < 1e-9);
        let with_o = SpqrLayer::compress(
            &w,
            &stats,
            &SpqrSpec::new(
                QuantSpec::int4_g128(),
                OutlierStore::Structured { k: 16, m: 256 },
            ),
        );
        assert!(with_o.bits_per_param() > 4.125);
        assert!(with_o.bits_per_param() < 6.0);
    }
}
