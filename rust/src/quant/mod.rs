//! Quantization substrate — the comparison axis of the paper's
//! *Performance Threshold* framing.
//!
//! The introduction defines the threshold as "a compressed model matches
//! the accuracy of its uncompressed or smaller counterpart under
//! equivalent memory constraints" and observes that **quantized** models
//! routinely pass it while sparse models struggle. To actually measure
//! that comparison we need a quantizer: this module implements symmetric
//! per-group integer quantization ([`GroupQuant`]), the SPQR-style
//! compose (quantized base + salient weights carved into the structured
//! outlier format, [`spqr`]), and the bits-per-parameter accounting used
//! by the `a2_threshold` ablation bench.

mod groupq;
mod spqr;

pub use groupq::{GroupQuant, QuantSpec};
pub use spqr::{OutlierStore, SpqrLayer, SpqrSpec};

/// Bits per parameter of a plain group-quantized tensor: `b` value bits
/// plus one bf16 scale per group.
pub fn quant_bits_per_param(bits: u32, group: usize) -> f64 {
    bits as f64 + 16.0 / group as f64
}

/// Bits per parameter of an N:M sparse tensor stored packed (bf16 values
/// + codebook metadata), relative to the *dense* element count.
pub fn nm_bits_per_param(n: usize, m: usize) -> f64 {
    let info = crate::sparse::PatternInfo::new(n, m);
    16.0 * n as f64 / m as f64 + info.bits_per_element_codebook()
}

/// Bits per (dense) parameter of the fused sparse+quant format
/// ([`crate::sparse::PackedQnm`]): codebook mask metadata + `bits`-wide
/// codes and one bf16 scale per `group` kept values, both scaled by the
/// pattern density. 8:16 / int4 / g128 → 0.875 + 0.5·(4 + 16/128)
/// = 2.9375 — the number `sparselm quant --pack` reports and the
/// `hwsim` `sparse_nm_quant` traffic model streams.
pub fn nm_quant_bits_per_param(n: usize, m: usize, bits: u32, group: usize) -> f64 {
    let info = crate::sparse::PatternInfo::new(n, m);
    info.bits_per_element_codebook() + info.density() * quant_bits_per_param(bits, group)
}

/// Bits per (dense) parameter of the ternary sparse format
/// ([`crate::sparse::PackedTnm`]): codebook mask metadata + 1.6-bit trit
/// codes (5 trits per byte, log2 not byte-rounded here — this is the
/// asymptotic model; exact per-row byte accounting lives on the format
/// itself) and one bf16 scale per `group` kept values, scaled by the
/// pattern density. 8:16 / g128 → 0.875 + 0.5·(1.6 + 16/128) = 1.7375
/// — the sub-2-bits/param point the `spmm-t` backend serves from.
pub fn nm_ternary_bits_per_param(n: usize, m: usize, group: usize) -> f64 {
    let info = crate::sparse::PatternInfo::new(n, m);
    info.bits_per_element_codebook() + info.density() * (1.6 + 16.0 / group as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_matches_paper_table1() {
        // Table 1 metadata overheads (codebook encoding)
        assert!((nm_bits_per_param(2, 4) - (8.0 + 0.75)).abs() < 1e-9);
        assert!((nm_bits_per_param(8, 16) - (8.0 + 0.875)).abs() < 1e-9);
        // int4 g128 ≈ 4.125 bits/param
        assert!((quant_bits_per_param(4, 128) - 4.125).abs() < 1e-9);
    }

    #[test]
    fn sparse_8_16_sits_between_int8_and_bf16() {
        let s = nm_bits_per_param(8, 16); // 8.875
        assert!(s > quant_bits_per_param(8, 128));
        assert!(s < 16.0);
    }

    #[test]
    fn fused_sparse_quant_accounting() {
        // 8:16 int4 g128: 0.875 mask + 2 code bits + 0.0625 scale bits
        assert!((nm_quant_bits_per_param(8, 16, 4, 128) - 2.9375).abs() < 1e-12);
        // quantizing the kept values must beat both parents
        assert!(nm_quant_bits_per_param(8, 16, 4, 128) < nm_bits_per_param(8, 16));
        assert!(nm_quant_bits_per_param(8, 16, 4, 128) < quant_bits_per_param(4, 128));
        // and lands ≤ 0.20× dense bf16 — the f2/f3 acceptance bar
        assert!(nm_quant_bits_per_param(8, 16, 4, 128) / 16.0 <= 0.20);
    }

    #[test]
    fn ternary_sparse_accounting() {
        // 8:16 g128: 0.875 mask + 0.5·(1.6 + 0.125) = 1.7375 bits/param
        assert!((nm_ternary_bits_per_param(8, 16, 128) - 1.7375).abs() < 1e-12);
        // ternary undercuts the int4 fused format and the ≤ 0.12× dense
        // bar the t158 f2/f3 gates enforce
        assert!(nm_ternary_bits_per_param(8, 16, 128) < nm_quant_bits_per_param(8, 16, 4, 128));
        assert!(nm_ternary_bits_per_param(8, 16, 128) / 16.0 <= 0.12);
        // the value-side streams alone (trits + scales, no mask) sit at
        // 0.8625 ≤ 1.5 bits/param — the "streamed on decode" headline
        let info = crate::sparse::PatternInfo::new(8, 16);
        assert!(info.density() * (1.6 + 16.0 / 128.0) <= 1.5);
    }
}
